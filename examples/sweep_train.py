"""Seed-variance sweep — 4 seeds of a neural PBM trained in ONE process.

Every click-model paper reports mean +/- std over seeds; run sequentially
that costs 4x wall-clock. ``Trainer(replicas=4)`` stacks the 4 runs on a
vmapped replica axis inside the scan-jitted engine: one dispatch stream,
batched BLAS, 4x params/opt-state memory but 1x data. The attraction tower
is an MLP over features (paper Listing 4's neural form) so the init seed
actually matters — classic embedding tables init to constants.

    PYTHONPATH=src python examples/sweep_train.py
"""
import numpy as np

from repro import optim
from repro.core import MLPParameterConfig, PositionBasedModel
from repro.data import ClickLogLoader, SyntheticConfig, generate_click_log, split_sessions
from repro.train import Trainer, select_replica

# 1. A click log with per-item feature vectors (swap in your own arrays).
cfg = SyntheticConfig(n_sessions=30_000, n_queries=200, docs_per_query=15,
                      positions=10, behavior="pbm", seed=0, n_features=16)
data, _ = generate_click_log(cfg)
train, val, test = split_sessions(data, (0.8, 0.1, 0.1), seed=0)

# 2. Neural PBM + a 4-replica sweep trainer (distinct init seeds, shared lr).
model = PositionBasedModel(
    positions=cfg.positions,
    attraction=MLPParameterConfig(features=cfg.n_features, hidden=(32, 32)),
)
trainer = Trainer(
    optimizer=optim.adamw(0.003, weight_decay=1e-4),
    epochs=50,
    patience=1,           # per-replica: finished replicas freeze in place
    replicas=4,
    replica_seeds=[0, 1, 2, 3],
)

# 3. One train call advances all 4 runs; test returns per-replica lists.
history = trainer.train(model,
                        ClickLogLoader(train, batch_size=2048, seed=0),
                        ClickLogLoader(val, batch_size=8192, shuffle=False,
                                       drop_last=False))
results = trainer.test(model, ClickLogLoader(test, batch_size=8192,
                                             shuffle=False, drop_last=False))

print("\nper-replica test perplexity:")
for i, (ppl, ll) in enumerate(zip(results["ppl"], results["ll"])):
    print(f"  seed {trainer.replica_seeds[i]}: ppl={ppl:.4f}  ll={ll:.4f}")
ppls = np.asarray(results["ppl"])
print(f"  mean +/- std: {ppls.mean():.4f} +/- {ppls.std():.4f}")

# 4. Any replica extracts to a standalone params tree (resume/test alone).
best = int(np.argmin(ppls))
params_best = select_replica(trainer._final_state.params, best)
solo = trainer.evaluate(model, params_best,
                        ClickLogLoader(test, batch_size=8192, shuffle=False,
                                       drop_last=False))
print(f"best replica (seed {trainer.replica_seeds[best]}) standalone "
      f"re-eval: ppl={solo['ppl']:.4f}")
