"""Quickstart — the paper's Listing 1: train a UBM with the CLAX Trainer.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import optim
from repro.core import UserBrowsingModel
from repro.data import ClickLogLoader, SyntheticConfig, generate_click_log, split_sessions
from repro.train import Trainer

# 1. A click log (synthetic here; swap in your own padded session arrays).
cfg = SyntheticConfig(n_sessions=30_000, n_queries=200, docs_per_query=15,
                      positions=10, behavior="ubm", seed=0)
data, _ = generate_click_log(cfg)
train, val, test = split_sessions(data, (0.8, 0.1, 0.1), seed=0)

# 2. Model + trainer (paper Listing 1: UBM over query-document ids).
model = UserBrowsingModel(
    query_doc_pairs=cfg.n_query_doc_pairs,
    positions=10,
    init_prob=1 / 9,
)
trainer = Trainer(
    optimizer=optim.adamw(0.003, weight_decay=1e-4),
    epochs=50,
    patience=1,  # paper: stop after first epoch without val improvement
)

# 3. Train + test.
history = trainer.train(model,
                        ClickLogLoader(train, batch_size=2048, seed=0),
                        ClickLogLoader(val, batch_size=8192, shuffle=False,
                                 drop_last=False))
results = trainer.test(model, ClickLogLoader(test, batch_size=8192, shuffle=False,
                                             drop_last=False))
print("\ntest metrics:")
for k, v in results.items():
    if k != "per_rank":
        print(f"  {k}: {v:.4f}")
print("  per-rank ppl:", [round(x, 3) for x in results["per_rank"]["ppl"]])
