"""Streaming quickstart — train from an on-disk session store.

The log is synthesized chunk-by-chunk straight into a sharded columnar
store (never held in RAM), then a ``StreamingClickLogLoader`` feeds the
Trainer through memory-mapped shard windows. Same Trainer, same models,
same checkpoint/resume semantics as the in-memory quickstart — only the
data layer changed, which is the point: swap ``ClickLogLoader(dict)`` for
``StreamingClickLogLoader(store)`` and the log no longer has to fit in
memory.

    PYTHONPATH=src python examples/streaming_train.py
"""
import os
import tempfile

from repro import optim
from repro.core import UserBrowsingModel
from repro.data import StreamingClickLogLoader, SyntheticConfig, ingest_synthetic
from repro.train import Trainer

workdir = tempfile.mkdtemp(prefix="clax_store_")

# 1. Ingest: stream the synthetic log into train/val/test stores. Peak data
#    memory is O(chunk_sessions + shard_rows) rows — the 30k here could be
#    100M and this step would still fit in the same RAM budget.
cfg = SyntheticConfig(n_sessions=30_000, n_queries=200, docs_per_query=15,
                      positions=10, behavior="ubm", seed=0)
stores = ingest_synthetic(cfg, workdir, chunk_sessions=2_000, shard_rows=5_000,
                          splits={"train": 0.8, "val": 0.1, "test": 0.1})
print("ingested:", {name: f"{s.rows} rows / {s.n_shards} shards"
                    for name, s in stores.items()})

# 2. Model + trainer, exactly as in examples/quickstart.py.
model = UserBrowsingModel(query_doc_pairs=cfg.n_query_doc_pairs,
                          positions=10, init_prob=1 / 9)
trainer = Trainer(optimizer=optim.adamw(0.003, weight_decay=1e-4),
                  epochs=50, patience=1)

# 3. Train + test from disk. The loader shuffles shard order and in-shard
#    windows per epoch, reads ahead on a background thread, and its
#    (epoch, shard, step) cursor checkpoints bit-exactly with the trainer.
history = trainer.train(
    model,
    StreamingClickLogLoader(stores["train"], batch_size=2048, seed=0),
    StreamingClickLogLoader(stores["val"], batch_size=8192, shuffle=False,
                            drop_last=False))
results = trainer.test(model, StreamingClickLogLoader(
    stores["test"], batch_size=8192, shuffle=False, drop_last=False))
print("\ntest metrics:")
for k, v in results.items():
    if k != "per_rank":
        print(f"  {k}: {v:.4f}")
print("  per-rank ppl:", [round(x, 3) for x in results["per_rank"]["ppl"]])
print("store kept at:", workdir, "(delete freely)")
