"""Two-tower PBM — the paper's Listing 4: examination from a rank table,
attraction from a DeepCrossV2 network over query-document features, trained
end-to-end; compared against the naive DCTR (no bias correction) on ranking.

    PYTHONPATH=src python examples/two_tower.py
"""
import jax
import jax.numpy as jnp

from repro import optim
from repro.core import (DeepCrossParameterConfig, DocumentCTR,
                        PositionBasedModel, ndcg_metric)
from repro.data import ClickLogLoader, SyntheticConfig, generate_click_log, split_sessions
from repro.train import Trainer

cfg = SyntheticConfig(n_sessions=30_000, n_queries=200, docs_per_query=15,
                      positions=10, behavior="pbm", seed=1, n_features=16,
                      exam_decay=0.6, ranker_noise=2.0)
data, _ = generate_click_log(cfg)
train, val, test = split_sessions(data, (0.8, 0.1, 0.1), seed=0)

two_tower = PositionBasedModel(
    positions=10,
    attraction=DeepCrossParameterConfig(
        use_feature="query_doc_features",
        features=16,
        cross_layers=2,
        deep_layers=2,
    ),
)
naive = DocumentCTR(
    positions=10,
    attraction=DeepCrossParameterConfig(
        use_feature="query_doc_features", features=16,
        cross_layers=2, deep_layers=2),
)


def ranking_ndcg(model, params):
    batch = {k: jnp.asarray(v[:4096]) for k, v in test.items()
             if k in ("positions", "query_doc_ids", "clicks", "mask",
                      "query_doc_features")}
    scores = model.predict_relevance(params, batch)
    graded = jnp.clip((jnp.asarray(test["true_attractiveness"][:4096]) * 5)
                      .astype(jnp.int32), 0, 4)
    return float(ndcg_metric(scores, graded, where=batch["mask"], top_n=10))


for name, model in [("two-tower PBM", two_tower), ("naive DCTR", naive)]:
    trainer = Trainer(optim.adamw(0.01), epochs=20, patience=2,
                      log_fn=lambda *_: None)
    trainer.train(model, ClickLogLoader(train, batch_size=2048, seed=0),
                  ClickLogLoader(val, batch_size=8192, shuffle=False,
                                 drop_last=False))
    results = trainer.test(model, ClickLogLoader(test, batch_size=8192, shuffle=False,
                                                 drop_last=False),
                           per_rank=False)
    print(f"{name}: ppl={results['ppl']:.4f} "
          f"ndcg@10={ranking_ndcg(model, trainer._final_state.params):.4f}")
print("note: with strong informative features the nDCG gap narrows (paper "
      "Fig.4 finds the same on Baidu-ULTR); the embedding-parameterized "
      "grid in benchmarks/bench_features.py shows the bias-correction "
      "ranking gap clearly.")
