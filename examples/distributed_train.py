"""End-to-end driver: train a ~100M-parameter DBN click model for a few
hundred steps with production plumbing — checkpoint/restart (kill it mid-run
and relaunch: it resumes bit-exactly), preemption handling, periodic eval.

    PYTHONPATH=src python examples/distributed_train.py \
        [--pairs 50000000] [--steps 300] [--ckpt /tmp/clax_ckpt]

~100M params = 2 tables (attraction + satisfaction) x `--pairs` rows hashed
10x. Default --pairs sized for the CPU container; at --pairs 50M the model
crosses 100M trained parameters (the brief's 100M-scale driver) — same code.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import Compression, DynamicBayesianNetwork, EmbeddingParameterConfig
from repro.data import ClickLogLoader, SyntheticConfig, generate_click_log, split_sessions
from repro.train import CheckpointManager, PreemptionHandler, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", type=int, default=50_000_000)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--ckpt", default="/tmp/clax_ckpt")
    args = ap.parse_args()

    cfg = SyntheticConfig(n_sessions=200_000, n_queries=2_000,
                          docs_per_query=20, positions=10, behavior="dbn",
                          seed=0)
    data, _ = generate_click_log(cfg)
    train, val, _ = split_sessions(data, (0.9, 0.05, 0.05), seed=0)

    table = EmbeddingParameterConfig(
        parameters=args.pairs, compression=Compression.HASH,
        compression_ratio=10.0, baseline_correction=True, init_logit=-2.0)
    model = DynamicBayesianNetwork(positions=10, attraction=table,
                                   satisfaction=table)
    n_rows = 2 * max(int(args.pairs / 10), 2)
    print(f"[driver] ~{n_rows / 1e6:.0f}M trained embedding rows "
          f"(+AdamW state)")

    epochs = max(args.steps * args.batch // train["positions"].shape[0], 1) + 1
    trainer = Trainer(
        optimizer=optim.adamw(3e-3, weight_decay=1e-4),
        epochs=epochs, patience=10**9,
        checkpoint_dir=args.ckpt, checkpoint_every_steps=50,
        keep_checkpoints=2, handle_preemption=True,
    )
    loader = ClickLogLoader(train, batch_size=args.batch, seed=0)
    val_loader = ClickLogLoader(val, batch_size=8192, shuffle=False,
                                 drop_last=False)

    t0 = time.time()
    trainer.train(model, loader, val_loader, resume=True)
    print(f"[driver] done in {time.time() - t0:.0f}s; "
          f"checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
