"""Mixture model — the paper's Listing 5 / §4.3: learn a prior over a PBM and
a DBN that SHARE an attraction table, on a population with two browsing
behaviors. The mixture should fit better than either member alone.

    PYTHONPATH=src python examples/mixture_models.py
"""
import jax
import jax.numpy as jnp

from repro import optim
from repro.core import (DynamicBayesianNetwork, EmbeddingParameter,
                        EmbeddingParameterConfig, MixtureModel,
                        PositionBasedModel)
from repro.data import ClickLogLoader, SyntheticConfig, generate_click_log, split_sessions
from repro.train import Trainer

cfg = SyntheticConfig(n_sessions=30_000, n_queries=200, docs_per_query=15,
                      positions=10, behavior="mixture", seed=2)
data, _ = generate_click_log(cfg)
train, val, test = split_sessions(data, (0.8, 0.1, 0.1), seed=0)

# Shared attraction table (Listing 5): same module object in both models.
attraction = EmbeddingParameter(EmbeddingParameterConfig(
    parameters=cfg.n_query_doc_pairs, init_logit=-2.0))
pbm = PositionBasedModel(attraction=attraction, positions=10)
dbn = DynamicBayesianNetwork(attraction=attraction, positions=10,
                             query_doc_pairs=cfg.n_query_doc_pairs)
mixture = MixtureModel(models=[pbm, dbn], temperature=1.0)

for name, model in [("pbm", PositionBasedModel(
                        query_doc_pairs=cfg.n_query_doc_pairs, positions=10,
                        init_prob=1 / 9)),
                    ("dbn", DynamicBayesianNetwork(
                        query_doc_pairs=cfg.n_query_doc_pairs, positions=10,
                        init_prob=1 / 9)),
                    ("mixture(pbm+dbn, shared table)", mixture)]:
    trainer = Trainer(optim.adamw(0.02), epochs=25, patience=2,
                      log_fn=lambda *_: None)
    trainer.train(model, ClickLogLoader(train, batch_size=2048, seed=0),
                  ClickLogLoader(val, batch_size=8192, shuffle=False,
                                 drop_last=False))
    results = trainer.test(model, ClickLogLoader(test, batch_size=8192, shuffle=False,
                                                 drop_last=False),
                           per_rank=False)
    line = f"{name}: ppl={results['ppl']:.4f} cond_ppl={results['cond_ppl']:.4f}"
    if isinstance(model, MixtureModel):
        prior = jax.nn.softmax(trainer._final_state.params["prior_logits"])
        line += f" learned_prior={[round(float(p), 3) for p in prior]}"
    print(line)
