"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, asserting output shapes + no NaNs (brief f)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import registry
from repro.configs.lm_common import lm_smoke_batch
from repro.models.gnn import (NeighborSampler, SAGEConfig, init_params as sage_init,
                              make_full_graph_train_step, make_sampled_train_step,
                              random_graph)
from repro.models.gnn.graphsage import full_graph_forward, sampled_forward
from repro.models.lm import (forward, init_cache, init_params, lm_loss,
                             make_decode_step, make_train_step)
from repro.models.recsys import AutoInt, BST, DeepFM, MIND

LM_ARCHS = list(registry.LM_ARCHS)


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
               for x in jax.tree_util.tree_leaves(tree))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_smoke(arch):
    cfg = registry.get_arch(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = lm_smoke_batch(cfg, batch=2, seq=16)
    logits = forward(cfg, params, batch["tokens"])
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert _finite(logits)
    # one train step reduces nothing but must run and stay finite
    optzr = optim.adamw(1e-3)
    step = jax.jit(make_train_step(cfg, optzr))
    p2, o2, loss = step(params, optzr.init(params), batch)
    assert np.isfinite(float(loss))
    assert _finite(p2)
    # decode one token
    cache = init_cache(cfg, batch=2, max_seq=16)
    dec = make_decode_step(cfg)
    lg, cache = dec(p2, cache, batch["tokens"][:, :1], jnp.int32(0))
    assert lg.shape == (2, 1, cfg.padded_vocab)
    # padded columns are -inf, real columns finite
    assert _finite(lg[..., :cfg.vocab])


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_full_config_dims_match_assignment(arch):
    """The FULL configs must carry the exact published dimensions."""
    cfg = registry.get_arch(arch).FULL
    expected = {
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == expected
    if arch == "granite-moe-1b-a400m":
        assert (cfg.n_experts, cfg.top_k) == (32, 8)
    if arch == "llama4-maverick-400b-a17b":
        assert (cfg.n_experts, cfg.top_k, cfg.moe_layer_step) == (128, 1, 2)
        # total/active ballpark: 400B total, 17B active
        assert 3.5e11 < cfg.param_count() < 4.6e11
        assert 1.2e10 < cfg.active_param_count() < 2.2e10
    if arch == "llama3-405b":
        assert 3.9e11 < cfg.param_count() < 4.2e11


def test_graphsage_smoke():
    cfg = registry.get_arch("graphsage-reddit").reduced()
    g = random_graph(150, 600, cfg.d_in, cfg.n_classes, seed=3)
    graph = {k: jnp.asarray(v) for k, v in g.items()}
    params = sage_init(cfg, jax.random.PRNGKey(0))
    logits = full_graph_forward(cfg, params, graph)
    assert logits.shape == (150, cfg.n_classes) and _finite(logits)
    step = jax.jit(make_full_graph_train_step(cfg))
    opt = optim.adam(1e-2).init(params)
    p2, o2, loss = step(params, opt, graph)
    assert np.isfinite(float(loss))
    # sampled path
    sampler = NeighborSampler(g["src"], g["dst"], 150, seed=0)
    batch = sampler.sample_batch(np.arange(16), cfg.sample_sizes,
                                 g["features"], g["labels"])
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    out = sampled_forward(cfg, params, batch)
    assert out.shape == (16, cfg.n_classes) and _finite(out)
    sstep = jax.jit(make_sampled_train_step(cfg))
    p3, o3, loss2 = sstep(params, optim.adam(1e-2).init(params), batch)
    assert np.isfinite(float(loss2))


RECSYS = {
    "deepfm": (DeepFM, "field_ids"),
    "autoint": (AutoInt, "field_ids"),
    "bst": (BST, "sequence"),
    "mind": (MIND, "sequence"),
}


@pytest.mark.parametrize("arch", sorted(RECSYS))
def test_recsys_smoke(arch):
    rng = np.random.default_rng(0)
    model_cls, style = RECSYS[arch]
    cfg = registry.get_arch(arch).reduced()
    model = model_cls(cfg)
    B = 32
    if style == "field_ids":
        batch = {"field_ids": jnp.asarray(rng.integers(0, 500, (B, cfg.n_sparse))),
                 "labels": jnp.asarray(rng.integers(0, 2, B).astype(np.float32))}
    else:
        hist_len = cfg.seq_len if arch == "bst" else cfg.history_len
        batch = {"history_ids": jnp.asarray(rng.integers(0, 400, (B, hist_len))),
                 "target_ids": jnp.asarray(rng.integers(0, 400, B)),
                 "labels": jnp.asarray(rng.integers(0, 2, B).astype(np.float32))}
    params = model.init(jax.random.PRNGKey(0))
    logits = model.forward(params, batch)
    assert logits.shape == (B,) and _finite(logits)
    step = jax.jit(model.make_train_step())
    opt = optim.adamw(1e-3).init(params)
    p2, o2, loss = step(params, opt, batch)
    assert np.isfinite(float(loss)) and _finite(p2)
    # training actually reduces loss on a learnable target
    for _ in range(30):
        p2, o2, loss2 = step(p2, o2, batch)
    assert float(loss2) < float(loss)


def test_recsys_compression_variants():
    """Paper tech on recsys tables: hash + QR compressions stay finite."""
    from repro.models.recsys import DeepFMConfig
    rng = np.random.default_rng(1)
    batch = {"field_ids": jnp.asarray(rng.integers(0, 100_000, (16, 8))),
             "labels": jnp.asarray(rng.integers(0, 2, 16).astype(np.float32))}
    for compression in ("hash", "qr"):
        cfg = DeepFMConfig(name="c", n_sparse=8, embed_dim=4, mlp=(8,),
                           table_rows=100_000, compression=compression,
                           compression_ratio=50.0)
        model = DeepFM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        n_rows = sum(x.shape[0] for x in
                     jax.tree_util.tree_leaves(params["embedding"]))
        assert n_rows < 100_000 / 10  # actually compressed
        assert np.isfinite(float(model.loss(params, batch)))


MOE_ORACLE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_auto_mesh, set_mesh
from repro.models.lm import LMConfig, init_params, forward

# capacity_factor >= n_experts => lossless routing => shard_map == dense oracle
cfg = LMConfig(name="m", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
               d_ff=64, vocab=64, head_dim=16, moe=True, n_experts=8, top_k=2,
               d_ff_moe=32, moe_layer_step=1, attn_chunk=8,
               capacity_factor=64.0)
params = init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 64)
dense = forward(cfg, params, toks, mesh=None)

mesh = make_auto_mesh((2, 4), ("data", "model"))
with set_mesh(mesh):
    sharded = jax.jit(lambda p, t: forward(cfg, p, t, mesh=mesh))(params, toks)
err = float(jnp.max(jnp.abs(dense.astype(jnp.float32) - sharded.astype(jnp.float32))))
assert err < 2e-2, err
print("MOE_ORACLE_OK", err)
"""


def test_moe_shard_map_matches_dense_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    # Pin the subprocess to CPU: probing other platform plugins (e.g. the
    # baked-in TPU runtime on dev images) can stall minutes in metadata
    # retries. --xla_force_host_platform_device_count still applies on cpu.
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", MOE_ORACLE_SCRIPT],
                          capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MOE_ORACLE_OK" in proc.stdout


FLASH_DECODE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_auto_mesh, set_mesh
from repro.models.lm import LMConfig, init_params, init_cache, make_decode_step, forward

cfg0 = LMConfig(name="m", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab=64, head_dim=16, attn_chunk=8, max_seq=16)
cfg1 = dataclasses.replace(cfg0, flash_decode=True, decode_seq_axes=("model",))
params = init_params(cfg0, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 64)
nxt = jax.random.randint(jax.random.PRNGKey(3), (4, 1), 0, 64)
ref = forward(cfg0, params, jnp.concatenate([toks, nxt], 1))[:, -1]
mesh = make_auto_mesh((2, 4), ("data", "model"))
with set_mesh(mesh):
    cache = init_cache(cfg0, batch=4, max_seq=16)
    dec_dense = make_decode_step(cfg0, mesh=mesh)
    for i in range(8):
        _, cache = jax.jit(dec_dense)(params, cache, toks[:, i:i+1], jnp.int32(i))
    cache = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, NamedSharding(
            mesh, P(None, None, "data", "model", None, None))), cache)
    dec_flash = make_decode_step(cfg1, mesh=mesh)
    lg, cache2 = jax.jit(dec_flash)(params, cache, nxt, jnp.int32(8))
err = float(jnp.max(jnp.abs(lg[:, 0].astype(jnp.float32) - ref.astype(jnp.float32))))
assert err < 5e-2, err
assert float(jnp.abs(jax.device_get(cache2["k"])[:, :, :, 8]).sum()) > 0
print("FLASH_DECODE_OK", err)
"""


def test_flash_decode_matches_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    # Pin the subprocess to CPU: probing other platform plugins (e.g. the
    # baked-in TPU runtime on dev images) can stall minutes in metadata
    # retries. --xla_force_host_platform_device_count still applies on cpu.
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", FLASH_DECODE_SCRIPT],
                          capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "FLASH_DECODE_OK" in proc.stdout


DST_PARTITIONED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.compat import make_auto_mesh, set_mesh
from repro.models.gnn import SAGEConfig, init_params, random_graph
from repro.models.gnn.graphsage import full_graph_forward

N, E, SHARDS = 160, 800, 8
g = random_graph(N, E, 12, 4, seed=0)
n_local = N // SHARDS
buckets = [[] for _ in range(SHARDS)]
for e in range(E):
    buckets[g["dst"][e] // n_local].append(e)
cap = max(len(b) for b in buckets)
src, dst, w = [], [], []
for i, b in enumerate(buckets):
    idx = np.asarray(b, np.int64)
    src.extend(g["src"][idx]); dst.extend(g["dst"][idx]); w.extend([1.0] * len(b))
    for _ in range(cap - len(b)):
        src.append(0); dst.append(i * n_local); w.append(0.0)
gp = {"features": g["features"], "degree_inv": g["degree_inv"],
      "labels": g["labels"], "src": np.asarray(src, np.int32),
      "dst": np.asarray(dst, np.int32),
      "edge_weight": np.asarray(w, np.float32)}
cfg0 = SAGEConfig(n_layers=2, d_in=12, d_hidden=16, n_classes=4)
cfg1 = dataclasses.replace(cfg0, partitioned_edges=True)
params = init_params(cfg0, jax.random.PRNGKey(0))
dense = full_graph_forward(cfg0, params, {k: jnp.asarray(v) for k, v in g.items()})
mesh = make_auto_mesh((2, 4), ("data", "model"))
with set_mesh(mesh):
    out = jax.jit(lambda p, gr: full_graph_forward(cfg1, p, gr, mesh))(
        params, {k: jnp.asarray(v) for k, v in gp.items()})
err = float(jnp.max(jnp.abs(out - dense)))
assert err < 1e-5, err
print("DST_PARTITIONED_OK", err)
"""


def test_gnn_dst_partitioned_matches_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    # Pin the subprocess to CPU: probing other platform plugins (e.g. the
    # baked-in TPU runtime on dev images) can stall minutes in metadata
    # retries. --xla_force_host_platform_device_count still applies on cpu.
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", DST_PARTITIONED_SCRIPT],
                          capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DST_PARTITIONED_OK" in proc.stdout
