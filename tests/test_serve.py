"""Serving-engine suite: admission, batching, degradation, chaos drills.

Every guarantee the serving engine advertises is pinned here:

* exactly one result per submitted request — under overload, poison
  floods, injected model failures, and SIGTERM drain;
* a poisoned request is rejected alone; its batch-mates are answered;
* traffic never compiles after warmup (trace-counter equality);
* breakers trip to the degraded ladder and recover half-open;
* the full chaos drill (slow model + poison + mid-flight SIGTERM) is
  bit-deterministic across seeded virtual-clock runs.
"""
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import MODEL_REGISTRY
from repro.serve import (ADMIT, ADMIT_BACKPRESSURE, CLOSED, HALF_OPEN, OPEN,
                         SHED_OVERLOAD, SHED_QUEUE_FULL, TIERS,
                         AdmissionQueue, CircuitBreaker, DeadlineBatcher,
                         DegradationLadder, ModelRegistry, ServeEngine,
                         ServeRequest, ServiceModel, VirtualClock,
                         make_request, poisson_trace, validate_request)
from repro.testing import (POISON_MODES, PoisonTrace, ServeKillSwitch,
                          SlowModel, poison_request)

N_PAIRS = 500
K = 10
BUCKETS = (1, 4, 16)
MODELS = ("pbm", "dbn")


def _perturbed_params(model, seed=0):
    """Fresh-init params are constant per leaf (quantization would be
    exact); perturb so the int8 tier has a real error to measure."""
    params = model.init(jax.random.PRNGKey(seed))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), len(leaves))
    out = [l + 0.5 * jax.random.normal(k, l.shape, l.dtype)
           if jnp.issubdtype(l.dtype, jnp.floating) else l
           for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


@pytest.fixture(scope="module")
def registry():
    reg = ModelRegistry(buckets=BUCKETS, service_model=ServiceModel())
    for name in MODELS:
        model = MODEL_REGISTRY[name](query_doc_pairs=N_PAIRS, positions=K)
        reg.add(name, model, _perturbed_params(model), n_pairs=N_PAIRS,
                quantize_min_size=64)
    reg.warmup()
    return reg


def _engine(registry, **kw):
    kw.setdefault("clock", VirtualClock())
    return ServeEngine(registry, **kw)


def _req(request_id=0, model="pbm", deadline_s=0.2, arrival_s=0.0, seed=0):
    return make_request(request_id, model, K, np.random.default_rng(seed),
                        N_PAIRS, deadline_s=deadline_s, arrival_s=arrival_s)


def _trace(n, qps=300.0, deadline_s=0.05, seed=1, models=MODELS):
    return poisson_trace(n, qps=qps, models=list(models), positions_k=K,
                         n_pairs=N_PAIRS, deadline_s=deadline_s, seed=seed)


def _signature(results):
    return [(r.request_id, r.status, r.tier, r.reason) for r in results]


# -- validation ---------------------------------------------------------------
def test_validator_accepts_wellformed():
    assert validate_request(_req(), positions=K, n_pairs=N_PAIRS) is None


def test_validator_rejects_every_poison_mode():
    for i, mode in enumerate(POISON_MODES):
        bad = poison_request(_req(seed=i), mode, seed=i)
        reason = validate_request(bad, positions=K, n_pairs=N_PAIRS)
        assert reason is not None, f"mode {mode} slipped through"
        assert isinstance(reason, str)


def test_validator_feature_dim_contract():
    req = _req()
    # model expects features but the request has none
    assert validate_request(req, positions=K, n_pairs=N_PAIRS,
                            feature_dim=4) is not None
    req.features = np.zeros((K, 4), np.float32)
    assert validate_request(req, positions=K, n_pairs=N_PAIRS,
                            feature_dim=4) is None
    req.features = np.zeros((K, 3), np.float32)
    assert validate_request(req, positions=K, n_pairs=N_PAIRS,
                            feature_dim=4) is not None


@settings(max_examples=60)
@given(st.lists(st.integers(min_value=-10 ** 12, max_value=10 ** 12),
                min_size=0, max_size=2 * K),
       st.floats(min_value=-1e6, max_value=1e6),
       st.integers(min_value=0, max_value=len(POISON_MODES) - 1))
def test_validator_fuzz_total_function(ids, deadline, mode_i):
    """The validator is total: arbitrary junk ids/deadlines and every
    poison mode yield a reason-or-None, never an exception."""
    req = _req()
    req.query_doc_ids = np.asarray(ids)
    req.deadline_s = deadline
    out = validate_request(req, positions=K, n_pairs=N_PAIRS)
    assert out is None or isinstance(out, str)
    if len(ids) != K:
        assert out is not None
    elif any(i < 0 or i >= N_PAIRS for i in ids):
        assert out is not None
    mutated = poison_request(_req(), POISON_MODES[mode_i], seed=abs(int(
        deadline)) % 997)
    out2 = validate_request(mutated, positions=K, n_pairs=N_PAIRS)
    assert out2 is not None and isinstance(out2, str)


# -- admission queue ----------------------------------------------------------
def test_queue_watermark_ladder():
    q = AdmissionQueue(capacity=8, shed_watermark=6, backpressure_watermark=4)
    outcomes = [q.offer(_req(i), now=0.0) for i in range(8)]
    assert outcomes == [ADMIT] * 4 + [ADMIT_BACKPRESSURE] * 2 + \
        [SHED_OVERLOAD] * 2
    assert q.depth == 6  # sheds were not enqueued


def test_queue_full_when_watermark_equals_capacity():
    q = AdmissionQueue(capacity=4, shed_watermark=4, backpressure_watermark=2)
    outcomes = [q.offer(_req(i), now=0.0) for i in range(5)]
    assert outcomes[-1] == SHED_QUEUE_FULL
    assert q.depth == 4


def test_queue_admit_stamps_time_and_pops_fifo():
    q = AdmissionQueue(capacity=8)
    for i in range(3):
        q.offer(_req(i), now=float(i))
    assert q.peek("pbm").admit_s == 0.0
    popped = q.pop("pbm", 2)
    assert [r.request_id for r in popped] == [0, 1]
    assert q.depth == 1


def test_queue_remove_if_preserves_survivor_order():
    q = AdmissionQueue(capacity=8)
    for i in range(4):
        q.offer(_req(i), now=0.0)
    removed = q.remove_if("pbm", lambda r: r.request_id % 2 == 0)
    assert [r.request_id for r in removed] == [0, 2]
    assert [r.request_id for r in q.pop("pbm", 4)] == [1, 3]


# -- circuit breaker ----------------------------------------------------------
def test_breaker_full_lifecycle():
    b = CircuitBreaker("m/primary", window=8, threshold=0.5, min_samples=2,
                       cooldown=3)
    assert b.state == CLOSED and b.available()
    b.record(False)
    b.record(False)
    assert b.state == OPEN and not b.available()
    for _ in range(3):
        b.note_skipped()
    assert b.state == HALF_OPEN and b.available()
    b.begin()
    assert not b.available()  # one probe at a time
    b.record(False)
    assert b.state == OPEN  # failed probe re-opens
    for _ in range(3):
        b.note_skipped()
    b.begin()
    b.record(True)
    assert b.state == CLOSED and b.available()
    assert b.transitions == 5


def test_breaker_available_is_pure():
    b = CircuitBreaker("m/primary", min_samples=2, cooldown=2)
    b.record(False)
    b.record(False)
    assert b.state == OPEN
    for _ in range(10):  # planner may consult many times per loop
        assert not b.available()
    assert b.state == OPEN  # no cooldown ticks from observation


def test_ladder_select_walk_and_skip_ticks():
    lad = DegradationLadder("m", breaker_kwargs=dict(min_samples=2,
                                                     cooldown=2))
    assert lad.select() == "primary"
    assert lad.walk_from("primary") == ["primary", "int8", "prior"]
    lad.record("primary", False)
    lad.record("primary", False)
    assert lad.select() == "int8"
    assert lad.walk_from("int8") == ["int8", "prior"]
    # two dispatches answered below primary tick its cooldown -> half-open
    lad.finish_dispatch("int8", {"int8"})
    lad.finish_dispatch("int8", {"int8"})
    assert lad.breakers["primary"].state == HALF_OPEN
    assert lad.select() == "primary"  # probe allowed


# -- deadline batcher ---------------------------------------------------------
def _queued(registry, reqs, now=0.0):
    q = AdmissionQueue(capacity=64)
    for r in reqs:
        q.offer(r, now=now)
    return q


def test_batcher_waits_then_fires_on_max_wait(registry):
    b = DeadlineBatcher(registry, max_wait_s=0.005)
    q = _queued(registry, [_req(0, deadline_s=1.0)])
    assert b.plan(q, "pbm", "primary", now=0.0) is None
    t = b.next_decision_time(q, "pbm", "primary", now=0.0)
    assert t == pytest.approx(0.005)
    plan = b.plan(q, "pbm", "primary", now=t)
    assert plan is not None and plan.bucket == 1


def test_batcher_fires_full_batch_immediately(registry):
    b = DeadlineBatcher(registry)
    q = _queued(registry, [_req(i, deadline_s=1.0) for i in
                           range(registry.max_bucket)])
    plan = b.plan(q, "pbm", "primary", now=0.0)
    assert plan is not None
    assert plan.bucket == registry.max_bucket
    assert len(plan.requests) == registry.max_bucket


def test_batcher_slack_trigger_protects_oldest(registry):
    est = registry["pbm"].estimate("primary", 1)
    b = DeadlineBatcher(registry, max_wait_s=10.0, slack_margin_s=0.001)
    q = _queued(registry, [_req(0, deadline_s=est + 0.002)])
    # slack barely above est+margin: hold
    assert b.plan(q, "pbm", "primary", now=0.0) is None
    t = b.next_decision_time(q, "pbm", "primary", now=0.0)
    assert b.plan(q, "pbm", "primary", now=t) is not None


def test_batcher_plan_fires_exactly_at_decision_time(registry):
    """Regression: (admit + wait) - admit can round below wait in float64;
    plan() must use the same trigger expressions as next_decision_time or
    the event loop spins at the decision time without dispatching."""
    b = DeadlineBatcher(registry, max_wait_s=0.005)
    req = _req(0, deadline_s=1.0, arrival_s=0.02649782139617092)
    q = AdmissionQueue(capacity=8)
    q.offer(req, now=0.027641919546832948)
    t = b.next_decision_time(q, "pbm", "primary",
                             now=0.027641919546832948)
    assert b.plan(q, "pbm", "primary", now=t) is not None


def test_batcher_reaps_unmeetable(registry):
    b = DeadlineBatcher(registry)
    floor = registry["pbm"].estimate("primary", BUCKETS[0])
    q = _queued(registry, [_req(0, deadline_s=floor / 2),
                           _req(1, deadline_s=1.0)])
    reaped = b.reap_unmeetable(q, "pbm", "primary", now=0.0)
    assert [r.request_id for r in reaped] == [0]
    assert q.depth == 1


def test_batcher_flush_drains_partial(registry):
    b = DeadlineBatcher(registry, max_wait_s=10.0)
    q = _queued(registry, [_req(0, deadline_s=10.0)])
    assert b.plan(q, "pbm", "primary", now=0.0) is None
    assert b.plan(q, "pbm", "primary", now=0.0, flush=True) is not None


# -- engine: healthy path -----------------------------------------------------
def test_every_request_answered_exactly_once(registry):
    eng = _engine(registry)
    trace = _trace(40)
    results = eng.run_trace(trace, handle_signals=False)
    assert sorted(r.request_id for r in results) == list(range(40))
    assert all(r.status == "ok" for r in results)
    assert eng.stats["serve.answered"] == 40
    s = eng.summary(results)
    assert s["deadline_hit_rate"] == 1.0
    assert s["p99_ms"] >= s["p50_ms"] > 0


def test_warm_traffic_never_retraces(registry):
    """After warmup every (tier, bucket) program is cached: a fresh burst
    of traffic must not bump any trace counter."""
    before = {m: dict(registry[m].trace_counts) for m in MODELS}
    for m in MODELS:  # warmup compiled exactly one program per bucket
        assert before[m]["primary"] == len(BUCKETS)
        assert before[m]["int8"] == len(BUCKETS)
    eng = _engine(registry)
    eng.run_trace(_trace(60, seed=7), handle_signals=False)
    after = {m: dict(registry[m].trace_counts) for m in MODELS}
    assert after == before


def test_overload_sheds_with_reason(registry):
    eng = _engine(registry, queue=AdmissionQueue(capacity=8))
    # a burst far above service rate: everything arrives at ~t=0
    results = eng.run_trace(_trace(60, qps=100000.0, deadline_s=0.5),
                            handle_signals=False)
    assert len(results) == 60
    shed = [r for r in results if r.status == "shed"]
    assert shed and all(r.reason in ("shed_overload", "shed_queue_full")
                        for r in shed)
    answered = [r for r in results if r.answered]
    assert answered, "admitted requests must still be served"
    assert eng.stats["serve.shed"] == len(shed)


def test_unmeetable_deadline_is_shed_not_late(registry):
    eng = _engine(registry)
    floor = registry["pbm"].estimate("primary", BUCKETS[0])
    trace = [_req(0, deadline_s=floor / 3, arrival_s=0.001)]
    results = eng.run_trace(trace, handle_signals=False)
    assert results[0].status == "shed"
    assert results[0].reason == "deadline_unmeetable"
    assert eng.stats["serve.deadline_miss"] == 1


def test_unknown_model_rejected(registry):
    eng = _engine(registry)
    trace = [_req(0, model="nope", arrival_s=0.001)]
    results = eng.run_trace(trace, handle_signals=False)
    assert results[0].status == "rejected"
    assert results[0].reason == "unknown_model"


def test_force_tier_paths_and_int8_tolerance(registry):
    """Forcing each tier serves; int8 predictions match primary within the
    documented quantization tolerance (scale/2 per table read)."""
    out = {}
    for tier in TIERS:
        eng = _engine(registry, force_tier=tier)
        results = eng.run_trace(_trace(20, seed=3, models=("pbm",)),
                                handle_signals=False)
        assert all(r.answered and r.tier == tier for r in results)
        out[tier] = {r.request_id: r.log_ctr for r in results}
    for rid, primary in out["primary"].items():
        dprob = np.abs(np.exp(primary) - np.exp(out["int8"][rid])).max()
        assert dprob < 0.01, f"int8 drifted {dprob} from primary"
    prior = registry["pbm"].prior_log_ctr
    assert all(np.allclose(v, prior) for v in out["prior"].values())


def test_poison_rejected_alone_batchmates_answered(registry):
    """One poisoned request in a same-instant burst is rejected by
    validation; every batch-mate is answered normally."""
    burst = [_req(i, deadline_s=0.5, arrival_s=0.001, seed=i)
             for i in range(8)]
    trace = list(PoisonTrace(burst, at=[3], modes=("nan_ids",)))
    eng = _engine(registry)
    results = {r.request_id: r for r in
               eng.run_trace(trace, handle_signals=False)}
    assert results[3].status == "rejected"
    assert results[3].reason.startswith("nonfinite_values")
    for i in set(range(8)) - {3}:
        assert results[i].answered and results[i].deadline_hit


# -- engine: degradation ------------------------------------------------------
def test_model_failure_degrades_and_breaker_trips(registry):
    eng = _engine(registry,
                  faults=[SlowModel(model="pbm", fail=True,
                                    at_dispatches=range(0, 4))],
                  breaker_kwargs=dict(window=8, min_samples=2,
                                      threshold=0.5, cooldown=2))
    results = eng.run_trace(_trace(30, seed=5, models=("pbm",)),
                            handle_signals=False)
    assert all(r.answered for r in results)
    degraded = [r for r in results if r.degraded]
    assert degraded, "injected failures must push traffic down the ladder"
    assert eng.stats["serve.model_errors"] >= 2
    assert eng.stats["serve.degraded"] == len(degraded)
    primary = eng.ladders["pbm"].breakers["primary"]
    assert primary.transitions >= 2  # tripped open, then recovered
    assert primary.state == CLOSED  # fault window passed: recovered


def test_slow_model_misses_trip_breaker(registry):
    """Pure latency (no exceptions): deadline misses alone count as batch
    failures and open the breaker."""
    eng = _engine(registry,
                  faults=[SlowModel(model="pbm", delay_seconds=0.1,
                                    at_dispatches=range(0, 3))],
                  breaker_kwargs=dict(min_samples=2, threshold=0.5,
                                      cooldown=50))
    results = eng.run_trace(_trace(30, seed=5, models=("pbm",), qps=100.0,
                                   deadline_s=0.12),
                            handle_signals=False)
    assert len(results) == 30
    assert eng.stats["serve.deadline_miss"] >= 2
    primary = eng.ladders["pbm"].breakers["primary"]
    assert primary.transitions >= 1 and primary.state == OPEN
    assert any(r.degraded for r in results)


def test_prior_injected_failure_fails_closed(registry):
    """Even the terminal rung raising (only possible via injection) sheds
    the batch per-request instead of crashing the loop."""
    eng = _engine(registry,
                  faults=[SlowModel(model="pbm", fail=True,
                                    tiers=TIERS)])
    results = eng.run_trace(_trace(10, seed=2, models=("pbm",)),
                            handle_signals=False)
    assert len(results) == 10
    assert all(r.status == "shed" and r.reason == "model_failure"
               for r in results)


def test_multi_model_isolation(registry):
    """A failing pbm must not degrade dbn traffic."""
    eng = _engine(registry,
                  faults=[SlowModel(model="pbm", fail=True,
                                    at_dispatches=range(100))],
                  breaker_kwargs=dict(min_samples=2, cooldown=1000))
    results = eng.run_trace(_trace(40, seed=9), handle_signals=False)
    by_model = {}
    for r in results:
        by_model.setdefault(r.model, []).append(r)
    assert all(not r.degraded for r in by_model["dbn"])
    assert any(r.degraded for r in by_model["pbm"])
    health = eng.health()
    assert health["pbm"]["breakers"]["primary"] == OPEN
    assert health["dbn"]["breakers"]["primary"] == CLOSED
    assert health["dbn"]["tier"] == "primary"
    assert health["pbm"]["tier"] == "int8"


# -- engine: drain ------------------------------------------------------------
def test_sigterm_drain_zero_drops(registry):
    """SIGTERM mid-trace: admission stops (remaining arrivals rejected
    with 'draining'), queued requests are flushed, nothing is dropped."""
    eng = _engine(registry, faults=[ServeKillSwitch(at_request=20)])
    results = eng.run_trace(_trace(50, seed=4), handle_signals=True)
    assert sorted(r.request_id for r in results) == list(range(50))
    draining = [r for r in results if r.reason == "draining"]
    answered = [r for r in results if r.answered]
    assert draining and answered
    assert eng.stats["serve.drains"] == 1
    # everything admitted before the signal was served, not dropped
    assert len(answered) + len(draining) == 50
    # the handler restored the previous SIGTERM disposition on exit
    assert signal.getsignal(signal.SIGTERM) is not None


def test_disarmed_serve_killswitch_is_inert(registry):
    ks = ServeKillSwitch(at_request=5, armed=False)
    eng = _engine(registry, faults=[ks])
    results = eng.run_trace(_trace(12, seed=4), handle_signals=True)
    assert not ks.fired
    assert all(r.answered for r in results)


# -- the pinned chaos drill ---------------------------------------------------
def _chaos_drill(registry, seed=1):
    faults = [
        SlowModel(model="pbm", fail=True, at_dispatches=range(0, 6)),
        ServeKillSwitch(at_request=70),
    ]
    trace = PoisonTrace(_trace(90, qps=500.0, seed=seed),
                        at=[5, 12, 19, 26, 33], seed=0)
    eng = _engine(registry, faults=faults,
                  breaker_kwargs=dict(window=8, min_samples=2,
                                      threshold=0.5, cooldown=4))
    results = eng.run_trace(trace, handle_signals=True)
    return eng, results


def test_chaos_drill_guarantees_and_determinism(registry):
    """The flagship drill: slow/failing primary + poison flood + SIGTERM
    at request 70, twice. Zero drops, poison rejected individually,
    breaker trips, drain completes, and both runs match bit-for-bit."""
    eng1, res1 = _chaos_drill(registry)
    eng2, res2 = _chaos_drill(registry)

    # zero uncaught exceptions is implicit (we got here); zero drops:
    assert sorted(r.request_id for r in res1) == list(range(90))
    by_id = {r.request_id: r for r in res1}
    # poison rejected individually, batch-mates answered
    for rid in (5, 12, 19, 26, 33):
        assert by_id[rid].status == "rejected"
    neighbors = [by_id[i] for i in (4, 6, 11, 13)]
    assert all(r.answered or r.reason == "draining" for r in neighbors)
    # breaker tripped to degraded
    assert any(r.degraded for r in res1)
    assert eng1.ladders["pbm"].breakers["primary"].transitions >= 1
    # drain: everything after request 70 rejected, none dropped
    assert eng1.stats["serve.drains"] == 1
    assert eng1.stats["serve.rejected_draining"] > 0
    # nonzero deterministic counters, identical across runs
    assert eng1.stats["serve.model_errors"] > 0
    assert dict(eng1.stats) == dict(eng2.stats)
    assert _signature(res1) == _signature(res2)


def test_chaos_drill_counters_flow_to_recorder(registry, tmp_path):
    """Engine counters ride the standard Recorder: the drill's shed /
    degraded / breaker counters land in the JSONL sink."""
    from repro import obs

    path = str(tmp_path / "serve_metrics.jsonl")
    rec = obs.Recorder(sinks=[obs.JsonlSink(path)])
    faults = [SlowModel(model="pbm", fail=True, at_dispatches=range(0, 4))]
    eng = _engine(registry, recorder=rec, faults=faults,
                  breaker_kwargs=dict(min_samples=2, cooldown=4))
    eng.run_trace(_trace(30, seed=5, models=("pbm",)), handle_signals=False)
    rec.close()
    events = obs.read_jsonl(path)
    names = {e["name"] for e in events}
    assert "serve_latency_ms" in names
    assert "model_error" in names
    assert "breaker_transition" in names
    snapshots = [e for e in events if e.get("kind") == "counters"]
    assert snapshots, "run_trace must flush a counters snapshot"
    snap = snapshots[-1]["data"]
    assert snap.get("serve.model_errors", 0) > 0
    assert snap.get("serve.degraded", 0) > 0
    assert snap.get("serve.breaker_transitions", 0) > 0
    assert "serve.queue_depth:gauge" in snap
