"""Chaos suite: deterministic fault injection against every hardened layer.

Each test injects one failure class (disk corruption, NaN batches, flaky IO,
a dead read-ahead producer, torn checkpoints, process death) and asserts the
system's declared guarantee: deterministic skip, retry-then-recover,
fall-back-to-valid, or crash-exact resume.
"""
import json
import os
import signal
import subprocess
import sys
import threading
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import PositionBasedModel
from repro.data import (ClickLogLoader, SessionStore, ShardCorruptionError,
                        StreamingClickLogLoader, SyntheticConfig,
                        generate_click_log, split_sessions,
                        write_session_store)
from repro.testing import (FlakyShardReads, KillSwitch,
                           NonFiniteBatchInjector, corrupt_shard_file,
                           truncate_tail)
from repro.train import (CheckpointCorruptionError, CheckpointManager,
                         PreemptionHandler, TrainEngine, Trainer,
                         run_with_restarts)


# -- fixtures -----------------------------------------------------------------
@pytest.fixture()
def small_log():
    cfg = SyntheticConfig(n_sessions=600, n_queries=20, docs_per_query=10,
                          positions=5, behavior="pbm", seed=11)
    data, _ = generate_click_log(cfg)
    return cfg, data


@pytest.fixture()
def store_dir(tmp_path, small_log):
    cfg, data = small_log
    d = str(tmp_path / "store")
    write_session_store(data, d, shard_rows=150)  # 4 shards
    return d


@pytest.fixture()
def store_dir_auto(tmp_path, small_log):
    """Same 4-shard store written with per-column compression (format v2)."""
    cfg, data = small_log
    d = str(tmp_path / "store_auto")
    write_session_store(data, d, shard_rows=150, codec="auto")
    return d


def _model(cfg):
    return PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                              positions=cfg.positions)


# -- fault injector primitives -------------------------------------------------
def test_corrupt_shard_file_breaks_crc(store_dir):
    store = SessionStore(store_dir)
    store.verify()  # pristine store passes
    info = corrupt_shard_file(store_dir, shard=1, column="clicks", seed=3)
    assert info["column"] == "clicks" and len(info["offsets"]) == 1
    with pytest.raises(ShardCorruptionError):
        SessionStore(store_dir).verify(1)
    # other shards still verify
    SessionStore(store_dir).verify(0)


def test_corrupt_shard_file_is_replayable(store_dir):
    a = corrupt_shard_file(store_dir, shard=0, seed=7)
    b = corrupt_shard_file(store_dir, shard=0, seed=7)  # same bytes re-flipped
    assert a["offsets"] == b["offsets"]
    SessionStore(store_dir).verify(0)  # double XOR restored the bytes


def test_nonfinite_injector_counts(small_log):
    cfg, data = small_log
    loader = ClickLogLoader(data, batch_size=64, seed=5)
    inj = NonFiniteBatchInjector(loader, at_steps=[1, 3], key="clicks")
    batches = list(iter(inj))
    assert inj.injected == 2 and inj.produced == len(batches)
    assert np.isnan(batches[1]["clicks"]).all()
    assert np.isfinite(batches[0]["clicks"]).all()
    assert inj.batch_size == 64  # proxy forwards attributes


# -- non-finite guard in the engine / trainer ---------------------------------
def test_nonfinite_guard_skips_poisoned_step(small_log):
    cfg, data = small_log
    model = _model(cfg)
    loader = ClickLogLoader(data, batch_size=64, seed=5)
    engine = TrainEngine(model, optim.adamw(0.05), chunk_batches=4,
                         nonfinite_guard=True)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = engine.init_opt_state(params)
    chunks = []
    batches = [b for b in iter(loader)][:4]
    poisoned = dict(batches[2])
    poisoned["clicks"] = np.full_like(poisoned["clicks"], np.nan)
    batches[2] = poisoned
    chunk = {k: np.stack([b[k] for b in batches]) for k in batches[0]}
    params2, opt2, telemetry = engine.step(params, opt_state, chunk)
    skipped = np.asarray(telemetry["skipped"])
    np.testing.assert_array_equal(skipped, [False, False, True, False])
    losses = np.asarray(telemetry["loss"])
    assert np.isnan(losses[2]) and np.isfinite(losses[[0, 1, 3]]).all()
    # params stayed finite through the poisoned step
    for leaf in jax.tree_util.tree_leaves(jax.device_get(params2)):
        assert np.isfinite(leaf).all()


def test_trainer_nonfinite_guard_counts_and_stays_finite(small_log):
    cfg, data = small_log
    model = _model(cfg)
    loader = NonFiniteBatchInjector(
        ClickLogLoader(data, batch_size=64, seed=5), at_steps=[2, 12])
    trainer = Trainer(optim.adamw(0.05), epochs=2, patience=100,
                      chunk_batches=3, nonfinite_guard=True,
                      log_fn=lambda *_: None)
    history = trainer.train(model, loader)
    assert [r["skipped_steps"] for r in history] == [1, 1]
    assert all(np.isfinite(r["train_loss"]) for r in history)
    for leaf in jax.tree_util.tree_leaves(
            jax.device_get(trainer._final_state.params)):
        assert np.isfinite(leaf).all()


def test_trainer_guard_off_poisoned_params_diverge(small_log):
    # control: without the guard a NaN batch destroys the run
    cfg, data = small_log
    model = _model(cfg)
    loader = NonFiniteBatchInjector(
        ClickLogLoader(data, batch_size=64, seed=5), at_steps=[2])
    trainer = Trainer(optim.adamw(0.05), epochs=1, patience=100,
                      chunk_batches=3, log_fn=lambda *_: None)
    history = trainer.train(model, loader)
    assert "skipped_steps" not in history[0]
    assert not np.isfinite(history[0]["train_loss"])


def test_nonfinite_guard_replicas(small_log):
    cfg, data = small_log
    model = _model(cfg)
    loader = NonFiniteBatchInjector(
        ClickLogLoader(data, batch_size=64, seed=5), at_steps=[1])
    trainer = Trainer(optim.adamw(0.05), epochs=1, patience=100, replicas=2,
                      chunk_batches=3, nonfinite_guard=True,
                      log_fn=lambda *_: None)
    history = trainer.train(model, loader)
    # a broadcast poisoned batch skips on every replica
    assert history[0]["skipped_steps"] == [1, 1]
    assert all(np.isfinite(v) for v in history[0]["train_loss"])


# -- self-healing streaming data plane ----------------------------------------
def test_streaming_verify_checksums_raises(store_dir):
    corrupt_shard_file(store_dir, shard=2, column="clicks", seed=1)
    loader = StreamingClickLogLoader(store_dir, batch_size=50,
                                     verify_checksums=True)
    with pytest.raises(ShardCorruptionError):
        list(iter(loader))
    # without verification the corrupt bytes stream through silently
    loader2 = StreamingClickLogLoader(store_dir, batch_size=50)
    assert len(list(iter(loader2))) == loader2.batches_per_epoch


def test_streaming_skip_policy_is_deterministic(store_dir):
    clean = [b["clicks"].copy() for b in iter(
        StreamingClickLogLoader(store_dir, batch_size=50, seed=3))]
    corrupt_shard_file(store_dir, shard=1, column="clicks", seed=1)
    logs = []

    def run():
        ld = StreamingClickLogLoader(store_dir, batch_size=50, seed=3,
                                     verify_checksums=True,
                                     corrupt_policy="skip",
                                     log_fn=logs.append)
        return ld, [b["clicks"].copy() for b in iter(ld)]

    ld_a, run_a = run()
    ld_b, run_b = run()
    assert ld_a.quarantined == {1}
    assert len(run_a) == len(run_b) < len(clean)
    for x, y in zip(run_a, run_b):
        np.testing.assert_array_equal(x, y)
    assert any("QUARANTINED shard 1" in m for m in logs)
    # epoch 2 pre-excludes the quarantined shard and agrees with the cap
    run_a2 = [b["clicks"] for b in iter(ld_a)]
    assert len(run_a2) == ld_a.batches_per_epoch


def test_streaming_quarantine_rides_state_dict(store_dir):
    # corrupt the shard that epoch 0 (seed=3) opens FIRST, so the quarantine
    # deterministically precedes the mid-epoch save below
    first = int(np.random.default_rng((3, 0, 0)).permutation(4)[0])
    corrupt_shard_file(store_dir, shard=first, column="clicks", seed=1)
    mk = lambda: StreamingClickLogLoader(store_dir, batch_size=50, seed=3,
                                         verify_checksums=True,
                                         corrupt_policy="skip",
                                         log_fn=lambda *_: None)
    full_ld = mk()
    full = [b["clicks"].copy() for b in iter(full_ld)]
    part = mk()
    it = iter(part)
    head = [next(it)["clicks"].copy() for _ in range(2)]
    sd = part.state_dict()
    it.close()
    assert sd["quarantined"] == [first]
    resumed = mk()
    resumed.load_state_dict(sd)
    tail = [b["clicks"].copy() for b in iter(resumed)]
    assert len(head) + len(tail) == len(full)
    for x, y in zip(head + tail, full):
        np.testing.assert_array_equal(x, y)


def test_streaming_skip_policy_rejected_multihost(store_dir):
    with pytest.raises(ValueError, match="per-host"):
        StreamingClickLogLoader(store_dir, batch_size=50, host_id=0,
                                host_count=2, corrupt_policy="skip")
    with pytest.raises(ValueError, match="corrupt_policy"):
        StreamingClickLogLoader(store_dir, batch_size=50,
                                corrupt_policy="quarantine")


def test_compressed_store_corruption_fails_closed(store_dir_auto):
    """Corrupting a *compressed* column (bitpacked clicks) trips the same
    crc-over-stored-bytes path as a raw one under verify_checksums=True."""
    store = SessionStore(store_dir_auto)
    assert store.shard_codec(2, "clicks") == "bitpack"
    corrupt_shard_file(store_dir_auto, shard=2, column="clicks", seed=1)
    loader = StreamingClickLogLoader(store_dir_auto, batch_size=50,
                                     verify_checksums=True)
    with pytest.raises(ShardCorruptionError):
        list(iter(loader))


def test_compressed_store_quarantine_is_deterministic(store_dir_auto):
    """skip-policy quarantine works unchanged on a compressed store: the
    corrupt shard contributes zero rows, replayably."""
    clean = [b["clicks"].copy() for b in iter(
        StreamingClickLogLoader(store_dir_auto, batch_size=50, seed=3))]
    corrupt_shard_file(store_dir_auto, shard=1, column="clicks", seed=1)
    logs = []

    def run():
        ld = StreamingClickLogLoader(store_dir_auto, batch_size=50, seed=3,
                                     verify_checksums=True,
                                     corrupt_policy="skip",
                                     log_fn=logs.append)
        return ld, [b["clicks"].copy() for b in iter(ld)]

    ld_a, run_a = run()
    ld_b, run_b = run()
    assert ld_a.quarantined == {1}
    assert len(run_a) == len(run_b) < len(clean)
    for x, y in zip(run_a, run_b):
        np.testing.assert_array_equal(x, y)
    assert any("QUARANTINED shard 1" in m for m in logs)


def test_streaming_io_retry_recovers(store_dir):
    clean = [b["clicks"].copy() for b in iter(
        StreamingClickLogLoader(store_dir, batch_size=50, seed=3))]
    flaky = FlakyShardReads(SessionStore(store_dir), fail_times=2)
    loader = StreamingClickLogLoader(flaky, batch_size=50, seed=3,
                                     io_retries=3, io_retry_backoff=0.001,
                                     log_fn=lambda *_: None)
    got = [b["clicks"].copy() for b in iter(loader)]
    assert flaky.failures == 2 and len(got) == len(clean)
    for x, y in zip(got, clean):
        np.testing.assert_array_equal(x, y)


def test_streaming_io_retries_exhausted_raises(store_dir):
    flaky = FlakyShardReads(SessionStore(store_dir), fail_times=100)
    loader = StreamingClickLogLoader(flaky, batch_size=50, io_retries=1,
                                     io_retry_backoff=0.001,
                                     watchdog_restarts=1,
                                     log_fn=lambda *_: None)
    with pytest.raises(OSError, match="injected transient"):
        list(iter(loader))


def test_producer_watchdog_restarts_once(store_dir):
    clean = [b["clicks"].copy() for b in iter(
        StreamingClickLogLoader(store_dir, batch_size=50, seed=3))]
    logs = []
    # two failures, no per-read retries: only the watchdog's restarted
    # producer (third open_shard call) survives
    flaky = FlakyShardReads(SessionStore(store_dir), fail_times=2)
    loader = StreamingClickLogLoader(flaky, batch_size=50, seed=3,
                                     io_retries=0, watchdog_restarts=2,
                                     log_fn=logs.append)
    got = [b["clicks"].copy() for b in iter(loader)]
    assert len(got) == len(clean)
    for x, y in zip(got, clean):
        np.testing.assert_array_equal(x, y)
    assert sum("producer died" in m for m in logs) == 2


def test_producer_error_preserves_traceback(store_dir):
    flaky = FlakyShardReads(SessionStore(store_dir), fail_times=100)
    loader = StreamingClickLogLoader(flaky, batch_size=50, io_retries=0,
                                     watchdog_restarts=0,
                                     log_fn=lambda *_: None)
    try:
        list(iter(loader))
        raise AssertionError("expected OSError")
    except OSError as e:
        frames = [f.name for f in traceback.extract_tb(e.__traceback__)]
        # the worker thread's frames survive the cross-thread re-raise
        assert "_read_plan" in frames and "open_shard" in frames


def test_abandoned_iterator_joins_reader_thread(store_dir):
    loader = StreamingClickLogLoader(store_dir, batch_size=50, seed=3,
                                     window_rows=25, read_ahead=1)
    it = iter(loader)
    next(it)
    it.close()  # abandon mid-epoch; generator finally must stop + join
    deadline = time.time() + 5.0
    while time.time() < deadline:
        alive = [t for t in threading.enumerate()
                 if t.name == "store-read-ahead" and t.is_alive()]
        if not alive:
            break
        time.sleep(0.01)
    assert not alive, "read-ahead thread leaked after iterator abandonment"


def test_close_beats_watchdog_no_restart_after_shutdown(store_dir):
    """Regression: a producer dying around a cross-thread close() must
    surface shutdown (or the original error) immediately — the watchdog
    never restarts a producer after close(), even with restarts budgeted."""
    store = SessionStore(store_dir)
    real = store.open_shard

    def open_shard(i, **kw):
        if i != 0:
            raise OSError(f"injected: shard {i} unreachable")
        return real(i, **kw)

    store.open_shard = open_shard
    logs = []
    loader = StreamingClickLogLoader(store, batch_size=50, shuffle=False,
                                     read_ahead=2, watchdog_restarts=5,
                                     log_fn=logs.append)
    it = iter(loader)
    next(it)  # shard 0 delivered; the producer dies on shard 1
    loader.close()
    with pytest.raises((RuntimeError, OSError)):
        for _ in it:
            pass
    assert not any("restarting" in m for m in logs)
    # closed is permanent: a fresh epoch refuses to start
    with pytest.raises(RuntimeError, match="closed"):
        next(iter(loader))


def test_close_stops_inline_stream_too(store_dir):
    """The read_ahead=0 path honors close() between windows as well."""
    loader = StreamingClickLogLoader(store_dir, batch_size=50, shuffle=False,
                                     read_ahead=0)
    it = iter(loader)
    next(it)
    loader.close()
    with pytest.raises(RuntimeError, match="close"):
        for _ in it:
            pass


# -- hardened checkpoints ------------------------------------------------------
@pytest.fixture()
def ckpt_tree():
    return {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones(4)}


def test_checkpoint_writes_leaf_checksums(tmp_path, ckpt_tree):
    m = CheckpointManager(str(tmp_path), log_fn=lambda *_: None)
    m.save(1, ckpt_tree)
    meta = json.load(open(tmp_path / "step_0000000001" / "structure.json"))
    assert set(meta["checksums"]) == {"w", "b"}


def test_restore_falls_back_to_newest_valid(tmp_path, ckpt_tree):
    logs = []
    m = CheckpointManager(str(tmp_path), keep=5, log_fn=logs.append)
    for s in (1, 2, 3):
        m.save(s, ckpt_tree, aux={"s": s})
    truncate_tail(str(tmp_path / "step_0000000003" / "arrays.npz"), 64)
    tree, aux, step = m.restore(like=ckpt_tree)
    assert step == 2 and aux["s"] == 2
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.asarray(ckpt_tree["w"]))
    # the torn checkpoint was deleted, not just skipped
    assert not (tmp_path / "step_0000000003").exists()
    assert any("corrupt" in m_ for m_ in logs)


def test_restore_detects_bit_rot_via_crc(tmp_path, ckpt_tree):
    m = CheckpointManager(str(tmp_path), keep=5, log_fn=lambda *_: None)
    m.save(1, ckpt_tree, aux={"s": 1})
    m.save(2, ckpt_tree, aux={"s": 2})
    path = tmp_path / "step_0000000002" / "arrays.npz"
    data = bytearray(path.read_bytes())
    data[len(data) // 2] ^= 0xFF
    path.write_bytes(bytes(data))
    _, aux, step = m.restore(like=ckpt_tree)
    assert step == 1


def test_restore_explicit_corrupt_step_raises(tmp_path, ckpt_tree):
    m = CheckpointManager(str(tmp_path), log_fn=lambda *_: None)
    m.save(1, ckpt_tree)
    truncate_tail(str(tmp_path / "step_0000000001" / "arrays.npz"), 16)
    with pytest.raises(CheckpointCorruptionError):
        m.restore(step=1, like=ckpt_tree)


def test_all_checkpoints_invalid_raises_not_found(tmp_path, ckpt_tree):
    m = CheckpointManager(str(tmp_path), log_fn=lambda *_: None)
    m.save(1, ckpt_tree)
    truncate_tail(str(tmp_path / "step_0000000001" / "arrays.npz"), 16)
    with pytest.raises(FileNotFoundError):
        m.restore(like=ckpt_tree)


def test_partial_write_gc_and_pre_checksum_compat(tmp_path, ckpt_tree):
    m = CheckpointManager(str(tmp_path), log_fn=lambda *_: None)
    m.save(4, ckpt_tree, aux={"s": 4})
    # crash-mid-save simulants: tmp dir and COMMIT-less step dir
    (tmp_path / ".tmp_step_9_x").mkdir()
    partial = tmp_path / "step_0000000009"
    partial.mkdir()
    (partial / "arrays.npz").write_bytes(b"torn")
    # legacy checkpoint without checksums must stay restorable
    sp = tmp_path / "step_0000000004" / "structure.json"
    meta = json.loads(sp.read_text())
    del meta["checksums"]
    sp.write_text(json.dumps(meta))
    m2 = CheckpointManager(str(tmp_path), log_fn=lambda *_: None)
    assert not (tmp_path / ".tmp_step_9_x").exists()
    assert not partial.exists()
    _, aux, step = m2.restore(like=ckpt_tree)
    assert step == 4 and aux["s"] == 4


# -- preemption + restarts -----------------------------------------------------
def test_preemption_handler_context_manager_restores():
    before_term = signal.getsignal(signal.SIGTERM)
    before_int = signal.getsignal(signal.SIGINT)
    with PreemptionHandler() as h:
        assert signal.getsignal(signal.SIGTERM) is not before_term
        assert signal.getsignal(signal.SIGINT) is not before_int  # new default
        os.kill(os.getpid(), signal.SIGTERM)
        assert h.should_stop
    assert signal.getsignal(signal.SIGTERM) is before_term
    assert signal.getsignal(signal.SIGINT) is before_int


def test_preemption_handler_restores_on_exception():
    before = signal.getsignal(signal.SIGTERM)
    with pytest.raises(RuntimeError):
        with PreemptionHandler():
            raise RuntimeError("train loop blew up")
    assert signal.getsignal(signal.SIGTERM) is before


def test_run_with_restarts_recovers_from_crash(tmp_path):
    marker = tmp_path / "crashed_once"
    script = tmp_path / "flaky.py"
    script.write_text(
        "import os, sys\n"
        f"m = {str(marker)!r}\n"
        "if not os.path.exists(m):\n"
        "    open(m, 'w').close()\n"
        "    sys.exit(137)\n"
        "print('done')\n")
    logs = []
    rc = run_with_restarts([sys.executable, str(script)], max_restarts=2,
                           log_fn=logs.append)
    assert rc == 0
    assert any("relaunching" in m for m in logs)


def test_run_with_restarts_budget_exhausted(tmp_path):
    script = tmp_path / "always_dies.py"
    script.write_text("import sys; sys.exit(3)\n")
    rc = run_with_restarts([sys.executable, str(script)], max_restarts=1,
                           log_fn=lambda *_: None)
    assert rc == 3


# -- crash-exact resume (the tentpole proof obligation) ------------------------
_RUN_SCRIPT = r"""
import json, os, signal, sys
import numpy as np
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax
from repro import optim
from repro.core import PositionBasedModel
from repro.data import StreamingClickLogLoader
from repro.testing import KillSwitch
from repro.train import Trainer

store, ckpt_dir, kill_at, out, n_pairs, n_pos = (
    sys.argv[1], sys.argv[2], int(sys.argv[3]), sys.argv[4],
    int(sys.argv[5]), int(sys.argv[6]))
loader = StreamingClickLogLoader(store, batch_size=50, seed=5)
if kill_at >= 0:
    committed = os.path.isdir(ckpt_dir) and any(
        n.startswith("step_") and
        os.path.exists(os.path.join(ckpt_dir, n, "COMMIT"))
        for n in os.listdir(ckpt_dir))
    if not committed:
        loader = KillSwitch(loader, after_batches=kill_at,
                            sig=signal.SIGKILL)
model = PositionBasedModel(query_doc_pairs=n_pairs, positions=n_pos)
trainer = Trainer(optim.adamw(0.05), epochs=3, patience=100, seed=7,
                  checkpoint_dir=ckpt_dir, checkpoint_every_steps=4,
                  chunk_batches=2, nonfinite_guard=True,
                  log_fn=lambda *_: None)
hist = trainer.train(model, loader, resume=True)
leaves = jax.tree_util.tree_leaves(
    jax.device_get(trainer._final_state.params))
digest = [np.asarray(l).tobytes().hex() for l in leaves]
for r in hist:
    r.pop("seconds", None)
json.dump({"history": hist, "digest": digest}, open(out, "w"))
"""


def test_sigkill_and_resume_is_bit_exact(tmp_path, small_log):
    cfg, data = small_log
    store = str(tmp_path / "store")
    write_session_store(data, store, shard_rows=150)
    script = tmp_path / "run.py"
    script.write_text(_RUN_SCRIPT)
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), os.pardir, "src"),
         env.get("PYTHONPATH", "")])
    tail = [str(cfg.n_query_doc_pairs), str(cfg.positions)]

    def run(kill_at, tag):
        ckpt = str(tmp_path / f"ckpt_{tag}")
        out = str(tmp_path / f"out_{tag}.json")
        attempts = 0
        while True:
            p = subprocess.run(
                [sys.executable, str(script), store, ckpt, str(kill_at), out]
                + tail, env=env, capture_output=True, text=True)
            attempts += 1
            if p.returncode == 0:
                return json.load(open(out)), attempts
            assert p.returncode == -signal.SIGKILL, p.stderr[-2000:]
            assert attempts < 4, "kill switch failed to disarm after resume"

    clean, clean_attempts = run(-1, "clean")
    assert clean_attempts == 1
    # kill mid-epoch 2 (12 batches/epoch at bs=50, checkpoints every 4
    # steps): epoch-1 checkpoints are committed long before batch 17
    killed, attempts = run(17, "killed")
    assert attempts == 2  # died exactly once, then completed
    assert killed["digest"] == clean["digest"]  # params bit-for-bit
    assert killed["history"] == clean["history"]  # incl. mid-epoch losses


# -- caller-armed kill gate & flaky-read latency -------------------------------
def test_killswitch_caller_armed_gate(small_log):
    """A disarmed KillSwitch is inert through any number of batches; after
    arm() it fires exactly once at the pinned batch index. SIGTERM is
    absorbed by a PreemptionHandler so the gate is testable in-process."""
    cfg, data = small_log
    ks = KillSwitch(ClickLogLoader(data, batch_size=64, seed=5),
                    after_batches=0, sig=signal.SIGTERM, armed=False)
    with PreemptionHandler() as h:
        for _ in ks:
            pass
        assert not ks.fired and not h.should_stop
        ks.arm()
        ks.produced = 0
        next(iter(ks))
        assert ks.fired and h.should_stop
        # fire-once: replaying the pinned batch does not re-signal
        h.should_stop = False
        ks.produced = 0
        next(iter(ks))
        assert not h.should_stop


def test_flaky_reads_delay_seconds(store_dir):
    """FlakyShardReads charges its configured latency on the failing calls
    (slow remote filesystem), then passes through at full speed."""
    flaky = FlakyShardReads(SessionStore(store_dir), fail_times=2,
                            delay_seconds=0.05)
    t0 = time.perf_counter()
    for _ in range(2):
        with pytest.raises(OSError):
            flaky.open_shard(0)
    elapsed = time.perf_counter() - t0
    assert elapsed >= 0.1  # two delayed failures
    shard = flaky.open_shard(0)  # third call passes through
    assert shard is not None
    assert flaky.failures == 2 and flaky.calls == 3
