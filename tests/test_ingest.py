"""Parallel ingest tests: the multi-process store is byte-identical to the
serial reference path, and the single-writer manifest merge refuses
ambiguous (overlapping/gappy) worker output."""
import functools
import json
import os

import numpy as np
import pytest

from repro.data import SyntheticConfig, ingest_synthetic
from repro.data.ingest import ingest_chunks, merge_shard_groups
from repro.data.synthetic import chunk_sizes, synthesize_chunk

CFG = SyntheticConfig(n_sessions=700, n_queries=12, docs_per_query=8,
                      positions=6, behavior="dbn", seed=17)
SPLITS = {"train": 0.8, "val": 0.1, "test": 0.1}


def tree_bytes(root):
    out = {}
    for dirpath, _, files in os.walk(root):
        for fn in files:
            p = os.path.join(dirpath, fn)
            with open(p, "rb") as f:
                out[os.path.relpath(p, root)] = f.read()
    return out


def assert_trees_identical(a, b):
    """Byte-identical store trees; manifests may differ ONLY in the
    recorded ``metadata.ingest_workers``."""
    ta, tb = tree_bytes(a), tree_bytes(b)
    assert set(ta) == set(tb)
    for rel in sorted(ta):
        if os.path.basename(rel) == "manifest.json":
            ma, mb = json.loads(ta[rel]), json.loads(tb[rel])
            ma["metadata"].pop("ingest_workers", None)
            mb["metadata"].pop("ingest_workers", None)
            assert ma == mb, rel
        else:
            assert ta[rel] == tb[rel], rel


def test_parallel_ingest_bit_identical_to_serial(tmp_path):
    """The pin: 3 spawn workers over ragged shard blocks produce the same
    shard files and manifests (modulo the recorded worker count) as the
    single-process reference, split routing included."""
    serial = ingest_synthetic(CFG, str(tmp_path / "w1"), chunk_sessions=150,
                              shard_rows=120, splits=SPLITS, codec="auto",
                              workers=1)
    par = ingest_synthetic(CFG, str(tmp_path / "w3"), chunk_sessions=150,
                           shard_rows=120, splits=SPLITS, codec="auto",
                           workers=3)
    assert_trees_identical(tmp_path / "w1", tmp_path / "w3")
    for name, store in par.items():
        store.verify()
        assert store.metadata["ingest_workers"] == 3
        assert store.metadata["store_codec"] == "auto"
        assert serial[name].metadata["ingest_workers"] == 1
        a, b = serial[name].read_all(), store.read_all()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=(name, k))
    # compression actually engaged on the 0/1 columns
    assert par["train"].shard_codec(0, "clicks") == "bitpack"
    assert par["train"].shard_codec(0, "mask") == "bitpack"


def test_ingest_chunks_no_splits_parallel_raw(tmp_path):
    rows = chunk_sizes(CFG, 200)
    fn = functools.partial(synthesize_chunk, CFG, chunk_sessions=200)
    one = ingest_chunks(fn, rows, str(tmp_path / "w1"), shard_rows=150,
                        codec="raw", workers=1, seed=CFG.seed)[""]
    two = ingest_chunks(fn, rows, str(tmp_path / "w2"), shard_rows=150,
                        codec="raw", workers=2, seed=CFG.seed)[""]
    assert_trees_identical(tmp_path / "w1", tmp_path / "w2")
    assert one.rows == two.rows == CFG.n_sessions
    # raw codec keeps the zero-copy memmap read path
    assert isinstance(two.open_shard(0)["clicks"], np.memmap)


def test_more_workers_than_shards(tmp_path):
    """Workers whose shard block is empty contribute nothing; the merged
    store is still complete and identical to serial."""
    cfg = SyntheticConfig(n_sessions=120, n_queries=8, docs_per_query=6,
                          positions=4, behavior="pbm", seed=5)
    ingest_synthetic(cfg, str(tmp_path / "w1"), chunk_sessions=50,
                     shard_rows=100, workers=1)
    many = ingest_synthetic(cfg, str(tmp_path / "w4"), chunk_sessions=50,
                            shard_rows=100, workers=4)
    assert_trees_identical(tmp_path / "w1", tmp_path / "w4")
    assert many[""].rows == 120 and many[""].n_shards == 2


def _entry(i, rows=10):
    return {"name": f"shard_{i:05d}", "rows": rows}


def test_merge_shard_groups_orders_and_validates():
    merged = merge_shard_groups([[_entry(2)], [_entry(0), _entry(1)]])
    assert [e["name"] for e in merged] == [f"shard_{i:05d}" for i in range(3)]
    with pytest.raises(ValueError, match="overlapping shard groups"):
        merge_shard_groups([[_entry(0)], [_entry(0)]])
    with pytest.raises(ValueError, match="gaps"):
        merge_shard_groups([[_entry(0)], [_entry(2)]])
    with pytest.raises(ValueError, match="no shards"):
        merge_shard_groups([[], []])


def test_ingest_chunks_matches_concatenated_chunks(tmp_path):
    rows = [7, 7, 7, 4]
    fn = lambda c: {"x": np.arange(rows[c], dtype=np.int64)[:, None] + 100 * c,
                    "y": np.full((rows[c],), c, np.int32)}
    store = ingest_chunks(fn, rows, str(tmp_path / "s"), shard_rows=10,
                          codec="auto", workers=1)[""]
    store.verify()
    got = store.read_all()
    np.testing.assert_array_equal(
        got["x"], np.concatenate([np.arange(n, dtype=np.int64)[:, None]
                                  + 100 * c for c, n in enumerate(rows)]))
    np.testing.assert_array_equal(
        got["y"], np.concatenate([np.full(n, c, np.int32)
                                  for c, n in enumerate(rows)]))


def test_ingest_chunks_validation(tmp_path):
    fn = lambda c: {"x": np.zeros((10, 2), np.float32)}
    with pytest.raises(ValueError, match="codec"):
        ingest_chunks(fn, [10], str(tmp_path / "a"), codec="zstd")
    with pytest.raises(ValueError, match="workers"):
        ingest_chunks(fn, [10], str(tmp_path / "b"), workers=0)
    with pytest.raises(ValueError, match="chunk_rows"):
        ingest_chunks(fn, [], str(tmp_path / "c"))
    with pytest.raises(ValueError, match="zero rows"):
        ingest_chunks(fn, [10], str(tmp_path / "d"),
                      splits={"train": 0.99, "val": 0.01})
    # a chunk_fn that disagrees with the plan is a hard error, not bad bytes
    with pytest.raises(ValueError, match="deterministic in the chunk index"):
        ingest_chunks(fn, [10, 12], str(tmp_path / "e"), workers=1)
    # nothing above may have committed a manifest
    for sub in ("a", "b", "c", "d", "e"):
        assert not os.path.exists(tmp_path / sub / "manifest.json")
