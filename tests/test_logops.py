"""Property tests for the stable log-space primitives (paper §5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.stable import (
    log1mexp, log_sigmoid, log1m_sigmoid, logsumexp, log_bce, log_or,
    log_prob_to_logit,
)


@given(st.floats(min_value=-50.0, max_value=-1e-6))
@settings(max_examples=200, deadline=None)
def test_log1mexp_matches_float64_reference(a):
    got = float(log1mexp(jnp.float64(a) if jax.config.jax_enable_x64 else jnp.float32(a)))
    want = np.log1p(-np.exp(np.float64(a)))
    assert np.isfinite(got)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_log1mexp_extreme_ranges():
    # p ~ 1 (a ~ 0-): no catastrophic cancellation
    a = jnp.float32(-1e-7)
    assert np.isfinite(float(log1mexp(a)))
    # p ~ 0 (a very negative): no underflow to -inf
    a = jnp.float32(-80.0)
    np.testing.assert_allclose(float(log1mexp(a)), 0.0, atol=1e-6)
    # exactly 0 input -> -inf is mathematically right
    assert float(log1mexp(jnp.float32(0.0))) == -np.inf


@given(st.floats(min_value=-80.0, max_value=80.0))
@settings(max_examples=200, deadline=None)
def test_log_sigmoid_bounds(x):
    ls = float(log_sigmoid(jnp.float32(x)))
    l1ms = float(log1m_sigmoid(jnp.float32(x)))
    assert ls <= 0.0 and l1ms <= 0.0
    # exp(ls) + exp(l1ms) == 1
    np.testing.assert_allclose(np.exp(ls) + np.exp(l1ms), 1.0, rtol=1e-5)


def test_log_sigmoid_no_overflow():
    assert np.isfinite(float(log_sigmoid(jnp.float32(-1e4))) + 1e4)
    np.testing.assert_allclose(float(log_sigmoid(jnp.float32(1e4))), 0.0, atol=1e-6)


@given(st.lists(st.floats(min_value=-100, max_value=100), min_size=1, max_size=8))
@settings(max_examples=200, deadline=None)
def test_logsumexp_matches_numpy(xs):
    a = jnp.asarray(xs, jnp.float32)
    got = float(logsumexp(a))
    want = np.log(np.sum(np.exp(np.float64(np.asarray(xs))))) if len(xs) else -np.inf
    np.testing.assert_allclose(got, np.float32(want), rtol=1e-4, atol=1e-5)


def test_logsumexp_mask():
    a = jnp.asarray([0.0, 100.0, -3.0], jnp.float32)
    where = jnp.asarray([True, False, True])
    got = float(logsumexp(a, where=where))
    want = np.log(np.exp(0.0) + np.exp(-3.0))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_logsumexp_all_masked_is_neg_inf():
    a = jnp.asarray([1.0, 2.0], jnp.float32)
    got = float(logsumexp(a, where=jnp.asarray([False, False])))
    assert got == -np.inf


@given(st.floats(min_value=1e-6, max_value=1 - 1e-4), st.integers(0, 1))
@settings(max_examples=200, deadline=None)
def test_log_bce_matches_direct(p, c):
    # rtol bounded by float32 rounding of log(p) near p ~ 1 in the test
    # construction itself; log_bce is exact given its log-space input.
    lp = jnp.log(jnp.float32(p))
    got = float(log_bce(lp, jnp.float32(c)))
    want = -(c * np.log(p) + (1 - c) * np.log1p(-p))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-5)


def test_log_or():
    p, q = 0.3, 0.2
    got = float(log_or(jnp.log(jnp.float32(p)), jnp.log(jnp.float32(q))))
    np.testing.assert_allclose(np.exp(got), p + q - p * q, rtol=1e-5)


def test_log_prob_to_logit_roundtrip():
    for p in [0.01, 0.5, 0.99]:
        logit = float(log_prob_to_logit(jnp.log(jnp.float32(p))))
        np.testing.assert_allclose(1 / (1 + np.exp(-logit)), p, rtol=1e-4)


def test_gradients_are_finite_at_boundaries():
    g = jax.grad(lambda x: log1mexp(x))(jnp.float32(-1e-6))
    assert np.isfinite(float(g))
    g = jax.grad(lambda x: log_sigmoid(x))(jnp.float32(-100.0))
    assert np.isfinite(float(g))
