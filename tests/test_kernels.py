"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import dcn_cross, embedding_bag, fm_interaction, flash_attention

RNG = np.random.default_rng(0)


def randn(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,N,D", [(4, 3, 32, 16), (8, 1, 64, 128),
                                     (3, 7, 16, 200), (16, 5, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(B, L, N, D, dtype):
    table = randn(N, D, dtype=dtype)
    ids = jnp.asarray(RNG.integers(-1, N, (B, L)), jnp.int32)  # -1 = pad
    w = randn(B, L)
    got = embedding_bag(table, ids, w, impl="pallas")
    want = ref.embedding_bag_ref(table, ids, w)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_embedding_bag_combiners():
    table = randn(16, 8)
    ids = jnp.asarray([[0, 1, -1], [2, -1, -1]], jnp.int32)
    got_mean = embedding_bag(table, ids, combiner="mean", impl="pallas")
    want0 = (np.asarray(table)[0] + np.asarray(table)[1]) / 2
    np.testing.assert_allclose(np.asarray(got_mean)[0], want0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got_mean)[1], np.asarray(table)[2],
                               rtol=1e-6)


def test_embedding_bag_grads_match_ref():
    table = randn(32, 16)
    ids = jnp.asarray(RNG.integers(-1, 32, (6, 4)), jnp.int32)
    w = randn(6, 4)

    def loss_k(t, w_):
        return jnp.sum(embedding_bag(t, ids, w_, impl="pallas") ** 2)

    def loss_r(t, w_):
        return jnp.sum(ref.embedding_bag_ref(t, ids, w_) ** 2)

    gt_k, gw_k = jax.grad(loss_k, argnums=(0, 1))(table, w)
    gt_r, gw_r = jax.grad(loss_r, argnums=(0, 1))(table, w)
    np.testing.assert_allclose(np.asarray(gt_k), np.asarray(gt_r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gw_k), np.asarray(gw_r), rtol=1e-5)


@given(st.integers(1, 12), st.integers(1, 6), st.integers(2, 40),
       st.integers(1, 150))
@settings(max_examples=12, deadline=None)
def test_embedding_bag_property(B, L, N, D):
    table = randn(N, D)
    ids = jnp.asarray(RNG.integers(-1, N, (B, L)), jnp.int32)
    w = randn(B, L)
    got = embedding_bag(table, ids, w, impl="pallas")
    want = ref.embedding_bag_ref(table, ids, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fm_interaction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,F,D", [(4, 39, 10), (130, 8, 16), (7, 3, 128),
                                   (256, 39, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fm_interaction_sweep(B, F, D, dtype):
    v = randn(B, F, D, dtype=dtype)
    got = fm_interaction(v, impl="pallas")
    want = ref.fm_interaction_ref(v)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_fm_matches_explicit_pairwise():
    """FM identity: 0.5[(Σv)² − Σv²] == Σ_{i<j} <v_i, v_j>."""
    v = randn(3, 6, 4)
    want = np.zeros(3, np.float32)
    vn = np.asarray(v)
    for i in range(6):
        for j in range(i + 1, 6):
            want += np.sum(vn[:, i] * vn[:, j], axis=-1)
    got = fm_interaction(v, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# dcn_cross
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,D", [(8, 64), (300, 128), (5, 190), (64, 469)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dcn_cross_sweep(B, D, dtype):
    x0, x = randn(B, D, dtype=dtype), randn(B, D, dtype=dtype)
    w, b = randn(D, D, dtype=dtype), randn(D, dtype=dtype)
    got = dcn_cross(x0, x, w, b, impl="pallas")
    want = ref.dcn_cross_ref(x0, x, w, b)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,Dh", [
    (2, 4, 4, 128, 128, 64),    # MHA square
    (1, 8, 2, 64, 256, 64),     # GQA cross-length
    (2, 4, 1, 96, 160, 128),    # MQA, non-multiple seq (q padding path)
    (1, 2, 2, 1, 512, 64),      # decode: one query vs long KV
])
@pytest.mark.parametrize("causal", [False, True])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Sk, Dh, causal):
    q = randn(B, Hq, Sq, Dh)
    k = randn(B, Hkv, Sk, Dh)
    v = randn(B, Hkv, Sk, Dh)
    got = flash_attention(q, k, v, causal=causal, impl="pallas",
                          block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = randn(1, 2, 64, 64, dtype=jnp.bfloat16)
    k = randn(1, 2, 128, 64, dtype=jnp.bfloat16)
    v = randn(1, 2, 128, 64, dtype=jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, impl="pallas")
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)


def test_flash_attention_softmax_rows_sum_to_one():
    """Degenerate check: with v = ones, output must be exactly ones."""
    q = randn(1, 2, 64, 64)
    k = randn(1, 2, 128, 64)
    v = jnp.ones((1, 2, 128, 64), jnp.float32)
    got = flash_attention(q, k, v, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# embedding_bag backward: peak-memory regression
# ---------------------------------------------------------------------------

def test_embedding_bag_backward_never_materializes_BL_by_D():
    """The backward scatter must stay O(N*D + B*D): the former segment_sum
    path expanded the cotangents into a (B*L, D) contrib buffer before
    reducing. Pinned on the optimized HLO: no buffer of that shape may
    appear anywhere in the compiled backward."""
    B, L, N, D = 64, 16, 200, 48  # B*L = 1024: unambiguous in the HLO text
    table = randn(N, D)
    ids = jnp.asarray(RNG.integers(-1, N, (B, L)), jnp.int32)
    w = randn(B, L)

    def loss(t, w_):
        return jnp.sum(embedding_bag(t, ids, w_, impl="xla") ** 2)

    hlo = (jax.jit(jax.grad(loss, argnums=(0, 1)))
           .lower(table, w).compile().as_text())
    assert f"f32[{B * L},{D}]" not in hlo, \
        "backward materializes the (B*L, D) contrib intermediate"
    # sanity: the (N, D) scatter target does appear
    assert f"f32[{N},{D}]" in hlo


def test_embedding_bag_backward_matches_dense_oracle():
    """Value check for the scan-scatter backward against the dense autodiff
    of the ref composition (duplicate ids, padding slots, zero weights)."""
    table = randn(24, 8)
    ids = jnp.asarray([[0, 0, 3, -1], [5, 5, 5, 5], [-1, -1, -1, -1],
                       [7, 2, -1, 0]], jnp.int32)
    w = jnp.asarray([[1.0, 2.0, 0.5, 9.9], [0.25, 0.25, 0.25, 0.25],
                     [1.0, 1.0, 1.0, 1.0], [0.0, 1.0, 5.0, -2.0]], jnp.float32)
    proj = randn(4, 8)

    def loss_k(t, w_):
        return jnp.sum(embedding_bag(t, ids, w_, impl="xla") * proj)

    def loss_r(t, w_):
        return jnp.sum(ref.embedding_bag_ref(t, ids, w_) * proj)

    gt_k, gw_k = jax.grad(loss_k, argnums=(0, 1))(table, w)
    gt_r, gw_r = jax.grad(loss_r, argnums=(0, 1))(table, w)
    np.testing.assert_allclose(np.asarray(gt_k), np.asarray(gt_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gw_k), np.asarray(gw_r),
                               rtol=1e-5, atol=1e-6)
