"""Click-model correctness: log-space recursions vs brute-force prob-space
enumeration oracles, API invariants, and sampling consistency."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CascadeModel, ClickChainModel, DependentClickModel, DocumentCTR,
    DynamicBayesianNetwork, GlobalCTR, PositionBasedModel, RankCTR,
    SimplifiedDBN, UserBrowsingModel, MODEL_REGISTRY,
)

K = 5
B = 4
N_DOCS = 40


def make_batch(seed=0, clicks=None):
    rng = np.random.default_rng(seed)
    batch = {
        "positions": jnp.asarray(np.tile(np.arange(1, K + 1), (B, 1)), jnp.int32),
        "query_doc_ids": jnp.asarray(rng.integers(0, N_DOCS, (B, K))),
        "clicks": jnp.asarray(clicks if clicks is not None
                              else rng.integers(0, 2, (B, K)).astype(np.float32)),
        "mask": jnp.ones((B, K), bool),
    }
    return batch


def all_models():
    return {name: cls(query_doc_pairs=N_DOCS, positions=K)
            for name, cls in MODEL_REGISTRY.items()}


# ---------------------------------------------------------------------------
# Brute-force oracle: enumerate all 2^K click sequences, score each with the
# model's *conditional* probabilities, and marginalize. If the model's
# unconditional prediction is consistent with its conditional recursion, the
# two must agree (the PGM is Markov in its session state).
# ---------------------------------------------------------------------------

def brute_force_marginals(model, params, batch):
    B_, K_ = batch["clicks"].shape
    total = np.zeros((B_, K_))
    norm = np.zeros((B_,))
    for seq in itertools.product([0.0, 1.0], repeat=K_):
        c = jnp.asarray(np.tile(np.asarray(seq, np.float32), (B_, 1)))
        b = dict(batch, clicks=c)
        cond_lp = np.asarray(model.predict_conditional_clicks(params, b),
                             np.float64)
        cond_p = np.exp(cond_lp)
        seq_p = np.prod(np.where(np.asarray(seq) > 0, cond_p, 1 - cond_p), axis=1)
        total += seq_p[:, None] * np.asarray(seq)[None, :]
        norm += seq_p
    return total, norm


@pytest.mark.parametrize("name", ["pbm", "ubm", "dcm", "ccm", "dbn", "sdbn"])
def test_unconditional_matches_brute_force(name):
    model = MODEL_REGISTRY[name](query_doc_pairs=N_DOCS, positions=K)
    params = model.init(jax.random.PRNGKey(3))
    # randomize parameters so the test is not trivially symmetric
    params = jax.tree_util.tree_map(
        lambda x: x + 0.7 * jax.random.normal(jax.random.PRNGKey(11), x.shape),
        params)
    batch = make_batch(1)
    marg, norm = brute_force_marginals(model, params, batch)
    np.testing.assert_allclose(norm, 1.0, rtol=1e-5)  # proper distribution
    pred = np.exp(np.asarray(model.predict_clicks(params, batch), np.float64))
    np.testing.assert_allclose(pred, marg, rtol=2e-4, atol=1e-6)


def test_cascade_brute_force_closed_form():
    model = CascadeModel(query_doc_pairs=N_DOCS, positions=K)
    params = model.init(jax.random.PRNGKey(5))
    params = jax.tree_util.tree_map(
        lambda x: x + 0.5 * jax.random.normal(jax.random.PRNGKey(6), x.shape), params)
    batch = make_batch(2)
    la = np.asarray(model.parts["attraction"](params["attraction"], batch), np.float64)
    gamma = 1 / (1 + np.exp(-la))
    want = gamma * np.cumprod(np.concatenate(
        [np.ones((B, 1)), 1 - gamma[:, :-1]], axis=1), axis=1)
    got = np.exp(np.asarray(model.predict_clicks(params, batch), np.float64))
    np.testing.assert_allclose(got, want, rtol=1e-5)


# ---------------------------------------------------------------------------
# API invariants
# ---------------------------------------------------------------------------

def test_all_log_probs_nonpositive_and_finite():
    batch = make_batch(4)
    for name, model in all_models().items():
        params = model.init(jax.random.PRNGKey(1))
        for fn in (model.predict_clicks, model.predict_conditional_clicks):
            lp = np.asarray(fn(params, batch))
            assert np.all(np.isfinite(lp) | (lp <= 0)), name
            assert np.all(lp <= 1e-5), name
        loss = model.compute_loss(params, batch)
        assert np.isfinite(float(loss)), name


def test_position_independent_models_cond_equals_uncond():
    batch = make_batch(8)
    for name in ("gctr", "rctr", "dctr", "pbm"):
        model = MODEL_REGISTRY[name](query_doc_pairs=N_DOCS, positions=K)
        params = model.init(jax.random.PRNGKey(2))
        np.testing.assert_array_equal(
            np.asarray(model.predict_clicks(params, batch)),
            np.asarray(model.predict_conditional_clicks(params, batch)))


def test_gradients_flow_to_all_parameters():
    batch = make_batch(9)
    for name, model in all_models().items():
        params = model.init(jax.random.PRNGKey(1))
        grads = jax.grad(model.compute_loss)(params, batch)
        flat = jax.tree_util.tree_leaves_with_path(grads)
        for path, g in flat:
            assert np.all(np.isfinite(np.asarray(g))), (name, path)
        total = sum(float(jnp.sum(jnp.abs(g))) for _, g in flat)
        assert total > 0, name


def test_cascade_conditional_floors_after_click():
    model = CascadeModel(query_doc_pairs=N_DOCS, positions=K)
    params = model.init(jax.random.PRNGKey(0))
    clicks = np.zeros((B, K), np.float32)
    clicks[:, 1] = 1.0  # click at rank 2
    batch = make_batch(3, clicks=clicks)
    lp = np.asarray(model.predict_conditional_clicks(params, batch))
    from repro.stable import MIN_LOG_PROB
    assert np.all(lp[:, 2:] == MIN_LOG_PROB)
    assert np.all(lp[:, :2] > MIN_LOG_PROB)


def test_sampling_matches_marginals_statistically():
    """Monte-Carlo CTR per rank ~= unconditional click probability."""
    for name in ("pbm", "dcm", "dbn", "cm", "ubm", "ccm"):
        model = MODEL_REGISTRY[name](query_doc_pairs=N_DOCS, positions=K)
        params = model.init(jax.random.PRNGKey(4))
        params = jax.tree_util.tree_map(
            lambda x: x + 0.5 * jax.random.normal(jax.random.PRNGKey(7), x.shape),
            params)
        rng = np.random.default_rng(0)
        big_b = 4000
        batch = {
            "positions": jnp.asarray(np.tile(np.arange(1, K + 1), (big_b, 1)), jnp.int32),
            "query_doc_ids": jnp.asarray(rng.integers(0, N_DOCS, (big_b, K))),
            "clicks": jnp.zeros((big_b, K), jnp.float32),
            "mask": jnp.ones((big_b, K), bool),
        }
        pred = np.exp(np.asarray(model.predict_clicks(params, batch), np.float64))
        samples = model.sample(params, batch, jax.random.PRNGKey(123))
        emp = np.asarray(samples["clicks"], np.float64)
        np.testing.assert_allclose(emp.mean(axis=0), pred.mean(axis=0),
                                   atol=0.03, err_msg=name)


def test_right_padding_does_not_change_real_positions():
    """Chain recursions must be unaffected by what sits in the padded tail."""
    for name in ("dcm", "ccm", "dbn", "sdbn", "ubm", "cm", "pbm"):
        model = MODEL_REGISTRY[name](query_doc_pairs=N_DOCS, positions=K)
        params = model.init(jax.random.PRNGKey(1))
        batch = make_batch(5)
        mask = np.ones((B, K), bool)
        mask[:, -2:] = False  # pad the last two ranks
        b1 = dict(batch, mask=jnp.asarray(mask))
        # scramble padded ids/clicks; real prefix must be untouched
        ids2 = np.asarray(batch["query_doc_ids"]).copy()
        ids2[:, -2:] = 0
        clicks2 = np.asarray(batch["clicks"]).copy()
        clicks2[:, -2:] = 0.0
        b2 = dict(b1, query_doc_ids=jnp.asarray(ids2), clicks=jnp.asarray(clicks2))
        for fn in ("predict_clicks",):
            lp1 = np.asarray(getattr(model, fn)(params, b1))[:, :-2]
            lp2 = np.asarray(getattr(model, fn)(params, b2))[:, :-2]
            np.testing.assert_allclose(lp1, lp2, rtol=1e-6, err_msg=(name, fn))


def test_loss_respects_mask():
    model = PositionBasedModel(query_doc_pairs=N_DOCS, positions=K)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_batch(6)
    mask = np.ones((B, K), bool)
    mask[:, -1] = False
    clicks_mod = np.asarray(batch["clicks"]).copy()
    b1 = dict(batch, mask=jnp.asarray(mask))
    clicks_mod[:, -1] = 1 - clicks_mod[:, -1]  # flip masked click
    b2 = dict(b1, clicks=jnp.asarray(clicks_mod))
    assert float(model.compute_loss(params, b1)) == pytest.approx(
        float(model.compute_loss(params, b2)), rel=1e-6)
