"""Distribution-layer tests: compression math + multi-device shard_map paths.

Multi-device cases run in a subprocess with XLA_FLAGS forcing 8 host devices
(the main test process stays single-device; see conftest note and the dry-run
contract in the brief).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distrib import quantize_int8, dequantize_int8, CompressedAllReduce


def test_int8_quantization_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32)) * 3.0
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) / 2 + 1e-6  # half-ULP of the grid


def test_error_feedback_converges_on_quadratic():
    """EF-compressed GD matches uncompressed GD's optimum on a quadratic."""
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    A = A @ A.T / 16 + jnp.eye(16)
    b = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    x_star = jnp.linalg.solve(A, b)

    def grad(x):
        return A @ x - b

    x = jnp.zeros(16)
    state = CompressedAllReduce.init(x)
    lr = 0.1
    for _ in range(400):
        payload, state = state.compress_correct(grad(x))
        g = dequantize_int8(*payload)
        x = x - lr * g
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_star), atol=1e-2)


def test_compression_without_error_feedback_is_worse():
    rng = np.random.default_rng(2)
    A = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    A = A @ A.T / 16 + jnp.eye(16)
    b = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    x_star = jnp.linalg.solve(A, b)

    def run(use_ef):
        x = jnp.zeros(16)
        state = CompressedAllReduce.init(x)
        for _ in range(200):
            g = A @ x - b
            if use_ef:
                payload, state = state.compress_correct(g)
            else:
                payload = quantize_int8(g)
            x = x - 0.1 * dequantize_int8(*payload) if not use_ef else \
                x - 0.1 * dequantize_int8(*payload)
            if use_ef:
                pass
        return float(jnp.linalg.norm(x - x_star))

    # plain quantization stalls at a grid-limited error; EF does not
    assert run(True) <= run(False) + 1e-6


MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import make_auto_mesh, set_mesh
from repro.distrib import masked_psum_lookup
from repro.distrib.compression import compressed_psum, CompressedAllReduce
from repro.compat import shard_map

mesh = make_auto_mesh((2, 4), ("data", "model"))

# --- masked psum lookup == dense take -----------------------------------------
N, D, B, K = 64, 4, 8, 5
rng = np.random.default_rng(0)
table = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
ids = jnp.asarray(rng.integers(0, N, size=(B, K)))
with set_mesh(mesh):
    lookup = masked_psum_lookup(mesh, batch_dims=2)
    got = jax.jit(lookup)(
        jax.device_put(table, NamedSharding(mesh, P("model", None))),
        jax.device_put(ids, NamedSharding(mesh, P("data", None))))
want = np.asarray(table)[np.asarray(ids)]
np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)

# gradient flows back into the sharded table
def loss(t):
    return jnp.sum(lookup(t, ids) ** 2)
g = jax.jit(jax.grad(loss))(
    jax.device_put(table, NamedSharding(mesh, P("model", None))))
# reference grad
import numpy as onp
ref = onp.zeros((N, D), onp.float32)
e = onp.asarray(table)[onp.asarray(ids)]
for bi in range(B):
    for ki in range(K):
        ref[int(ids[bi, ki])] += 2 * e[bi, ki]
np.testing.assert_allclose(np.asarray(g), ref, rtol=1e-5)

# --- compressed psum across 'data' --------------------------------------------
grads = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))

def body(g):
    state = CompressedAllReduce.init(g)
    out, _ = compressed_psum(g, "data", state)
    return out

f = shard_map(body, mesh=mesh, in_specs=P("data", None), out_specs=P("data", None))
out = jax.jit(f)(grads)
# each data shard holds 4 rows; result = mean across the 2 data shards
want = (np.asarray(grads[:4]) + np.asarray(grads[4:])) / 2
got = np.asarray(out)
np.testing.assert_allclose(got[:4], want, atol=0.05)
np.testing.assert_allclose(got[4:], want, atol=0.05)
print("MULTIDEV_OK")
"""


def test_shard_map_paths_on_8_fake_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    # Pin the subprocess to CPU: probing other platform plugins (e.g. the
    # baked-in TPU runtime on dev images) can stall minutes in metadata
    # retries. --xla_force_host_platform_device_count still applies on cpu.
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                          capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MULTIDEV_OK" in proc.stdout


def test_hlo_cost_walker_on_synthetic_module():
    """While-aware walker: trip counts multiply flops/bytes/wire."""
    from repro.launch.hlo_cost import analyze_hlo

    hlo = """
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant(0)
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups=[2,4]<=[8]
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %a)
  %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,16] get-tuple-element(%loop), index=1
}
"""
    out = analyze_hlo(hlo)
    # dot flops: 2 * 8*16 * 16 = 4096 per iteration, x5 trips
    np.testing.assert_allclose(out["flops"], 5 * 4096)
    # all-reduce wire: ring 2*(4-1)/4 * 8*16*4 bytes = 768, x5
    np.testing.assert_allclose(out["collective_ops"]["all-reduce"], 5 * 768)
    assert out["unknown_trip_loops"] == 0
