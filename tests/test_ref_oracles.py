"""Property/fuzz tests for the ref.py oracles themselves.

The conformance harness measures every impl against these functions, so the
ground truth needs its own pin: each oracle is checked against a brute-force
numpy transcription (loops, float64) over hypothesis-drawn shapes, with the
degenerate corners the harness's random inputs rarely hit — bags that are all
padding, single-slot bags, zero weights, single-position sessions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# embedding_bag_ref
# ---------------------------------------------------------------------------

def _bag_brute(table, ids, weights):
    B, L = ids.shape
    out = np.zeros((B, table.shape[1]), np.float64)
    for b in range(B):
        for l in range(L):
            if ids[b, l] >= 0:
                out[b] += float(weights[b, l]) * table[ids[b, l]].astype(np.float64)
    return out


@given(st.integers(1, 10), st.integers(1, 6), st.integers(2, 30),
       st.integers(1, 40))
@settings(max_examples=15, deadline=None)
def test_embedding_bag_ref_vs_brute_force(B, L, N, D):
    table = RNG.normal(size=(N, D)).astype(np.float32)
    ids = RNG.integers(-1, N, (B, L)).astype(np.int32)
    w = RNG.normal(size=(B, L)).astype(np.float32)
    got = np.asarray(ref.embedding_bag_ref(
        jnp.asarray(table), jnp.asarray(ids), jnp.asarray(w)))
    np.testing.assert_allclose(got, _bag_brute(table, ids, w),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_ref_all_padding_is_zero():
    table = jnp.asarray(RNG.normal(size=(8, 5)), jnp.float32)
    ids = jnp.full((3, 4), -1, jnp.int32)
    w = jnp.ones((3, 4), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ref.embedding_bag_ref(table, ids, w)), 0.0)


def test_embedding_bag_ref_single_slot_is_row_scale():
    table = jnp.asarray(RNG.normal(size=(8, 5)), jnp.float32)
    ids = jnp.asarray([[3], [0], [7]], jnp.int32)
    w = jnp.asarray([[2.0], [0.0], [-1.5]], jnp.float32)
    got = np.asarray(ref.embedding_bag_ref(table, ids, w))
    want = np.asarray(w) * np.asarray(table)[np.asarray(ids)[:, 0]]
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_embedding_bag_ref_zero_weights_zero_output_and_grad():
    table = jnp.asarray(RNG.normal(size=(8, 5)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, 8, (4, 3)), jnp.int32)
    w = jnp.zeros((4, 3), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ref.embedding_bag_ref(table, ids, w)), 0.0)
    g = jax.grad(lambda t: jnp.sum(ref.embedding_bag_ref(t, ids, w)))(table)
    np.testing.assert_array_equal(np.asarray(g), 0.0)


# ---------------------------------------------------------------------------
# session_nll_ref
# ---------------------------------------------------------------------------

def _session_brute(x, c, m):
    x, c, m = (np.asarray(a, np.float64) for a in (x, c, m))
    p = 1.0 / (1.0 + np.exp(-x))
    nll = -(c * np.log(p) + (1.0 - c) * np.log1p(-p))
    return float(np.sum(nll * m) / max(np.sum(m), 1.0))


@given(st.integers(1, 12), st.integers(1, 12), st.floats(0.0, 1.0))
@settings(max_examples=15, deadline=None)
def test_session_nll_ref_vs_brute_force(B, K, click_p):
    x = RNG.normal(size=(B, K)).astype(np.float32) * 3
    c = (RNG.random((B, K)) < click_p).astype(np.float32)
    m = RNG.random((B, K)) < 0.8
    got = float(ref.session_nll_ref(jnp.asarray(x), jnp.asarray(c),
                                    jnp.asarray(m)))
    np.testing.assert_allclose(got, _session_brute(x, c, m),
                               rtol=1e-5, atol=1e-6)


def test_session_nll_ref_empty_mask_is_zero():
    x = jnp.asarray(RNG.normal(size=(4, 6)), jnp.float32)
    c = jnp.zeros((4, 6), jnp.float32)
    m = jnp.zeros((4, 6), bool)
    assert float(ref.session_nll_ref(x, c, m)) == 0.0


def test_session_nll_ref_single_position():
    x = jnp.asarray([[1.3]], jnp.float32)
    for c in (0.0, 1.0):
        got = float(ref.session_nll_ref(x, jnp.asarray([[c]]),
                                        jnp.ones((1, 1), bool)))
        np.testing.assert_allclose(got, _session_brute(x, [[c]], [[1.0]]),
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# examination_nll_ref
# ---------------------------------------------------------------------------

def _examination_brute(x, c, m, pss, pd, pr, prn,
                       floor=1e-9, cap=1e9):
    """Float64 transcription of the death-odds recurrence + BCE."""
    x, c, m, pss, pd, pr, prn = (np.asarray(a, np.float64)
                                 for a in (x, c, m, pss, pd, pr, prn))
    B, K = x.shape
    loss, count = 0.0, 0.0
    for b in range(B):
        r = 0.0
        for k in range(K):
            p = (1.0 / (1.0 + np.exp(-x[b, k]))) / (1.0 + r)
            nll = -(c[b, k] * np.log(p) + (1.0 - c[b, k]) * np.log1p(-p))
            loss += nll * m[b, k]
            count += m[b, k]
            if c[b, k] > 0:
                r = prn[b, k] / max(pr[b, k], floor)
            else:
                r = (r + pd[b, k]) / max(pss[b, k], floor)
            r = min(r, cap)
    return loss / max(count, 1.0)


@given(st.integers(1, 8), st.integers(1, 10), st.floats(0.0, 1.0))
@settings(max_examples=15, deadline=None)
def test_examination_nll_ref_vs_brute_force(B, K, click_p):
    x = RNG.normal(size=(B, K)).astype(np.float32) * 2
    c = (RNG.random((B, K)) < click_p).astype(np.float32)
    m = np.arange(K)[None, :] < RNG.integers(1, K + 1, (B, 1))
    pss = RNG.uniform(0.2, 0.95, (B, K)).astype(np.float32)
    pd = RNG.uniform(0.0, 0.4, (B, K)).astype(np.float32)
    pr = RNG.uniform(0.2, 0.95, (B, K)).astype(np.float32)
    prn = (1.0 - pr).astype(np.float32)
    got = float(ref.examination_nll_ref(*map(jnp.asarray,
                                             (x, c, m, pss, pd, pr, prn))))
    want = _examination_brute(x, c, m, pss, pd, pr, prn)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_examination_nll_ref_single_position_is_plain_bce():
    """K=1: the virtual sure-reset start means r=0, so the conditional NLL
    collapses to the session BCE of the raw logits."""
    x = jnp.asarray(RNG.normal(size=(6, 1)) * 3, jnp.float32)
    c = jnp.asarray(RNG.integers(0, 2, (6, 1)), jnp.float32)
    m = jnp.ones((6, 1), bool)
    z = jnp.zeros((6, 1), jnp.float32)
    o = jnp.ones((6, 1), jnp.float32)
    got = float(ref.examination_nll_ref(x, c, m, o, z, o, z))
    want = float(ref.session_nll_ref(x, c, m))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_examination_nll_ref_empty_mask_is_zero():
    z = jnp.zeros((3, 4), jnp.float32)
    o = jnp.ones((3, 4), jnp.float32)
    got = float(ref.examination_nll_ref(z, z, jnp.zeros((3, 4), bool),
                                        o, z, o, z))
    assert got == 0.0


# ---------------------------------------------------------------------------
# fm_interaction_ref / dcn_cross_ref / flash_attention_ref / segment_mean_ref
# ---------------------------------------------------------------------------

@given(st.integers(1, 8), st.integers(1, 6), st.integers(1, 20))
@settings(max_examples=15, deadline=None)
def test_fm_interaction_ref_vs_pairwise_sum(B, F, D):
    v = RNG.normal(size=(B, F, D)).astype(np.float32)
    got = np.asarray(ref.fm_interaction_ref(jnp.asarray(v)))
    v64 = v.astype(np.float64)
    want = np.zeros(B)
    for f1 in range(F):
        for f2 in range(f1 + 1, F):
            want += np.sum(v64[:, f1] * v64[:, f2], axis=-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fm_interaction_ref_single_field_is_zero():
    v = jnp.asarray(RNG.normal(size=(5, 1, 16)), jnp.float32)
    np.testing.assert_allclose(np.asarray(ref.fm_interaction_ref(v)), 0.0,
                               atol=1e-4)


@given(st.integers(1, 8), st.integers(1, 24))
@settings(max_examples=10, deadline=None)
def test_dcn_cross_ref_identity_and_linearity(B, D):
    x0 = jnp.asarray(RNG.normal(size=(B, D)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(B, D)), jnp.float32)
    # w = 0, b = 0: the layer is the identity on x.
    zero_w = jnp.zeros((D, D), jnp.float32)
    zero_b = jnp.zeros((D,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ref.dcn_cross_ref(x0, x, zero_w, zero_b)),
        np.asarray(x), rtol=1e-6)
    # w = 0, b = 1: y = x0 + x.
    np.testing.assert_allclose(
        np.asarray(ref.dcn_cross_ref(x0, x, zero_w, jnp.ones(D))),
        np.asarray(x0) + np.asarray(x), rtol=1e-6, atol=1e-6)


def test_flash_attention_ref_single_kv_returns_v():
    q = jnp.asarray(RNG.normal(size=(2, 2, 5, 8)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 2, 1, 8)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 2, 1, 8)), jnp.float32)
    got = np.asarray(ref.flash_attention_ref(q, k, v))
    want = np.broadcast_to(np.asarray(v), (2, 2, 5, 8))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@given(st.integers(1, 20), st.integers(1, 5), st.integers(1, 8))
@settings(max_examples=10, deadline=None)
def test_segment_mean_ref_vs_brute_force(n, S, D):
    vals = RNG.normal(size=(n, D)).astype(np.float32)
    segs = RNG.integers(0, S, n).astype(np.int32)
    got = np.asarray(ref.segment_mean_ref(jnp.asarray(vals),
                                          jnp.asarray(segs), S))
    for s in range(S):
        rows = vals[segs == s]
        want = rows.mean(axis=0) if len(rows) else np.zeros(D)
        np.testing.assert_allclose(got[s], want, rtol=1e-5, atol=1e-5)
