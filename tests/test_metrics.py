"""Click + ranking metric tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ConditionalPerplexity, LogLikelihood, MultiMetric,
                        Perplexity, average_precision_metric, dcg_metric,
                        mrr_metric, ndcg_metric)
from repro.core.metrics import RaxMetric


def _state_after(metric, log_probs, clicks, where=None, K=4):
    state = metric.init_state(K)
    kwargs = {"log_probs": log_probs, "conditional_log_probs": log_probs,
              "clicks": clicks}
    if where is not None:
        kwargs["where"] = where
    routed = {k: v for k, v in kwargs.items() if k in metric.requires}
    return metric.update(state, **routed)


def test_perplexity_perfect_and_random():
    clicks = jnp.asarray([[1.0, 0.0, 1.0, 0.0]])
    near_perfect = jnp.log(jnp.where(clicks > 0, 1 - 1e-7, 1e-7))
    m = Perplexity()
    np.testing.assert_allclose(
        float(m.compute(_state_after(m, near_perfect, clicks))), 1.0,
        atol=1e-4)
    coin = jnp.full((1, 4), jnp.log(0.5))
    np.testing.assert_allclose(
        float(m.compute(_state_after(m, coin, clicks))), 2.0, rtol=1e-5)


def test_per_rank_vs_global():
    clicks = jnp.asarray([[1.0, 0.0]])
    lp = jnp.log(jnp.asarray([[0.9, 0.4]]))
    m = Perplexity()
    state = _state_after(m, lp, clicks, K=2)
    per_rank = np.asarray(m.compute_per_rank(state))
    want0 = 2 ** (-np.log2(0.9))
    want1 = 2 ** (-np.log2(0.6))
    np.testing.assert_allclose(per_rank, [want0, want1], rtol=1e-5)


def test_masking_excludes_padding():
    clicks = jnp.asarray([[1.0, 1.0]])
    lp = jnp.log(jnp.asarray([[0.9, 1e-9]]))  # horrid prediction at rank 2
    where = jnp.asarray([[True, False]])
    m = LogLikelihood()
    got = float(m.compute(_state_after(m, lp, clicks, where=where, K=2)))
    np.testing.assert_allclose(got, np.log(0.9), rtol=1e-5)


def test_multimetric_routing_and_streaming():
    mm = MultiMetric({"ll": LogLikelihood(), "ppl": Perplexity(),
                      "cond": ConditionalPerplexity()})
    state = mm.init_state(2)
    clicks = jnp.asarray([[1.0, 0.0]])
    lp = jnp.log(jnp.asarray([[0.8, 0.3]]))
    # two updates must equal one update with both rows
    state = mm.update(state, log_probs=lp, conditional_log_probs=lp,
                      clicks=clicks, where=jnp.ones((1, 2), bool))
    state = mm.update(state, log_probs=lp, conditional_log_probs=lp,
                      clicks=clicks, where=jnp.ones((1, 2), bool))
    once = mm.init_state(2)
    both = jnp.concatenate([lp, lp])
    once = mm.update(once, log_probs=both, conditional_log_probs=both,
                     clicks=jnp.concatenate([clicks, clicks]),
                     where=jnp.ones((2, 2), bool))
    for key in ("ll", "ppl", "cond"):
        np.testing.assert_allclose(float(mm.compute(state)[key]),
                                   float(mm.compute(once)[key]), rtol=1e-6)


def test_multimetric_ignores_inputs_no_metric_requires():
    """Routing drops unknown inputs: an update carrying extras (e.g. ranking
    scores alongside click outputs) must not raise or perturb any state."""
    mm = MultiMetric({"ll": LogLikelihood(), "ppl": Perplexity()})
    clicks = jnp.asarray([[1.0, 0.0]])
    lp = jnp.log(jnp.asarray([[0.8, 0.3]]))
    kwargs = dict(log_probs=lp, conditional_log_probs=lp, clicks=clicks,
                  where=jnp.ones((1, 2), bool))
    plain = mm.update(mm.init_state(2), **kwargs)
    extra = mm.update(mm.init_state(2), scores=jnp.zeros((1, 2)),
                      labels=jnp.zeros((1, 2)), totally_unknown=object(),
                      **kwargs)
    for key in ("ll", "ppl"):
        np.testing.assert_array_equal(np.asarray(plain[key]["sum"]),
                                      np.asarray(extra[key]["sum"]))
        np.testing.assert_array_equal(np.asarray(plain[key]["count"]),
                                      np.asarray(extra[key]["count"]))


def test_multimetric_compute_on_never_updated_state_is_finite():
    """compute / compute_per_rank on a fresh state must hit the count floor
    (max(count, 1)), not divide by zero: ll -> 0.0, perplexities -> 2^0."""
    mm = MultiMetric({"ll": LogLikelihood(), "ppl": Perplexity(),
                      "cond_ppl": ConditionalPerplexity()})
    state = mm.init_state(3)
    finals = {k: float(v) for k, v in mm.compute(state).items()}
    assert finals == {"ll": 0.0, "ppl": 1.0, "cond_ppl": 1.0}
    per = mm.compute_per_rank(state)
    for k, want in (("ll", 0.0), ("ppl", 1.0), ("cond_ppl", 1.0)):
        arr = np.asarray(per[k])
        assert arr.shape == (3,)
        np.testing.assert_array_equal(arr, want)
        assert np.isfinite(arr).all()


def test_multimetric_replica_stacked_state_matches_per_replica():
    """init_state(replicas=R) + a vmapped update must equal R independent
    single evaluations, and vmapped compute must reduce per replica (a
    plain compute would sum across the stacked axis)."""
    import jax

    mm = MultiMetric({"ll": LogLikelihood(), "ppl": Perplexity()})
    clicks = jnp.asarray([[1.0, 0.0]])
    lps = [jnp.log(jnp.asarray([[0.8, 0.3]])),
           jnp.log(jnp.asarray([[0.6, 0.5]]))]

    def update(state, lp):
        return mm.update(state, log_probs=lp, conditional_log_probs=lp,
                         clicks=clicks, where=jnp.ones((1, 2), bool))

    stacked = jax.vmap(update)(mm.init_state(2, replicas=2), jnp.stack(lps))
    finals = jax.vmap(mm.compute)(stacked)
    for i, lp in enumerate(lps):
        single = mm.compute(update(mm.init_state(2), lp))
        for k in ("ll", "ppl"):
            np.testing.assert_allclose(float(finals[k][i]), float(single[k]),
                                       rtol=1e-6)


def test_per_rank_output_json_roundtrips_through_trainer_test():
    """Trainer.test's per_rank payload must survive json round-trips (sweep
    tooling serializes it): pure python floats/lists, no jnp scalars."""
    import json

    from repro import optim
    from repro.core import PositionBasedModel
    from repro.data import (ClickLogLoader, SyntheticConfig,
                            generate_click_log, split_sessions)
    from repro.train import Trainer

    cfg = SyntheticConfig(n_sessions=600, n_queries=10, docs_per_query=8,
                          positions=4, behavior="pbm", seed=1)
    data, _ = generate_click_log(cfg)
    train, _, test = split_sessions(data, (0.8, 0.1, 0.1), seed=0)
    model = PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                               positions=cfg.positions, init_prob=0.2)
    trainer = Trainer(optim.adamw(0.05), epochs=1, log_fn=lambda *_: None)
    trainer.train(model, ClickLogLoader(train, batch_size=128, seed=0))
    results = trainer.test(model, ClickLogLoader(test, batch_size=64,
                                                 shuffle=False,
                                                 drop_last=False))
    assert set(results["per_rank"]) == {"ll", "ppl", "cond_ppl"}
    assert all(len(v) == cfg.positions for v in results["per_rank"].values())
    roundtrip = json.loads(json.dumps(results))
    assert roundtrip == results


def test_dcg_hand_computed():
    scores = jnp.asarray([[0.9, 0.5, 0.1]])
    labels = jnp.asarray([[0, 2, 1]])
    # ranking by score: item0 (label 0), item1 (label 2), item2 (label 1)
    want = 0.0 + (2**2 - 1) / np.log2(3) + (2**1 - 1) / np.log2(4)
    np.testing.assert_allclose(float(dcg_metric(scores, labels)), want,
                               rtol=1e-5)


def test_ndcg_is_one_for_ideal_order():
    scores = jnp.asarray([[3.0, 2.0, 1.0]])
    labels = jnp.asarray([[2, 1, 0]])
    np.testing.assert_allclose(float(ndcg_metric(scores, labels)), 1.0,
                               rtol=1e-6)


def test_mrr():
    scores = jnp.asarray([[0.9, 0.8, 0.7]])
    labels = jnp.asarray([[0, 0, 1]])
    np.testing.assert_allclose(float(mrr_metric(scores, labels)), 1 / 3,
                               rtol=1e-6)


def test_average_precision():
    scores = jnp.asarray([[0.9, 0.8, 0.7, 0.6]])
    labels = jnp.asarray([[1, 0, 1, 0]])
    want = (1 / 1 + 2 / 3) / 2
    np.testing.assert_allclose(float(average_precision_metric(scores, labels)),
                               want, rtol=1e-6)


def test_ranking_metrics_respect_mask():
    scores = jnp.asarray([[0.9, 0.8, 100.0]])
    labels = jnp.asarray([[1, 0, 5]])
    where = jnp.asarray([[True, True, False]])
    got = float(mrr_metric(scores, labels, where=where))
    np.testing.assert_allclose(got, 1.0, rtol=1e-6)  # masked item excluded


def test_rax_metric_adapter():
    m = RaxMetric(ndcg_metric, top_n=2)
    state = m.init_state(3)
    state = m.update(state, scores=jnp.asarray([[3.0, 2.0, 1.0]]),
                     labels=jnp.asarray([[2, 1, 0]]),
                     where=jnp.ones((1, 3), bool))
    np.testing.assert_allclose(float(m.compute(state)), 1.0, rtol=1e-6)


def test_tied_scores_rank_stably_in_index_order():
    """argsort is stable, so tied scores must rank by original index."""
    from repro.core.metrics import _rank_by_score

    scores = jnp.asarray([[0.5, 0.5, 0.5, 0.5]])
    where = jnp.ones((1, 4), bool)
    np.testing.assert_array_equal(np.asarray(_rank_by_score(scores, where)),
                                  [[1, 2, 3, 4]])
    # partial tie: items 1 and 2 tied; item 1 (earlier index) ranks first
    scores = jnp.asarray([[0.9, 0.4, 0.4, 0.1]])
    np.testing.assert_array_equal(np.asarray(_rank_by_score(scores, where)),
                                  [[1, 2, 3, 4]])


def test_dcg_with_tied_scores_matches_stable_order():
    # items 0/1 tied at 0.7 -> stable order keeps (0, 1); hand-compute on that
    scores = jnp.asarray([[0.7, 0.7, 0.1]])
    labels = jnp.asarray([[1, 2, 0]])
    want = (2**1 - 1) / np.log2(2) + (2**2 - 1) / np.log2(3) + 0.0
    np.testing.assert_allclose(float(dcg_metric(scores, labels)), want,
                               rtol=1e-6)


def test_mrr_with_tied_scores_uses_first_relevant_index():
    scores = jnp.asarray([[0.5, 0.5, 0.5]])
    labels = jnp.asarray([[0, 1, 1]])
    # all tied -> ranks are index order -> first relevant is rank 2
    np.testing.assert_allclose(float(mrr_metric(scores, labels)), 1 / 2,
                               rtol=1e-6)


def test_ndcg_all_tied_scores_is_deterministic_and_bounded():
    scores = jnp.zeros((1, 4))
    labels = jnp.asarray([[0, 2, 1, 0]])
    got = float(ndcg_metric(scores, labels))
    again = float(ndcg_metric(scores, labels))
    assert got == again
    assert 0.0 < got < 1.0  # tied uniform scores cannot be the ideal order
