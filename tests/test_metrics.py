"""Click + ranking metric tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ConditionalPerplexity, LogLikelihood, MultiMetric,
                        Perplexity, average_precision_metric, dcg_metric,
                        mrr_metric, ndcg_metric)
from repro.core.metrics import RaxMetric


def _state_after(metric, log_probs, clicks, where=None, K=4):
    state = metric.init_state(K)
    kwargs = {"log_probs": log_probs, "conditional_log_probs": log_probs,
              "clicks": clicks}
    if where is not None:
        kwargs["where"] = where
    routed = {k: v for k, v in kwargs.items() if k in metric.requires}
    return metric.update(state, **routed)


def test_perplexity_perfect_and_random():
    clicks = jnp.asarray([[1.0, 0.0, 1.0, 0.0]])
    near_perfect = jnp.log(jnp.where(clicks > 0, 1 - 1e-7, 1e-7))
    m = Perplexity()
    np.testing.assert_allclose(
        float(m.compute(_state_after(m, near_perfect, clicks))), 1.0,
        atol=1e-4)
    coin = jnp.full((1, 4), jnp.log(0.5))
    np.testing.assert_allclose(
        float(m.compute(_state_after(m, coin, clicks))), 2.0, rtol=1e-5)


def test_per_rank_vs_global():
    clicks = jnp.asarray([[1.0, 0.0]])
    lp = jnp.log(jnp.asarray([[0.9, 0.4]]))
    m = Perplexity()
    state = _state_after(m, lp, clicks, K=2)
    per_rank = np.asarray(m.compute_per_rank(state))
    want0 = 2 ** (-np.log2(0.9))
    want1 = 2 ** (-np.log2(0.6))
    np.testing.assert_allclose(per_rank, [want0, want1], rtol=1e-5)


def test_masking_excludes_padding():
    clicks = jnp.asarray([[1.0, 1.0]])
    lp = jnp.log(jnp.asarray([[0.9, 1e-9]]))  # horrid prediction at rank 2
    where = jnp.asarray([[True, False]])
    m = LogLikelihood()
    got = float(m.compute(_state_after(m, lp, clicks, where=where, K=2)))
    np.testing.assert_allclose(got, np.log(0.9), rtol=1e-5)


def test_multimetric_routing_and_streaming():
    mm = MultiMetric({"ll": LogLikelihood(), "ppl": Perplexity(),
                      "cond": ConditionalPerplexity()})
    state = mm.init_state(2)
    clicks = jnp.asarray([[1.0, 0.0]])
    lp = jnp.log(jnp.asarray([[0.8, 0.3]]))
    # two updates must equal one update with both rows
    state = mm.update(state, log_probs=lp, conditional_log_probs=lp,
                      clicks=clicks, where=jnp.ones((1, 2), bool))
    state = mm.update(state, log_probs=lp, conditional_log_probs=lp,
                      clicks=clicks, where=jnp.ones((1, 2), bool))
    once = mm.init_state(2)
    both = jnp.concatenate([lp, lp])
    once = mm.update(once, log_probs=both, conditional_log_probs=both,
                     clicks=jnp.concatenate([clicks, clicks]),
                     where=jnp.ones((2, 2), bool))
    for key in ("ll", "ppl", "cond"):
        np.testing.assert_allclose(float(mm.compute(state)[key]),
                                   float(mm.compute(once)[key]), rtol=1e-6)


def test_dcg_hand_computed():
    scores = jnp.asarray([[0.9, 0.5, 0.1]])
    labels = jnp.asarray([[0, 2, 1]])
    # ranking by score: item0 (label 0), item1 (label 2), item2 (label 1)
    want = 0.0 + (2**2 - 1) / np.log2(3) + (2**1 - 1) / np.log2(4)
    np.testing.assert_allclose(float(dcg_metric(scores, labels)), want,
                               rtol=1e-5)


def test_ndcg_is_one_for_ideal_order():
    scores = jnp.asarray([[3.0, 2.0, 1.0]])
    labels = jnp.asarray([[2, 1, 0]])
    np.testing.assert_allclose(float(ndcg_metric(scores, labels)), 1.0,
                               rtol=1e-6)


def test_mrr():
    scores = jnp.asarray([[0.9, 0.8, 0.7]])
    labels = jnp.asarray([[0, 0, 1]])
    np.testing.assert_allclose(float(mrr_metric(scores, labels)), 1 / 3,
                               rtol=1e-6)


def test_average_precision():
    scores = jnp.asarray([[0.9, 0.8, 0.7, 0.6]])
    labels = jnp.asarray([[1, 0, 1, 0]])
    want = (1 / 1 + 2 / 3) / 2
    np.testing.assert_allclose(float(average_precision_metric(scores, labels)),
                               want, rtol=1e-6)


def test_ranking_metrics_respect_mask():
    scores = jnp.asarray([[0.9, 0.8, 100.0]])
    labels = jnp.asarray([[1, 0, 5]])
    where = jnp.asarray([[True, True, False]])
    got = float(mrr_metric(scores, labels, where=where))
    np.testing.assert_allclose(got, 1.0, rtol=1e-6)  # masked item excluded


def test_rax_metric_adapter():
    m = RaxMetric(ndcg_metric, top_n=2)
    state = m.init_state(3)
    state = m.update(state, scores=jnp.asarray([[3.0, 2.0, 1.0]]),
                     labels=jnp.asarray([[2, 1, 0]]),
                     where=jnp.ones((1, 3), bool))
    np.testing.assert_allclose(float(m.compute(state)), 1.0, rtol=1e-6)


def test_tied_scores_rank_stably_in_index_order():
    """argsort is stable, so tied scores must rank by original index."""
    from repro.core.metrics import _rank_by_score

    scores = jnp.asarray([[0.5, 0.5, 0.5, 0.5]])
    where = jnp.ones((1, 4), bool)
    np.testing.assert_array_equal(np.asarray(_rank_by_score(scores, where)),
                                  [[1, 2, 3, 4]])
    # partial tie: items 1 and 2 tied; item 1 (earlier index) ranks first
    scores = jnp.asarray([[0.9, 0.4, 0.4, 0.1]])
    np.testing.assert_array_equal(np.asarray(_rank_by_score(scores, where)),
                                  [[1, 2, 3, 4]])


def test_dcg_with_tied_scores_matches_stable_order():
    # items 0/1 tied at 0.7 -> stable order keeps (0, 1); hand-compute on that
    scores = jnp.asarray([[0.7, 0.7, 0.1]])
    labels = jnp.asarray([[1, 2, 0]])
    want = (2**1 - 1) / np.log2(2) + (2**2 - 1) / np.log2(3) + 0.0
    np.testing.assert_allclose(float(dcg_metric(scores, labels)), want,
                               rtol=1e-6)


def test_mrr_with_tied_scores_uses_first_relevant_index():
    scores = jnp.asarray([[0.5, 0.5, 0.5]])
    labels = jnp.asarray([[0, 1, 1]])
    # all tied -> ranks are index order -> first relevant is rank 2
    np.testing.assert_allclose(float(mrr_metric(scores, labels)), 1 / 2,
                               rtol=1e-6)


def test_ndcg_all_tied_scores_is_deterministic_and_bounded():
    scores = jnp.zeros((1, 4))
    labels = jnp.asarray([[0, 2, 1, 0]])
    got = float(ndcg_metric(scores, labels))
    again = float(ndcg_metric(scores, labels))
    assert got == again
    assert 0.0 < got < 1.0  # tied uniform scores cannot be the ideal order
