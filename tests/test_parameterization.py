"""Parameterization tests: hashing, QR, baseline correction, feature towers,
EM baselines, sparse-row optimizer, recovery properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Compression, DeepCrossParameterConfig,
                        EmbeddingParameter, EmbeddingParameterConfig,
                        LinearParameterConfig, MLPParameterConfig,
                        PositionBasedModel, build_parameter, em)
from repro.core.parameterization import hash_ids
from repro.optim.sparse import (init_sparse_table_state, sparse_adamw_update,
                                sparse_row_grads)


def test_hash_ids_deterministic_and_in_range():
    ids = jnp.arange(1000)
    h1, h2 = hash_ids(ids, 128), hash_ids(ids, 128)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    assert int(h1.min()) >= 0 and int(h1.max()) < 128


def test_hash_distribution_roughly_uniform():
    h = np.asarray(hash_ids(jnp.arange(100_000), 64))
    counts = np.bincount(h, minlength=64)
    assert counts.min() > 100_000 / 64 * 0.8
    assert counts.max() < 100_000 / 64 * 1.2


def test_hash_compression_reduces_rows():
    cfg = EmbeddingParameterConfig(parameters=1_000_000,
                                   compression=Compression.HASH,
                                   compression_ratio=100.0)
    mod = EmbeddingParameter(cfg)
    params = mod.init(jax.random.PRNGKey(0))
    assert params["table"].shape[0] <= 1_000_000 / 50  # rounded to 512
    batch = {"query_doc_ids": jnp.asarray([[0, 999_999]])}
    out = mod(params, batch)
    assert out.shape == (1, 2)


def test_qr_distinct_ids_mostly_distinct_embeddings():
    cfg = EmbeddingParameterConfig(parameters=100_000,
                                   compression=Compression.QR,
                                   compression_ratio=10.0, features=4)
    mod = EmbeddingParameter(cfg)
    params = mod.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(
        lambda x: jax.random.normal(jax.random.PRNGKey(1), x.shape), params)
    ids = jnp.arange(2000)[None]
    out = np.asarray(mod(params, {"query_doc_ids": ids}))[0]
    uniq = len(np.unique(out.round(5), axis=0))
    assert uniq > 1900  # QR: collisions ~ |ids|/(q*r), essentially none here


def test_baseline_correction_gradient_flows_to_baseline():
    cfg = EmbeddingParameterConfig(parameters=100, baseline_correction=True,
                                   init_logit=-1.5)
    mod = EmbeddingParameter(cfg)
    params = mod.init(jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(params["table"]), 0.0)
    np.testing.assert_allclose(np.asarray(params["baseline"]), -1.5)
    batch = {"query_doc_ids": jnp.asarray([[1, 2, 3]])}

    def loss(p):
        return jnp.sum(mod(p, batch) ** 2)

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["baseline"]).sum()) > 0


@pytest.mark.parametrize("config", [
    LinearParameterConfig(features=8),
    MLPParameterConfig(features=8, hidden=(16,)),
    DeepCrossParameterConfig(features=8, cross_layers=2, deep_layers=1),
])
def test_feature_towers_shape(config):
    mod = build_parameter(config)
    params = mod.init(jax.random.PRNGKey(0))
    batch = {"query_doc_features": jnp.ones((3, 5, 8))}
    out = mod(params, batch)
    assert out.shape == (3, 5)


# ---------------------------------------------------------------------------
# EM correctness properties
# ---------------------------------------------------------------------------

def _pbm_loglik(theta, gamma, pos, docs, clicks, mask):
    p = np.clip(theta[pos] * gamma[docs], 1e-9, 1 - 1e-9)
    ll = clicks * np.log(p) + (1 - clicks) * np.log(1 - p)
    return float((ll * mask).sum())


def test_pbm_em_monotonically_improves_loglik(small_log):
    cfg, data, meta = small_log
    batch = {k: jnp.asarray(v) for k, v in data.items()
             if k in ("positions", "query_doc_ids", "clicks", "mask")}
    pos = np.asarray(batch["positions"]).reshape(-1) - 1
    docs = np.asarray(batch["query_doc_ids"]).reshape(-1)
    clicks = np.asarray(batch["clicks"]).reshape(-1)
    mask = np.asarray(batch["mask"]).reshape(-1)
    lls = []
    for iters in (1, 3, 10, 30):
        theta, gamma = em.fit_pbm_em(batch, cfg.positions,
                                     cfg.n_query_doc_pairs, n_iters=iters)
        lls.append(_pbm_loglik(np.asarray(theta), np.asarray(gamma),
                               pos, docs, clicks, mask))
    assert all(b >= a - 1e-6 for a, b in zip(lls, lls[1:])), lls


def test_mle_counting_matches_numpy(small_log):
    cfg, data, meta = small_log
    batch = {k: jnp.asarray(v) for k, v in data.items()
             if k in ("positions", "query_doc_ids", "clicks", "mask")}
    np.testing.assert_allclose(float(em.fit_gctr(batch)),
                               data["clicks"].mean(), rtol=1e-6)
    rctr = np.asarray(em.fit_rctr(batch, cfg.positions))
    np.testing.assert_allclose(rctr, data["clicks"].mean(axis=0), rtol=1e-5)


# ---------------------------------------------------------------------------
# Sparse-row optimizer == dense AdamW on touched rows
# ---------------------------------------------------------------------------

def test_sparse_adamw_matches_dense_on_touched_rows():
    from repro import optim

    R, D = 64, 4
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(R, D)).astype(np.float32))
    ids = jnp.asarray([[1, 5, 5], [9, 1, 2]], jnp.int32)
    row_grads = jnp.asarray(rng.normal(size=(2, 3, D)).astype(np.float32))

    # dense reference: scatter-add grads then dense adamw
    dense_g = np.zeros((R, D), np.float32)
    for b in range(2):
        for k in range(3):
            dense_g[int(ids[b, k])] += np.asarray(row_grads[b, k])
    tx = optim.adamw(0.01, weight_decay=0.0)
    state = tx.init(table)
    updates, _ = tx.update(jnp.asarray(dense_g), state, table)
    dense_next = optim.apply_updates(table, updates)

    sstate = init_sparse_table_state(table)
    uids, ugrads = sparse_row_grads(row_grads, ids, R)
    sparse_next, _ = sparse_adamw_update(table, sstate, uids, ugrads, lr=0.01)

    touched = sorted({int(i) for i in np.asarray(ids).reshape(-1)})
    np.testing.assert_allclose(np.asarray(sparse_next)[touched],
                               np.asarray(dense_next)[touched], rtol=1e-5)
    untouched = [r for r in range(R) if r not in touched]
    np.testing.assert_array_equal(np.asarray(sparse_next)[untouched],
                                  np.asarray(table)[untouched])


# ---------------------------------------------------------------------------
# End-to-end recovery: training on a model's own samples recovers the fit
# ---------------------------------------------------------------------------

def test_pbm_gradient_training_matches_em_fit(small_log):
    from benchmarks.common import evaluate_clicks, train_gradient

    cfg, data, meta = small_log
    full = {k: jnp.asarray(v) for k, v in data.items()
            if k in ("positions", "query_doc_ids", "clicks", "mask")}
    theta, gamma = em.fit_pbm_em(full, cfg.positions, cfg.n_query_doc_pairs,
                                 n_iters=40, init=1 / 9)
    pbm = PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                             positions=cfg.positions)
    em_m = evaluate_clicks(pbm, em.pbm_params_from_em(theta, gamma), data,
                           positions=cfg.positions, batch_size=256)
    model = PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                               positions=cfg.positions, init_prob=1 / 9)
    params, _ = train_gradient(model, data, None, epochs=20, batch_size=128,
                               lr=0.05)
    grad_m = evaluate_clicks(model, params, data, positions=cfg.positions,
                             batch_size=256)
    assert abs(grad_m["ppl"] - em_m["ppl"]) < 0.02  # the paper's Fig-1 claim


def test_sdbn_mle_counting(small_log):
    """SDBN MLE on SDBN-like data: gamma estimates correlate with truth."""
    import jax.numpy as jnp

    from repro.data import SyntheticConfig, generate_click_log

    cfg = SyntheticConfig(n_sessions=20_000, n_queries=20, docs_per_query=10,
                          positions=8, behavior="dbn", continuation=1.0,
                          seed=13)  # lambda=1 == SDBN behavior
    data, meta = generate_click_log(cfg)
    batch = {k: jnp.asarray(v) for k, v in data.items()
             if k in ("positions", "query_doc_ids", "clicks", "mask")}
    gamma, sigma = em.fit_sdbn_mle(batch, cfg.n_query_doc_pairs)
    g, t = np.asarray(gamma), meta["gamma"]
    seen = g > 0
    assert seen.sum() > 50
    corr = np.corrcoef(g[seen], t[seen])[0, 1]
    assert corr > 0.8, corr
