"""Observability suite: zero-sync telemetry, spans, sinks, profiler hooks.

The hard guarantees pinned here:

* enabling engine telemetry adds ZERO extra host syncs per step (the
  telemetry rides the loss drain — exactly one ``jax.device_get`` per
  chunk either way), never retraces the compiled chunk, and leaves params
  bit-identical to a telemetry-off run;
* the trainer's per-epoch loss/skip bookkeeping is a derived view over the
  telemetry stream (``TelemetryDrain``), with the historical bit-exact
  python-float accumulation semantics (crash-exact resume stays green);
* replica-tagged events reproduce the per-replica history, and a poisoned
  replica is the only one that emits ``skipped_step`` events;
* the data plane's spans/counters/events flow through the same recorder,
  including from the read-ahead producer thread.
"""
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import PositionBasedModel
from repro.data import (ClickLogLoader, DevicePrefetcher, SessionStore,
                        StreamingClickLogLoader, SyntheticConfig,
                        generate_click_log, write_session_store)
from repro.obs import (EVENT_KINDS, ConsoleReporter, JsonlSink, MemorySink,
                       ProfileWindow, Recorder, SpanTracer, TelemetryDrain,
                       make_event, parse_profile_steps, read_jsonl,
                       validate_event)
from repro.testing import (FlakyShardReads, NonFiniteBatchInjector,
                           corrupt_shard_file)
from repro.train import StepWatchdog, Trainer, TrainEngine


# -- fixtures -----------------------------------------------------------------
@pytest.fixture(scope="module")
def small_log():
    cfg = SyntheticConfig(n_sessions=600, n_queries=20, docs_per_query=10,
                          positions=5, behavior="pbm", seed=11)
    data, _ = generate_click_log(cfg)
    return cfg, data


def _model(cfg):
    return PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                              positions=cfg.positions)


def _chunk(data, batch_size=64, n=4, seed=5, poison_step=None):
    batches = [b for b in iter(ClickLogLoader(data, batch_size=batch_size,
                                              seed=seed))][:n]
    if poison_step is not None:
        poisoned = dict(batches[poison_step])
        poisoned["clicks"] = np.full_like(poisoned["clicks"], np.nan)
        batches[poison_step] = poisoned
    return {k: np.stack([b[k] for b in batches]) for k in batches[0]}


# -- events and sinks ---------------------------------------------------------
def test_make_event_schema_roundtrip():
    e = make_event("metric", "train_step", np.float32(0.5), step=np.int64(3),
                   epoch=1, replica=0, data={"grad_norm": 0.1}, shard=2)
    validate_event(e)
    assert e["value"] == 0.5 and isinstance(e["value"], float)
    assert e["step"] == 3 and isinstance(e["step"], int)
    assert e["tags"] == {"shard": 2}
    json.dumps(e)  # JSON-able end to end


def test_validate_event_rejects_malformed():
    with pytest.raises(ValueError, match="missing required"):
        validate_event({"kind": "metric", "name": "x"})
    with pytest.raises(ValueError, match="unknown event kind"):
        validate_event(make_event("metric", "x") | {"kind": "nope"})
    with pytest.raises(ValueError, match="must be a dict"):
        validate_event(make_event("metric", "x") | {"data": [1]})
    with pytest.raises(ValueError, match="must be an int"):
        validate_event(make_event("metric", "x") | {"step": 1.5})
    assert "metric" in EVENT_KINDS and "span" in EVENT_KINDS


def test_memory_sink_queries():
    s = MemorySink()
    s.emit(make_event("metric", "loss", 1.0, step=0, replica=0))
    s.emit(make_event("metric", "loss", 2.0, step=1, replica=1))
    s.emit(make_event("event", "quarantine"))
    assert len(s) == 3
    assert s.series("loss") == [1.0, 2.0]
    assert s.series("loss", replica=1) == [2.0]
    assert [e["name"] for e in s.by_kind("event")] == ["quarantine"]


def test_jsonl_sink_roundtrip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    sink = JsonlSink(path, flush_every=2)
    for i in range(5):
        sink.emit(make_event("metric", "loss", float(i), step=i))
    sink.close()
    events = read_jsonl(path)  # validates every line
    assert [e["value"] for e in events] == [0.0, 1.0, 2.0, 3.0, 4.0]
    # late emit after close (daemon reader thread) must not raise
    sink.emit(make_event("metric", "loss", 9.0))
    assert len(read_jsonl(path)) == 5


def test_console_reporter_rate_limits_metrics():
    lines = []
    rep = ConsoleReporter(log_fn=lines.append, every=10)
    for i in range(25):
        rep.emit(make_event("metric", "loss", float(i), step=i))
    rep.emit(make_event("event", "quarantine", data={"shard": 1}))
    metric_lines = [l for l in lines if "metric/loss" in l]
    assert len(metric_lines) == 3  # samples 0, 10, 20
    assert any("event/quarantine" in l for l in lines)


# -- spans --------------------------------------------------------------------
def test_span_tracer_nesting_and_ring_buffer():
    tr = SpanTracer(capacity=4)
    with tr.span("outer", epoch=0):
        with tr.span("inner"):
            pass
    assert [s.name for s in tr.spans] == ["inner", "outer"]  # exit order
    assert tr.spans[-1].tags == {"epoch": 0}
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.spans) == 4  # bounded: old spans fell off


def test_span_recorded_even_on_error():
    tr = SpanTracer()
    with pytest.raises(RuntimeError):
        with tr.span("doomed"):
            raise RuntimeError("boom")
    assert [s.name for s in tr.spans] == ["doomed"]


def test_chrome_trace_export(tmp_path):
    rec = Recorder()
    with rec.span("epoch", epoch=0):
        with rec.span("chunk"):
            time.sleep(0.002)
    path = str(tmp_path / "trace.json")
    n = rec.export_chrome_trace(path)
    assert n == 2
    trace = json.load(open(path))
    assert trace["displayTimeUnit"] == "ms"
    by_name = {e["name"]: e for e in trace["traceEvents"]}
    assert set(by_name) == {"epoch", "chunk"}
    for e in trace["traceEvents"]:
        assert e["ph"] == "X" and e["dur"] >= 0 and e["ts"] > 0
    assert by_name["chunk"]["dur"] >= 2000  # microseconds
    assert by_name["epoch"]["args"] == {"epoch": 0}


def test_recorder_disabled_is_noop_but_spans_still_trace():
    rec = Recorder()  # no sinks
    assert not rec.enabled
    rec.metric("loss", 1.0)  # must not raise, must not store
    with rec.span("epoch"):
        pass
    assert len(rec.tracer.spans) == 1


def test_recorder_counters_gauges_and_flush():
    sink = MemorySink()
    rec = Recorder(sinks=[sink])
    rec.add("io_retries")
    rec.add("bytes_read", 100)
    rec.add("bytes_read", 28)
    rec.gauge("queue_depth", 3)
    snap = rec.counters_snapshot()
    assert snap == {"io_retries": 1, "bytes_read": 128, "queue_depth:gauge": 3}
    rec.flush_counters(epoch=0)
    (e,) = sink.by_kind("counters")
    assert e["data"]["bytes_read"] == 128 and e["epoch"] == 0


def test_recorder_span_forwarded_to_sinks():
    sink = MemorySink()
    rec = Recorder(sinks=[sink])
    with rec.span("shard_read", shard=2):
        pass
    (e,) = sink.by_kind("span")
    assert e["name"] == "shard_read" and e["tags"] == {"shard": 2}
    assert e["value"] >= 0  # seconds


def test_process_stats_reports_host_rss():
    rec = Recorder(sinks=[MemorySink()])
    stats = rec.process_stats(epoch=1)
    assert stats["rss_bytes"] > 0
    (e,) = rec.sinks[0].by_kind("process")
    assert e["data"]["rss_bytes"] == stats["rss_bytes"]


def test_recorder_thread_safety_under_producer_emits():
    sink = MemorySink()
    rec = Recorder(sinks=[sink])

    def worker(tid):
        for i in range(200):
            rec.add("n")
            rec.metric("m", float(i), step=i, replica=tid)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert rec.counters_snapshot()["n"] == 800
    assert len(sink.by_name("m")) == 800


# -- engine telemetry: the zero-sync / no-retrace / bit-exact pins ------------
def test_engine_telemetry_params_bit_exact_and_payload(small_log):
    cfg, data = small_log
    model = _model(cfg)
    chunk = _chunk(data)

    def run(telemetry):
        eng = TrainEngine(model, optim.adamw(0.05), chunk_batches=4,
                          telemetry=telemetry)
        params = model.init(jax.random.PRNGKey(0))
        p, _, out = eng.step(params, eng.init_opt_state(params), chunk)
        return jax.device_get(p), jax.device_get(out)

    p_off, losses = run(False)
    p_on, out = run(True)
    assert set(out) == {"loss", "grad_norm", "param_norm"}
    np.testing.assert_array_equal(np.asarray(losses), out["loss"])
    for (ka, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(p_off),
                               jax.tree_util.tree_leaves_with_path(p_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"telemetry changed {ka}")


def test_engine_telemetry_values_match_manual_computation(small_log):
    cfg, data = small_log
    model = _model(cfg)
    chunk = _chunk(data, n=1)
    eng = TrainEngine(model, optim.adamw(0.05), chunk_batches=1,
                      telemetry=True)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = eng.init_opt_state(params)
    batch = {k: v[0] for k, v in chunk.items()}
    loss, grads = jax.value_and_grad(model.compute_loss)(params, batch)
    p2, _, out = eng.step(params, opt_state, chunk)
    out = jax.device_get(out)
    np.testing.assert_allclose(out["grad_norm"][0],
                               float(optim.global_norm(grads)), rtol=1e-6)
    np.testing.assert_allclose(out["param_norm"][0],
                               float(optim.global_norm(p2)), rtol=1e-6)
    np.testing.assert_allclose(out["loss"][0], float(loss), rtol=1e-6)


def test_engine_telemetry_lr_series_with_injected_lr(small_log):
    cfg, data = small_log
    model = _model(cfg)
    eng = TrainEngine(model, optim.adamw(0.05, inject_lr=True),
                      chunk_batches=4, telemetry=True)
    params = model.init(jax.random.PRNGKey(0))
    _, _, out = eng.step(params, eng.init_opt_state(params), _chunk(data))
    np.testing.assert_allclose(np.asarray(out["lr"]), 0.05, rtol=1e-6)


def test_engine_telemetry_never_retraces_across_chunks(small_log):
    """The trace-counter pin (same pattern as test_dispatch): a Python-side
    counter in the loss closure counts traces — jit cache hits never
    re-enter Python, so telemetry must cost exactly as many traces as the
    bare engine (one per chunk shape)."""
    cfg, data = small_log
    model = _model(cfg)
    traces = []

    def loss_fn(params, batch):
        traces.append(1)
        return model.compute_loss(params, batch)

    eng = TrainEngine(model, optim.adamw(0.05), chunk_batches=4,
                      telemetry=True, nonfinite_guard=True, loss_fn=loss_fn)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = eng.init_opt_state(params)
    chunk = _chunk(data)
    params, opt_state, _ = eng.step(params, opt_state, chunk)
    n_traces = len(traces)
    assert n_traces > 0
    for _ in range(3):
        params, opt_state, out = eng.step(params, opt_state, chunk)
    assert len(traces) == n_traces  # compiled chunk never re-entered Python
    assert np.isfinite(np.asarray(out["loss"])).all()


def test_trainer_telemetry_zero_extra_host_syncs(small_log, monkeypatch):
    """Telemetry-on and telemetry-off trainer runs perform EXACTLY the same
    number of jax.device_get calls: one per chunk (the loss drain telemetry
    rides along with). Counted by wrapping jax.device_get itself."""
    cfg, data = small_log

    def run(telemetry):
        model = _model(cfg)
        loader = ClickLogLoader(data, batch_size=64, seed=5)
        trainer = Trainer(optim.adamw(0.05), epochs=2, patience=100,
                          chunk_batches=4, telemetry=telemetry,
                          recorder=Recorder(sinks=[MemorySink()]),
                          log_fn=lambda *_: None)
        calls = []
        real = jax.device_get
        monkeypatch.setattr(jax, "device_get",
                            lambda x: (calls.append(1), real(x))[1])
        try:
            trainer.train(model, loader)
        finally:
            monkeypatch.setattr(jax, "device_get", real)
        return len(calls)

    chunks_per_epoch = -(-(len(list(iter(ClickLogLoader(
        data, batch_size=64, seed=5)))) ) // 4)
    n_off, n_on = run(False), run(True)
    assert n_on == n_off == 2 * chunks_per_epoch


# -- TelemetryDrain: the single source of truth -------------------------------
def test_drain_scalar_accumulation_is_bit_exact_python_floats():
    rng = np.random.default_rng(0)
    losses = rng.normal(size=13).astype(np.float32)
    acc = TelemetryDrain()
    acc.drain(losses[:4], first_step=0)
    acc.drain(losses[4:], first_step=4)
    expected = 0.0
    for x in losses:
        expected += float(x)
    assert acc.train_loss == expected  # bitwise, not allclose
    assert acc.n_batches == 13
    assert acc.mean_loss() == expected / 13


def test_drain_aux_json_roundtrip_exact():
    acc = TelemetryDrain()
    acc.drain(np.asarray([0.1, 0.2, 0.3], np.float32))
    aux = json.loads(json.dumps(acc.aux()))
    acc2 = TelemetryDrain()
    acc2.load(aux)
    assert acc2.train_loss == acc.train_loss  # python floats round-trip json
    assert acc2.n_batches == 3 and acc2.skipped_steps == 0


def test_drain_skipped_steps_excluded_from_mean():
    acc = TelemetryDrain()
    acc.drain({"loss": np.asarray([1.0, np.nan, 3.0], np.float32),
               "skipped": np.asarray([False, True, False])})
    assert acc.skipped_steps == 1 and acc.n_batches == 3
    assert acc.mean_loss() == (1.0 + 3.0) / 2


def test_drain_replica_accumulation_and_events():
    sink = MemorySink()
    rec = Recorder(sinks=[sink])
    acc = TelemetryDrain(replicas=2, recorder=rec, epoch=0)
    loss = np.asarray([[1.0, 10.0], [2.0, np.nan]], np.float32)
    skipped = np.asarray([[False, False], [False, True]])
    acc.drain({"loss": loss, "skipped": skipped,
               "grad_norm": np.ones((2, 2), np.float32)}, first_step=0)
    np.testing.assert_array_equal(acc.train_loss, [3.0, 10.0])
    np.testing.assert_array_equal(acc.skipped_steps, [0, 1])
    np.testing.assert_array_equal(acc.mean_loss(), [1.5, 10.0])
    assert sink.series("train_step", replica=0) == [1.0, 2.0]
    skips = sink.by_name("skipped_step")
    assert [(e["step"], e["replica"]) for e in skips] == [(1, 1)]
    # extras ride in data, per replica
    assert sink.by_name("train_step")[0]["data"] == {"grad_norm": 1.0}


def test_drain_every_rate_limits_metrics_not_skips():
    sink = MemorySink()
    acc = TelemetryDrain(recorder=Recorder(sinks=[sink]), every=4)
    acc.drain({"loss": np.arange(8, dtype=np.float32),
               "skipped": np.asarray([0, 0, 1, 0, 0, 0, 0, 1], bool)},
              first_step=0)
    assert [e["step"] for e in sink.by_name("train_step")] == [0, 4]
    assert [e["step"] for e in sink.by_name("skipped_step")] == [2, 7]


# -- trainer integration ------------------------------------------------------
def test_trainer_history_is_derived_view_of_event_stream(small_log):
    """Satellite: per-epoch train_loss is exactly the mean of the per-step
    telemetry events — one source of truth, no double bookkeeping."""
    cfg, data = small_log
    model = _model(cfg)
    sink = MemorySink()
    trainer = Trainer(optim.adamw(0.05), epochs=2, patience=100,
                      chunk_batches=4, telemetry=True,
                      recorder=Recorder(sinks=[sink]),
                      log_fn=lambda *_: None)
    history = trainer.train(model, ClickLogLoader(data, batch_size=64, seed=5))
    for epoch, record in enumerate(history):
        vals = [e["value"] for e in sink.by_name("train_step")
                if e["epoch"] == epoch]
        assert len(vals) == 9  # 600 sessions * 64 batch
        assert abs(np.mean(vals) - record["train_loss"]) < 1e-9
        # per-step events carry the on-device norm series
        datas = [e["data"] for e in sink.by_name("train_step")
                 if e["epoch"] == epoch]
        assert all(d["grad_norm"] > 0 and d["param_norm"] > 0 for d in datas)
    epochs = sink.by_kind("epoch")
    assert [e["data"]["train_loss"] for e in epochs] == \
        [r["train_loss"] for r in history]
    assert len(sink.by_kind("process")) == 2  # one per epoch


def test_trainer_replica_events_match_history(small_log):
    """Satellite: replica-tagged events from a vmapped 4-way sweep reproduce
    each replica's loss history to <= 1e-5."""
    cfg, data = small_log
    model = _model(cfg)
    sink = MemorySink()
    lrs = [0.01, 0.02, 0.05, 0.1]
    trainer = Trainer(optim.adamw(0.05, inject_lr=True), epochs=1,
                      patience=100, replicas=4, replica_lrs=lrs,
                      chunk_batches=3, telemetry=True,
                      recorder=Recorder(sinks=[sink]),
                      log_fn=lambda *_: None)
    history = trainer.train(model, ClickLogLoader(data, batch_size=64, seed=5))
    for r in range(4):
        series = sink.series("train_step", replica=r)
        assert len(series) == 9
        assert abs(np.mean(series) - history[0]["train_loss"][r]) <= 1e-5
        # each replica's events carry its own injected lr
        lr_seen = {e["data"]["lr"] for e in sink.by_name("train_step")
                   if e["replica"] == r}
        assert len(lr_seen) == 1
        assert abs(lr_seen.pop() - lrs[r]) < 1e-6
    # distinct lrs -> distinct trajectories in the event stream too
    assert sink.series("train_step", replica=0) != \
        sink.series("train_step", replica=1)


def test_trainer_broadcast_poison_tags_every_replica(small_log):
    """A NonFiniteBatchInjector batch is broadcast to all replicas: each one
    skips it and each emits its own replica-tagged skipped event."""
    cfg, data = small_log
    model = _model(cfg)
    sink = MemorySink()
    loader = NonFiniteBatchInjector(
        ClickLogLoader(data, batch_size=64, seed=5), at_steps=[2])
    trainer = Trainer(optim.adamw(0.05), epochs=1, patience=100, replicas=2,
                      chunk_batches=3, nonfinite_guard=True,
                      recorder=Recorder(sinks=[sink]),
                      log_fn=lambda *_: None)
    history = trainer.train(model, loader)
    assert history[0]["skipped_steps"] == [1, 1]
    skips = sink.by_name("skipped_step")
    assert sorted((e["step"], e["replica"]) for e in skips) == \
        [(2, 0), (2, 1)]


def test_only_poisoned_replica_emits_skipped_events(small_log):
    """One replica's params poisoned with NaN: its every step skips (its own
    loss is non-finite), the healthy replica's never do — the in-memory sink
    sees skipped events only from the poisoned replica."""
    cfg, data = small_log
    model = _model(cfg)
    eng = TrainEngine(model, optim.adamw(0.05), chunk_batches=4, replicas=2,
                      nonfinite_guard=True)
    params = eng.init_replica_params([0, 1])
    # poison replica 1's params wholesale
    params = jax.tree_util.tree_map(
        lambda x: np.asarray(x).copy(), params)
    for leaf in jax.tree_util.tree_leaves(params):
        leaf[1] = np.nan
    params = jax.tree_util.tree_map(jnp.asarray, params)
    opt_state = eng.init_opt_state(params)
    sink = MemorySink()
    acc = TelemetryDrain(replicas=2, recorder=Recorder(sinks=[sink]))
    _, _, out = eng.step(params, opt_state, _chunk(data))
    acc.drain(out, first_step=0)
    np.testing.assert_array_equal(acc.skipped_steps, [0, 4])
    assert {e["replica"] for e in sink.by_name("skipped_step")} == {1}
    assert len(sink.by_name("skipped_step")) == 4


def test_trainer_scalar_skip_events_at_poisoned_steps(small_log):
    cfg, data = small_log
    model = _model(cfg)
    sink = MemorySink()
    loader = NonFiniteBatchInjector(
        ClickLogLoader(data, batch_size=64, seed=5), at_steps=[2, 7])
    trainer = Trainer(optim.adamw(0.05), epochs=1, patience=100,
                      chunk_batches=3, nonfinite_guard=True,
                      recorder=Recorder(sinks=[sink]),
                      log_fn=lambda *_: None)
    history = trainer.train(model, loader)
    assert history[0]["skipped_steps"] == 2
    assert [e["step"] for e in sink.by_name("skipped_step")] == [2, 7]


def test_trainer_emits_spans_and_roofline(small_log):
    cfg, data = small_log
    model = _model(cfg)
    sink = MemorySink()
    rec = Recorder(sinks=[sink])
    trainer = Trainer(optim.adamw(0.05), epochs=1, patience=100,
                      chunk_batches=4, recorder=rec, emit_roofline=True,
                      log_fn=lambda *_: None)
    trainer.train(model, ClickLogLoader(data, batch_size=64, seed=5),
                  ClickLogLoader(data, batch_size=256, shuffle=False,
                                 drop_last=False))
    span_names = {e["name"] for e in sink.by_kind("span")}
    assert {"epoch", "eval", "roofline"} <= span_names
    (rf,) = sink.by_kind("roofline")
    assert rf["data"]["bytes"] > 0 and rf["data"]["chunk_batches"] == 4
    assert rf["data"]["unknown_trip_loops"] == 0  # scan trip count resolved


def test_engine_roofline_scales_with_chunk(small_log):
    cfg, data = small_log
    model = _model(cfg)

    def cost(n):
        eng = TrainEngine(model, optim.adamw(0.05), chunk_batches=n)
        params = model.init(jax.random.PRNGKey(0))
        return eng.roofline(params, eng.init_opt_state(params),
                            _chunk(data, n=n))

    c2, c4 = cost(2), cost(4)
    assert c4["chunk_batches"] == 4 and c2["chunk_batches"] == 2
    # while-aware: doubling the scan trip count ~doubles traffic
    assert c4["bytes"] > 1.5 * c2["bytes"]


# -- watchdog + profiler hooks ------------------------------------------------
def test_watchdog_violation_emits_event():
    sink = MemorySink()
    wd = StepWatchdog(0.01, recorder=Recorder(sinks=[sink]))
    wd.check(0.005, step=4)   # within budget
    wd.check(0.5, step=8)     # violation
    assert wd.violations == 1
    (e,) = sink.by_name("watchdog_violation")
    assert e["step"] == 8 and e["value"] == 0.5
    assert e["data"]["budget_seconds"] == 0.01


def test_parse_profile_steps():
    assert parse_profile_steps("10:20") == (10, 20)
    for bad in ("10", "20:10", "a:b", "-1:5"):
        with pytest.raises(ValueError):
            parse_profile_steps(bad)


def test_profile_window_opens_and_closes_on_chunk_boundaries(monkeypatch):
    calls = []
    import jax.profiler

    monkeypatch.setattr(jax.profiler, "start_trace",
                        lambda d: calls.append(("start", d)))
    monkeypatch.setattr(jax.profiler, "stop_trace",
                        lambda: calls.append(("stop",)))
    sink = MemorySink()
    pw = ProfileWindow(8, 16, log_dir="prof",
                       recorder=Recorder(sinks=[sink]))
    pw.before_chunk(0)
    pw.after_chunk(4)
    assert calls == []          # window not reached
    pw.before_chunk(8)
    assert calls == [("start", "prof")]
    pw.after_chunk(12)          # inside the window: stays open
    pw.before_chunk(12)         # idempotent while active
    pw.after_chunk(16)
    assert calls == [("start", "prof"), ("stop",)]
    pw.before_chunk(20)         # window done: never reopens
    assert calls == [("start", "prof"), ("stop",)]
    names = [e["name"] for e in sink.by_kind("event")]
    assert names == ["profile_start", "profile_stop"]
    assert sink.by_name("profile_start")[0]["step"] == 8


def test_profile_window_close_flushes_open_trace(monkeypatch):
    calls = []
    import jax.profiler

    monkeypatch.setattr(jax.profiler, "start_trace",
                        lambda d: calls.append("start"))
    monkeypatch.setattr(jax.profiler, "stop_trace",
                        lambda: calls.append("stop"))
    pw = ProfileWindow(0, 100, log_dir="prof", recorder=Recorder())
    pw.before_chunk(0)
    pw.close(8)  # training ended inside the window
    assert calls == ["start", "stop"]
    pw.close(8)  # idempotent
    assert calls == ["start", "stop"]


# -- streaming data plane -----------------------------------------------------
@pytest.fixture()
def store_dir(tmp_path, small_log):
    cfg, data = small_log
    d = str(tmp_path / "store")
    write_session_store(data, d, shard_rows=150)  # 4 shards
    return d


def test_streaming_emits_spans_and_counters(store_dir):
    sink = MemorySink()
    rec = Recorder(sinks=[sink])
    loader = StreamingClickLogLoader(store_dir, batch_size=50, seed=3,
                                     verify_checksums=True, recorder=rec)
    n = len(list(iter(loader)))
    assert n == loader.batches_per_epoch
    reads = sink.by_name("shard_read", kind="span")
    assert len(reads) == 4  # every shard read exactly once
    assert {e["tags"]["shard"] for e in reads} == {0, 1, 2, 3}
    assert len(sink.by_name("crc_verify", kind="span")) == 4
    snap = rec.counters_snapshot()
    assert snap["stream.bytes_read"] > 0
    assert snap["stream.sessions"] == n * 50
    assert snap["stream.queue_stall_s"] >= 0
    assert "stream.queue_depth:gauge" in snap


def test_streaming_io_retry_telemetry(store_dir):
    sink = MemorySink()
    rec = Recorder(sinks=[sink])
    store = FlakyShardReads(SessionStore(store_dir), fail_times=2)
    loader = StreamingClickLogLoader(store, batch_size=50, seed=3,
                                     io_retries=3, io_retry_backoff=0.001,
                                     recorder=rec, log_fn=lambda *_: None)
    assert len(list(iter(loader))) == loader.batches_per_epoch
    assert rec.counters_snapshot()["stream.io_retries"] == 2
    waits = sink.by_name("io_retry_wait", kind="span")
    assert [e["tags"]["attempt"] for e in waits] == [1, 2]


def test_streaming_quarantine_event(store_dir):
    corrupt_shard_file(store_dir, shard=1, column="clicks", seed=1)
    sink = MemorySink()
    rec = Recorder(sinks=[sink])
    loader = StreamingClickLogLoader(store_dir, batch_size=50, seed=3,
                                     verify_checksums=True,
                                     corrupt_policy="skip", recorder=rec,
                                     log_fn=lambda *_: None)
    list(iter(loader))
    (e,) = sink.by_name("quarantine")
    assert e["data"]["shard"] == 1
    assert rec.counters_snapshot()["stream.quarantined_shards"] == 1


def test_streaming_watchdog_restart_event(store_dir):
    # io_retries=0: the producer dies on the first flaky open; the consumer
    # watchdog restarts it and the event records the restart, not the death
    sink = MemorySink()
    rec = Recorder(sinks=[sink])
    store = FlakyShardReads(SessionStore(store_dir), fail_times=1)
    loader = StreamingClickLogLoader(store, batch_size=50, seed=3,
                                     io_retries=0, watchdog_restarts=1,
                                     recorder=rec, log_fn=lambda *_: None)
    assert len(list(iter(loader))) == loader.batches_per_epoch
    (e,) = sink.by_name("watchdog_restart")
    assert "OSError" in e["data"]["error"]
    assert rec.counters_snapshot()["stream.watchdog_restarts"] == 1
