"""Mixture-model behaviour (paper §4.3)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import (DocumentCTR, DynamicBayesianNetwork, EmbeddingParameter,
                        EmbeddingParameterConfig, GlobalCTR, MixtureModel,
                        PositionBasedModel)

N_DOCS, K, B = 60, 6, 32


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "positions": jnp.asarray(np.tile(np.arange(1, K + 1), (B, 1)), jnp.int32),
        "query_doc_ids": jnp.asarray(rng.integers(0, N_DOCS, (B, K))),
        "clicks": jnp.asarray(rng.integers(0, 2, (B, K)).astype(np.float32)),
        "mask": jnp.ones((B, K), bool),
    }


def test_shared_parameters_accumulate_gradients():
    attr = EmbeddingParameter(EmbeddingParameterConfig(parameters=N_DOCS))
    pbm = PositionBasedModel(attraction=attr, positions=K)
    dbn = DynamicBayesianNetwork(attraction=attr, positions=K,
                                 query_doc_pairs=N_DOCS)
    mix = MixtureModel([pbm, dbn])
    params = mix.init(jax.random.PRNGKey(0))
    # exactly one attraction table in the store
    attraction_keys = [k for k in params["store"] if "attraction" in k]
    assert len(attraction_keys) == 1
    g = jax.grad(mix.compute_loss)(params, _batch())
    # grads flow into the single shared copy and the prior
    assert float(jnp.abs(g["store"][attraction_keys[0]]["table"]).sum()) > 0
    assert float(jnp.abs(g["prior_logits"]).sum()) > 0


def test_mixture_loss_never_worse_than_best_member_at_init():
    """At uniform prior, -log sum_m pi_m exp(-L_m) <= min_m L_m + log M."""
    pbm = PositionBasedModel(query_doc_pairs=N_DOCS, positions=K)
    gctr = GlobalCTR(positions=K)
    mix = MixtureModel([pbm, gctr])
    params = mix.init(jax.random.PRNGKey(1))
    batch = _batch(1)
    mix_loss = float(mix.compute_loss(params, batch))
    member_losses = [
        float(pbm.compute_loss(mix._model_params(params, 0), batch)),
        float(gctr.compute_loss(mix._model_params(params, 1), batch)),
    ]
    # per-item normalized mixture loss is bounded by the best member plus
    # the prior penalty (log M spread over items)
    n_items = B * K
    assert mix_loss <= min(member_losses) + np.log(2) / 1 + 1e-6


def test_prior_concentrates_on_generating_model():
    """Data sampled from a PBM: mixture(PBM, GCTR) should upweight the PBM."""
    from repro.data import SyntheticConfig, generate_click_log

    cfg = SyntheticConfig(n_sessions=4000, n_queries=40, docs_per_query=12,
                          positions=K, behavior="pbm", seed=5)
    data, _ = generate_click_log(cfg)
    pbm = PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                             positions=K, init_prob=1 / 9)
    gctr = GlobalCTR(positions=K, init_prob=1 / 9)
    mix = MixtureModel([pbm, gctr], temperature=1.0)
    tx = optim.adamw(0.05)
    params = mix.init(jax.random.PRNGKey(0))
    opt = tx.init(params)

    @jax.jit
    def step(params, opt, batch):
        loss, g = jax.value_and_grad(mix.compute_loss)(params, batch)
        updates, opt = tx.update(g, opt, params)
        return optim.apply_updates(params, updates), opt, loss

    n = data["positions"].shape[0]
    for epoch in range(4):
        order = np.random.default_rng(epoch).permutation(n)
        for i in range(n // 512):
            idx = order[i * 512:(i + 1) * 512]
            batch = {k: jnp.asarray(v[idx]) for k, v in data.items()
                     if k in ("positions", "query_doc_ids", "clicks", "mask")}
            params, opt, _ = step(params, opt, batch)
    prior = np.asarray(jax.nn.softmax(params["prior_logits"]))
    assert prior[0] > 0.6, prior  # PBM favored


def test_mixture_predictions_are_valid_log_probs():
    pbm = PositionBasedModel(query_doc_pairs=N_DOCS, positions=K)
    dctr = DocumentCTR(query_doc_pairs=N_DOCS, positions=K)
    mix = MixtureModel([pbm, dctr])
    params = mix.init(jax.random.PRNGKey(2))
    batch = _batch(2)
    for fn in (mix.predict_clicks, mix.predict_conditional_clicks):
        lp = np.asarray(fn(params, batch))
        assert np.all(np.isfinite(lp)) and np.all(lp <= 1e-6)
    s = mix.sample(params, batch, jax.random.PRNGKey(3))
    assert s["clicks"].shape == (B, K)
    assert s["model_choice"].shape == (B,)
