"""Differential conformance sweep: every kernel x impl x dtype x shape.

The harness (repro.testing.conformance) pins three properties per cell:
value parity against the ref oracle (<= 1e-5 in float32), gradient parity
via the ref oracle VJPs, and NaN-freedom (values and bounded gradients) on
the extreme-logit / fully-masked corpus from test_recursions.py. Shapes sit
below, at, and straddling the 128-lane width and each kernel's batch block.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.testing.conformance import (IMPLS, KERNEL_SPECS, SPECS_BY_NAME,
                                       check_extreme, check_grads,
                                       check_value)

KERNEL_NAMES = [s.name for s in KERNEL_SPECS]

VALUE_CELLS = [(s.name, impl, shape)
               for s in KERNEL_SPECS for impl in IMPLS for shape in s.shapes]
GRAD_CELLS = [(s.name, impl, shape)
              for s in KERNEL_SPECS for impl in s.grad_impls
              for shape in s.shapes]


def test_harness_covers_all_registered_kernels():
    """The sweep is total: every kernel in the dispatch registry has a spec,
    and every spec's impls are all registered."""
    from repro.kernels import dispatch

    registered = set(dispatch.registered_kernels())
    assert registered == set(KERNEL_NAMES), (registered, KERNEL_NAMES)
    for name in registered:
        assert dispatch.kernel_impls(name) == IMPLS


@pytest.mark.parametrize("name,impl,shape", VALUE_CELLS,
                         ids=[f"{n}-{i}-{'x'.join(map(str, s))}"
                              for n, i, s in VALUE_CELLS])
def test_value_parity_f32(name, impl, shape):
    check_value(SPECS_BY_NAME[name], impl, shape, jnp.float32)


@pytest.mark.parametrize("name,impl", [(s.name, impl) for s in KERNEL_SPECS
                                       for impl in IMPLS],
                         ids=[f"{s.name}-{impl}" for s in KERNEL_SPECS
                              for impl in IMPLS])
def test_value_parity_bf16(name, impl):
    """bfloat16 inputs, fp32 accumulation: parity within bf16 rounding."""
    spec = SPECS_BY_NAME[name]
    check_value(spec, impl, spec.shapes[0], jnp.bfloat16)


@pytest.mark.parametrize("name,impl,shape", GRAD_CELLS,
                         ids=[f"{n}-{i}-{'x'.join(map(str, s))}"
                              for n, i, s in GRAD_CELLS])
def test_grad_parity_f32(name, impl, shape):
    check_grads(SPECS_BY_NAME[name], impl, shape, jnp.float32)


@pytest.mark.parametrize("name,impl",
                         [(s.name, impl) for s in KERNEL_SPECS
                          for impl in IMPLS if s.extreme_cases is not None],
                         ids=[f"{s.name}-{impl}" for s in KERNEL_SPECS
                              for impl in IMPLS if s.extreme_cases is not None])
def test_extreme_corpus_nan_free(name, impl):
    check_extreme(SPECS_BY_NAME[name], impl)


def test_examination_nll_grads_identical_across_impls():
    """The custom VJP differentiates the ref composition regardless of the
    forward impl, so gradients are bit-identical — not merely close."""
    import jax

    spec = SPECS_BY_NAME["examination_nll"]
    rng = np.random.default_rng(3)
    args = spec.make_inputs(rng, (8, 10), jnp.float32)

    def grads(impl):
        def scalar(x, pss):
            full = list(args)
            full[0], full[3] = x, pss
            return spec.call(tuple(full), impl)
        return jax.grad(scalar, argnums=(0, 1))(args[0], args[3])

    ref = grads("ref")
    for impl in ("xla", "pallas"):
        for a, b in zip(grads(impl), ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_examination_nll_saturated_sessions_finite_with_zero_grad():
    """A chain driven past the odds cap keeps a finite loss and the capped
    positions stop contributing gradient (core/recursions saturation
    semantics, preserved through every impl)."""
    import jax

    from repro import kernels

    B, K = 4, 12
    ones = jnp.ones((B, K), jnp.float32)
    x = ones * 36.0
    clicks = jnp.zeros((B, K), jnp.float32)
    mask = jnp.ones((B, K), bool)
    # Attractive items, never clicked, reset never fires: odds explode into
    # the cap after a few positions.
    gn = ones * float(np.exp(-36.0))
    for impl in IMPLS:
        loss, grad = jax.value_and_grad(
            lambda pss: kernels.examination_nll(
                x, clicks, mask, pss, ones * 0.0, ones * 0.5, ones * 0.5,
                impl=impl))(gn)
        assert np.isfinite(float(loss)), impl
        g = np.asarray(grad)
        assert np.all(np.isfinite(g)), impl
        # tail positions are saturated: their factor gradient must be 0
        assert np.all(np.abs(g[:, -1]) == 0.0), (impl, g[:, -1])
