"""Trainer / checkpoint / fault-tolerance behaviour tests."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import PositionBasedModel
from repro.data import ClickLogLoader, SyntheticConfig, generate_click_log, split_sessions
from repro.train import CheckpointManager, Trainer, drop_slowest_aggregate


@pytest.fixture()
def log_and_loaders():
    cfg = SyntheticConfig(n_sessions=3000, n_queries=30, docs_per_query=12,
                          positions=8, behavior="pbm", seed=11)
    data, meta = generate_click_log(cfg)
    train, val, test = split_sessions(data, (0.7, 0.15, 0.15), seed=0)
    mk = lambda d: ClickLogLoader(d, batch_size=256, seed=5)
    return cfg, mk(train), mk(val), mk(test)


def test_trainer_reduces_loss_and_early_stops(log_and_loaders):
    cfg, train_loader, val_loader, test_loader = log_and_loaders
    model = PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                               positions=cfg.positions, init_prob=0.2)
    trainer = Trainer(optim.adamw(0.05), epochs=30, patience=2,
                      log_fn=lambda *_: None)
    history = trainer.train(model, train_loader, val_loader)
    assert history[-1]["train_loss"] < history[0]["train_loss"]
    assert len(history) < 30  # early stopping fired
    results = trainer.test(model, test_loader)
    assert 1.0 < results["ppl"] < 2.0
    assert "per_rank" in results and len(results["per_rank"]["ppl"]) == cfg.positions


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    for step in (1, 2, 3):
        ckpt.save(step, tree, aux={"epoch": step, "global_step": step,
                                   "loader": {"epoch": 0, "step": step}})
    assert ckpt.latest_step() == 3
    # keep=2 garbage-collects step 1
    restored, aux, step = ckpt.restore(like=tree)
    assert step == 3 and aux["epoch"] == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    with pytest.raises(Exception):
        ckpt.restore(step=1, like=tree)


def test_partial_checkpoint_is_ignored(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=3)
    ckpt.save(5, {"x": jnp.zeros(3)})
    # simulate a crash mid-save: directory without COMMIT marker
    bad = tmp_path / "step_0000000009"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    ckpt2 = CheckpointManager(str(tmp_path), keep=3)
    assert ckpt2.latest_step() == 5


def test_resume_is_bit_exact(tmp_path, log_and_loaders):
    cfg, train_loader, val_loader, _ = log_and_loaders
    model = PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                               positions=cfg.positions)

    def run(epochs, ckpt_dir, resume=False, loader_seed=5):
        # fresh loader each run so state starts clean
        loader = ClickLogLoader(train_loader.data, batch_size=256, seed=loader_seed)
        trainer = Trainer(optim.adamw(0.01), epochs=epochs, patience=100,
                          checkpoint_dir=ckpt_dir, log_fn=lambda *_: None)
        trainer.train(model, loader, val_loader=None, resume=resume)
        return trainer._final_state.params

    # uninterrupted 4 epochs
    p_full = run(4, str(tmp_path / "full"))
    # interrupted: 2 epochs, then resume to 4
    run(2, str(tmp_path / "resume"))
    p_resumed = run(4, str(tmp_path / "resume"), resume=True)

    for (ka, a), (kb, b) in zip(
            jax.tree_util.tree_leaves_with_path(p_full),
            jax.tree_util.tree_leaves_with_path(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(ka))


def test_drop_slowest_aggregate():
    g1 = {"w": jnp.ones(3)}
    g2 = {"w": 3 * jnp.ones(3)}
    g3 = {"w": 5 * jnp.ones(3)}
    out = drop_slowest_aggregate([g1, g2, g3], arrived=[True, True, False])
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0)
    with pytest.raises(RuntimeError):
        drop_slowest_aggregate([g1], arrived=[False])


def test_loader_resume_mid_epoch():
    data = {"positions": np.tile(np.arange(1, 5, dtype=np.int32), (100, 1)),
            "query_doc_ids": np.arange(400, dtype=np.int64).reshape(100, 4),
            "clicks": np.zeros((100, 4), np.float32),
            "mask": np.ones((100, 4), bool)}
    l1 = ClickLogLoader(data, batch_size=10, seed=3)
    seen_first = [b["query_doc_ids"][0, 0] for b in iter(l1)]
    # replay: consume 4 batches, checkpoint, restore into a new loader
    l2 = ClickLogLoader(data, batch_size=10, seed=3)
    it = iter(l2)
    for _ in range(4):
        next(it)
    state = l2.state_dict()
    l3 = ClickLogLoader(data, batch_size=10, seed=3)
    l3.load_state_dict(state)
    rest = [b["query_doc_ids"][0, 0] for b in iter(l3)]
    assert rest == seen_first[4:]


def test_loader_host_sharding_disjoint():
    data = {"positions": np.tile(np.arange(1, 3, dtype=np.int32), (64, 1)),
            "query_doc_ids": np.arange(128, dtype=np.int64).reshape(64, 2),
            "clicks": np.zeros((64, 2), np.float32),
            "mask": np.ones((64, 2), bool)}
    shards = [ClickLogLoader(data, batch_size=8, shuffle=False,
                             host_id=i, host_count=4) for i in range(4)]
    ids = [set(l.data["query_doc_ids"].reshape(-1).tolist()) for l in shards]
    for i in range(4):
        for j in range(i + 1, 4):
            assert not (ids[i] & ids[j])
    assert len(set().union(*ids)) == 128


def test_split_sessions_partitions_exactly():
    # 25 * (0.8, 0.1, 0.1) rounds to 20 + 2 + 2 = 24: the old per-fraction
    # rounding silently dropped a tail session. The last split must take the
    # exact remainder and the splits must partition the input.
    for n, fractions in [(25, (0.8, 0.1, 0.1)), (10, (1 / 3, 1 / 3, 1 / 3)),
                         (7, (0.5, 0.25, 0.25)), (5, (0.9, 0.05, 0.05))]:
        data = {"positions": np.tile(np.arange(1, 3, dtype=np.int32), (n, 1)),
                "query_doc_ids": np.arange(n, dtype=np.int64)[:, None]
                * np.ones((1, 2), np.int64)}
        splits = split_sessions(data, fractions, seed=1)
        sizes = [s["positions"].shape[0] for s in splits]
        assert sum(sizes) == n, (n, fractions, sizes)
        ids = [set(s["query_doc_ids"][:, 0].tolist()) for s in splits]
        assert not (ids[0] & ids[1] or ids[0] & ids[2] or ids[1] & ids[2])
        assert set().union(*ids) == set(range(n))


def _tiny_log(n=103, k=4):
    return {"positions": np.tile(np.arange(1, k + 1, dtype=np.int32), (n, 1)),
            "query_doc_ids": np.arange(n * k, dtype=np.int64).reshape(n, k),
            "clicks": np.zeros((n, k), np.float32),
            "mask": np.ones((n, k), bool)}


def test_loader_drop_last_false_final_partial_batch():
    data = _tiny_log(n=103)
    loader = ClickLogLoader(data, batch_size=10, seed=2, drop_last=False)
    assert loader.batches_per_epoch == 11
    batches = list(iter(loader))
    assert [b["clicks"].shape[0] for b in batches] == [10] * 10 + [3]
    for b in batches:
        assert b["query_doc_ids"].shape[1:] == (4,)
    seen = np.concatenate([b["query_doc_ids"][:, 0] for b in batches])
    assert len(set(seen.tolist())) == 103  # every session exactly once


def test_loader_drop_last_false_prefetcher_resume_bit_exact():
    """Mid-epoch resume through DevicePrefetcher while the final partial
    batch is in flight inside the prefetch queue."""
    from repro.data import DevicePrefetcher

    data = _tiny_log(n=103)
    mk = lambda: ClickLogLoader(data, batch_size=10, seed=2, drop_last=False)
    recorded = list(DevicePrefetcher(mk(), size=3))
    assert len(recorded) == 11
    # resume from batch 9: the partial batch 11 was already prefetched when
    # batch 9's state was recorded (loader ran ahead by the prefetch depth)
    state = recorded[8][1]
    resumed = mk()
    resumed.load_state_dict(state)
    rest = list(iter(resumed))
    assert [b["clicks"].shape[0] for b in rest] == [10, 3]
    for want, got in zip(recorded[9:], rest):
        for k in got:
            np.testing.assert_array_equal(np.asarray(want[0][k]),
                                          np.asarray(got[k]), err_msg=k)
