"""Dispatch-registry tests: resolution order, env/flag overrides, the
no-retrace guarantee for compiled programs, and loss-parity pins showing the
CTR family, the chain family, and a recsys model all train through the
dispatch layer with per-step losses matching the pre-refactor compositions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels, optim
from repro.core import MODEL_REGISTRY, DocumentCTR
from repro.core.base import masked_mean
from repro.kernels import dispatch, ref
from repro.stable import log_bce
from repro.train import TrainEngine

IMPLS = dispatch.IMPLS
K, B, N_DOCS = 5, 16, 40


@pytest.fixture(autouse=True)
def _clean_overrides():
    """No test leaks programmatic overrides into the rest of the suite."""
    saved = dict(dispatch._OVERRIDES)
    yield
    dispatch._OVERRIDES.clear()
    dispatch._OVERRIDES.update(saved)


def make_batch(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "positions": jnp.asarray(np.tile(np.arange(1, K + 1), (B, 1)), jnp.int32),
        "query_doc_ids": jnp.asarray(rng.integers(0, N_DOCS, (B, K))),
        "clicks": jnp.asarray(rng.integers(0, 2, (B, K)).astype(np.float32)),
        "mask": jnp.asarray(np.arange(K)[None, :]
                            < rng.integers(2, K + 1, (B, 1))),
    }


# ---------------------------------------------------------------------------
# resolution order
# ---------------------------------------------------------------------------

def test_backend_default():
    want = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert dispatch.default_impl() == want
    for name in dispatch.registered_kernels():
        assert dispatch.resolve_impl(name) == want


def test_explicit_impl_beats_everything(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_GLOBAL, "xla")
    with dispatch.override_impl("xla", session_nll="xla"):
        assert dispatch.resolve_impl("session_nll", "ref") == "ref"


def test_per_kernel_override_beats_global_override():
    with dispatch.override_impl("xla", session_nll="ref"):
        assert dispatch.resolve_impl("session_nll") == "ref"
        assert dispatch.resolve_impl("embedding_bag") == "xla"


def test_env_global_and_per_kernel(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_GLOBAL, "ref")
    assert dispatch.resolve_impl("session_nll") == "ref"
    assert dispatch.resolve_impl("fm_interaction") == "ref"
    # per-kernel env var beats the global one
    monkeypatch.setenv("CLAX_KERNEL_IMPL_SESSION_NLL", "pallas")
    assert dispatch.resolve_impl("session_nll") == "pallas"
    assert dispatch.resolve_impl("fm_interaction") == "ref"
    # programmatic override beats both env vars
    with dispatch.override_impl(session_nll="xla"):
        assert dispatch.resolve_impl("session_nll") == "xla"
    assert dispatch.resolve_impl("session_nll") == "pallas"


def test_override_impl_restores_on_exit_and_on_error():
    base = dispatch.resolve_impl("session_nll")
    with pytest.raises(RuntimeError):
        with dispatch.override_impl("ref"):
            assert dispatch.resolve_impl("session_nll") == "ref"
            raise RuntimeError("boom")
    assert dispatch.resolve_impl("session_nll") == base


def test_set_impl_override_none_clears():
    dispatch.set_impl_override("ref", kernel="session_nll")
    assert dispatch.resolve_impl("session_nll") == "ref"
    dispatch.set_impl_override(None, kernel="session_nll")
    assert dispatch.resolve_impl("session_nll") == dispatch.default_impl()


def test_unknown_kernel_and_impl_errors():
    with pytest.raises(KeyError, match="unknown kernel"):
        dispatch.resolve_impl("not_a_kernel")
    with pytest.raises(ValueError, match="impl must be one of"):
        dispatch.set_impl_override("cuda")
    with pytest.raises(ValueError, match="no impl"):
        dispatch.resolve_impl("session_nll", "not_an_impl")


def test_dispatch_invokes_resolved_callable():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)
    c = jnp.asarray(rng.integers(0, 2, (4, 6)), jnp.float32)
    m = jnp.ones((4, 6), bool)
    got = dispatch.dispatch("session_nll", "ref", x, c, m)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.session_nll_ref(x, c, m)))
    assert dispatch.get_impl("session_nll", "ref") is ref.session_nll_ref


# ---------------------------------------------------------------------------
# the no-retrace guarantee
# ---------------------------------------------------------------------------

def test_impl_flip_does_not_retrace_compiled_engine_chunk():
    """Overrides resolve at trace time: flipping one after the TrainEngine's
    scan-jitted chunk step has compiled must NOT retrace it (drill semantics:
    flip the env var, restart the job). A Python-side counter inside the loss
    closure counts traces — jit cache hits never re-enter Python."""
    traces = []

    def loss_fn(params, batch):
        traces.append(dispatch.resolve_impl("session_nll"))
        return kernels.session_nll(params["w"] * batch["x"],
                                   batch["clicks"], batch["mask"])

    engine = TrainEngine(None, optim.adamw(0.05), chunk_batches=2,
                         loss_fn=loss_fn)
    params = {"w": jnp.ones((), jnp.float32)}
    opt_state = engine.init_opt_state(params)
    rng = np.random.default_rng(0)

    def chunk():
        return {"x": jnp.asarray(rng.normal(size=(2, 8, K)), jnp.float32),
                "clicks": jnp.asarray(rng.integers(0, 2, (2, 8, K)),
                                      jnp.float32),
                "mask": jnp.ones((2, 8, K), bool)}

    params, opt_state, losses = engine.step(params, opt_state, chunk())
    assert traces and set(traces) == {dispatch.default_impl()}
    n_traces = len(traces)

    with dispatch.override_impl("ref"):
        # a fresh trace would resolve to "ref" ...
        assert dispatch.resolve_impl("session_nll") == "ref"
        params, opt_state, losses = engine.step(params, opt_state, chunk())
    # ... but the compiled chunk step never re-entered Python.
    assert len(traces) == n_traces
    assert np.all(np.isfinite(np.asarray(losses)))


# ---------------------------------------------------------------------------
# loss parity with the pre-refactor paths (acceptance pins)
# ---------------------------------------------------------------------------

def _pre_refactor_loss(model):
    """The PR 1 composition ``compute_loss`` replaced: per-position log-probs
    through ``log_bce`` and a masked mean — no fused kernels, no dispatch."""
    def loss(params, batch):
        log_probs = model.predict_conditional_clicks(params, batch)
        return masked_mean(log_bce(log_probs, batch["clicks"]), batch["mask"])
    return loss


@pytest.mark.parametrize("name", ["gctr", "rctr", "dctr"])
def test_ctr_compute_loss_matches_pre_refactor_all_impls(name):
    model = MODEL_REGISTRY[name](query_doc_pairs=N_DOCS, positions=K)
    params = model.init(jax.random.PRNGKey(1))
    params = jax.tree_util.tree_map(
        lambda x: x + 0.5 * jax.random.normal(jax.random.PRNGKey(2), x.shape),
        params)
    batch = make_batch(3)
    want = float(_pre_refactor_loss(model)(params, batch))
    for impl in IMPLS:
        with dispatch.override_impl(impl):
            got = float(model.compute_loss(params, batch))
        assert abs(got - want) <= 1e-5, (name, impl, got, want)


@pytest.mark.parametrize("name", ["dcm", "ccm", "dbn", "sdbn"])
def test_chain_compute_loss_matches_pre_refactor_all_impls(name):
    model = MODEL_REGISTRY[name](query_doc_pairs=N_DOCS, positions=K)
    params = model.init(jax.random.PRNGKey(4))
    params = jax.tree_util.tree_map(
        lambda x: x + 0.5 * jax.random.normal(jax.random.PRNGKey(5), x.shape),
        params)
    batch = make_batch(6)
    want = float(_pre_refactor_loss(model)(params, batch))
    for impl in IMPLS:
        with dispatch.override_impl(impl):
            got = float(model.compute_loss(params, batch))
        assert abs(got - want) <= 1e-5, (name, impl, got, want)


def test_ctr_trains_through_dispatch_with_matching_per_step_losses():
    """A DCTR run through the engine's dispatched ``session_nll`` hot path
    reproduces the pre-refactor log-space composition step for step."""
    model = DocumentCTR(query_doc_pairs=N_DOCS, positions=K)
    init = model.init(jax.random.PRNGKey(7))
    rng = np.random.default_rng(8)
    chunks = [jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[make_batch(int(rng.integers(1 << 30)))
                                     for _ in range(2)]) for _ in range(3)]

    def run(loss_fn):
        engine = TrainEngine(model, optim.adamw(0.05), chunk_batches=2,
                             loss_fn=loss_fn)
        params = jax.tree_util.tree_map(jnp.array, init)
        opt_state = engine.init_opt_state(params)
        losses = []
        for chunk in chunks:
            params, opt_state, l = engine.step(params, opt_state, chunk)
            losses.extend(np.asarray(l).tolist())
        return losses

    new = run(None)  # model.compute_loss -> dispatched session_nll
    old = run(_pre_refactor_loss(model))
    np.testing.assert_allclose(new, old, atol=1e-5, rtol=0)


def test_deepfm_trains_through_dispatch_with_matching_per_step_losses():
    """DeepFM's embedding_bag/fm_interaction hot path vs the pre-refactor
    dense-lookup composition: identical per-step losses over a short run."""
    from repro.models.recsys import DeepFM, DeepFMConfig
    from repro.models.recsys.embedding import table_lookup

    cfg = DeepFMConfig(name="d", n_sparse=6, embed_dim=8, mlp=(16,),
                       table_rows=300)
    model = DeepFM(cfg)
    init = model.init(jax.random.PRNGKey(9))
    rng = np.random.default_rng(10)
    batches = [{"field_ids": jnp.asarray(rng.integers(0, 300, (32, 6))),
                "labels": jnp.asarray(rng.integers(0, 2, 32).astype(np.float32))}
               for _ in range(5)]

    def old_loss(params, batch):
        from repro.stable import log_sigmoid
        ids = batch["field_ids"]
        v = table_lookup(cfg.table, params["embedding"], ids)
        first = table_lookup(cfg.first_order_table,
                             params["first_order"], ids)[..., 0]
        fm = ref.fm_interaction_ref(v)
        deep = model.mlp(params["mlp"], v.reshape(v.shape[0], -1))[..., 0]
        logits = params["bias"] + jnp.sum(first, axis=-1) + fm + deep
        return jnp.mean(log_bce(log_sigmoid(logits), batch["labels"]))

    def run(loss_fn):
        tx = optim.adamw(1e-2)
        step = jax.jit(lambda p, o, b: _sgd_step(loss_fn, tx, p, o, b))
        params = jax.tree_util.tree_map(jnp.array, init)
        opt_state = tx.init(params)
        losses = []
        for batch in batches:
            params, opt_state, loss = step(params, opt_state, batch)
            losses.append(float(loss))
        return losses

    new = run(model.loss)  # dispatched embedding_bag + fm_interaction
    old = run(old_loss)
    np.testing.assert_allclose(new, old, atol=1e-5, rtol=0)


def _sgd_step(loss_fn, tx, params, opt_state, batch):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    updates, opt_state = tx.update(grads, opt_state, params)
    return optim.apply_updates(params, updates), opt_state, loss
