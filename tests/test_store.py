"""Session store + streaming loader tests: round-trip, equivalence with the
in-memory loader, bit-exact cursor resume, host sharding, checksums, and
bounded-memory chunked ingestion."""
import json
import os

import numpy as np
import pytest

from repro.data import (ClickLogLoader, DevicePrefetcher, SessionStore,
                        SessionStoreWriter, ShardCorruptionError,
                        StreamingClickLogLoader, SyntheticConfig,
                        generate_click_log, ingest_synthetic,
                        iter_click_log_chunks, write_session_store)


@pytest.fixture(scope="module")
def log():
    cfg = SyntheticConfig(n_sessions=1000, n_queries=25, docs_per_query=12,
                          positions=8, behavior="dbn", seed=13)
    data, _ = generate_click_log(cfg)
    return cfg, data


def batches_equal(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert set(x) == set(y)
        for k in x:
            np.testing.assert_array_equal(np.asarray(x[k]), np.asarray(y[k]),
                                          err_msg=k)


# -- format / round-trip -------------------------------------------------------

def test_roundtrip_bit_exact(tmp_path, log):
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=128)
    assert store.rows == 1000 and store.n_shards == 8
    back = store.read_all()
    assert set(back) == set(data)
    for k in data:
        assert back[k].dtype == data[k].dtype
        np.testing.assert_array_equal(back[k], data[k], err_msg=k)


def test_chunked_append_equals_single_append(tmp_path, log):
    _, data = log
    one = write_session_store(data, str(tmp_path / "one"), shard_rows=300)
    with SessionStoreWriter(str(tmp_path / "many"), shard_rows=300) as w:
        for lo in range(0, 1000, 170):  # chunk size coprime-ish with shard
            w.append({k: v[lo:lo + 170] for k, v in data.items()})
    many = SessionStore(str(tmp_path / "many"))
    a, b = one.read_all(), many.read_all()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_manifest_schema_and_metadata(tmp_path, log):
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=400,
                                metadata={"origin": "test"})
    with open(tmp_path / "s" / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["rows"] == 1000
    assert [s["rows"] for s in manifest["shards"]] == [400, 400, 200]
    assert manifest["columns"]["clicks"]["dtype"] == "<f4"
    assert manifest["columns"]["clicks"]["shape"] == [8]
    assert manifest["metadata"]["origin"] == "test"
    # memmapped shard is zero-copy read-only
    shard = store.open_shard(0)
    assert isinstance(shard["clicks"], np.memmap)
    with pytest.raises(ValueError):
        shard["clicks"][0, 0] = 1.0


def test_checksum_detects_corruption(tmp_path, log):
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=500)
    store.verify()
    path = tmp_path / "s" / "shard_00001" / "clicks.bin"
    raw = bytearray(path.read_bytes())
    raw[0] ^= 0xFF
    path.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="checksum mismatch"):
        SessionStore(str(tmp_path / "s"), verify=True)


def test_uncommitted_directory_is_not_a_store(tmp_path, log):
    _, data = log
    w = SessionStoreWriter(str(tmp_path / "s"), shard_rows=100)
    w.append(data)  # shards flushed, but no close() -> no manifest
    with pytest.raises(FileNotFoundError):
        SessionStore(str(tmp_path / "s"))


def test_writer_rejects_schema_drift(tmp_path, log):
    _, data = log
    w = SessionStoreWriter(str(tmp_path / "s"), shard_rows=100)
    w.append(data)
    bad = dict(data, clicks=data["clicks"].astype(np.float64))
    with pytest.raises(ValueError, match="dtype"):
        w.append(bad)
    ragged = dict(data)
    ragged["clicks"] = data["clicks"][:10]
    with pytest.raises(ValueError, match="ragged"):
        w.append(ragged)
    extra = dict(data, surprise=np.zeros((1000, 2), np.float32))
    with pytest.raises(KeyError, match="absent from the schema"):
        w.append(extra)


def test_reingest_invalidates_stale_manifest(tmp_path, log):
    """Opening a writer over a committed store must drop the old manifest,
    so a crash mid-rewrite can't serve old metadata over new shard bytes."""
    _, data = log
    write_session_store(data, str(tmp_path / "s"), shard_rows=400)
    w = SessionStoreWriter(str(tmp_path / "s"), shard_rows=300)
    w.append(data)  # crash before close(): no manifest, not a store
    with pytest.raises(FileNotFoundError):
        SessionStore(str(tmp_path / "s"))
    w.close()
    assert SessionStore(str(tmp_path / "s")).rows == 1000


def test_truncated_shard_file_detected_on_open(tmp_path, log):
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=500)
    path = tmp_path / "s" / "shard_00000" / "clicks.bin"
    path.write_bytes(path.read_bytes()[:-8])
    with pytest.raises(ValueError, match="truncated or mismatched"):
        store.open_shard(0)


# -- format v2: per-column compression + v1 compat -----------------------------

def test_raw_store_bytes_are_the_v1_format(tmp_path, log):
    """codec='raw' (the default) stores each column's exact array bytes —
    the v1 on-disk format — so raw v2 stores are byte-compatible with v1."""
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=400)
    raw = (tmp_path / "s" / "shard_00000" / "clicks.bin").read_bytes()
    assert raw == data["clicks"][:400].tobytes()
    for i in range(store.n_shards):
        for col in store.columns:
            assert store.shard_codec(i, col) == "raw"


def test_v1_manifest_reads_unchanged(tmp_path, log):
    """A v1 store (format_version=1, no codec/nbytes fields) opens,
    verifies, and reads bit-for-bit through the v2 reader."""
    _, data = log
    write_session_store(data, str(tmp_path / "s"), shard_rows=300)
    mpath = tmp_path / "s" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["format_version"] = 1
    for shard in manifest["shards"]:
        del shard["codecs"]
        del shard["nbytes"]
    mpath.write_text(json.dumps(manifest))
    store = SessionStore(str(tmp_path / "s"), verify=True)
    assert store.shard_codec(0, "clicks") == "raw"
    assert isinstance(store.open_shard(0)["clicks"], np.memmap)
    back = store.read_all()
    for k in data:
        np.testing.assert_array_equal(back[k], data[k], err_msg=k)
    # stored size falls back to rows * row_nbytes manifest arithmetic
    assert (store.shard_stored_nbytes(0, "clicks")
            == 300 * store.columns["clicks"].row_nbytes)


def test_unreadable_format_version_rejected(tmp_path, log):
    _, data = log
    write_session_store(data, str(tmp_path / "s"), shard_rows=500)
    mpath = tmp_path / "s" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["format_version"] = 99
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="format_version"):
        SessionStore(str(tmp_path / "s"))


def test_auto_codec_roundtrip_compression_and_verify(tmp_path, log):
    _, data = log
    raw = write_session_store(data, str(tmp_path / "raw"), shard_rows=250)
    auto = write_session_store(data, str(tmp_path / "auto"), shard_rows=250,
                               codec="auto")
    back = auto.read_all()
    for k in data:
        assert back[k].dtype == data[k].dtype
        np.testing.assert_array_equal(back[k], data[k], err_msg=k)
    auto.verify()  # crc covers the stored (encoded) bytes
    # 0/1 columns bitpack (32x on float32 clicks); overall clears 2x easily
    assert auto.shard_codec(0, "clicks") == "bitpack"
    assert auto.shard_codec(0, "mask") == "bitpack"
    assert auto.stored_nbytes(["clicks"]) * 16 <= raw.stored_nbytes(["clicks"])
    assert auto.stored_nbytes() * 2 <= raw.stored_nbytes()
    # the manifest's nbytes map matches the files on disk
    for i in range(auto.n_shards):
        for col in auto.columns:
            path = tmp_path / "auto" / f"shard_{i:05d}" / f"{col}.bin"
            assert path.stat().st_size == auto.shard_stored_nbytes(i, col)
    # decoded columns are read-only, like the raw memmaps
    with pytest.raises(ValueError):
        auto.open_shard(0)["clicks"][0, 0] = 1.0


def test_compressed_shard_corruption_fails_closed(tmp_path, log):
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=250,
                                codec="auto")
    col = next(c for c in store.columns
               if store.shard_codec(1, c) == "zlib")
    path = tmp_path / "s" / "shard_00001" / f"{col}.bin"
    blob = bytearray(path.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    path.write_bytes(bytes(blob))
    # crc over the stored bytes catches the flip without decoding
    with pytest.raises(ValueError, match="checksum mismatch"):
        store.verify(1)
    # same size, bad stream: the decode itself fails closed on open
    with pytest.raises(ShardCorruptionError):
        store.open_shard(1)
    # truncation is caught by the stored-size check before any decode
    path.write_bytes(bytes(blob[:-5]))
    with pytest.raises(ValueError, match="truncated or mismatched"):
        store.open_shard(1)


# -- chunked synthesis ---------------------------------------------------------

def test_iter_click_log_chunks_deterministic_and_complete(log):
    cfg, _ = log
    chunks = list(iter_click_log_chunks(cfg, 300))
    assert [c["clicks"].shape[0] for c in chunks] == [300, 300, 300, 100]
    again = list(iter_click_log_chunks(cfg, 300))
    for a, b in zip(chunks, again):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    # ground truth tables are shared with the monolithic path: session-level
    # samples differ, but the true-attractiveness values live on the same grid
    mono = generate_click_log(cfg)[0]
    assert set(chunks[0]) == set(mono)


def test_ingest_split_partitions_log(tmp_path, log):
    cfg, _ = log
    stores = ingest_synthetic(cfg, str(tmp_path), chunk_sessions=150,
                              shard_rows=200,
                              splits={"train": 0.8, "val": 0.1, "test": 0.1})
    assert sum(s.rows for s in stores.values()) == cfg.n_sessions
    assert stores["train"].rows > stores["val"].rows
    assert stores["train"].metadata["synthetic_config"]["n_queries"] == cfg.n_queries
    # deterministic: same seed re-ingests identically
    again = ingest_synthetic(cfg, str(tmp_path / "again"), chunk_sessions=150,
                             shard_rows=200,
                             splits={"train": 0.8, "val": 0.1, "test": 0.1})
    for name in stores:
        a, b = stores[name].read_all(), again[name].read_all()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=(name, k))


# -- streaming loader ----------------------------------------------------------

@pytest.mark.parametrize("shuffle", [True, False])
@pytest.mark.parametrize("drop_last", [True, False])
def test_single_shard_stream_equals_in_memory_loader(tmp_path, log, shuffle,
                                                     drop_last):
    """Acceptance: single shard + same seed => identical batch stream."""
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=10_000)
    mem = ClickLogLoader(data, batch_size=96, shuffle=shuffle, seed=5,
                         drop_last=drop_last)
    stream = StreamingClickLogLoader(store, batch_size=96, shuffle=shuffle,
                                     seed=5, drop_last=drop_last)
    assert stream.batches_per_epoch == mem.batches_per_epoch
    for _ in range(2):  # epochs shuffle differently but stay in lockstep
        batches_equal(list(iter(mem)), list(iter(stream)))


def test_multi_shard_unshuffled_stream_matches_row_order(tmp_path, log):
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=128)
    mem = ClickLogLoader(data, batch_size=100, shuffle=False, seed=0)
    stream = StreamingClickLogLoader(store, batch_size=100, shuffle=False,
                                     seed=0, read_ahead=3)
    batches_equal(list(iter(mem)), list(iter(stream)))


@pytest.mark.parametrize("window_rows", [None, 64])
def test_multi_shard_shuffle_covers_every_session_once(tmp_path, log,
                                                       window_rows):
    _, data = log
    data = dict(data, session_uid=np.arange(1000, dtype=np.int64)[:, None]
                * np.ones((1, 8), np.int64))
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=256)
    stream = StreamingClickLogLoader(
        store, batch_size=125, seed=9, window_rows=window_rows,
        include_keys=("session_uid", "clicks"))
    seen = np.concatenate([b["session_uid"][:, 0] for b in iter(stream)])
    assert len(seen) == 1000
    np.testing.assert_array_equal(np.sort(seen), np.arange(1000))
    # different epochs produce different orders (two-level shuffle advances)
    seen2 = np.concatenate([b["session_uid"][:, 0] for b in iter(stream)])
    assert not np.array_equal(seen, seen2)
    np.testing.assert_array_equal(np.sort(seen2), np.arange(1000))


@pytest.mark.parametrize("read_ahead", [0, 2])
@pytest.mark.parametrize("window_rows", [None, 100])
def test_mid_epoch_cursor_resume_bit_exact(tmp_path, log, read_ahead,
                                           window_rows):
    """Acceptance: checkpoint/restore of (epoch, shard, step) resumes the
    exact remaining batch stream."""
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=300)
    mk = lambda: StreamingClickLogLoader(store, batch_size=64, seed=3,
                                         drop_last=False,
                                         window_rows=window_rows,
                                         read_ahead=read_ahead)
    full = list(iter(mk()))
    loader = mk()
    it = iter(loader)
    for _ in range(7):
        next(it)
    cursor = loader.state_dict()
    assert set(cursor) == {"epoch", "step", "shard"}
    resumed = mk()
    resumed.load_state_dict(json.loads(json.dumps(cursor)))  # survives JSON
    batches_equal(full[7:], list(iter(resumed)))


def test_stream_epoch_rollover_and_epochs_helper(tmp_path, log):
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=400)
    loader = StreamingClickLogLoader(store, batch_size=100, seed=1)
    n = sum(1 for _ in loader.epochs(3))
    assert n == 3 * loader.batches_per_epoch
    assert loader.state.epoch == 3 and loader.state.step == 0


def test_stream_through_device_prefetcher_resume(tmp_path, log):
    """The streaming loader plugs into DevicePrefetcher; the recorded
    per-batch state is the bit-exact resume point even though the loader
    runs ahead by the prefetch depth."""
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=300)
    mk = lambda: StreamingClickLogLoader(store, batch_size=64, seed=3,
                                         drop_last=False)
    recorded = list(DevicePrefetcher(mk(), size=3))
    batches = [b for b, _ in recorded]
    state_at_5 = recorded[4][1]
    resumed = mk()
    resumed.load_state_dict(state_at_5)
    rest = [{k: np.asarray(v) for k, v in b.items()} for b in iter(resumed)]
    batches_equal([{k: np.asarray(v) for k, v in b.items()}
                   for b in batches[5:]], rest)


def test_host_sharding_at_shard_granularity(tmp_path):
    data = {"positions": np.tile(np.arange(1, 3, dtype=np.int32), (64, 1)),
            "query_doc_ids": np.arange(128, dtype=np.int64).reshape(64, 2),
            "clicks": np.zeros((64, 2), np.float32),
            "mask": np.ones((64, 2), bool)}
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=16)
    loaders = [StreamingClickLogLoader(store, batch_size=8, shuffle=False,
                                       host_id=i, host_count=4)
               for i in range(4)]
    ids = []
    for l in loaders:
        assert l.n == 16  # 1 of 4 shards each
        ids.append(set(np.concatenate(
            [b["query_doc_ids"].reshape(-1) for b in iter(l)]).tolist()))
    for i in range(4):
        for j in range(i + 1, 4):
            assert not (ids[i] & ids[j])
    assert len(set().union(*ids)) == 128
    with pytest.raises(ValueError, match="shard granularity"):
        StreamingClickLogLoader(store, batch_size=8, host_id=0, host_count=5)


def test_unequal_host_shards_stay_in_lockstep(tmp_path):
    """Hosts with unequal row counts (partial last shard) must still agree
    on batches_per_epoch, or pod-scale collectives desync."""
    n = 64
    data = {"positions": np.tile(np.arange(1, 3, dtype=np.int32), (n, 1)),
            "query_doc_ids": np.arange(2 * n, dtype=np.int64).reshape(n, 2),
            "clicks": np.zeros((n, 2), np.float32),
            "mask": np.ones((n, 2), bool)}
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=24)
    assert [store.shard_rows(i) for i in range(3)] == [24, 24, 16]
    loaders = [StreamingClickLogLoader(store, batch_size=8, seed=2,
                                       host_id=i, host_count=3)
               for i in range(3)]
    assert [l.n for l in loaders] == [24, 24, 16]  # unequal placement...
    assert {l.batches_per_epoch for l in loaders} == {2}  # ...equal steps
    for l in loaders:
        assert len(list(iter(l))) == 2
    with pytest.raises(ValueError, match="drop_last"):
        StreamingClickLogLoader(store, batch_size=8, drop_last=False,
                                host_id=0, host_count=3)
    # a host with surplus rows must not read shards past the epoch's step
    # cap (2 batches * 8 rows fit entirely in its first 24-row shard)
    surplus = loaders[0]
    opened = []
    real = store.open_shard
    store.open_shard = lambda i, **kw: (opened.append(i), real(i, **kw))[1]
    try:
        assert len(list(iter(surplus))) == 2
    finally:
        store.open_shard = real
    assert set(opened) <= {0}


def test_read_ahead_failure_propagates(tmp_path, log):
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=300)
    loader = StreamingClickLogLoader(store, batch_size=64, seed=0,
                                     read_ahead=2)
    os.remove(tmp_path / "s" / "shard_00002" / "clicks.bin")
    with pytest.raises(FileNotFoundError):
        list(iter(loader))


# -- overlapped device prefetch ------------------------------------------------

def test_prefetcher_overlap_matches_inline(tmp_path, log):
    """overlap=True (staging thread) must yield the identical item stream —
    payloads, resume states, chunk counts — as the inline overlap=False
    path, in both batch and chunk modes."""
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=300)
    mk = lambda: StreamingClickLogLoader(store, batch_size=64, seed=3,
                                         drop_last=False)
    inline = list(DevicePrefetcher(mk(), size=3, overlap=False))
    staged = list(DevicePrefetcher(mk(), size=3, overlap=True))
    assert [s for _, s in inline] == [s for _, s in staged]
    batches_equal([{k: np.asarray(v) for k, v in b.items()}
                   for b, _ in inline],
                  [{k: np.asarray(v) for k, v in b.items()}
                   for b, _ in staged])
    inline_c = list(DevicePrefetcher(mk(), size=2, chunk_batches=4,
                                     overlap=False))
    staged_c = list(DevicePrefetcher(mk(), size=2, chunk_batches=4,
                                     overlap=True))
    assert [(s, n) for _, s, n in inline_c] == \
        [(s, n) for _, s, n in staged_c]
    batches_equal([{k: np.asarray(v) for k, v in c.items()}
                   for c, _, _ in inline_c],
                  [{k: np.asarray(v) for k, v in c.items()}
                   for c, _, _ in staged_c])


def test_prefetcher_overlap_propagates_reader_errors(tmp_path, log):
    """A staging-thread failure (missing shard file) re-raises on the
    consumer instead of hanging or truncating the epoch."""
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=300)
    os.remove(tmp_path / "s" / "shard_00002" / "clicks.bin")
    loader = StreamingClickLogLoader(store, batch_size=64, seed=0,
                                     read_ahead=2)
    with pytest.raises(FileNotFoundError):
        list(DevicePrefetcher(loader, size=2))


def test_prefetcher_overlap_abandoned_mid_epoch_shuts_down(tmp_path, log):
    """Breaking out of an overlapped iteration must unwind the staging
    thread and the loader's read-ahead machinery promptly."""
    import threading
    import time
    _, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=300)
    loader = StreamingClickLogLoader(store, batch_size=64, seed=3)
    it = iter(DevicePrefetcher(loader, size=2))
    next(it)
    next(it)
    it.close()  # generator finally: stop + join the staging thread
    deadline = time.time() + 5.0
    while time.time() < deadline:
        leaked = [t for t in threading.enumerate()
                  if t.name in ("device-prefetch", "store-read-ahead")
                  and t.is_alive()]
        if not leaked:
            break
        time.sleep(0.02)
    assert not leaked, "prefetch threads leaked after iterator abandonment"


def test_stream_trains_identically_to_in_memory(tmp_path, log):
    """Same data, same seeds: a Trainer fed by the streaming loader must
    produce bit-identical params to one fed by ClickLogLoader."""
    import jax
    from repro import optim
    from repro.core import PositionBasedModel
    from repro.train import Trainer

    cfg, data = log
    store = write_session_store(data, str(tmp_path / "s"), shard_rows=10_000)
    model = PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                               positions=cfg.positions)

    def run(loader):
        trainer = Trainer(optim.adamw(0.01), epochs=2, patience=100,
                          log_fn=lambda *_: None)
        trainer.train(model, loader)
        return trainer._final_state.params

    p_mem = run(ClickLogLoader(data, batch_size=128, seed=4))
    p_stream = run(StreamingClickLogLoader(store, batch_size=128, seed=4))
    for (ka, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(p_mem),
            jax.tree_util.tree_leaves_with_path(p_stream)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(ka))
