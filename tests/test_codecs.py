"""Storage codec unit tests: exact round-trips, deterministic auto choice,
and fail-closed decode on corrupt or mis-sized streams."""
import numpy as np
import pytest

from repro.data import codecs


def roundtrip(codec, arr):
    stored = codecs.encode(codec, arr)
    back = codecs.decode(codec, stored, arr.dtype, arr.shape)
    assert back.dtype == arr.dtype and back.shape == arr.shape
    np.testing.assert_array_equal(back, arr)
    return stored


@pytest.mark.parametrize("dtype", [np.bool_, np.float32, np.int64])
def test_bitpack_roundtrip_and_size(dtype):
    arr = (np.random.default_rng(0).random((37, 5)) < 0.3).astype(dtype)
    stored = roundtrip("bitpack", arr)
    assert len(stored) == -(-arr.size // 8)  # 1 bit/elem: 32x on float32


def test_bitpack_refuses_lossy_input():
    with pytest.raises(ValueError, match="0 or 1"):
        codecs.encode("bitpack", np.array([0.0, 0.5, 1.0], np.float32))


def test_zlib_roundtrip():
    arr = (np.arange(500, dtype=np.int64) % 7).reshape(100, 5)
    stored = roundtrip("zlib", arr)
    assert len(stored) < arr.nbytes


def test_raw_roundtrip_is_array_bytes():
    arr = np.random.default_rng(1).standard_normal((50, 3)).astype(np.float32)
    assert roundtrip("raw", arr) == arr.tobytes()


def test_is_binary():
    assert codecs.is_binary(np.zeros(4, np.bool_))
    assert codecs.is_binary(np.array([0.0, 1.0], np.float32))
    assert codecs.is_binary(np.array([0, 1, 1], np.int64))
    assert not codecs.is_binary(np.array([0.0, 0.5], np.float32))
    assert not codecs.is_binary(np.array([0, 2], np.int64))
    assert not codecs.is_binary(np.array(["0", "1"]))


def test_unknown_codec_rejected():
    with pytest.raises(ValueError, match="unknown codec"):
        codecs.encode("zstd", np.zeros(3, np.float32))
    with pytest.raises(ValueError, match="unknown codec"):
        codecs.decode("zstd", b"", np.float32, (3,))


def test_decode_fails_closed_on_mis_sized_streams():
    arr = np.ones((8, 4), np.float32)
    with pytest.raises(ValueError, match="elements"):
        codecs.decode("raw", codecs.encode("raw", arr), np.float32, (9, 4))
    with pytest.raises(ValueError, match="bytes"):
        codecs.decode("bitpack", codecs.encode("bitpack", arr) + b"\x00",
                      np.float32, (8, 4))
    z = codecs.encode("zlib", np.arange(32, dtype=np.int64))
    with pytest.raises(ValueError, match="elements"):
        codecs.decode("zlib", z, np.int64, (33,))


def test_zlib_corrupt_stream_fails_closed():
    blob = bytearray(codecs.encode("zlib", np.arange(1000, dtype=np.int64)))
    blob[len(blob) // 2] ^= 0xFF
    with pytest.raises(ValueError, match="corrupt|elements"):
        codecs.decode("zlib", bytes(blob), np.int64, (1000,))


def test_encode_auto_choices_roundtrip_and_determinism():
    binary = (np.random.default_rng(2).random((64, 8)) < .5).astype(np.float32)
    reps = np.tile(np.arange(1, 9, dtype=np.int32), (64, 1))
    noise = np.random.default_rng(3).integers(0, 2 ** 62, size=256)
    picks = {}
    for arr in (binary, reps, noise):
        codec, stored = codecs.encode_auto(arr)
        picks[id(arr)] = codec
        # chosen encoding is exact and deterministic in the column bytes
        np.testing.assert_array_equal(
            codecs.decode(codec, stored, arr.dtype, arr.shape), arr)
        assert codecs.encode_auto(arr) == (codec, stored)
    assert picks[id(binary)] == "bitpack"  # exact 1-bit packing wins
    assert picks[id(reps)] == "zlib"       # repetitive non-binary: DEFLATE
    assert picks[id(noise)] == "raw"       # incompressible: keep memmap path


def test_encode_auto_zlib_acceptance_threshold():
    # zlib is only chosen when it clears the acceptance ratio
    reps = np.tile(np.arange(1, 9, dtype=np.int32), (64, 1))
    _, stored = codecs.encode_auto(reps)
    assert len(stored) <= codecs.ZLIB_ACCEPT * reps.nbytes
