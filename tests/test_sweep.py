"""Vmapped multi-replica sweep engine tests: replicas=None bit-exactness,
vmapped-vs-sequential parity (params, losses, val metrics, early stopping),
active-mask freezing, stacked checkpoints + select_replica, injected-lr
plumbing, chunked scanned evaluation, and the LRU eval cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import PositionBasedModel
from repro.data import (ClickLogLoader, DevicePrefetcher, SyntheticConfig,
                        generate_click_log, split_sessions)
from repro.train import Trainer, TrainEngine, select_replica, stack_replicas


@pytest.fixture(scope="module")
def pbm_log():
    cfg = SyntheticConfig(n_sessions=2200, n_queries=25, docs_per_query=12,
                          positions=6, behavior="pbm", seed=13)
    data, _ = generate_click_log(cfg)
    train, val, _ = split_sessions(data, (0.8, 0.1, 0.1), seed=0)
    return cfg, train, val


def _model(cfg):
    return PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                              positions=cfg.positions, init_prob=0.2)


def _copy(tree):
    return jax.tree_util.tree_map(lambda x: jnp.array(np.asarray(x)), tree)


def _assert_trees_equal(a, b, msg=""):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert len(la) == len(lb)
    for (ka, va), (_, vb) in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                      err_msg=f"{msg}{ka}")


def _assert_trees_close(a, b, atol=1e-5, msg=""):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert len(la) == len(lb)
    for (ka, va), (_, vb) in zip(la, lb):
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb), atol=atol,
                                   err_msg=f"{msg}{ka}")


def _sequential_engine_run(cfg, data, *, seed, lr, epochs, chunk=4,
                           batch_size=256):
    model = _model(cfg)
    engine = TrainEngine(model, optim.adamw(lr), chunk_batches=chunk)
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = engine.init_opt_state(params)
    loader = ClickLogLoader(data, batch_size=batch_size, seed=5)
    losses = []
    for _ in range(epochs):
        for chunk_arr, _, _ in DevicePrefetcher(loader, chunk_batches=chunk):
            params, opt_state, l = engine.step(params, opt_state, chunk_arr)
            losses.extend(np.asarray(l).tolist())
    return params, opt_state, losses


# ---------------------------------------------------------------------------
# replicas=None regression: the new code path must be byte-for-byte PR 4.
# ---------------------------------------------------------------------------

def test_no_replica_path_bitexact_with_per_batch_loop(pbm_log):
    """TrainEngine(replicas=None) — the default — must still reproduce the
    historical per-batch loop bit-for-bit (the PR-4 guarantee; the heavier
    chunk-shape matrix lives in tests/test_engine.py)."""
    cfg, train, _ = pbm_log
    model = _model(cfg)
    tx = optim.adamw(0.05)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = tx.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.compute_loss)(params, batch)
        updates, opt_state = tx.update(grads, opt_state, params)
        return optim.apply_updates(params, updates), opt_state, loss

    loader = ClickLogLoader(train, batch_size=256, seed=5)
    ref_losses = []
    for batch in iter(loader):
        batch = {k: jax.device_put(v) for k, v in batch.items()}
        params, opt_state, loss = step(params, opt_state, batch)
        ref_losses.append(float(loss))

    p, o, losses = _sequential_engine_run(cfg, train, seed=0, lr=0.05,
                                          epochs=1)
    assert [float(x) for x in losses] == ref_losses
    _assert_trees_equal(params, p, msg="params ")
    _assert_trees_equal(opt_state, o, msg="opt_state ")


def test_no_replica_step_rejects_active_mask(pbm_log):
    cfg, train, _ = pbm_log
    model = _model(cfg)
    engine = TrainEngine(model, optim.adamw(0.05))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = engine.init_opt_state(params)
    loader = ClickLogLoader(train, batch_size=256, seed=5)
    chunk, _, _ = next(iter(DevicePrefetcher(loader, chunk_batches=2)))
    with pytest.raises(ValueError, match="active"):
        engine.step(params, opt_state, chunk, active=jnp.ones((1,), bool))


# ---------------------------------------------------------------------------
# Vmapped sweep vs sequential runs: per-replica parity.
# ---------------------------------------------------------------------------

SEEDS = [0, 7, 13, 21]
LRS = [0.05, 0.02, 0.08, 0.05]


def test_vmapped_sweep_matches_sequential_runs(pbm_log):
    """Replica i of an R=4 vmapped sweep (distinct seeds AND lrs) must match
    the sequential engine run with the same seed/lr to <=1e-5 on final
    params and the full per-step loss history (vmap batching may legally
    change BLAS reduction order, so not bit-exact)."""
    cfg, train, _ = pbm_log
    model = _model(cfg)
    engine = TrainEngine(model, optim.adamw(0.99, inject_lr=True),
                         chunk_batches=4, replicas=4)
    params = engine.init_replica_params(SEEDS)
    opt_state = engine.init_opt_state(params)
    opt_state = engine.set_replica_lrs(opt_state, LRS)
    loader = ClickLogLoader(train, batch_size=256, seed=5)
    losses = []
    for _ in range(2):
        for chunk_arr, _, n in DevicePrefetcher(loader, chunk_batches=4):
            params, opt_state, l = engine.step(params, opt_state, chunk_arr)
            assert l.shape == (n, 4)
            losses.append(np.asarray(l))
    losses = np.concatenate(losses, axis=0)

    for i, (seed, lr) in enumerate(zip(SEEDS, LRS)):
        p_seq, _, l_seq = _sequential_engine_run(cfg, train, seed=seed, lr=lr,
                                                 epochs=2)
        _assert_trees_close(p_seq, select_replica(params, i),
                            msg=f"replica {i} ")
        np.testing.assert_allclose(losses[:, i], l_seq, atol=1e-5)


def test_replica_histories_diverge_across_seeds():
    """Distinct init seeds at one shared lr must produce diverging
    per-replica loss histories (the seed-variance study this engine exists
    for). Classic table models init to constants, so seed variance needs a
    neural parameterization — an MLP attraction tower over features."""
    from repro.core import MLPParameterConfig

    cfg = SyntheticConfig(n_sessions=1000, n_queries=20, docs_per_query=10,
                          positions=5, behavior="pbm", seed=3, n_features=8)
    data, _ = generate_click_log(cfg)
    train, _, _ = split_sessions(data, (0.8, 0.1, 0.1), seed=0)
    model = PositionBasedModel(
        positions=cfg.positions,
        attraction=MLPParameterConfig(features=8, hidden=(16,)))
    trainer = Trainer(optim.adamw(0.05), epochs=2, patience=100,
                      log_fn=lambda *_: None, chunk_batches=4, replicas=4,
                      replica_seeds=[0, 1, 2, 3])
    history = trainer.train(model,
                            ClickLogLoader(train, batch_size=128, seed=5))
    first = history[0]["train_loss"]
    assert isinstance(first, list) and len(first) == 4
    assert len(set(first)) == 4, f"replica losses identical: {first}"


# ---------------------------------------------------------------------------
# Per-replica early stopping: freeze-in-place via the active mask.
# ---------------------------------------------------------------------------

def test_sweep_early_stopping_matches_sequential_trainers(pbm_log):
    """Full Trainer parity under per-replica early stopping: a replica that
    runs out of patience freezes in place, and its final params / val
    metrics must match the sequential Trainer run with the same seed/lr —
    including when one replica stops epochs before the other."""
    cfg, train, val = pbm_log
    seeds, lrs = [3, 4], [0.5, 0.01]  # big lr stops early, small keeps going
    epochs, patience = 8, 1
    mk_train = lambda: ClickLogLoader(train, batch_size=256, seed=5)
    mk_val = lambda: ClickLogLoader(val, batch_size=128, shuffle=False,
                                    drop_last=False)

    seq_params, seq_vals, seq_epochs = [], [], []
    for seed, lr in zip(seeds, lrs):
        t = Trainer(optim.adamw(lr), epochs=epochs, patience=patience,
                    seed=seed, log_fn=lambda *_: None, chunk_batches=4)
        h = t.train(_model(cfg), mk_train(), mk_val())
        seq_params.append(t._final_state.params)
        seq_vals.append(h[-1]["val_ll"])
        seq_epochs.append(len(h))

    assert seq_epochs[0] != seq_epochs[1], (
        f"both sequential runs stopped at epoch {seq_epochs[0]}; pick lrs "
        "that early-stop at different epochs to exercise the freeze path")

    sweep = Trainer(optim.adamw(0.99, inject_lr=True), epochs=epochs,
                    patience=patience, log_fn=lambda *_: None,
                    chunk_batches=4, replicas=2, replica_seeds=seeds,
                    replica_lrs=lrs)
    h = sweep.train(_model(cfg), mk_train(), mk_val())
    assert len(h) == max(seq_epochs)  # runs until the last replica stops
    final = sweep._final_state.params
    for i in range(2):
        _assert_trees_close(seq_params[i], select_replica(final, i),
                            msg=f"replica {i} ")
        # the frozen replica's val metric is pinned at its stopping epoch
        np.testing.assert_allclose(h[seq_epochs[i] - 1]["val_ll"][i],
                                   seq_vals[i], atol=1e-5)
        np.testing.assert_allclose(h[-1]["val_ll"][i], seq_vals[i], atol=1e-5)
    # the active mask in the history flips exactly when the early replica
    # stops (records carry the mask the epoch trained under)
    stop_first = min(seq_epochs)
    i_first = seq_epochs.index(stop_first)
    assert h[stop_first - 1]["active"][i_first] is True
    assert h[stop_first]["active"][i_first] is False


def test_sweep_resume_keeps_stopped_replicas_frozen(tmp_path, pbm_log):
    """Early-stop state (active mask, best_val, bad_epochs) rides in the
    checkpoint aux: a sweep resumed after a replica stopped must NOT
    reactivate it — the resumed run matches the uninterrupted one
    bit-for-bit."""
    cfg, train, val = pbm_log
    seeds, lrs = [3, 4], [0.5, 0.01]
    epochs = 8
    mk_train = lambda: ClickLogLoader(train, batch_size=256, seed=5)
    mk_val = lambda: ClickLogLoader(val, batch_size=128, shuffle=False,
                                    drop_last=False)

    def make_trainer(n_epochs, ckpt_dir=None):
        return Trainer(optim.adamw(0.99, inject_lr=True), epochs=n_epochs,
                       patience=1, log_fn=lambda *_: None, chunk_batches=4,
                       replicas=2, replica_seeds=seeds, replica_lrs=lrs,
                       checkpoint_dir=ckpt_dir)

    full = make_trainer(epochs)
    h_full = full.train(_model(cfg), mk_train(), mk_val())
    # first epoch that trained under a partial mask
    stopped_epochs = [r["epoch"] for r in h_full if not all(r["active"])]
    assert stopped_epochs, "no replica stopped — tune lrs"
    e0 = stopped_epochs[0] - 1  # the epoch whose END stopped the replica

    interrupted = make_trainer(e0, ckpt_dir=str(tmp_path / "sweep"))
    interrupted.train(_model(cfg), mk_train(), mk_val())
    resumed = make_trainer(epochs, ckpt_dir=str(tmp_path / "sweep"))
    h_resumed = resumed.train(_model(cfg), mk_train(), mk_val(), resume=True)
    # history is restored from the checkpoint, so the resumed run returns
    # the full record and the stopped replica stays inactive from the first
    # resumed epoch on
    assert len(h_resumed) == len(h_full)
    assert h_resumed[e0]["active"] == h_full[e0]["active"]
    assert [r["active"] for r in h_resumed] == [r["active"] for r in h_full]
    _assert_trees_equal(full._final_state.params,
                        resumed._final_state.params)


# ---------------------------------------------------------------------------
# Stacked checkpoints + select_replica round-trip.
# ---------------------------------------------------------------------------

def test_select_replica_roundtrips_through_checkpoint(tmp_path, pbm_log):
    cfg, train, val = pbm_log
    trainer = Trainer(optim.adamw(0.05), epochs=2, patience=100,
                      log_fn=lambda *_: None, chunk_batches=4, replicas=3,
                      replica_seeds=[0, 1, 2],
                      checkpoint_dir=str(tmp_path / "sweep"))
    trainer.train(_model(cfg), ClickLogLoader(train, batch_size=256, seed=5))
    final = trainer._final_state

    like = {"params": final.params, "opt_state": final.opt_state}
    restored, aux, _ = trainer.ckpt.restore(like=like)
    _assert_trees_equal(like, restored)
    # every replica extracts to a standalone, evaluable tree
    single = Trainer(optim.adamw(0.05), log_fn=lambda *_: None)
    vloader = lambda: ClickLogLoader(val, batch_size=128, shuffle=False,
                                     drop_last=False)
    model = _model(cfg)
    sweep_metrics = trainer.evaluate(model, final.params, vloader(),
                                     replicas=3)
    for i in range(3):
        p_i = select_replica(restored["params"], i)
        for single_leaf, stacked_leaf in zip(
                jax.tree_util.tree_leaves(p_i),
                jax.tree_util.tree_leaves(restored["params"])):
            # replica axis gone: rank drops by exactly one
            assert single_leaf.ndim == stacked_leaf.ndim - 1
            assert single_leaf.shape == stacked_leaf.shape[1:]
        out = single.evaluate(model, p_i, vloader())
        np.testing.assert_allclose(out["ll"], sweep_metrics["ll"][i],
                                   atol=1e-5)
        # the sweep trainer's own test() treats explicit params as a
        # standalone run (the select_replica workflow)
        solo = trainer.test(model, vloader(), params=p_i)
        np.testing.assert_allclose(solo["ll"], sweep_metrics["ll"][i],
                                   atol=1e-5)
    # ...and with no explicit params it reports all replicas
    full = trainer.test(model, vloader())
    assert len(full["ll"]) == 3
    # stack_replicas inverts select_replica
    restacked = stack_replicas([select_replica(restored["params"], i)
                                for i in range(3)])
    _assert_trees_equal(final.params, restacked)


# ---------------------------------------------------------------------------
# Injected-lr plumbing.
# ---------------------------------------------------------------------------

def test_set_replica_lrs_requires_injected_optimizer(pbm_log):
    cfg, _, _ = pbm_log
    model = _model(cfg)
    engine = TrainEngine(model, optim.adamw(0.05), replicas=2)
    params = engine.init_replica_params([0, 1])
    opt_state = engine.init_opt_state(params)
    with pytest.raises(ValueError, match="inject_lr"):
        engine.set_replica_lrs(opt_state, [0.05, 0.01])


def test_injected_lr_matches_static_lr_bit_exact(pbm_log):
    """inject_lr only moves the lr into state — the update math must be
    bit-identical to the static-lr optimizer."""
    cfg, train, _ = pbm_log
    model = _model(cfg)
    batch = next(iter(ClickLogLoader(train, batch_size=256, seed=5)))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    params = model.init(jax.random.PRNGKey(0))
    outs = []
    for tx in (optim.adamw(0.03), optim.adamw(0.03, inject_lr=True)):
        p, o = _copy(params), tx.init(_copy(params))
        for _ in range(3):
            loss, grads = jax.value_and_grad(model.compute_loss)(p, batch)
            updates, o = tx.update(grads, o, p)
            p = optim.apply_updates(p, updates)
        outs.append(p)
    _assert_trees_equal(outs[0], outs[1])


def test_set_injected_lr_on_plain_state_raises():
    tx = optim.adamw(0.05)
    state = tx.init({"w": jnp.ones(3)})
    with pytest.raises(ValueError, match="InjectLRState"):
        optim.set_injected_lr(state, 0.01)
    tx2 = optim.adamw(0.05, inject_lr=True)
    state2 = optim.set_injected_lr(tx2.init({"w": jnp.ones(3)}), 0.01)
    np.testing.assert_allclose(float(optim.get_injected_lr(state2)), 0.01)


def test_replica_lrs_refused_with_sparse_tables(pbm_log):
    cfg, _, _ = pbm_log
    model = _model(cfg)
    engine = TrainEngine(model, optim.adamw(0.05, weight_decay=0.0,
                                            inject_lr=True),
                         replicas=2, sparse_tables=True,
                         sparse_table_kwargs=dict(lr=0.05, weight_decay=0.0))
    params = engine.init_replica_params([0, 1])
    opt_state = engine.init_opt_state(params)
    with pytest.raises(NotImplementedError, match="sparse"):
        engine.set_replica_lrs(opt_state, [0.05, 0.01])


def test_sparse_tables_vmapped_sweep_matches_sequential(pbm_log):
    """Sparse lazy-AdamW segment scatters vmap over the replica axis: an
    R=2 seed sweep with sparse tables matches two sequential sparse runs."""
    cfg, train, _ = pbm_log
    kwargs = dict(sparse_tables=True,
                  sparse_table_kwargs=dict(lr=0.05, weight_decay=0.0))
    model = _model(cfg)
    engine = TrainEngine(model, optim.adamw(0.05, weight_decay=0.0),
                         chunk_batches=4, replicas=2, **kwargs)
    params = engine.init_replica_params([0, 9])
    opt_state = engine.init_opt_state(params)
    loader = ClickLogLoader(train, batch_size=256, seed=5)
    for chunk_arr, _, _ in DevicePrefetcher(loader, chunk_batches=4):
        params, opt_state, _ = engine.step(params, opt_state, chunk_arr)

    for i, seed in enumerate([0, 9]):
        m = _model(cfg)
        eng = TrainEngine(m, optim.adamw(0.05, weight_decay=0.0),
                          chunk_batches=4, **kwargs)
        p = m.init(jax.random.PRNGKey(seed))
        o = eng.init_opt_state(p)
        loader = ClickLogLoader(train, batch_size=256, seed=5)
        for chunk_arr, _, _ in DevicePrefetcher(loader, chunk_batches=4):
            p, o, _ = eng.step(p, o, chunk_arr)
        _assert_trees_close(p, select_replica(params, i), msg=f"replica {i} ")


# ---------------------------------------------------------------------------
# Chunked scanned evaluation.
# ---------------------------------------------------------------------------

def test_chunked_eval_matches_per_batch_eval(pbm_log):
    """evaluate() through DevicePrefetcher(chunk_batches=N) + scanned step
    must equal the per-batch path exactly (same accumulation order),
    including the odd-shaped drop_last=False tail flushing into its own
    chunk."""
    cfg, train, val = pbm_log
    model = _model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mk = lambda: ClickLogLoader(val, batch_size=48, shuffle=False,
                                drop_last=False)
    assert mk().batches_per_epoch % 4 != 0  # exercise the partial tail
    per_batch = Trainer(optim.adamw(0.05), log_fn=lambda *_: None,
                        chunk_batches=1)
    chunked = Trainer(optim.adamw(0.05), log_fn=lambda *_: None,
                      chunk_batches=4)
    out_b = per_batch.evaluate(model, params, mk(), per_rank=True)
    out_c = chunked.evaluate(model, params, mk(), per_rank=True)
    assert set(out_b) == set(out_c)
    for k in ("ll", "ppl", "cond_ppl"):
        np.testing.assert_allclose(out_b[k], out_c[k], rtol=1e-6)
        np.testing.assert_allclose(out_b["per_rank"][k], out_c["per_rank"][k],
                                   rtol=1e-6)


def test_chunked_eval_dispatches_once_per_chunk(pbm_log, monkeypatch):
    """The scanned eval step must be called once per chunk, not per batch."""
    cfg, train, val = pbm_log
    model = _model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trainer = Trainer(optim.adamw(0.05), log_fn=lambda *_: None,
                      chunk_batches=4)
    loader = ClickLogLoader(val, batch_size=64, shuffle=False,
                            drop_last=False)
    nb = loader.batches_per_epoch
    metrics, step, chunk_step = trainer._get_eval_step(model, None)
    calls = []

    def counting(params, state, chunk):
        calls.append(int(chunk["positions"].shape[0]))
        return chunk_step(params, state, chunk)

    trainer._eval_cache[(model, None)] = (metrics, step, counting)
    trainer.evaluate(model, params, loader)
    assert sum(calls) == nb
    # full-shape batches chunk together; the odd-shaped drop_last=False
    # tail flushes into its own chunk of 1
    full = loader.n // loader.batch_size
    assert len(calls) == -(-full // 4) + (1 if loader.n % loader.batch_size
                                          else 0)


# ---------------------------------------------------------------------------
# LRU eval cache.
# ---------------------------------------------------------------------------

def test_eval_cache_is_lru_not_fifo(pbm_log):
    """In a >4-model sweep, the model evaluated every epoch must stay
    cached: insertion-order eviction used to evict the hot model as soon
    as 4 cold ones passed through."""
    cfg, train, val = pbm_log
    trainer = Trainer(optim.adamw(0.05), log_fn=lambda *_: None)
    makes = []
    original = trainer._make_eval_step

    def counting(model_, metrics_, replicas=None):
        makes.append(model_)
        return original(model_, metrics_, replicas)

    trainer._make_eval_step = counting
    hot = _model(cfg)
    cold = [_model(cfg) for _ in range(4)]
    params = hot.init(jax.random.PRNGKey(0))
    loader = lambda: ClickLogLoader(val, batch_size=128, shuffle=False,
                                    drop_last=False)
    trainer.evaluate(hot, params, loader())
    for m in cold:
        # hot is re-touched before each cold model, as a real sweep's
        # every-epoch validation would
        trainer.evaluate(hot, params, loader())
        trainer.evaluate(m, m.init(jax.random.PRNGKey(1)), loader())
    assert makes.count(hot) == 1, (
        f"hot model retraced {makes.count(hot)} times — cache evicted it")
    assert len(makes) == 5  # hot once + each cold model once


def test_trainer_replica_knob_validation():
    with pytest.raises(ValueError, match="replica"):
        Trainer(optim.adamw(0.05), replica_lrs=[0.1, 0.2])
    with pytest.raises(ValueError, match="replica_seeds"):
        Trainer(optim.adamw(0.05), replicas=3, replica_seeds=[1, 2])


# ---------------------------------------------------------------------------
# Replica sweep composed with the data-parallel mesh (8 fake host devices,
# subprocess — the main test process stays single-device, see
# tests/test_distrib.py).
# ---------------------------------------------------------------------------

SWEEP_DP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro import optim
from repro.core import PositionBasedModel
from repro.data import ClickLogLoader, SyntheticConfig, generate_click_log, split_sessions
from repro.train import Trainer
from repro.launch.mesh import make_data_parallel_mesh

cfg = SyntheticConfig(n_sessions=2200, n_queries=25, docs_per_query=12,
                      positions=6, behavior="pbm", seed=13)
data, _ = generate_click_log(cfg)
train, val, _ = split_sessions(data, (0.8, 0.1, 0.1), seed=0)
lrs = [0.05, 0.02, 0.08, 0.05]

def run(mesh):
    model = PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                               positions=cfg.positions, init_prob=0.2)
    trainer = Trainer(optim.adamw(0.99, inject_lr=True), epochs=2,
                      patience=100, log_fn=lambda *_: None, chunk_batches=4,
                      mesh=mesh, replicas=4, replica_lrs=lrs,
                      replica_seeds=[0, 1, 2, 3])
    loader = ClickLogLoader(train, batch_size=256, seed=5)
    vloader = ClickLogLoader(val, batch_size=128, shuffle=False,
                             drop_last=False)
    history = trainer.train(model, loader, vloader)
    return history, trainer._final_state.params

mesh = make_data_parallel_mesh()
h_dp, p_dp = run(mesh)
h_1, p_1 = run(None)
# replica axis replicated, batch axis sharded: every replica's params match
# the single-device sweep to float tolerance
for (ka, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(p_1),
                           jax.tree_util.tree_leaves_with_path(p_dp)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                               err_msg=str(ka))
for r1, r8 in zip(h_1, h_dp):
    np.testing.assert_allclose(r1["train_loss"], r8["train_loss"], atol=1e-5)
    np.testing.assert_allclose(r1["val_ll"], r8["val_ll"], atol=1e-5)
sharded = [x.sharding for x in jax.tree_util.tree_leaves(p_dp)]
assert all(len(s.device_set) == 8 for s in sharded), sharded
print("SWEEP_DP_OK")
"""


def test_vmapped_sweep_on_data_parallel_mesh():
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["JAX_PLATFORMS"] = "cpu"  # see test_distrib.py: avoid TPU probing
    proc = subprocess.run([sys.executable, "-c", SWEEP_DP_SCRIPT],
                          capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SWEEP_DP_OK" in proc.stdout
