"""nn + optim substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn, optim


def test_dense_shapes_and_init_determinism():
    layer = nn.Dense(8, 16)
    p1 = layer.init(jax.random.PRNGKey(0))
    p2 = layer.init(jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(p1["kernel"]), np.asarray(p2["kernel"]))
    y = layer(p1, jnp.ones((4, 8)))
    assert y.shape == (4, 16)


def test_mlp_depth_and_activation():
    mlp = nn.MLP(4, [8, 8], 2, activation="relu")
    p = mlp.init(jax.random.PRNGKey(1))
    assert len(p) == 3
    y = mlp(p, jnp.ones((5, 4)))
    assert y.shape == (5, 2)


def test_deepcross_cross_layer_identity():
    """With zero cross/deep weights, stacked DCN passes x0 through head."""
    dcn = nn.DeepCrossV2(6, cross_layers=2, deep_layers=0, out_features=1)
    p = dcn.init(jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (7, 6))
    # zero the cross kernels -> crossed == x (x0 * (0 + 0) + x)
    p0 = jax.tree_util.tree_map(jnp.zeros_like, p)
    p0["head"] = p["head"]
    got = dcn(p0, x)
    want = x @ p["head"]["kernel"] + p["head"]["bias"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_rmsnorm_layer_norm_stats():
    ln = nn.LayerNorm(16)
    p = ln.init(jax.random.PRNGKey(0))
    y = ln(p, jax.random.normal(jax.random.PRNGKey(1), (3, 16)) * 5 + 2)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.std(y, -1)), 1.0, atol=1e-2)
    rn = nn.RMSNorm(16)
    pr = rn.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 16))
    y = rn(pr, x)
    ms = jnp.mean(jnp.square(y), -1)
    np.testing.assert_allclose(np.asarray(ms), 1.0, atol=1e-2)


def test_adamw_first_step_magnitude():
    """First AdamW update ~= lr * sign(grad) (bias-corrected)."""
    tx = optim.adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([1.0, -2.0])}
    state = tx.init(params)
    grads = {"w": jnp.asarray([0.3, -0.7])}
    updates, _ = tx.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(updates["w"]),
                               [-0.1, 0.1], rtol=1e-4)


def test_adamw_decoupled_weight_decay():
    tx = optim.adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.asarray([2.0])}
    state = tx.init(params)
    updates, _ = tx.update({"w": jnp.asarray([0.0])}, state, params)
    # zero grad -> update = -lr * wd * w = -0.1*0.5*2
    np.testing.assert_allclose(np.asarray(updates["w"]), [-0.1], rtol=1e-4)


@pytest.mark.parametrize("factory", [
    lambda: optim.sgd(0.1, momentum=0.9),
    lambda: optim.adam(0.05),
    lambda: optim.adagrad(0.5),
    lambda: optim.adamw(0.05),
])
def test_optimizers_converge_on_quadratic(factory):
    tx = factory()
    x = jnp.asarray([3.0, -4.0])
    state = tx.init(x)
    for _ in range(300):
        g = 2 * x
        updates, state = tx.update(g, state, x)
        x = optim.apply_updates(x, updates)
    assert float(jnp.linalg.norm(x)) < 0.05


def test_clip_by_global_norm():
    tx = optim.chain(optim.clip_by_global_norm(1.0), optim.scale(-1.0))
    g = {"a": jnp.asarray([3.0, 4.0])}
    u, _ = tx.update(g, tx.init(g), None)
    np.testing.assert_allclose(float(jnp.linalg.norm(-u["a"])), 1.0, rtol=1e-5)


def test_gradient_accumulation_equals_big_batch():
    """k accumulated microbatches == one big batch step (same update)."""
    inner = optim.sgd(0.1)
    acc = optim.accumulate_gradients(inner, every=4)
    w_acc = jnp.asarray([1.0])
    state = acc.init(w_acc)
    micro_grads = [jnp.asarray([g]) for g in (1.0, 2.0, 3.0, 4.0)]
    for g in micro_grads:
        updates, state = acc.update(g, state, w_acc)
        w_acc = optim.apply_updates(w_acc, updates)
    w_big = optim.apply_updates(
        jnp.asarray([1.0]),
        inner.update(jnp.asarray([2.5]), inner.init(jnp.asarray([1.0])), None)[0])
    np.testing.assert_allclose(np.asarray(w_acc), np.asarray(w_big), rtol=1e-6)


def test_schedules():
    from repro.optim import warmup_cosine, cosine_decay, linear_decay
    s = warmup_cosine(1.0, warmup_steps=10, decay_steps=110)
    assert float(s(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(s(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(s(jnp.asarray(110))) < 1e-6
    np.testing.assert_allclose(float(cosine_decay(2.0, 100)(jnp.asarray(0))),
                               2.0)
    np.testing.assert_allclose(
        float(linear_decay(1.0, 0.0, 100)(jnp.asarray(50))), 0.5)
