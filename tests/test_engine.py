"""Training-engine tests: scan-jitted chunk steps vs the per-batch loop
(bit-exact), chunked prefetch, checkpoint/resume at chunk granularity,
sparse-table lazy AdamW, data-parallel execution, and eval-step caching."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import PositionBasedModel
from repro.data import (ClickLogLoader, DevicePrefetcher, SyntheticConfig,
                        generate_click_log, split_sessions)
from repro.train import Trainer, TrainEngine


@pytest.fixture(scope="module")
def pbm_log():
    cfg = SyntheticConfig(n_sessions=2200, n_queries=25, docs_per_query=12,
                          positions=6, behavior="pbm", seed=13)
    data, _ = generate_click_log(cfg)
    train, val, _ = split_sessions(data, (0.8, 0.1, 0.1), seed=0)
    return cfg, train, val


def _model(cfg):
    return PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                              positions=cfg.positions, init_prob=0.2)


def _copy(tree):
    return jax.tree_util.tree_map(lambda x: jnp.array(np.asarray(x)), tree)


def _assert_trees_equal(a, b, msg=""):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert len(la) == len(lb)
    for (ka, va), (_, vb) in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                      err_msg=f"{msg}{ka}")


def _loop_reference(cfg, data, epochs, batch_size=256, lr=0.05):
    """The historical trainer loop: one jit dispatch + one blocking
    ``float(loss)`` per batch. The engine must reproduce it bit-for-bit."""
    model = _model(cfg)
    tx = optim.adamw(lr)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = tx.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.compute_loss)(params, batch)
        updates, opt_state = tx.update(grads, opt_state, params)
        return optim.apply_updates(params, updates), opt_state, loss

    loader = ClickLogLoader(data, batch_size=batch_size, seed=5)
    losses = []
    for _ in range(epochs):
        for batch in iter(loader):
            batch = {k: jax.device_put(v) for k, v in batch.items()}
            params, opt_state, loss = step(params, opt_state, batch)
            losses.append(float(loss))
    return params, opt_state, losses


def _engine_run(cfg, data, epochs, chunk, batch_size=256, lr=0.05):
    model = _model(cfg)
    engine = TrainEngine(model, optim.adamw(lr), chunk_batches=chunk)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = engine.init_opt_state(params)
    loader = ClickLogLoader(data, batch_size=batch_size, seed=5)
    losses = []
    for _ in range(epochs):
        for chunk_arr, _, n in DevicePrefetcher(loader, chunk_batches=chunk):
            params, opt_state, step_losses = engine.step(params, opt_state,
                                                         chunk_arr)
            assert step_losses.shape == (n,)
            losses.extend(float(x) for x in np.asarray(step_losses))
    return params, opt_state, losses


@pytest.mark.parametrize("chunk", [1, 4, 5])
def test_engine_bitexact_vs_per_batch_loop(pbm_log, chunk):
    """Params, opt_state, and the full per-step loss history must be
    identical for chunk 1, a dividing chunk, and a non-dividing chunk
    (6 batches/epoch at B=256: chunk 5 leaves a partial trailing chunk)."""
    cfg, train, _ = pbm_log
    p_ref, o_ref, l_ref = _loop_reference(cfg, train, epochs=2)
    p, o, losses = _engine_run(cfg, train, epochs=2, chunk=chunk)
    assert losses == l_ref
    _assert_trees_equal(p_ref, p, msg=f"chunk={chunk} params ")
    _assert_trees_equal(o_ref, o, msg=f"chunk={chunk} opt_state ")


def test_trainer_chunked_matches_loop_history(pbm_log):
    cfg, train, _ = pbm_log

    def run(chunk):
        model = _model(cfg)
        trainer = Trainer(optim.adamw(0.05), epochs=3, patience=100,
                          log_fn=lambda *_: None, chunk_batches=chunk)
        loader = ClickLogLoader(train, batch_size=256, seed=5)
        history = trainer.train(model, loader, None)
        return history, trainer._final_state

    h1, s1 = run(1)
    h4, s4 = run(4)
    assert [r["train_loss"] for r in h1] == [r["train_loss"] for r in h4]
    assert s1.global_step == s4.global_step
    _assert_trees_equal(s1.params, s4.params)


def test_chunked_prefetcher_stacks_and_flushes_partial_shapes():
    n, k = 103, 4  # batch 10, drop_last=False: 10 full batches + one of 3
    data = {"positions": np.tile(np.arange(1, k + 1, dtype=np.int32), (n, 1)),
            "query_doc_ids": np.arange(n * k, dtype=np.int64).reshape(n, k),
            "clicks": np.zeros((n, k), np.float32),
            "mask": np.ones((n, k), bool)}
    loader = ClickLogLoader(data, batch_size=10, seed=2, drop_last=False)
    chunks = list(DevicePrefetcher(loader, chunk_batches=4))
    # 10 same-shape batches chunk as 4+4+2; the odd-shaped tail flushes into
    # its own chunk of 1 instead of breaking the stack
    assert [(c[2],) + tuple(c[0]["clicks"].shape) for c in chunks] == [
        (4, 4, 10, 4), (4, 4, 10, 4), (2, 2, 10, 4), (1, 1, 3, 4)]
    # every session appears exactly once across the stacked chunks
    seen = np.concatenate([np.asarray(c[0]["query_doc_ids"]).reshape(-1, k)[:, 0]
                           for c in chunks])
    assert len(set(seen.tolist())) == n
    # the recorded loader_state is the resume point after the chunk's last
    # batch: replaying from chunk 0's state yields batches 5.. onward
    state = chunks[0][1]
    resumed = ClickLogLoader(data, batch_size=10, seed=2, drop_last=False)
    resumed.load_state_dict(state)
    rest = list(iter(resumed))
    assert len(rest) == 7
    first_after = np.asarray(chunks[1][0]["query_doc_ids"])[0]
    np.testing.assert_array_equal(np.asarray(rest[0]["query_doc_ids"]),
                                  first_after)


def test_chunked_resume_is_bit_exact(tmp_path, pbm_log):
    """Interrupt + resume with checkpoint_every_steps not aligned to the
    chunk size: checkpoints land at chunk boundaries with the chunk's last
    loader_state, and the resumed run must match the uninterrupted one."""
    cfg, train, _ = pbm_log
    model = _model(cfg)

    def run(epochs, ckpt_dir, resume=False):
        loader = ClickLogLoader(train, batch_size=256, seed=5)
        trainer = Trainer(optim.adamw(0.01), epochs=epochs, patience=100,
                          checkpoint_dir=ckpt_dir, checkpoint_every_steps=5,
                          log_fn=lambda *_: None, chunk_batches=4)
        trainer.train(model, loader, None, resume=resume)
        return trainer._final_state.params

    p_full = run(4, str(tmp_path / "full"))
    run(2, str(tmp_path / "resume"))
    p_resumed = run(4, str(tmp_path / "resume"), resume=True)
    _assert_trees_equal(p_full, p_resumed)


def test_chunked_resume_through_prefetcher_mid_epoch(pbm_log, tmp_path):
    """Kill the run mid-epoch at a chunk boundary (checkpoint written from
    the chunk's loader_state while the prefetcher has run ahead), resume,
    and compare against an uninterrupted run."""
    cfg, train, _ = pbm_log
    model = _model(cfg)

    # uninterrupted: 2 epochs
    loader = ClickLogLoader(train, batch_size=256, seed=5)
    t_full = Trainer(optim.adamw(0.01), epochs=2, patience=100,
                     log_fn=lambda *_: None, chunk_batches=4)
    t_full.train(model, loader, None)

    # interrupted: stop after the first checkpoint (step 4 of 6 per epoch)
    class Stop(Exception):
        pass

    ckpt_dir = str(tmp_path / "mid")
    loader = ClickLogLoader(train, batch_size=256, seed=5)
    t_int = Trainer(optim.adamw(0.01), epochs=2, patience=100,
                    checkpoint_dir=ckpt_dir, checkpoint_every_steps=3,
                    log_fn=lambda *_: None, chunk_batches=4)
    saved = t_int._save
    calls = []

    def save_once(*args, **kwargs):
        saved(*args, **kwargs)
        calls.append(1)
        if len(calls) == 1:
            raise Stop

    t_int._save = save_once
    with pytest.raises(Stop):
        t_int.train(model, loader, None)

    # resume from the mid-epoch checkpoint with a FRESH loader
    loader = ClickLogLoader(train, batch_size=256, seed=5)
    t_res = Trainer(optim.adamw(0.01), epochs=2, patience=100,
                    checkpoint_dir=ckpt_dir, checkpoint_every_steps=10_000,
                    log_fn=lambda *_: None, chunk_batches=4)
    t_res.train(model, loader, None, resume=True)
    assert t_res._final_state.global_step == t_full._final_state.global_step
    _assert_trees_equal(t_full._final_state.params, t_res._final_state.params)


# ---------------------------------------------------------------------------
# Sparse embedding tables (optim/sparse.py lazy AdamW through the engine).
# ---------------------------------------------------------------------------

def _all_rows_batch(n_rows, b, k, seed):
    r = np.random.default_rng(seed)
    ids = r.permutation(n_rows).reshape(b, k)
    return {"positions": np.tile(np.arange(1, k + 1, dtype=np.int32), (b, 1)),
            "query_doc_ids": ids.astype(np.int64),
            "clicks": (r.random((b, k)) < 0.3).astype(np.float32),
            "mask": np.ones((b, k), bool)}


def test_sparse_tables_match_dense_adamw_when_all_rows_touched():
    """On a table whose every row appears in every batch, lazy AdamW must be
    bit-identical to the dense optimizer — params, moments, and losses."""
    R, B, K = 24, 6, 4
    model = PositionBasedModel(query_doc_pairs=R, positions=K, init_prob=0.2)
    lr, wd = 0.05, 1e-3
    dense = TrainEngine(model, optim.adamw(lr, weight_decay=wd))
    sparse = TrainEngine(model, optim.adamw(lr, weight_decay=wd),
                         sparse_tables=True,
                         sparse_table_kwargs=dict(lr=lr, weight_decay=wd))
    p0 = model.init(jax.random.PRNGKey(1))
    p_d, p_s = _copy(p0), _copy(p0)
    o_d, o_s = dense.init_opt_state(_copy(p0)), sparse.init_opt_state(_copy(p0))
    for step in range(5):
        chunk = {k: v[None] for k, v in _all_rows_batch(R, B, K, step).items()}
        p_d, o_d, l_d = dense.step(p_d, o_d, chunk)
        p_s, o_s, l_s = sparse.step(p_s, o_s, chunk)
        assert float(l_d[0]) == float(l_s[0])
    _assert_trees_equal(p_d, p_s)
    st = o_s["sparse"]["attraction/table"]
    np.testing.assert_array_equal(np.asarray(o_d[0].mu["attraction"]["table"]),
                                  np.asarray(st.mu))
    np.testing.assert_array_equal(np.asarray(o_d[0].nu["attraction"]["table"]),
                                  np.asarray(st.nu))


def test_sparse_tables_leave_untouched_rows_undecayed():
    """Rows absent from every batch keep their params AND moments untouched
    (lazy-Adam semantics); rows present get updated."""
    R, B, K = 24, 6, 4
    model = PositionBasedModel(query_doc_pairs=R, positions=K, init_prob=0.2)
    engine = TrainEngine(model, optim.adamw(0.05, weight_decay=0.0),
                         sparse_tables=True,
                         sparse_table_kwargs=dict(lr=0.05, weight_decay=0.0))
    params = _copy(model.init(jax.random.PRNGKey(2)))
    opt_state = engine.init_opt_state(_copy(model.init(jax.random.PRNGKey(2))))
    table0 = np.asarray(params["attraction"]["table"]).copy()
    r = np.random.default_rng(9)
    batch = {"positions": np.tile(np.arange(1, K + 1, dtype=np.int32), (B, 1)),
             "query_doc_ids": r.integers(0, 8, size=(B, K)).astype(np.int64),
             "clicks": (r.random((B, K)) < 0.5).astype(np.float32),
             "mask": np.ones((B, K), bool)}
    # warm the moments on rows 0..7, then keep stepping: moments of rows
    # 8.. must stay exactly zero (no decay, no weight-decay drift)
    for _ in range(4):
        chunk = {k: v[None] for k, v in batch.items()}
        params, opt_state, _ = engine.step(params, opt_state, chunk)
    table1 = np.asarray(params["attraction"]["table"])
    st = opt_state["sparse"]["attraction/table"]
    np.testing.assert_array_equal(table1[8:], table0[8:])
    np.testing.assert_array_equal(np.asarray(st.mu)[8:], 0.0)
    np.testing.assert_array_equal(np.asarray(st.nu)[8:], 0.0)
    assert not np.array_equal(table1[:8], table0[:8])
    assert np.any(np.asarray(st.mu)[:8] != 0.0)
    assert int(st.count) == 4


def test_sparse_row_grads_sentinel_padding_is_noop():
    """Fixed-size dedupe pads with the out-of-range sentinel: padding slots
    must not alias row 0 (the old fill_value=0 decayed its moments)."""
    from repro.optim.sparse import (init_sparse_table_state, sparse_adamw_update,
                                    sparse_row_grads)

    table = jnp.ones((8, 3))
    state = init_sparse_table_state(table)
    # lookups touch only rows 5 and 6; 4 lookup slots -> 2 padding slots
    ids = jnp.array([5, 6, 5, 6])
    row_grads = jnp.ones((4, 3))
    uids, grads = sparse_row_grads(row_grads, ids, n_rows=8)
    assert sorted(np.asarray(uids).tolist())[:2] == [5, 6]
    assert (np.asarray(uids) == 8).sum() == 2  # sentinel, not row 0
    new_table, new_state = sparse_adamw_update(table, state, uids, grads,
                                               lr=0.1)
    np.testing.assert_array_equal(np.asarray(new_table)[:5], 1.0)
    np.testing.assert_array_equal(np.asarray(new_state.mu)[0], 0.0)
    assert np.all(np.asarray(new_table)[5:7] != 1.0)


def test_sparse_tables_refuse_qr_compression():
    from repro.core import Compression, EmbeddingParameterConfig

    model = PositionBasedModel(
        query_doc_pairs=1024, positions=4,
        attraction=EmbeddingParameterConfig(
            parameters=1024, compression=Compression.QR, compression_ratio=4))
    with pytest.raises(NotImplementedError):
        TrainEngine(model, optim.adamw(0.05), sparse_tables=True,
                    sparse_table_kwargs=dict(lr=0.05, weight_decay=0.0))


def test_sparse_tables_require_explicit_hyperparams():
    """optim.adamw defaults weight_decay=1e-4 while the sparse update
    defaults to 0.0 — forgetting to mirror it must be an error, not a
    silent divergence from the dense optimizer."""
    model = PositionBasedModel(query_doc_pairs=64, positions=4)
    with pytest.raises(ValueError, match="weight_decay"):
        TrainEngine(model, optim.adamw(0.05), sparse_tables=True,
                    sparse_table_kwargs=dict(lr=0.05))


# ---------------------------------------------------------------------------
# Eval-step caching + single-transfer evaluation.
# ---------------------------------------------------------------------------

def test_eval_step_compiled_once_across_epochs(pbm_log):
    cfg, train, val = pbm_log
    model = _model(cfg)
    trainer = Trainer(optim.adamw(0.05), epochs=1, log_fn=lambda *_: None)
    makes = []
    original = trainer._make_eval_step

    def counting(model_, metrics_, replicas=None):
        makes.append(1)
        return original(model_, metrics_, replicas)

    trainer._make_eval_step = counting
    params = model.init(jax.random.PRNGKey(0))
    loader = ClickLogLoader(val, batch_size=128, shuffle=False,
                            drop_last=False)
    out1 = trainer.evaluate(model, params, loader)
    out2 = trainer.evaluate(model, params, loader)
    assert len(makes) == 1  # epochs 2..n reuse the compiled step
    assert out1 == out2
    assert set(out1) == {"ll", "ppl", "cond_ppl"}


# ---------------------------------------------------------------------------
# Data-parallel execution (8 fake host devices, subprocess — the main test
# process stays single-device, see tests/test_distrib.py).
# ---------------------------------------------------------------------------

DATA_PARALLEL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro import optim
from repro.core import PositionBasedModel
from repro.data import ClickLogLoader, SyntheticConfig, generate_click_log, split_sessions
from repro.train import Trainer
from repro.launch.mesh import make_data_parallel_mesh

cfg = SyntheticConfig(n_sessions=2200, n_queries=25, docs_per_query=12,
                      positions=6, behavior="pbm", seed=13)
data, _ = generate_click_log(cfg)
train, val, _ = split_sessions(data, (0.8, 0.1, 0.1), seed=0)

def run(mesh):
    model = PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                               positions=cfg.positions, init_prob=0.2)
    trainer = Trainer(optim.adamw(0.05), epochs=2, patience=100,
                      log_fn=lambda *_: None, chunk_batches=4, mesh=mesh)
    loader = ClickLogLoader(train, batch_size=256, seed=5)
    vloader = ClickLogLoader(val, batch_size=128, shuffle=False,
                             drop_last=False)
    history = trainer.train(model, loader, vloader)
    return history, trainer._final_state.params

mesh = make_data_parallel_mesh()
assert dict(mesh.shape) == {"data": 8, "model": 1}, mesh.shape
h_dp, p_dp = run(mesh)
h_1, p_1 = run(None)
for (ka, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(p_1),
                           jax.tree_util.tree_leaves_with_path(p_dp)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                               err_msg=str(ka))
for r1, r8 in zip(h_1, h_dp):
    assert abs(r1["train_loss"] - r8["train_loss"]) < 1e-5
    assert abs(r1["val_ll"] - r8["val_ll"]) < 1e-5

# params landed sharded on the mesh (replicated over data via model axis)
sharded = [x.sharding for x in jax.tree_util.tree_leaves(p_dp)]
assert all(len(s.device_set) == 8 for s in sharded), sharded

# indivisible batch size raises a clear error
model = PositionBasedModel(query_doc_pairs=cfg.n_query_doc_pairs,
                           positions=cfg.positions)
trainer = Trainer(optim.adamw(0.05), epochs=1, log_fn=lambda *_: None,
                  chunk_batches=4, mesh=mesh)
try:
    trainer.train(model, ClickLogLoader(train, batch_size=250, seed=5), None)
except ValueError as e:
    assert "divisible" in str(e), e
else:
    raise AssertionError("indivisible batch accepted")

# drop_last=False would leave an unsplittable tail batch: clear error upfront
try:
    trainer.train(model, ClickLogLoader(train, batch_size=256, seed=5,
                                        drop_last=False), None)
except ValueError as e:
    assert "drop_last" in str(e), e
else:
    raise AssertionError("drop_last=False accepted for data-parallel")
print("ENGINE_DP_OK")
"""


def test_data_parallel_engine_on_8_fake_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["JAX_PLATFORMS"] = "cpu"  # see test_distrib.py: avoid TPU probing
    proc = subprocess.run([sys.executable, "-c", DATA_PARALLEL_SCRIPT],
                          capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ENGINE_DP_OK" in proc.stdout
