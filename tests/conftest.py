import os

# Keep tests on a single CPU device (the dry-run sets 512 devices itself,
# in its own process). Force deterministic, quiet execution.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

jax.config.update("jax_default_matmul_precision", "highest")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def small_log():
    """A small synthetic PBM click log shared across tests."""
    from repro.data import SyntheticConfig, generate_click_log

    cfg = SyntheticConfig(n_sessions=512, n_queries=20, docs_per_query=12,
                          positions=8, behavior="pbm", seed=7)
    data, meta = generate_click_log(cfg)
    return cfg, data, meta


def jnp_batch(data, n=64, keys=("positions", "query_doc_ids", "clicks", "mask")):
    import jax.numpy as jnp

    return {k: jnp.asarray(v[:n]) for k, v in data.items() if k in keys}
