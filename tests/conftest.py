import os

# Keep tests on a single CPU device (the dry-run sets 512 devices itself,
# in its own process). Force deterministic, quiet execution.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

try:  # pragma: no cover - only exercised on images without hypothesis
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # Hermetic test images may lack hypothesis and nothing can be installed.
    # Register a tiny deterministic stand-in covering the subset this repo
    # uses: @given / @settings over integers / floats / lists strategies.
    import functools
    import inspect
    import random
    import sys
    import types

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]

        return _Strategy(draw)

    def _settings(max_examples=100, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def _given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 25))
                rng = random.Random(0)
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)

            # Hide the strategy parameters from pytest's fixture resolution.
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers, _st.floats, _st.lists = _integers, _floats, _lists
    _hyp = types.ModuleType("hypothesis")
    _hyp.given, _hyp.settings, _hyp.strategies = _given, _settings, _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

jax.config.update("jax_default_matmul_precision", "highest")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def small_log():
    """A small synthetic PBM click log shared across tests."""
    from repro.data import SyntheticConfig, generate_click_log

    cfg = SyntheticConfig(n_sessions=512, n_queries=20, docs_per_query=12,
                          positions=8, behavior="pbm", seed=7)
    data, meta = generate_click_log(cfg)
    return cfg, data, meta


def jnp_batch(data, n=64, keys=("positions", "query_doc_ids", "clicks", "mask")):
    import jax.numpy as jnp

    return {k: jnp.asarray(v[:n]) for k, v in data.items() if k in keys}
