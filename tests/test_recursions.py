"""Vectorized recursion engine vs the retired scan/loop oracles.

The chain models (DCM/CCM/DBN/SDBN) and UBM keep their original sequential
implementations as ``predict_*_scan`` / ``predict_clicks_loop`` methods; every
vectorized path must reproduce them — values AND gradients — on random padded
batches. Also covers the fused session_nll kernel against its jnp oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MODEL_REGISTRY
from repro.core.base import masked_mean
from repro.kernels import ref, session_nll
from repro.stable import exclusive_cumsum, log_add_exp, log_bce, log_cumsum

CHAIN_MODELS = ("dcm", "ccm", "dbn", "sdbn")
N_DOCS = 60


def make_padded_batch(seed, b=8, k=10, click_p=0.35):
    rng = np.random.default_rng(seed)
    n_real = rng.integers(1, k + 1, size=b)
    mask = np.arange(k)[None, :] < n_real[:, None]
    clicks = (rng.random((b, k)) < click_p).astype(np.float32)
    return {
        "positions": jnp.asarray(np.tile(np.arange(1, k + 1), (b, 1)), jnp.int32),
        "query_doc_ids": jnp.asarray(rng.integers(0, N_DOCS, (b, k))),
        "clicks": jnp.asarray(clicks),
        "mask": jnp.asarray(mask),
    }


def randomized_model(name, seed, k=10):
    model = MODEL_REGISTRY[name](query_doc_pairs=N_DOCS, positions=k)
    params = model.init(jax.random.PRNGKey(seed))
    params = jax.tree_util.tree_map(
        lambda x: x + 0.9 * jax.random.normal(jax.random.PRNGKey(seed + 1),
                                              x.shape), params)
    return model, params


# ---------------------------------------------------------------------------
# value equivalence: vectorized engine == scan oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", CHAIN_MODELS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chain_marginal_matches_scan(name, seed):
    model, params = randomized_model(name, 3 * seed + 11)
    batch = make_padded_batch(seed)
    got = np.asarray(model.predict_clicks(params, batch))
    want = np.asarray(model.predict_clicks_scan(params, batch))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("name", CHAIN_MODELS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chain_conditional_matches_scan(name, seed):
    model, params = randomized_model(name, 3 * seed + 17)
    batch = make_padded_batch(seed, click_p=0.5)
    got = np.asarray(model.predict_conditional_clicks(params, batch))
    want = np.asarray(model.predict_conditional_clicks_scan(params, batch))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("clicks_case", ["none", "all", "first", "last"])
@pytest.mark.parametrize("name", CHAIN_MODELS)
def test_chain_conditional_click_patterns(name, clicks_case):
    """Degenerate click patterns: no clicks, every position, boundary clicks."""
    model, params = randomized_model(name, 23)
    batch = make_padded_batch(7)
    b, k = batch["clicks"].shape
    c = {"none": np.zeros((b, k)), "all": np.ones((b, k)),
         "first": np.eye(1, k, 0).repeat(b, 0),
         "last": np.eye(1, k, k - 1).repeat(b, 0)}[clicks_case]
    batch = dict(batch, clicks=jnp.asarray(c, jnp.float32))
    got = np.asarray(model.predict_conditional_clicks(params, batch))
    want = np.asarray(model.predict_conditional_clicks_scan(params, batch))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ubm_marginal_matches_loop(seed):
    model, params = randomized_model("ubm", 5 * seed + 29)
    batch = make_padded_batch(seed)
    got = np.asarray(model.predict_clicks(params, batch))
    want = np.asarray(model.predict_clicks_loop(params, batch))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_ubm_marginal_gradients_match_loop():
    model, params = randomized_model("ubm", 31)
    batch = make_padded_batch(3)

    def total(fn):
        return lambda p: jnp.sum(
            jnp.where(batch["mask"], fn(p, batch), 0.0))

    g_vec = jax.grad(total(model.predict_clicks))(params)
    g_loop = jax.grad(total(model.predict_clicks_loop))(params)
    for gv, gl in zip(jax.tree_util.tree_leaves(g_vec),
                      jax.tree_util.tree_leaves(g_loop)):
        assert np.all(np.isfinite(np.asarray(gv)))
        np.testing.assert_allclose(np.asarray(gv), np.asarray(gl),
                                   atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# gradient equivalence through compute_loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", CHAIN_MODELS)
def test_chain_loss_gradients_match_scan(name):
    model, params = randomized_model(name, 41)
    batch = make_padded_batch(5, click_p=0.5)

    def scan_loss(p):
        lp = model.predict_conditional_clicks_scan(p, batch)
        return masked_mean(log_bce(lp, batch["clicks"]), batch["mask"])

    loss_vec, g_vec = jax.value_and_grad(model.compute_loss)(params, batch)
    loss_scan, g_scan = jax.value_and_grad(scan_loss)(params)
    np.testing.assert_allclose(float(loss_vec), float(loss_scan), rtol=1e-6)
    for gv, gs in zip(jax.tree_util.tree_leaves(g_vec),
                      jax.tree_util.tree_leaves(g_scan)):
        assert np.all(np.isfinite(np.asarray(gv))), name
        np.testing.assert_allclose(np.asarray(gv), np.asarray(gs),
                                   atol=1e-5, rtol=1e-4, err_msg=name)


@pytest.mark.parametrize("click_p", [0.0, 0.4])
@pytest.mark.parametrize("name", CHAIN_MODELS)
def test_chain_conditional_extreme_logits_stay_finite(name, click_p):
    """Skip runs whose odds leave the saturation domain must clamp, not NaN.

    The scan oracle stays finite in log space; the odds-space engine
    saturates at a large finite value — either way the loss must not be
    poisoned by one outlier session. Covers both all-skip sessions and
    sessions with clicks (resets exercise the reset-odds branch)."""
    model, params = randomized_model(name, 57)
    # drive every logit to +36: P(skip) ~ e^-36 per position
    params = jax.tree_util.tree_map(lambda x: jnp.abs(x) * 0 + 36.0, params)
    batch = make_padded_batch(1, click_p=click_p)
    if click_p == 0.0:
        batch = dict(batch, clicks=jnp.zeros_like(batch["clicks"]))
    lp = np.asarray(model.predict_conditional_clicks(params, batch))
    assert np.all(np.isfinite(lp) | (lp == -np.inf)), lp
    assert not np.any(np.isnan(lp)), lp
    loss, grads = jax.value_and_grad(model.compute_loss)(params, batch)
    assert np.isfinite(float(loss))
    for g in jax.tree_util.tree_leaves(grads):
        assert np.all(np.isfinite(np.asarray(g)))
        # saturated regions must contribute ~zero gradient, not the
        # finite-but-astronomical products of capped backward chains
        assert np.max(np.abs(np.asarray(g))) < 100.0


@pytest.mark.parametrize("scale", [10.0, 14.0])
def test_chain_conditional_saturation_boundary_gradients(scale):
    """Clicks after long skip runs straddle the odds cap: gradients must stay
    at the scan path's scale (the capped VJP once returned ~1e15 here)."""
    for name in CHAIN_MODELS:
        model, params = randomized_model(name, 71)
        params = jax.tree_util.tree_map(lambda x: jnp.abs(x) * 0 + scale,
                                        params)
        b, k = 4, 10
        clicks = np.zeros((b, k), np.float32)
        clicks[:, -1] = 1.0  # click after a 9-skip run
        batch = make_padded_batch(9, b=b, k=k)
        batch = dict(batch, clicks=jnp.asarray(clicks),
                     mask=jnp.ones((b, k), bool))
        grads = jax.grad(model.compute_loss)(params, batch)
        for g in jax.tree_util.tree_leaves(grads):
            arr = np.asarray(g)
            assert np.all(np.isfinite(arr)), name
            assert np.max(np.abs(arr)) < 100.0, (name, scale,
                                                 float(np.max(np.abs(arr))))


def test_affine_scan_growth_products_stay_exact_below_odds_cap():
    """Composite growth factors above the odds cap but applied to tiny odds
    must stay exact: capping composites at the odds cap breaks associativity
    (regression: z3 came out 1e5 instead of 1e8)."""
    from repro.core.recursions import _affine_scan

    a = jnp.asarray([[0.0, 1e6, 1e6, 1e6]])
    b = jnp.asarray([[1e-10, 0.0, 0.0, 0.0]])
    z = np.asarray(_affine_scan(a, b))[0]
    np.testing.assert_allclose(z, [1e-10, 1e-4, 1e2, 1e8], rtol=1e-5)


def test_dcm_conditional_large_but_subcap_odds_match_scan():
    """High attraction + near-certain continuation: death odds grow by ~1e6
    per skip yet stay below the odds cap — the vectorized path must agree
    with the scan oracle through that window."""
    model = MODEL_REGISTRY["dcm"](query_doc_pairs=N_DOCS, positions=5)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda x: jnp.abs(x) * 0, params)
    params["attraction"]["table"] = params["attraction"]["table"] + 13.8
    params["continuation"]["table"] = params["continuation"]["table"] + 23.0
    clicks = np.zeros((2, 5), np.float32)
    clicks[:, 0] = 1.0  # click at rank 1, then all skips
    batch = dict(make_padded_batch(0, b=2, k=5), clicks=jnp.asarray(clicks),
                 mask=jnp.ones((2, 5), bool))
    got = np.asarray(model.predict_conditional_clicks(params, batch))
    want = np.asarray(model.predict_conditional_clicks_scan(params, batch))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_ubm_marginal_extreme_logits_stay_finite():
    """The probability-space solve must saturate (finite log, zero grad)
    when path probabilities underflow float32, not emit -inf/NaN."""
    model, params = randomized_model("ubm", 61)
    params = jax.tree_util.tree_map(lambda x: jnp.abs(x) * 0 - 60.0, params)
    batch = make_padded_batch(2)

    def total(p):
        return jnp.sum(jnp.where(batch["mask"],
                                 model.predict_clicks(p, batch), 0.0))

    lp = np.asarray(model.predict_clicks(params, batch))
    assert np.all(np.isfinite(lp)), lp
    for g in jax.tree_util.tree_leaves(jax.grad(total)(params)):
        assert np.all(np.isfinite(np.asarray(g)))


# ---------------------------------------------------------------------------
# stable primitives used by the engine
# ---------------------------------------------------------------------------

def test_exclusive_cumsum_matches_numpy():
    x = np.random.default_rng(0).normal(size=(4, 7)).astype(np.float32)
    got = np.asarray(exclusive_cumsum(jnp.asarray(x), axis=1))
    want = np.concatenate([np.zeros((4, 1)), np.cumsum(x, 1)[:, :-1]], 1)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert np.all(got[:, 0] == 0.0)


def test_log_cumsum_matches_running_logsumexp():
    x = np.random.default_rng(1).normal(size=(3, 9)).astype(np.float64) * 5
    got = np.asarray(log_cumsum(jnp.asarray(x), axis=1))
    probs = np.cumsum(np.exp(x), axis=1)
    np.testing.assert_allclose(got, np.log(probs), rtol=1e-5)


def test_log_add_exp_matches_logaddexp_and_handles_neg_inf():
    a = jnp.asarray([0.0, -5.0, -jnp.inf, -jnp.inf])
    b = jnp.asarray([-1.0, -jnp.inf, -2.0, -jnp.inf])
    got = np.asarray(log_add_exp(a, b))
    np.testing.assert_allclose(got[:3], np.logaddexp(np.asarray(a)[:3],
                                                     np.asarray(b)[:3]))
    assert got[3] == -np.inf


# ---------------------------------------------------------------------------
# session_nll kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,K", [(4, 5), (37, 10), (256, 10), (130, 200)])
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_session_nll_matches_oracle(B, K, impl):
    rng = np.random.default_rng(B + K)
    x = jnp.asarray(rng.normal(size=(B, K)).astype(np.float32) * 4)
    c = jnp.asarray(rng.integers(0, 2, (B, K)).astype(np.float32))
    m = jnp.asarray(rng.random((B, K)) < 0.8)
    got = float(session_nll(x, c, m, impl=impl))
    want = float(ref.session_nll_ref(x, c, m))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_session_nll_matches_logspace_composition():
    """Fused kernel == the log_sigmoid -> log1mexp -> BCE -> masked-mean path."""
    from repro.stable import log_sigmoid

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(16, 10)).astype(np.float32) * 3)
    c = jnp.asarray(rng.integers(0, 2, (16, 10)).astype(np.float32))
    m = jnp.asarray(rng.random((16, 10)) < 0.7)
    composed = masked_mean(log_bce(log_sigmoid(x), c), m)
    for impl in ("ref", "pallas"):
        np.testing.assert_allclose(float(session_nll(x, c, m, impl=impl)),
                                   float(composed), rtol=1e-5)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_session_nll_gradients(impl):
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(12, 10)).astype(np.float32) * 3)
    c = jnp.asarray(rng.integers(0, 2, (12, 10)).astype(np.float32))
    m = jnp.asarray(rng.random((12, 10)) < 0.8)
    g = jax.grad(lambda xx: session_nll(xx, c, m, impl=impl))(x)
    # closed form: (sigmoid(x) - c) * mask / count
    mf = np.asarray(m, np.float32)
    want = ((1 / (1 + np.exp(-np.asarray(x))) - np.asarray(c)) * mf
            / max(mf.sum(), 1.0))
    np.testing.assert_allclose(np.asarray(g), want, rtol=1e-5, atol=1e-7)
    # masked positions contribute no gradient
    assert np.all(np.asarray(g)[~np.asarray(m)] == 0.0)


def test_session_nll_respects_mask():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(8, 6)).astype(np.float32))
    c = jnp.asarray(rng.integers(0, 2, (8, 6)).astype(np.float32))
    m = np.ones((8, 6), bool)
    m[:, -2:] = False
    x2 = np.asarray(x).copy()
    x2[:, -2:] = 99.0  # scramble masked logits
    for impl in ("ref", "pallas"):
        a = float(session_nll(x, c, jnp.asarray(m), impl=impl))
        bb = float(session_nll(jnp.asarray(x2), c, jnp.asarray(m), impl=impl))
        np.testing.assert_allclose(a, bb, rtol=1e-6)
