"""Fused embedding-bag gather+reduce Pallas TPU kernel.

The hot path of CLAX at scale (paper §4.2: JAX has no EmbeddingBag / sparse
tables — we build it). One kernel serves three call sites:
  * CLAX per-item lookups (bag size 1) and multi-hot field bags,
  * recsys EmbeddingBag fields (DeepFM/AutoInt/BST/MIND),
  * GraphSAGE neighbor aggregation (ids = neighbor lists, weights = 1/deg).

TPU mapping: ids/weights ride scalar-prefetch (SMEM) so the *table BlockSpec
index map* performs the gather — each grid step (b, l) DMAs exactly row
ids[b, l] (a (1, D) VMEM tile, D padded to the 128-lane width) from HBM and
accumulates into the (1, D) output tile for bag b, which stays resident in
VMEM across the L fastest-varying grid steps. No (B*L, D) intermediate ever
materializes — that is the entire point vs the jnp reference (gather then
reduce), whose intermediate is L times the output.

Backward: the wrapper exposes a custom VJP — d(table) is a segment-sum
scatter of weighted output cotangents (ids stay in SMEM), d(weights) is a
row-dot; both reuse the same gather pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _bag_kernel(ids_ref, w_ref, table_ref, o_ref):
    """Grid (B, L): accumulate w[b,l] * table[ids[b,l]] into out[b]."""
    b, l = pl.program_id(0), pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += w_ref[b, l] * table_ref[...].astype(jnp.float32)


def embedding_bag_pallas(table: jax.Array, ids: jax.Array, weights: jax.Array,
                         *, interpret: bool = False) -> jax.Array:
    """out[b] = sum_l weights[b, l] * table[ids[b, l]]; ids < 0 are padding.

    table: (N, D); ids, weights: (B, L). Returns (B, D) float32.
    """
    B, L = ids.shape
    N, D = table.shape
    d_pad = (-D) % LANE
    if d_pad:
        table = jnp.pad(table, ((0, 0), (0, d_pad)))
    Dp = D + d_pad
    # Padding ids clamp to row 0 with weight forced to 0.
    weights = jnp.where(ids >= 0, weights, 0.0).astype(jnp.float32)
    safe_ids = jnp.maximum(ids, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # ids, weights live in SMEM
        grid=(B, L),
        in_specs=[
            pl.BlockSpec((1, Dp), lambda b, l, ids_p, w_p: (ids_p[b, l], 0)),
        ],
        out_specs=pl.BlockSpec((1, Dp), lambda b, l, ids_p, w_p: (b, 0)),
    )
    out = pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Dp), jnp.float32),
        interpret=interpret,
    )(safe_ids, weights, table)
    return out[:, :D]
