"""FlashAttention-2-style tiled attention Pallas TPU kernel (GQA-aware).

Online-softmax attention with (q-block, kv-block) tiling: grid is
(B, Hq, nQ, nK) with the KV axis varying fastest; the output tile plus the
running (m, l, acc) statistics stay in VMEM scratch across all KV steps, so
HBM traffic is one pass over Q/K/V and one write of O — the FlashAttention
IO bound — instead of the O(S^2) score matrix XLA would materialize.

GQA is folded into the K/V BlockSpec index maps (h // group), so grouped
heads never get physically repeated in HBM (the jnp reference does repeat —
that is part of what the kernel saves).

TPU notes: all tiles are (…, 128)-lane aligned; the running max/sum ride a
(bq, 128) broadcast tile (stats live in lanes, standard TPU FA layout);
matmuls request fp32 accumulation via preferred_element_type.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANE = 128


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               seq_q: int, seq_k: int):
    qi, ki = pl.program_id(2), pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, Dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, Dh)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        # absolute positions; decode offset aligns q to the END of kv
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
            + (seq_k - seq_q)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[:, :1]                      # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                     # (bq, bk)
    correction = jnp.exp(m_prev - m_new)       # (bq, 1)
    l_new = correction * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * correction + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[:, :1], 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = False, scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh); Hq % Hkv == 0."""
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (Dh ** 0.5)
    block_q = min(block_q, Sq)
    # shrink block_k to the largest divisor of Skv (no KV padding: padded KV
    # rows would need an extra validity mask in the non-causal path)
    block_k = min(block_k, Sk)
    while Sk % block_k:
        block_k -= 1
    q_pad = (-Sq) % block_q
    if q_pad:
        # padded q rows sit past the causal horizon (they see everything),
        # produce finite garbage, and are sliced off below.
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    nQ, nK = (Sq + q_pad) // block_q, Sk // block_k

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, seq_q=Sq, seq_k=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nQ, nK),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq + q_pad, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANE), jnp.float32),  # running max
            pltpu.VMEM((block_q, LANE), jnp.float32),  # running sum
            pltpu.VMEM((block_q, Dh), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :]
