"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition, written with plain jnp ops and
no performance tricks. Kernels must match these within tolerance across the
shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table: jax.Array, ids: jax.Array, weights: jax.Array
                      ) -> jax.Array:
    """Weighted bag reduction: out[b] = sum_l weights[b,l] * table[ids[b,l]].

    ids: (B, L) int32, entries < 0 are padding (weight must be 0 there too,
    but we also mask defensively). table: (N, D). weights: (B, L).
    Returns (B, D) float32.
    """
    safe = jnp.maximum(ids, 0)
    gathered = jnp.take(table, safe, axis=0)  # (B, L, D)
    w = jnp.where(ids >= 0, weights, 0.0).astype(jnp.float32)
    return jnp.einsum("bld,bl->bd", gathered.astype(jnp.float32), w)


def fm_interaction_ref(v: jax.Array) -> jax.Array:
    """Factorization-machine 2nd-order term [Rendle 2010]:

    out[b] = 0.5 * sum_d [ (sum_f v[b,f,d])^2 - sum_f v[b,f,d]^2 ].
    v: (B, F, D). Returns (B,) float32.
    """
    vf = v.astype(jnp.float32)
    sum_sq = jnp.square(jnp.sum(vf, axis=1))          # (B, D)
    sq_sum = jnp.sum(jnp.square(vf), axis=1)           # (B, D)
    return 0.5 * jnp.sum(sum_sq - sq_sum, axis=-1)


def dcn_cross_ref(x0: jax.Array, x: jax.Array, w: jax.Array, b: jax.Array
                  ) -> jax.Array:
    """DCN-V2 cross layer [Wang 2021]: y = x0 * (x @ W + b) + x.

    x0, x: (B, D); w: (D, D); b: (D,). Returns (B, D) float32.
    """
    xf = x.astype(jnp.float32)
    return x0.astype(jnp.float32) * (xf @ w.astype(jnp.float32)
                                     + b.astype(jnp.float32)) + xf


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = False, scale: float | None = None
                        ) -> jax.Array:
    """Softmax attention with GQA head groups.

    q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh) with Hq % Hkv == 0.
    Returns (B, Hq, Sq, Dh) in q.dtype.
    """
    B, Hq, Sq, Dh = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (Dh ** 0.5)
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    if causal:
        Skv = k.shape[2]
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)


def session_nll_ref(logits: jax.Array, clicks: jax.Array, mask: jax.Array
                    ) -> jax.Array:
    """Masked-mean Bernoulli click NLL from logits, written as the literal
    log_sigmoid -> log1mexp -> BCE -> masked-mean composition the fused
    kernel replaces. Returns a fp32 scalar."""
    x = logits.astype(jnp.float32)
    c = clicks.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    log_p = -jax.nn.softplus(-x)                      # log sigmoid(x)
    log_1mp = -jax.nn.softplus(x)                     # log(1 - sigmoid(x))
    nll = -(c * log_p + (1.0 - c) * log_1mp)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def examination_nll_ref(attr_logits: jax.Array, clicks: jax.Array,
                        mask: jax.Array, p_skip_survive: jax.Array,
                        p_death: jax.Array, p_reset: jax.Array,
                        p_reset_not: jax.Array) -> jax.Array:
    """Masked-mean conditional click NLL of the examination-chain models,
    written as the literal PR 1 composition the fused kernel replaces:

        r     = conditional_examination_odds(clicks, ...)   (capped scan)
        log_p = min(x, 0) - log1p(r + e + r*e)              (e = exp(-|x|))
        nll   = log_bce(log_p, clicks);  loss = masked mean

    This is bit-identical to ``_ChainModel.predict_conditional_clicks`` +
    ``ClickModel.compute_loss`` pre-dispatch, which makes it both the
    conformance oracle and the VJP the public ``examination_nll`` custom
    gradient differentiates through (inheriting the saturating custom VJP of
    ``_affine_scan``). Returns a fp32 scalar.
    """
    # Deferred: repro.core lazily imports repro.kernels in compute_loss, so a
    # module-level import here would complete the cycle at import time.
    from repro.core.recursions import conditional_examination_odds
    from repro.stable import log_bce

    x = attr_logits.astype(jnp.float32)
    c = clicks.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    e = jnp.exp(-jnp.abs(x))
    r = conditional_examination_odds(c, p_skip_survive, p_death, p_reset,
                                     p_reset_not)
    log_p = jnp.minimum(x, 0.0) - jnp.log1p(r + e + r * e)
    nll = log_bce(log_p, c)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def segment_mean_ref(values: jax.Array, segment_ids: jax.Array,
                     num_segments: int) -> jax.Array:
    """Mean-aggregation by segment (the GraphSAGE aggregator oracle)."""
    sums = jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)
    counts = jax.ops.segment_sum(jnp.ones_like(segment_ids, dtype=values.dtype),
                                 segment_ids, num_segments=num_segments)
    return sums / jnp.maximum(counts[..., None], 1.0)
