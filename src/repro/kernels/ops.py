"""Public jit'd kernel API on top of the dispatch registry.

Every kernel registers three implementations with
:mod:`repro.kernels.dispatch`:

  ============  ==========================================================
  ``pallas``    the Pallas lowering (compiled on TPU, interpret-mode
                elsewhere — a conformance tool off-TPU, not a fast path)
  ``xla``       the best XLA-fusable jnp expression (CPU/GPU fast path)
  ``ref``       the pure-jnp oracle from :mod:`repro.kernels.ref`
  ============  ==========================================================

``impl=None`` resolves per backend at trace time (pallas on TPU, xla
elsewhere), overridable programmatically (``override_impl``) or via the
``CLAX_KERNEL_IMPL[_<NAME>]`` environment variables for drills. Passing an
explicit ``impl`` always wins.

Gradient semantics are impl-independent: ``embedding_bag``, ``session_nll``
and ``examination_nll`` carry custom VJPs, so every impl trains with the same
backward pass — a segment scatter that never materializes a (B*L, D)
intermediate, the closed-form sigmoid delta, and ``jax.vjp`` of the ref
examination composition (inheriting ``core/recursions``' saturating VJP).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch as _dispatch
from repro.kernels import ref as _ref
from repro.kernels.dcn_cross import dcn_cross_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.examination_nll import (examination_nll_pallas,
                                           examination_nll_xla)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fm_interaction import fm_interaction_pallas
from repro.kernels.session_nll import session_nll_pallas

# Re-exported so callers can flip impls without importing the registry module.
override_impl = _dispatch.override_impl
set_impl_override = _dispatch.set_impl_override
resolve_impl = _dispatch.resolve_impl


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# XLA implementations (fused jnp forms; oracles live in ref.py)
# ---------------------------------------------------------------------------

def _embedding_bag_xla(table, ids, weights):
    safe = jnp.maximum(ids, 0)
    w = jnp.where(ids >= 0, weights, 0.0).astype(jnp.float32)
    gathered = jnp.take(table, safe, axis=0).astype(jnp.float32)  # (B, L, D)
    return jnp.sum(gathered * w[..., None], axis=1)


def _session_nll_xla(logits, clicks, mask):
    x = logits.astype(jnp.float32)
    c = clicks.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    # softplus(x) - c*x: the single-transcendental form of the BCE chain.
    nll = jnp.maximum(x, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(x))) - c * x
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def _fm_interaction_xla(v):
    vf = v.astype(jnp.float32)
    s = jnp.sum(vf, axis=1)
    # Subtract per-d before the lane reduction (as the ref does): the two
    # totals are large and nearly equal, so subtracting them last loses
    # most of the result's relative precision to cancellation.
    return 0.5 * jnp.sum(jnp.square(s) - jnp.sum(jnp.square(vf), axis=1),
                         axis=-1)


def _flash_attention_xla(q, k, v, causal=False, scale=None):
    """Grouped softmax attention: GQA via a reshape, never a repeated K/V."""
    B, Hq, Sq, Dh = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (Dh ** 0.5)
    qg = q.reshape(B, Hkv, group, Sq, Dh).astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg,
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, Sq, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_dispatch.register("embedding_bag", "pallas",
                   lambda t, i, w: embedding_bag_pallas(
                       t, i, w, interpret=_interpret()))
_dispatch.register("embedding_bag", "ref", _ref.embedding_bag_ref)
_dispatch.register("embedding_bag", "xla", _embedding_bag_xla)

_dispatch.register("session_nll", "pallas",
                   lambda x, c, m: session_nll_pallas(
                       x, c, m, interpret=_interpret()))
_dispatch.register("session_nll", "ref", _ref.session_nll_ref)
_dispatch.register("session_nll", "xla", _session_nll_xla)

_dispatch.register("fm_interaction", "pallas",
                   lambda v: fm_interaction_pallas(v, interpret=_interpret()))
_dispatch.register("fm_interaction", "ref", _ref.fm_interaction_ref)
_dispatch.register("fm_interaction", "xla", _fm_interaction_xla)

_dispatch.register("dcn_cross", "pallas",
                   lambda x0, x, w, b: dcn_cross_pallas(
                       x0, x, w, b, interpret=_interpret()))
_dispatch.register("dcn_cross", "ref", _ref.dcn_cross_ref)
# The ref expression (one GEMM + elementwise) is already the optimal XLA form.
_dispatch.register("dcn_cross", "xla", _ref.dcn_cross_ref)

_dispatch.register("flash_attention", "pallas",
                   lambda q, k, v, **kw: flash_attention_pallas(
                       q, k, v, interpret=_interpret(), **kw))
_dispatch.register("flash_attention", "ref", _ref.flash_attention_ref)
_dispatch.register("flash_attention", "xla", _flash_attention_xla)

_dispatch.register("examination_nll", "pallas",
                   lambda *a: examination_nll_pallas(
                       *a, interpret=_interpret()))
_dispatch.register("examination_nll", "ref", _ref.examination_nll_ref)
_dispatch.register("examination_nll", "xla", examination_nll_xla)


# ---------------------------------------------------------------------------
# embedding_bag with custom VJP
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _embedding_bag(table, ids, weights, impl):
    return _dispatch.dispatch("embedding_bag", impl, table, ids, weights)


def _bag_fwd(table, ids, weights, impl):
    return _embedding_bag(table, ids, weights, impl), (table, ids, weights)


def _bag_bwd(impl, res, g):
    table, ids, weights = res
    N, D = table.shape
    g = g.astype(jnp.float32)  # (B, D)
    w = jnp.where(ids >= 0, weights, 0.0).astype(jnp.float32)
    safe = jnp.maximum(ids, 0)

    # d_table[r] = sum_{(b,l): ids=r} w[b,l] * g[b], scattered one bag slot
    # at a time: the carry is the (N, D) output itself (donated through the
    # scan) and each step touches only a (B, D) slice — peak footprint
    # O(N*D + B*D), vs the former (B*L, D) contrib + segment_sum. d_w rides
    # the same scan: d_w[b,l] = <table[ids[b,l]], g[b]> from a (B, D) gather.
    def step(d_table, xs):
        ids_l, w_l = xs  # (B,), (B,)
        rows = jnp.take(table, ids_l, axis=0).astype(jnp.float32)
        d_w_l = jnp.sum(rows * g, axis=-1)
        return d_table.at[ids_l].add(w_l[:, None] * g), d_w_l

    d_table, d_w_cols = jax.lax.scan(
        step, jnp.zeros((N, D), jnp.float32), (safe.T, w.T))
    d_table = d_table.astype(table.dtype)
    d_w = jnp.where(ids >= 0, d_w_cols.T, 0.0).astype(weights.dtype)
    return d_table, None, d_w


_embedding_bag.defvjp(_bag_fwd, _bag_bwd)


def embedding_bag(table: jax.Array, ids: jax.Array,
                  weights: Optional[jax.Array] = None, combiner: str = "sum",
                  impl: Optional[str] = None) -> jax.Array:
    """out[b] = reduce_l table[ids[b, l]]; ids < 0 = padding.

    combiner: "sum" | "mean" (mean over non-padding entries).
    """
    impl = _dispatch.resolve_impl("embedding_bag", impl)
    if weights is None:
        weights = jnp.ones(ids.shape, jnp.float32)
    if combiner == "mean":
        count = jnp.sum((ids >= 0).astype(jnp.float32), axis=1, keepdims=True)
        weights = weights / jnp.maximum(count, 1.0)
    elif combiner != "sum":
        raise ValueError(f"unknown combiner {combiner!r}")
    return _embedding_bag(table, ids, weights, impl)


# ---------------------------------------------------------------------------
# session_nll with custom VJP
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _session_nll(logits, clicks, mask, impl):
    return _dispatch.dispatch("session_nll", impl, logits, clicks, mask)


def _nll_fwd(logits, clicks, mask, impl):
    return _session_nll(logits, clicks, mask, impl), (logits, clicks, mask)


def _nll_bwd(impl, res, g):
    logits, clicks, mask = res
    x = logits.astype(jnp.float32)
    c = clicks.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    inv_count = 1.0 / jnp.maximum(jnp.sum(m), 1.0)
    # d nll/dx = sigmoid(x) - c; d nll/dc = -x; both masked-mean weighted.
    d_logits = (g * (jax.nn.sigmoid(x) - c) * m * inv_count).astype(logits.dtype)
    d_clicks = (g * (-x) * m * inv_count).astype(clicks.dtype)
    return d_logits, d_clicks, None


_session_nll.defvjp(_nll_fwd, _nll_bwd)


def session_nll(logits: jax.Array, clicks: jax.Array, mask: jax.Array,
                impl: Optional[str] = None) -> jax.Array:
    """Masked-mean Bernoulli click NLL straight from logits.

    Fuses log_sigmoid -> log1mexp -> BCE -> masked mean in one pass over the
    (B, K) tile; the scalar loss (and its closed-form VJP) never materializes
    the per-element log-probability intermediates.
    """
    impl = _dispatch.resolve_impl("session_nll", impl)
    return _session_nll(logits, clicks, mask, impl)


# ---------------------------------------------------------------------------
# examination_nll with custom VJP (backward = jax.vjp of the ref composition)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(7,))
def _examination_nll(x, clicks, mask, pss, pd, pr, prn, impl):
    return _dispatch.dispatch("examination_nll", impl,
                              x, clicks, mask, pss, pd, pr, prn)


def _exam_fwd(x, clicks, mask, pss, pd, pr, prn, impl):
    out = _examination_nll(x, clicks, mask, pss, pd, pr, prn, impl)
    return out, (x, clicks, mask, pss, pd, pr, prn)


def _exam_bwd(impl, res, g):
    x, clicks, mask, pss, pd, pr, prn = res

    # Differentiate the ref composition regardless of the forward impl: every
    # impl then shares the exact pre-dispatch gradient, including the
    # saturating zero-cotangent semantics of core/recursions' _affine_scan.
    def composed(x_, c_, ss_, d_, r_, rn_):
        return _ref.examination_nll_ref(x_, c_, mask, ss_, d_, r_, rn_)

    _, vjp = jax.vjp(composed, x, clicks, pss, pd, pr, prn)
    dx, dc, dss, dd, dr, drn = vjp(g)
    return dx, dc, None, dss, dd, dr, drn


_examination_nll.defvjp(_exam_fwd, _exam_bwd)


def examination_nll(attr_logits: jax.Array, clicks: jax.Array,
                    mask: jax.Array, p_skip_survive: jax.Array,
                    p_death: jax.Array, p_reset: jax.Array,
                    p_reset_not: jax.Array,
                    impl: Optional[str] = None) -> jax.Array:
    """Fused conditional click NLL of the examination-chain models.

    Inputs are the raw attraction logits plus the four probability-space
    factors of ``core.recursions.conditional_examination_odds`` (all (B, K));
    the output is the scalar masked-mean NLL that
    ``_ChainModel.compute_loss`` minimizes. The factor -> odds-scan -> NLL
    chain runs in one pass with no (B, K) log-probability intermediates; see
    kernels/examination_nll.py for the lowering and the numerics contract.
    """
    impl = _dispatch.resolve_impl("examination_nll", impl)
    return _examination_nll(attr_logits, clicks, mask, p_skip_survive,
                            p_death, p_reset, p_reset_not, impl)


# ---------------------------------------------------------------------------
# fm_interaction / dcn_cross / flash_attention
# ---------------------------------------------------------------------------

def fm_interaction(v: jax.Array, impl: Optional[str] = None) -> jax.Array:
    return _dispatch.dispatch("fm_interaction", impl, v)


def dcn_cross(x0: jax.Array, x: jax.Array, w: jax.Array, b: jax.Array,
              impl: Optional[str] = None) -> jax.Array:
    return _dispatch.dispatch("dcn_cross", impl, x0, x, w, b)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = False, scale: Optional[float] = None,
                    impl: Optional[str] = None, **block_kwargs) -> jax.Array:
    impl = _dispatch.resolve_impl("flash_attention", impl)
    if impl == "pallas":
        return _dispatch.dispatch("flash_attention", impl, q, k, v,
                                  causal=causal, scale=scale, **block_kwargs)
    return _dispatch.dispatch("flash_attention", impl, q, k, v,
                              causal=causal, scale=scale)
