"""Public jit'd kernel API with implementation dispatch.

``impl``: "pallas" (compiled TPU path; interpret-mode on CPU), "ref" (pure
jnp oracle). Default is backend-aware: the ref path on CPU (interpret mode is
a correctness tool, not a fast path) and the Pallas kernel on TPU.

embedding_bag carries a custom VJP so the fused kernel is trainable: the
backward scatter (d_table) is a segment-sum over SMEM-resident ids — the same
memory pattern as the forward gather, no (B*L, D) intermediate.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dcn_cross import dcn_cross_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fm_interaction import fm_interaction_pallas
from repro.kernels.session_nll import session_nll_pallas


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# embedding_bag with custom VJP
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _embedding_bag(table, ids, weights, impl):
    if impl == "pallas":
        return embedding_bag_pallas(table, ids, weights, interpret=_interpret())
    return _ref.embedding_bag_ref(table, ids, weights)


def _bag_fwd(table, ids, weights, impl):
    return _embedding_bag(table, ids, weights, impl), (table, ids, weights)


def _bag_bwd(impl, res, g):
    table, ids, weights = res
    B, L = ids.shape
    N, D = table.shape
    g = g.astype(jnp.float32)  # (B, D)
    w = jnp.where(ids >= 0, weights, 0.0).astype(jnp.float32)
    safe = jnp.maximum(ids, 0).reshape(-1)
    # d_table[r] = sum_{(b,l): ids=r} w[b,l] * g[b]
    contrib = (w[..., None] * g[:, None, :]).reshape(B * L, D)
    d_table = jax.ops.segment_sum(contrib, safe, num_segments=N)
    d_table = d_table.astype(table.dtype)
    # d_w[b,l] = <table[ids[b,l]], g[b]>
    rows = jnp.take(table, safe.reshape(B, L), axis=0).astype(jnp.float32)
    d_w = jnp.einsum("bld,bd->bl", rows, g)
    d_w = jnp.where(ids >= 0, d_w, 0.0).astype(weights.dtype)
    return d_table, None, d_w


_embedding_bag.defvjp(_bag_fwd, _bag_bwd)


def embedding_bag(table: jax.Array, ids: jax.Array,
                  weights: Optional[jax.Array] = None, combiner: str = "sum",
                  impl: Optional[str] = None) -> jax.Array:
    """out[b] = reduce_l table[ids[b, l]]; ids < 0 = padding.

    combiner: "sum" | "mean" (mean over non-padding entries).
    """
    impl = impl or _default_impl()
    if weights is None:
        weights = jnp.ones(ids.shape, jnp.float32)
    if combiner == "mean":
        count = jnp.sum((ids >= 0).astype(jnp.float32), axis=1, keepdims=True)
        weights = weights / jnp.maximum(count, 1.0)
    elif combiner != "sum":
        raise ValueError(f"unknown combiner {combiner!r}")
    return _embedding_bag(table, ids, weights, impl)


# ---------------------------------------------------------------------------
# session_nll with custom VJP
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _session_nll(logits, clicks, mask, impl):
    if impl == "pallas":
        return session_nll_pallas(logits, clicks, mask, interpret=_interpret())
    return _ref.session_nll_ref(logits, clicks, mask)


def _nll_fwd(logits, clicks, mask, impl):
    return _session_nll(logits, clicks, mask, impl), (logits, clicks, mask)


def _nll_bwd(impl, res, g):
    logits, clicks, mask = res
    x = logits.astype(jnp.float32)
    c = clicks.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    inv_count = 1.0 / jnp.maximum(jnp.sum(m), 1.0)
    # d nll/dx = sigmoid(x) - c; d nll/dc = -x; both masked-mean weighted.
    d_logits = (g * (jax.nn.sigmoid(x) - c) * m * inv_count).astype(logits.dtype)
    d_clicks = (g * (-x) * m * inv_count).astype(clicks.dtype)
    return d_logits, d_clicks, None


_session_nll.defvjp(_nll_fwd, _nll_bwd)


def session_nll(logits: jax.Array, clicks: jax.Array, mask: jax.Array,
                impl: Optional[str] = None) -> jax.Array:
    """Masked-mean Bernoulli click NLL straight from logits.

    Fuses log_sigmoid -> log1mexp -> BCE -> masked mean in one pass over the
    (B, K) tile; the scalar loss (and its closed-form VJP) never materializes
    the per-element log-probability intermediates.
    """
    impl = impl or _default_impl()
    return _session_nll(logits, clicks, mask, impl)


# ---------------------------------------------------------------------------
# fm_interaction / dcn_cross / flash_attention
# ---------------------------------------------------------------------------

def fm_interaction(v: jax.Array, impl: Optional[str] = None) -> jax.Array:
    impl = impl or _default_impl()
    if impl == "pallas":
        return fm_interaction_pallas(v, interpret=_interpret())
    return _ref.fm_interaction_ref(v)


def dcn_cross(x0: jax.Array, x: jax.Array, w: jax.Array, b: jax.Array,
              impl: Optional[str] = None) -> jax.Array:
    impl = impl or _default_impl()
    if impl == "pallas":
        return dcn_cross_pallas(x0, x, w, b, interpret=_interpret())
    return _ref.dcn_cross_ref(x0, x, w, b)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = False, scale: Optional[float] = None,
                    impl: Optional[str] = None, **block_kwargs) -> jax.Array:
    impl = impl or _default_impl()
    if impl == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                      interpret=_interpret(), **block_kwargs)
    return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
