"""Pallas TPU kernels for the perf-critical hot spots (+ jnp oracles).

Kernels: embedding_bag (CLAX tables / recsys bags / GNN aggregation),
fm_interaction (DeepFM), dcn_cross (DCN-V2 towers, paper Listing 4),
flash_attention (BST / AutoInt / LM archs), session_nll (fused CTR-family
click loss), examination_nll (fused chain-family factors -> odds-scan -> NLL).

Every kernel resolves its implementation ("pallas" | "ref" | "xla") through
the dispatch registry at trace time — see ops.py for the public API,
dispatch.py for the resolution order, and ref.py for the oracles.
"""
from repro.kernels import dispatch, ref
from repro.kernels.ops import (dcn_cross, embedding_bag, examination_nll,
                               flash_attention, fm_interaction,
                               override_impl, resolve_impl, session_nll,
                               set_impl_override)

__all__ = ["embedding_bag", "fm_interaction", "dcn_cross", "flash_attention",
           "session_nll", "examination_nll", "override_impl", "resolve_impl",
           "set_impl_override", "dispatch", "ref"]
