"""Pallas TPU kernels for the perf-critical hot spots (+ jnp oracles).

Kernels: embedding_bag (CLAX tables / recsys bags / GNN aggregation),
fm_interaction (DeepFM), dcn_cross (DCN-V2 towers, paper Listing 4),
flash_attention (BST / AutoInt / LM archs), session_nll (fused CTR-family
click loss). See ops.py for the public API and ref.py for the oracles.
"""
from repro.kernels.ops import (embedding_bag, fm_interaction, dcn_cross,
                               flash_attention, session_nll)
from repro.kernels import ref

__all__ = ["embedding_bag", "fm_interaction", "dcn_cross", "flash_attention",
           "session_nll", "ref"]
