"""Factorization-machine second-order interaction Pallas kernel.

out[b] = 0.5 * sum_d [(sum_f V[b,f,d])^2 - sum_f V[b,f,d]^2]   [Rendle 2010]

TPU mapping: one grid step per batch block; the (bb, F, D) tile lives in
VMEM and both reductions fuse into a single pass (VPU element-wise +
cross-lane reduce), so HBM traffic is exactly one read of V and one (bb, 1)
write — the op is bandwidth-bound and this is its floor. The jnp reference
materializes sum/square intermediates; XLA usually fuses them too, but the
kernel guarantees it and keeps the fp32 accumulation explicit for bf16 in.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _fm_kernel(v_ref, o_ref):
    v = v_ref[...].astype(jnp.float32)  # (bb, F, D)
    sum_f = jnp.sum(v, axis=1)          # (bb, D)
    sum_sq = jnp.square(sum_f)
    sq_sum = jnp.sum(jnp.square(v), axis=1)
    o_ref[...] = 0.5 * jnp.sum(sum_sq - sq_sum, axis=-1, keepdims=True)


def fm_interaction_pallas(v: jax.Array, *, block_b: int = 128,
                          interpret: bool = False) -> jax.Array:
    """v: (B, F, D) field embeddings -> (B,) fp32 FM logit term."""
    B, F, D = v.shape
    d_pad = (-D) % LANE
    b_pad = (-B) % block_b
    if d_pad or b_pad:
        v = jnp.pad(v, ((0, b_pad), (0, 0), (0, d_pad)))
    Bp, Dp = B + b_pad, D + d_pad
    out = pl.pallas_call(
        _fm_kernel,
        grid=(Bp // block_b,),
        in_specs=[pl.BlockSpec((block_b, F, Dp), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 1), jnp.float32),
        interpret=interpret,
    )(v)
    return out[:B, 0]
