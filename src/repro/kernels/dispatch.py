"""Single dispatch registry for every kernel in the package.

Each kernel registers up to three implementations:

  ``pallas``  the Pallas lowering — compiled on TPU, interpret-mode elsewhere
              (a correctness tool, not a fast path off-TPU),
  ``xla``     the best XLA-fusable jnp expression (the fast path on CPU/GPU),
  ``ref``     the pure-jnp mathematical definition from :mod:`repro.kernels.ref`
              (the conformance oracle — no performance tricks).

Resolution happens **at trace time**, per call, in this order:

  1. an explicit ``impl=`` argument at the call site,
  2. a per-kernel programmatic override (:func:`override_impl` /
     :func:`set_impl_override`),
  3. a global programmatic override,
  4. the ``CLAX_KERNEL_IMPL_<NAME>`` environment variable (per kernel),
  5. the ``CLAX_KERNEL_IMPL`` environment variable (all kernels),
  6. the backend default: ``pallas`` on TPU, ``xla`` everywhere else.

Because resolution runs while JAX traces, an override only affects functions
traced (or retraced) after it is set: already-compiled programs — e.g. the
scan-jitted :class:`repro.train.engine.TrainEngine` chunk step — keep the impl
they were traced with and are **not** retraced by flipping an override (pinned
by tests/test_dispatch.py). That is the intended drill semantics: flip the
env var, restart the job, every kernel re-resolves.
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Callable, Dict, Optional, Tuple

import jax

IMPLS = ("pallas", "ref", "xla")

ENV_GLOBAL = "CLAX_KERNEL_IMPL"

_REGISTRY: Dict[str, Dict[str, Callable]] = {}
_OVERRIDES: Dict[str, str] = {}  # kernel name (or "*") -> impl

_GLOBAL = "*"


def _env_key(name: str) -> str:
    return f"{ENV_GLOBAL}_{name.upper()}"


def register(name: str, impl: str, fn: Callable) -> Callable:
    """Register ``fn`` as the ``impl`` implementation of kernel ``name``."""
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    _REGISTRY.setdefault(name, {})[impl] = fn
    return fn


def registered_kernels() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def kernel_impls(name: str) -> Tuple[str, ...]:
    """Implementations registered for ``name`` (registry order: pallas/ref/xla)."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown kernel {name!r}; registered: "
                       f"{registered_kernels()}")
    return tuple(i for i in IMPLS if i in _REGISTRY[name])


def default_impl() -> str:
    """Backend default: the compiled Pallas path on TPU, XLA elsewhere.

    Off-TPU the Pallas kernels only run in interpret mode (per-grid-step
    Python execution), so the fused jnp expression is the fast path there.
    """
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def resolve_impl(name: str, impl: Optional[str] = None) -> str:
    """Resolve the implementation for ``name`` (see module docstring order)."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown kernel {name!r}; registered: "
                       f"{registered_kernels()}")
    chosen = (impl
              or _OVERRIDES.get(name)
              or _OVERRIDES.get(_GLOBAL)
              or os.environ.get(_env_key(name))
              or os.environ.get(ENV_GLOBAL)
              or default_impl())
    if chosen not in _REGISTRY[name]:
        raise ValueError(
            f"kernel {name!r} has no impl {chosen!r}; available: "
            f"{kernel_impls(name)}")
    return chosen


def dispatch(name: str, impl: Optional[str], *args, **kwargs):
    """Resolve and call kernel ``name``; ``impl=None`` follows the chain."""
    return _REGISTRY[name][resolve_impl(name, impl)](*args, **kwargs)


def get_impl(name: str, impl: Optional[str] = None) -> Callable:
    """The callable that :func:`dispatch` would invoke right now."""
    return _REGISTRY[name][resolve_impl(name, impl)]


def set_impl_override(impl: Optional[str], kernel: Optional[str] = None) -> None:
    """Force ``impl`` for one kernel (or all, ``kernel=None``); ``None`` clears.

    Process-wide and trace-time only — see the module docstring for what that
    means for already-compiled programs. Prefer :func:`override_impl` in tests.
    """
    key = kernel or _GLOBAL
    if impl is None:
        _OVERRIDES.pop(key, None)
    else:
        if impl not in IMPLS:
            raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
        _OVERRIDES[key] = impl


@contextmanager
def override_impl(impl: Optional[str] = None, **per_kernel: str):
    """Scoped impl override: ``override_impl("ref")`` forces every kernel,
    ``override_impl(session_nll="ref")`` just one. Restores prior state."""
    saved = dict(_OVERRIDES)
    try:
        if impl is not None:
            set_impl_override(impl)
        for name, i in per_kernel.items():
            set_impl_override(i, kernel=name)
        yield
    finally:
        _OVERRIDES.clear()
        _OVERRIDES.update(saved)
