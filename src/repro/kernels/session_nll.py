"""Fused session negative-log-likelihood Pallas kernel.

Computes the masked-mean Bernoulli click NLL directly from logits:

    nll[b, k] = -[c log sigmoid(x) + (1-c) log(1 - sigmoid(x))]
              = softplus(x) - c * x
    out      = sum(mask * nll) / max(sum(mask), 1)

The jnp path materializes three (B, K) intermediates (log_sigmoid, log1mexp,
BCE) before the reduction; here the whole chain runs per VMEM tile and only
per-block partial sums leave the kernel, so HBM traffic is one read of the
logits/clicks/mask and a (G, 1) write. The final G-element reduction happens
outside the kernel (G = B / block_b scalars — negligible).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _session_nll_kernel(x_ref, c_ref, m_ref, sum_ref, cnt_ref):
    x = x_ref[...].astype(jnp.float32)   # (bb, Kp)
    c = c_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    # softplus(x) - c*x, the stable fused form of log_sigmoid -> log1mexp -> BCE
    nll = jnp.maximum(x, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(x))) - c * x
    sum_ref[...] = jnp.sum(nll * m, keepdims=True).reshape(1, 1)
    cnt_ref[...] = jnp.sum(m, keepdims=True).reshape(1, 1)


def session_nll_pallas(logits: jax.Array, clicks: jax.Array, mask: jax.Array,
                       *, block_b: int = 256, interpret: bool = False
                       ) -> jax.Array:
    """logits/clicks/mask: (B, K) -> scalar fp32 masked-mean NLL."""
    B, K = logits.shape
    k_pad = (-K) % LANE
    b_pad = (-B) % block_b
    m = mask.astype(jnp.float32)
    if k_pad or b_pad:
        logits = jnp.pad(logits, ((0, b_pad), (0, k_pad)))
        clicks = jnp.pad(clicks.astype(jnp.float32), ((0, b_pad), (0, k_pad)))
        m = jnp.pad(m, ((0, b_pad), (0, k_pad)))  # zero weight on padding
    grid = (logits.shape[0] // block_b,)
    sums, counts = pl.pallas_call(
        _session_nll_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, logits.shape[1]), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((1, 1), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((grid[0], 1), jnp.float32)] * 2,
        interpret=interpret,
    )(logits, clicks.astype(logits.dtype), m.astype(logits.dtype))
    return jnp.sum(sums) / jnp.maximum(jnp.sum(counts), 1.0)
