"""Fused examination-chain NLL Pallas kernel (DCM / CCM / DBN / SDBN loss).

One pass from per-position probability factors to the scalar loss:

    factors -> capped affine death-odds scan -> conditional log-probs -> NLL

The unfused path (PR 1) materializes the (B, K) odds, the (B, K) conditional
log-probabilities, and the (B, K) per-element BCE before the masked mean —
three HBM round-trips of the batch. Here the whole chain runs inside one VMEM
tile: the affine recurrence z_k = a_k z_{k-1} + b_k is solved in-register with
a Hillis-Steele doubling scan along the lane axis (ceil(log2 K) capped
multiply-add rounds), and only a (G, 1) partial sum / count pair per grid
block ever leaves the kernel.

Numerics follow :mod:`repro.core.recursions` exactly: the same ODDS_FLOOR on
denominators, the same ODDS_CAP saturation on the odds (finite log-probability
with zero gradient for dead chains, never inf/NaN), and the same GROWTH_CAP on
composite growth products so the capped combine stays order-insensitive for
every un-saturated span. The NLL uses the two-log fused form

    log P(C=1)  = min(x, 0) - log1p(r + e + r e)              e = exp(-|x|)
    log P(C=0)  = log(s + r (1 + e)) - log1p(r + e + r e)     s = e if x>=0 else 1

(the complement computed directly from the same denominator instead of
log1mexp of the first line — one extra log, no cancellation, no (B, K)
log-prob intermediate).

Gradients never flow through this lowering: the public
:func:`repro.kernels.ops.examination_nll` wraps every impl in a custom VJP
whose backward pass is ``jax.vjp`` of the ref composition, so all impls share
the saturating gradient semantics of ``core/recursions`` bit-for-bit.

``examination_nll_xla`` is the fused jnp counterpart (same two-log form, odds
via the associative scan) — the fast path on CPU/GPU where Pallas only
interprets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _examination_nll_kernel(floor, cap, growth_cap,
                            x_ref, c_ref, m_ref, ss_ref, pd_ref, pr_ref,
                            prn_ref, sum_ref, cnt_ref):
    f32 = jnp.float32
    x = x_ref[...].astype(f32)        # (bb, Kp) attraction logits
    c = c_ref[...].astype(f32)        # clicks
    m = m_ref[...].astype(f32)        # mask weights
    pss = ss_ref[...].astype(f32)     # p_skip_survive
    pd = pd_ref[...].astype(f32)      # p_death
    pr = pr_ref[...].astype(f32)      # p_reset
    prn = prn_ref[...].astype(f32)    # p_reset_not

    clicked = (c > 0).astype(f32)
    keep = 1.0 - clicked
    a = keep / jnp.maximum(pss, floor)
    b = jnp.minimum(a * pd + clicked * (prn / jnp.maximum(pr, floor)), cap)

    # Hillis-Steele inclusive scan of z_k = a_k z_{k-1} + b_k along lanes.
    # Each round folds the prefix `off` positions back: identity fill
    # (a=1, b=0) below the offset. b must update before a (the combine uses
    # the pre-round a as the right factor). Caps mirror _affine_scan_impl.
    kp = x.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    off = 1
    while off < kp:
        a_sh = jnp.where(lane >= off, jnp.roll(a, off, axis=1), 1.0)
        b_sh = jnp.where(lane >= off, jnp.roll(b, off, axis=1), 0.0)
        b = jnp.minimum(a * b_sh + b, cap)
        a = jnp.minimum(a * a_sh, growth_cap)
        off *= 2

    # r_k = z_{k-1} (virtual sure-reset start: r_0 = 0).
    r = jnp.where(lane >= 1, jnp.roll(b, 1, axis=1), 0.0)

    e = jnp.exp(-jnp.abs(x))
    denom = jnp.log1p(r + e + r * e)
    log_p = jnp.minimum(x, 0.0) - denom
    s = jnp.where(x >= 0, e, 1.0)
    log_1mp = jnp.log(s + r * (1.0 + e)) - denom
    nll = -(c * log_p + (1.0 - c) * log_1mp)
    sum_ref[...] = jnp.sum(nll * m, keepdims=True).reshape(1, 1)
    cnt_ref[...] = jnp.sum(m, keepdims=True).reshape(1, 1)


def examination_nll_pallas(attr_logits: jax.Array, clicks: jax.Array,
                           mask: jax.Array, p_skip_survive: jax.Array,
                           p_death: jax.Array, p_reset: jax.Array,
                           p_reset_not: jax.Array, *, block_b: int = 256,
                           interpret: bool = False) -> jax.Array:
    """All inputs (B, K) -> scalar fp32 masked-mean conditional click NLL."""
    from repro.core.recursions import GROWTH_CAP, ODDS_CAP, ODDS_FLOOR

    B, K = attr_logits.shape
    k_pad = (-K) % LANE
    b_pad = (-B) % block_b
    m = mask.astype(jnp.float32)
    inputs = [attr_logits.astype(jnp.float32), clicks.astype(jnp.float32), m,
              p_skip_survive.astype(jnp.float32), p_death.astype(jnp.float32),
              p_reset.astype(jnp.float32), p_reset_not.astype(jnp.float32)]
    if k_pad or b_pad:
        # Identity padding: no click, unit survive, sure reset, zero weight —
        # padded positions are scan no-ops and drop out of the masked sum.
        fills = (0.0, 0.0, 0.0, 1.0, 0.0, 1.0, 0.0)
        inputs = [jnp.pad(arr, ((0, b_pad), (0, k_pad)), constant_values=f)
                  for arr, f in zip(inputs, fills)]
    grid = (inputs[0].shape[0] // block_b,)
    kernel = functools.partial(_examination_nll_kernel,
                               ODDS_FLOOR, ODDS_CAP, GROWTH_CAP)
    sums, counts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, inputs[0].shape[1]),
                               lambda i: (i, 0))] * 7,
        out_specs=[pl.BlockSpec((1, 1), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((grid[0], 1), jnp.float32)] * 2,
        interpret=interpret,
    )(*inputs)
    return jnp.sum(sums) / jnp.maximum(jnp.sum(counts), 1.0)


def examination_nll_xla(attr_logits: jax.Array, clicks: jax.Array,
                        mask: jax.Array, p_skip_survive: jax.Array,
                        p_death: jax.Array, p_reset: jax.Array,
                        p_reset_not: jax.Array) -> jax.Array:
    """Fused jnp form: associative-scan odds + the kernel's two-log NLL."""
    from repro.core.recursions import conditional_examination_odds

    x = attr_logits.astype(jnp.float32)
    c = clicks.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    r = conditional_examination_odds(c, p_skip_survive, p_death, p_reset,
                                     p_reset_not)
    e = jnp.exp(-jnp.abs(x))
    denom = jnp.log1p(r + e + r * e)
    log_p = jnp.minimum(x, 0.0) - denom
    s = jnp.where(x >= 0, e, 1.0)
    log_1mp = jnp.log(s + r * (1.0 + e)) - denom
    nll = -(c * log_p + (1.0 - c) * log_1mp)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
