"""DCN-V2 cross-layer Pallas kernel: y = x0 * (x @ W + b) + x  [Wang 2021].

TPU mapping: grid over batch blocks; W (D, D) stays VMEM-resident across all
batch steps (D <= ~1k for recsys towers, so W is <= 4 MB — well inside the
~16 MB VMEM), the (bb, D) @ (D, D) matmul hits the MXU with fp32
accumulation, and the x0 *, + x epilogue fuses in the same tile, saving two
HBM round-trips of the (B, D) intermediate vs unfused ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _cross_kernel(x0_ref, x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    x0 = x0_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    bias = b_ref[...].astype(jnp.float32)  # (1, D)
    xw = jnp.dot(x, w, preferred_element_type=jnp.float32)
    o_ref[...] = x0 * (xw + bias) + x


def dcn_cross_pallas(x0: jax.Array, x: jax.Array, w: jax.Array, b: jax.Array,
                     *, block_b: int = 256, interpret: bool = False) -> jax.Array:
    """x0, x: (B, D); w: (D, D); b: (D,) -> (B, D) fp32."""
    B, D = x.shape
    d_pad = (-D) % LANE
    b_pad = (-B) % block_b
    if d_pad or b_pad:
        x0 = jnp.pad(x0, ((0, b_pad), (0, d_pad)))
        x = jnp.pad(x, ((0, b_pad), (0, d_pad)))
        w = jnp.pad(w, ((0, d_pad), (0, d_pad)))
        b = jnp.pad(b, ((0, d_pad),))
    Bp, Dp = B + b_pad, D + d_pad
    out = pl.pallas_call(
        _cross_kernel,
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, Dp), lambda i: (i, 0)),
            pl.BlockSpec((block_b, Dp), lambda i: (i, 0)),
            pl.BlockSpec((Dp, Dp), lambda i: (0, 0)),
            pl.BlockSpec((1, Dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, Dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, Dp), jnp.float32),
        interpret=interpret,
    )(x0, x, w, b.reshape(1, -1))
    return out[:B, :D]
