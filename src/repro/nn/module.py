"""Base Module protocol for the pure-pytree NN substrate."""
from __future__ import annotations

from typing import Any, Dict

import jax

Params = Dict[str, Any]


def split_rngs(rng: jax.Array, n: int):
    """Split an rng key into n keys (tuple)."""
    return tuple(jax.random.split(rng, n))


class Module:
    """A structure-only module: holds hyperparameters, no state.

    Subclasses implement:
      * ``init(rng) -> params``: build the parameter pytree.
      * ``__call__(params, *args, **kwargs)``: pure forward function.
    """

    def init(self, rng: jax.Array) -> Params:  # pragma: no cover - interface
        raise NotImplementedError

    def __call__(self, params: Params, *args, **kwargs):  # pragma: no cover
        raise NotImplementedError

    # Convenience: count parameters of an initialized pytree.
    @staticmethod
    def n_params(params: Params) -> int:
        leaves = jax.tree_util.tree_leaves(params)
        return int(sum(x.size for x in leaves))
