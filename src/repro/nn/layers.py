"""Core layers for the pure-pytree substrate."""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.nn import init as initializers
from repro.nn.module import Module, split_rngs

ACTIVATIONS = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "identity": lambda x: x,
}


class Dense(Module):
    """y = x @ W + b."""

    def __init__(self, in_features: int, out_features: int, use_bias: bool = True,
                 kernel_init=None, dtype=jnp.float32, param_dtype=jnp.float32):
        self.in_features = in_features
        self.out_features = out_features
        self.use_bias = use_bias
        self.kernel_init = kernel_init or initializers.lecun_normal()
        self.dtype = dtype
        self.param_dtype = param_dtype

    def init(self, rng):
        kw, _ = split_rngs(rng, 2)
        params = {"kernel": self.kernel_init(kw, (self.in_features, self.out_features), self.param_dtype)}
        if self.use_bias:
            params["bias"] = jnp.zeros((self.out_features,), self.param_dtype)
        return params

    def __call__(self, params, x):
        y = jnp.dot(x.astype(self.dtype), params["kernel"].astype(self.dtype))
        if self.use_bias:
            y = y + params["bias"].astype(self.dtype)
        return y


class Scalar(Module):
    """A single learnable scalar (or small vector) logit, e.g. GCTR's rho."""

    def __init__(self, shape=(), init_fn=None, param_dtype=jnp.float32):
        self.shape = tuple(shape)
        self.init_fn = init_fn or initializers.zeros
        self.param_dtype = param_dtype

    def init(self, rng):
        return {"value": self.init_fn(rng, self.shape, self.param_dtype)}

    def __call__(self, params):
        return params["value"]


class Embedding(Module):
    """Plain dense embedding table: ids -> rows."""

    def __init__(self, num_embeddings: int, features: int, embedding_init=None,
                 param_dtype=jnp.float32, dtype=jnp.float32):
        self.num_embeddings = num_embeddings
        self.features = features
        self.embedding_init = embedding_init or initializers.normal(0.02)
        self.param_dtype = param_dtype
        self.dtype = dtype

    def init(self, rng):
        return {"table": self.embedding_init(rng, (self.num_embeddings, self.features), self.param_dtype)}

    def __call__(self, params, ids):
        return jnp.take(params["table"], ids, axis=0).astype(self.dtype)


class LayerNorm(Module):
    def __init__(self, features: int, eps: float = 1e-6, dtype=jnp.float32,
                 param_dtype=jnp.float32, use_bias: bool = True):
        self.features = features
        self.eps = eps
        self.dtype = dtype
        self.param_dtype = param_dtype
        self.use_bias = use_bias

    def init(self, rng):
        del rng
        p = {"scale": jnp.ones((self.features,), self.param_dtype)}
        if self.use_bias:
            p["bias"] = jnp.zeros((self.features,), self.param_dtype)
        return p

    def __call__(self, params, x):
        xf = x.astype(jnp.float32)
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + self.eps)
        y = y * params["scale"].astype(jnp.float32)
        if self.use_bias:
            y = y + params["bias"].astype(jnp.float32)
        return y.astype(self.dtype)


class RMSNorm(Module):
    def __init__(self, features: int, eps: float = 1e-6, dtype=jnp.float32,
                 param_dtype=jnp.float32):
        self.features = features
        self.eps = eps
        self.dtype = dtype
        self.param_dtype = param_dtype

    def init(self, rng):
        del rng
        return {"scale": jnp.ones((self.features,), self.param_dtype)}

    def __call__(self, params, x):
        xf = x.astype(jnp.float32)
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + self.eps) * params["scale"].astype(jnp.float32)
        return y.astype(self.dtype)


class MLP(Module):
    """Multi-layer perceptron with configurable hidden dims + activation."""

    def __init__(self, in_features: int, hidden: Sequence[int], out_features: int,
                 activation: str = "relu", final_activation: str = "identity",
                 use_bias: bool = True, dtype=jnp.float32, param_dtype=jnp.float32):
        dims = [in_features, *hidden, out_features]
        self.layers = [
            Dense(dims[i], dims[i + 1], use_bias=use_bias, dtype=dtype,
                  param_dtype=param_dtype)
            for i in range(len(dims) - 1)
        ]
        self.activation = ACTIVATIONS[activation]
        self.final_activation = ACTIVATIONS[final_activation]

    def init(self, rng):
        keys = split_rngs(rng, len(self.layers))
        return {f"layer_{i}": l.init(k) for i, (l, k) in enumerate(zip(self.layers, keys))}

    def __call__(self, params, x):
        n = len(self.layers)
        for i, layer in enumerate(self.layers):
            x = layer(params[f"layer_{i}"], x)
            x = self.activation(x) if i < n - 1 else self.final_activation(x)
        return x


class DeepCrossV2(Module):
    """DCN-V2 [Wang et al. 2021]: explicit feature crosses + deep network.

    cross layer: x_{l+1} = x0 * (W_l x_l + b_l) + x_l
    combination: "stacked" (cross -> deep) or "parallel" (concat(cross, deep)).
    Final projection to ``out_features``.
    """

    def __init__(self, in_features: int, cross_layers: int = 2, deep_layers: int = 2,
                 deep_width: Optional[int] = None, out_features: int = 1,
                 combination: str = "stacked", dtype=jnp.float32,
                 param_dtype=jnp.float32):
        self.in_features = in_features
        self.cross_layers = cross_layers
        self.combination = combination
        deep_width = deep_width or in_features
        self.cross = [Dense(in_features, in_features, dtype=dtype, param_dtype=param_dtype)
                      for _ in range(cross_layers)]
        deep_in = in_features
        self.deep = MLP(deep_in, [deep_width] * max(deep_layers - 1, 0), deep_width,
                        activation="relu", final_activation="relu",
                        dtype=dtype, param_dtype=param_dtype) if deep_layers > 0 else None
        head_in = deep_width if combination == "stacked" else in_features + (deep_width if self.deep else 0)
        if self.deep is None:
            head_in = in_features
        self.head = Dense(head_in, out_features, dtype=dtype, param_dtype=param_dtype)

    def init(self, rng):
        keys = split_rngs(rng, len(self.cross) + 2)
        params = {f"cross_{i}": c.init(keys[i]) for i, c in enumerate(self.cross)}
        if self.deep is not None:
            params["deep"] = self.deep.init(keys[-2])
        params["head"] = self.head.init(keys[-1])
        return params

    def _cross_stack(self, params, x0):
        x = x0
        for i in range(self.cross_layers):
            x = x0 * self.cross[i](params[f"cross_{i}"], x) + x
        return x

    def __call__(self, params, x):
        crossed = self._cross_stack(params, x)
        if self.deep is None:
            return self.head(params["head"], crossed)
        if self.combination == "stacked":
            h = self.deep(params["deep"], crossed)
        else:  # parallel
            h = jnp.concatenate([crossed, self.deep(params["deep"], x)], axis=-1)
        return self.head(params["head"], h)


class Sequential(Module):
    def __init__(self, modules: Sequence[Module]):
        self.modules = list(modules)

    def init(self, rng):
        keys = split_rngs(rng, len(self.modules))
        return {f"mod_{i}": m.init(k) for i, (m, k) in enumerate(zip(self.modules, keys))}

    def __call__(self, params, x):
        for i, m in enumerate(self.modules):
            x = m(params[f"mod_{i}"], x)
        return x
