"""Minimal pure-pytree neural-network substrate.

The offline container has no flax/optax, so CLAX ships its own small module
system: a Module is a structure-only Python object with
``init(rng) -> params`` (a nested-dict pytree of jnp arrays) and
``__call__(params, *inputs) -> outputs``. Params are plain pytrees, so they
compose directly with jax.jit / pjit / shard_map and our optimizers.
"""
from repro.nn.module import Module, split_rngs
from repro.nn import init
from repro.nn.layers import (
    Dense,
    Embedding,
    LayerNorm,
    RMSNorm,
    MLP,
    DeepCrossV2,
    Sequential,
    Scalar,
)

__all__ = [
    "Module",
    "split_rngs",
    "init",
    "Dense",
    "Embedding",
    "LayerNorm",
    "RMSNorm",
    "MLP",
    "DeepCrossV2",
    "Sequential",
    "Scalar",
]
