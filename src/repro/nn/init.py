"""Parameter initializers (shape, dtype) -> array factories."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def zeros(rng, shape, dtype=jnp.float32):
    del rng
    return jnp.zeros(shape, dtype)


def ones(rng, shape, dtype=jnp.float32):
    del rng
    return jnp.ones(shape, dtype)


def constant(value):
    def _init(rng, shape, dtype=jnp.float32):
        del rng
        return jnp.full(shape, value, dtype)

    return _init


def normal(stddev=0.02):
    def _init(rng, shape, dtype=jnp.float32):
        return (jax.random.normal(rng, shape) * stddev).astype(dtype)

    return _init


def truncated_normal(stddev=0.02):
    def _init(rng, shape, dtype=jnp.float32):
        return (jax.random.truncated_normal(rng, -2.0, 2.0, shape) * stddev).astype(dtype)

    return _init


def _fans(shape):
    if len(shape) == 1:
        return shape[0], shape[0]
    receptive = 1
    for s in shape[:-2]:
        receptive *= s
    return shape[-2] * receptive, shape[-1] * receptive


def lecun_normal():
    def _init(rng, shape, dtype=jnp.float32):
        fan_in, _ = _fans(shape)
        std = (1.0 / max(fan_in, 1)) ** 0.5
        return (jax.random.truncated_normal(rng, -2.0, 2.0, shape) * std).astype(dtype)

    return _init


def glorot_uniform():
    def _init(rng, shape, dtype=jnp.float32):
        fan_in, fan_out = _fans(shape)
        limit = (6.0 / max(fan_in + fan_out, 1)) ** 0.5
        return jax.random.uniform(rng, shape, minval=-limit, maxval=limit).astype(dtype)

    return _init


def logit_of_prob(p: float):
    """Initialize a parameter so sigmoid(param) == p (CLAX CTR-style init)."""
    import math

    v = math.log(p) - math.log1p(-p)
    return constant(v)
