"""Mesh-axis conventions and sharding-rule helpers.

Axis convention (see DESIGN.md §5):
  * ``pod``   — outer data-parallel axis crossing the inter-pod DCI links.
  * ``data``  — in-pod data parallelism.
  * ``model`` — tensor/expert/embedding-table parallelism over ICI.

Batch dims shard over (pod, data); tables/weights shard over model.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"


def DATA_AXES(mesh) -> tuple:
    """Data-parallel axes present in this mesh ('pod' included if multi-pod)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_parallel_size(mesh) -> int:
    """Total ways the batch axis splits: product of the data-axis sizes."""
    size = 1
    for axis in DATA_AXES(mesh):
        size *= mesh.shape[axis]
    return size


def batch_spec(mesh, extra_dims: int = 1) -> P:
    """Leading dim over all data axes; remaining dims replicated."""
    return P(DATA_AXES(mesh), *([None] * extra_dims))


def chunked_batch_spec(mesh) -> P:
    """Spec for a ``(chunk, batch, ...)`` stacked-batch array: the chunk axis
    is scanned over (replicated), the batch axis splits over the data axes,
    trailing dims replicated (a PartitionSpec shorter than the rank leaves
    the remaining dims unsharded)."""
    return P(None, DATA_AXES(mesh))


def table_spec(mesh, extra_dims: int = 1) -> P:
    """Row-sharded embedding table / stacked weight over the model axis."""
    return P(MODEL_AXIS, *([None] * extra_dims))


def replicated_spec() -> P:
    return P()


def make_shardings(mesh, tree: Any, rule: Callable[[tuple, Any], P]):
    """Build a NamedSharding pytree from a (path, leaf) -> PartitionSpec rule."""
    def to_sharding(path, leaf):
        spec = rule(path, leaf)
        return NamedSharding(mesh, spec if spec is not None else P())

    return jax.tree_util.tree_map_with_path(to_sharding, tree)


def clax_param_rule(mesh, min_rows_to_shard: int = 1 << 16,
                    leading_axes: int = 0):
    """Sharding rule for CLAX/recsys params: big tables row-sharded over
    'model', everything else replicated (dense towers are tiny).

    ``leading_axes=k`` skips k leading dims before the row-count test and
    leaves them replicated — e.g. the ``(R,)`` replica axis of a vmapped
    sweep (every replica's table shards identically over 'model' while the
    replica axis stays replicated, composing with the data-sharded batch).
    """
    model_size = mesh.shape[MODEL_AXIS]

    def rule(path, leaf):
        row_dim = leading_axes
        if leaf.ndim >= row_dim + 1 and leaf.shape[row_dim] >= min_rows_to_shard \
                and leaf.shape[row_dim] % model_size == 0:
            return P(*([None] * row_dim), MODEL_AXIS,
                     *([None] * (leaf.ndim - row_dim - 1)))
        return P()

    return rule
