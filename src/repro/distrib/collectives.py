"""shard_map collectives: sharded embedding lookup + MoE dispatch.

Two lookup strategies for row-sharded tables (the CLAX scale story):

* ``sharded_embedding_lookup`` — pjit-auto: annotate shardings and let XLA
  pick collectives. Paper-faithful baseline ("let JAX handle it"). XLA
  typically all-gathers indices to every model shard and reduce-scatters or
  all-reduces the gathered rows.

* ``masked_psum_lookup`` — explicit shard_map: every model shard gathers the
  rows it owns (ids outside its range contribute zeros) and one psum over the
  model axis assembles full activations. Wire bytes = batch_items x dim x 4,
  *independent of table size*, and the gather stays local to the shard. This
  is the beyond-paper optimization measured in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.distrib.shardings import DATA_AXES, MODEL_AXIS


def sharded_embedding_lookup(table: jax.Array, ids: jax.Array, mesh) -> jax.Array:
    """pjit-auto baseline: constrain shardings, let XLA insert collectives."""
    table = jax.lax.with_sharding_constraint(
        table, jax.sharding.NamedSharding(mesh, P(MODEL_AXIS, None)))
    ids = jax.lax.with_sharding_constraint(
        ids, jax.sharding.NamedSharding(mesh, P(DATA_AXES(mesh), None)))
    return jnp.take(table, ids, axis=0)


def masked_psum_lookup(mesh, *, batch_dims: int = 2):
    """Build a shard_map lookup: (table (N, d) P(model,None), ids (B, K) or
    (B,) P(data...)) -> embeddings (B, K, d) P(data..., None, None).

    Differentiable: the transpose scatters grads back into the owning shard
    (scatter-add stays shard-local; only activations cross the wire).
    """
    data_axes = DATA_AXES(mesh)
    ids_spec = P(data_axes, *([None] * (batch_dims - 1)))
    out_spec = P(data_axes, *([None] * batch_dims))

    def lookup(table_shard: jax.Array, ids: jax.Array) -> jax.Array:
        midx = jax.lax.axis_index(MODEL_AXIS)
        rows = table_shard.shape[0]
        local = ids - midx * rows
        owned = (local >= 0) & (local < rows)
        safe = jnp.clip(local, 0, rows - 1)
        emb = jnp.take(table_shard, safe, axis=0)
        emb = jnp.where(owned[..., None], emb, jnp.zeros_like(emb))
        return jax.lax.psum(emb, MODEL_AXIS)

    return shard_map(
        lookup, mesh=mesh,
        in_specs=(P(MODEL_AXIS, None), ids_spec),
        out_specs=out_spec,
    )


def moe_all_to_all_dispatch(mesh, n_experts: int, capacity: int):
    """GShard-style capacity-bounded MoE dispatch (top-1), shard_map body.

    Each data shard routes its local tokens into per-expert-shard send
    buffers (capacity-bounded, overflow dropped), all_to_all exchanges them
    across the model axis, expert shards run their local experts, and the
    reverse all_to_all + scatter restores token order. Exposed for the MoE
    layer in repro/models/lm/moe.py; see that module for the full layer.
    """
    raise NotImplementedError(
        "dispatch lives in repro.models.lm.moe.MoELayer (kept with the model)")
