"""Distribution layer: sharding rules, shard_map collectives, compression."""
from repro.distrib.shardings import (
    batch_spec,
    table_spec,
    replicated_spec,
    make_shardings,
    DATA_AXES,
    MODEL_AXIS,
)
from repro.distrib.compression import (
    quantize_int8,
    dequantize_int8,
    quantize_tree,
    dequantize_tree,
    tree_nbytes,
    QuantizedTensor,
    CompressedAllReduce,
)
from repro.distrib.collectives import (
    sharded_embedding_lookup,
    masked_psum_lookup,
)

__all__ = [
    "batch_spec",
    "table_spec",
    "replicated_spec",
    "make_shardings",
    "DATA_AXES",
    "MODEL_AXIS",
    "quantize_int8",
    "dequantize_int8",
    "quantize_tree",
    "dequantize_tree",
    "tree_nbytes",
    "QuantizedTensor",
    "CompressedAllReduce",
    "sharded_embedding_lookup",
    "masked_psum_lookup",
]
