"""Gradient compression for the slow (cross-pod) all-reduce.

int8 uniform quantization with per-tensor scale + error feedback (EF-SGD,
Karimireddy et al. 2019): the quantization residual is added back into the
next step's gradient, so compression bias vanishes asymptotically and
convergence matches uncompressed SGD on smooth objectives (verified in
tests/test_distrib.py on a convex problem).

Bytes on the wire drop 4x (f32->i8); on a 2-pod mesh the pod-axis all-reduce
is the longest link, so this directly attacks the collective roofline term.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


class QuantizedTensor(NamedTuple):
    """An int8 tensor + its per-tensor scale — a 4x-smaller resident copy.

    A NamedTuple is a pytree, so a params tree whose large leaves were
    swapped for ``QuantizedTensor``s still flows through ``jax.jit`` (the
    serving registry jits the dequantize-then-predict composition over it).
    """

    q: Any      # int8 payload
    scale: Any  # f32 scalar

    @property
    def nbytes(self) -> int:
        return int(self.q.size) + 4


def _is_qt(x) -> bool:
    return isinstance(x, QuantizedTensor)


def quantize_tree(tree, min_size: int = 512):
    """int8-quantize every float leaf with ``size >= min_size``.

    Small leaves (scalars, rank tables, baselines) stay f32 — quantizing
    them saves nothing and costs accuracy; the embedding tables are where
    both the bytes and the tolerance budget live. Returns the mixed tree;
    invert with :func:`dequantize_tree`. Worst-case per-element error of a
    quantized leaf is ``scale / 2`` with ``scale = max|x| / 127``.
    """

    def one(leaf):
        leaf = jnp.asarray(leaf)
        if jnp.issubdtype(leaf.dtype, jnp.floating) and leaf.size >= min_size:
            return QuantizedTensor(*quantize_int8(leaf))
        return leaf

    return jax.tree_util.tree_map(one, tree)


def dequantize_tree(tree):
    """Rebuild the f32 tree from :func:`quantize_tree`'s output (jit-safe)."""
    return jax.tree_util.tree_map(
        lambda x: dequantize_int8(x.q, x.scale) if _is_qt(x) else x,
        tree, is_leaf=_is_qt)


def tree_nbytes(tree) -> int:
    """Resident bytes of a (possibly mixed f32/int8) params tree."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=_is_qt):
        if _is_qt(leaf):
            total += leaf.nbytes
        else:
            arr = jnp.asarray(leaf)
            total += int(arr.size * arr.dtype.itemsize)
    return total


class CompressedAllReduce(NamedTuple):
    """Error-feedback state + apply fn for compressed gradient aggregation."""

    error: Any  # residual pytree

    @staticmethod
    def init(params) -> "CompressedAllReduce":
        return CompressedAllReduce(
            error=jax.tree_util.tree_map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params))

    def compress_correct(self, grads):
        """Returns (quantized payloads, new_state). Payload per leaf is
        (int8 tensor, f32 scale) — what would cross the pod links."""
        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            q, scale = quantize_int8(corrected)
            deq = dequantize_int8(q, scale)
            return (q, scale), corrected - deq

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(self.error)
        payloads, new_err = zip(*(one(g, e) for g, e in zip(flat_g, flat_e))) \
            if flat_g else ((), ())
        return (jax.tree_util.tree_unflatten(treedef, list(payloads)),
                CompressedAllReduce(
                    jax.tree_util.tree_unflatten(treedef, list(new_err))))

    @staticmethod
    def decompress(payloads):
        return jax.tree_util.tree_map(
            lambda qs: dequantize_int8(*qs), payloads,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], (jax.Array, jnp.ndarray)))


def compressed_psum(grads, axis_name: str, state: CompressedAllReduce):
    """shard_map-side compressed all-reduce over ``axis_name``.

    Quantize (with error feedback), psum the int8 payload widened to int32
    (wire bytes ~ 1B/element + negligible scale), dequantize with the
    max-scale convention, and average.
    """
    payloads, new_state = state.compress_correct(grads)

    def reduce_one(payload):
        q, scale = payload
        # All replicas agree on a shared scale (max) so the int8 sum is exact.
        shared_scale = jax.lax.pmax(scale, axis_name)
        requant = jnp.clip(
            jnp.round(dequantize_int8(q, scale) / shared_scale), -127, 127
        ).astype(jnp.int32)
        total = jax.lax.psum(requant, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return total.astype(jnp.float32) * shared_scale / n

    is_payload = lambda x: (isinstance(x, tuple) and len(x) == 2)
    reduced = jax.tree_util.tree_map(reduce_one, payloads, is_leaf=is_payload)
    return reduced, new_state
