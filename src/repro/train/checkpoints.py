"""Atomic, keep-k, elastic-restore checkpointing for pytrees.

Layout per step:  <dir>/step_<n>/
    arrays.npz      — flat {path: array} of every leaf (host numpy)
    structure.json  — treedef + dtypes + aux metadata (loader state, step, rng)
A ``COMMIT`` marker file is written last; directories without it are treated
as partial writes (e.g. a preemption mid-save) and ignored + garbage-collected.

Elastic restore: arrays are saved unsharded (host-gathered). ``restore`` takes
optional ``shardings`` (a pytree of NamedSharding) and device_puts each leaf
accordingly — so a checkpoint written on an N-device mesh restores onto any
M-device mesh whose axis sizes divide the array dims (re-sharding happens at
device_put time). This is the standard reshard-on-restore elasticity model.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

COMMIT_MARKER = "COMMIT"


def select_replica(tree, index: int):
    """Slice replica ``index`` out of an R-stacked pytree (params, opt-state,
    or a whole restored checkpoint tree): every leaf loses its leading
    replica axis. The result is shaped exactly like a single sequential
    run's state, so any replica of a sweep checkpoint resumes or tests
    standalone."""
    return jax.tree_util.tree_map(lambda x: x[index], tree)


def stack_replicas(trees):
    """Inverse of :func:`select_replica`: stack per-replica pytrees (e.g.
    checkpoints of R independent sequential runs) into one R-stacked tree a
    ``TrainEngine(replicas=R)`` sweep can resume from."""
    if not trees:
        raise ValueError("stack_replicas needs at least one tree")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._gc_partial()

    # -- public API ---------------------------------------------------------------
    def save(self, step: int, tree: Any, aux: Optional[Dict] = None) -> str:
        """Atomically write a checkpoint for ``step``."""
        final_dir = self._step_dir(step)
        tmp_dir = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=self.directory)
        try:
            arrays, structure = self._to_host(tree)
            np.savez(os.path.join(tmp_dir, "arrays.npz"), **arrays)
            with open(os.path.join(tmp_dir, "structure.json"), "w") as f:
                json.dump({"step": step, "aux": aux or {}, "keys": structure}, f)
            with open(os.path.join(tmp_dir, COMMIT_MARKER), "w") as f:
                f.write("ok")
            if os.path.exists(final_dir):
                shutil.rmtree(final_dir)
            os.rename(tmp_dir, final_dir)
        except BaseException:
            shutil.rmtree(tmp_dir, ignore_errors=True)
            raise
        self._gc_old()
        return final_dir

    def latest_step(self) -> Optional[int]:
        steps = self._committed_steps()
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None, like: Any = None,
                shardings: Any = None):
        """Restore (tree, aux). ``like`` provides the pytree structure.

        If ``shardings`` is given (pytree of NamedSharding matching ``like``),
        every leaf is device_put with its sharding — elastic restore onto a
        different mesh.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "structure.json")) as f:
            meta = json.load(f)
        arrays = np.load(os.path.join(d, "arrays.npz"))
        if like is None:
            tree = {k: arrays[k] for k in arrays.files}
        else:
            flat, treedef = _flatten_with_paths(like)
            leaves = []
            for key in flat:
                if key not in arrays:
                    raise KeyError(f"checkpoint missing leaf {key!r}")
                leaves.append(arrays[key])
            # order must match tree_flatten order of `like`
            paths_in_order = list(flat.keys())
            restored = dict(zip(paths_in_order, leaves))
            tree = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(like),
                [restored[k] for k in paths_in_order])
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, meta["aux"], meta["step"]

    # -- internals -----------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def _committed_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, name, COMMIT_MARKER)):
                steps.append(int(name.split("_")[1]))
        return steps

    def _gc_partial(self):
        for name in os.listdir(self.directory):
            path = os.path.join(self.directory, name)
            is_partial = (name.startswith(".tmp_") or
                          (name.startswith("step_") and
                           not os.path.exists(os.path.join(path, COMMIT_MARKER))))
            if is_partial:
                shutil.rmtree(path, ignore_errors=True)

    def _gc_old(self):
        steps = sorted(self._committed_steps())
        for step in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(step), ignore_errors=True)

    @staticmethod
    def _to_host(tree):
        flat, _ = _flatten_with_paths(tree)
        arrays = {}
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            arrays[key] = arr
        return arrays, list(flat.keys())
