"""Atomic, durable, keep-k, elastic-restore checkpointing for pytrees.

Layout per step:  <dir>/step_<n>/
    arrays.npz      — flat {path: array} of every leaf (host numpy)
    structure.json  — treedef + dtypes + aux metadata (loader state, step,
                      rng) + per-leaf crc32 checksums
A ``COMMIT`` marker file is written last; directories without it are treated
as partial writes (e.g. a preemption mid-save) and ignored + garbage-collected.

Durability ordering (what makes a crash at *any* instant recoverable):
``arrays.npz`` and ``structure.json`` are fsynced, then ``COMMIT`` is
written and fsynced, then the tmp directory itself is fsynced (so the
marker's directory entry is durable), then the atomic rename into place,
then the parent directory is fsynced (so the rename is durable). A power
cut between any two steps leaves either no ``step_<n>`` entry or a
COMMIT-less partial — both GC'd on the next manager construction — never a
committed-but-torn checkpoint.

Restore is **corruption-aware**: every checkpoint is validated before use
(COMMIT present, ``structure.json`` parses, ``arrays.npz`` unzips, per-leaf
crc32 matches). ``restore(step=None)`` walks committed steps newest-first
and returns the first *valid* one, quarantining (deleting) invalid entries
as it goes — a torn or bit-rotted latest checkpoint costs one save
interval, not the run. An explicitly requested step that fails validation
raises :class:`CheckpointCorruptionError`.

Elastic restore: arrays are saved unsharded (host-gathered). ``restore`` takes
optional ``shardings`` (a pytree of NamedSharding) and device_puts each leaf
accordingly — so a checkpoint written on an N-device mesh restores onto any
M-device mesh whose axis sizes divide the array dims (re-sharding happens at
device_put time). This is the standard reshard-on-restore elasticity model.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

COMMIT_MARKER = "COMMIT"


class CheckpointCorruptionError(ValueError):
    """A committed checkpoint failed validation (unreadable archive, missing
    leaf, or crc32 mismatch). Raised only for an explicitly requested step;
    latest-checkpoint restore skips invalid entries instead."""


def select_replica(tree, index: int):
    """Slice replica ``index`` out of an R-stacked pytree (params, opt-state,
    or a whole restored checkpoint tree): every leaf loses its leading
    replica axis. The result is shaped exactly like a single sequential
    run's state, so any replica of a sweep checkpoint resumes or tests
    standalone."""
    return jax.tree_util.tree_map(lambda x: x[index], tree)


def stack_replicas(trees):
    """Inverse of :func:`select_replica`: stack per-replica pytrees (e.g.
    checkpoints of R independent sequential runs) into one R-stacked tree a
    ``TrainEngine(replicas=R)`` sweep can resume from."""
    if not trees:
        raise ValueError("stack_replicas needs at least one tree")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def _leaf_crc32(arr: np.ndarray) -> str:
    return f"{zlib.crc32(np.ascontiguousarray(arr).tobytes()):08x}"


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, log_fn=print):
        self.directory = directory
        self.keep = keep
        self.log_fn = log_fn
        os.makedirs(directory, exist_ok=True)
        self._gc_partial()

    # -- public API ---------------------------------------------------------------
    def save(self, step: int, tree: Any, aux: Optional[Dict] = None) -> str:
        """Atomically + durably write a checkpoint for ``step`` (see module
        docstring for the fsync/COMMIT/rename ordering)."""
        final_dir = self._step_dir(step)
        tmp_dir = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=self.directory)
        try:
            arrays, structure = self._to_host(tree)
            checksums = {k: _leaf_crc32(v) for k, v in arrays.items()}
            arrays_path = os.path.join(tmp_dir, "arrays.npz")
            np.savez(arrays_path, **arrays)
            _fsync_file(arrays_path)
            structure_path = os.path.join(tmp_dir, "structure.json")
            with open(structure_path, "w") as f:
                json.dump({"step": step, "aux": aux or {}, "keys": structure,
                           "checksums": checksums}, f)
                f.flush()
                os.fsync(f.fileno())
            with open(os.path.join(tmp_dir, COMMIT_MARKER), "w") as f:
                f.write("ok")
                f.flush()
                os.fsync(f.fileno())
            _fsync_dir(tmp_dir)
            if os.path.exists(final_dir):
                shutil.rmtree(final_dir)
            os.rename(tmp_dir, final_dir)
            _fsync_dir(self.directory)
        except BaseException:
            shutil.rmtree(tmp_dir, ignore_errors=True)
            raise
        self._gc_old()
        return final_dir

    def latest_step(self) -> Optional[int]:
        steps = self._committed_steps()
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None, like: Any = None,
                shardings: Any = None):
        """Restore (tree, aux, step). ``like`` provides the pytree structure.

        With ``step=None`` the newest committed checkpoint that passes
        validation wins; invalid ones (torn archive, crc mismatch) are
        logged and deleted so they can't shadow an older good save. An
        explicit ``step`` that fails validation raises
        :class:`CheckpointCorruptionError` — the caller asked for *that*
        state, so silently substituting another would be wrong.

        If ``shardings`` is given (pytree of NamedSharding matching ``like``),
        every leaf is device_put with its sharding — elastic restore onto a
        different mesh.
        """
        if step is None:
            meta = arrays = None
            for cand in sorted(self._committed_steps(), reverse=True):
                try:
                    meta, arrays = self._load_validated(cand)
                    break
                except CheckpointCorruptionError as e:
                    self.log_fn(f"[checkpoints] step {cand} is corrupt "
                                f"({e}); deleting and falling back")
                    shutil.rmtree(self._step_dir(cand), ignore_errors=True)
            if meta is None:
                raise FileNotFoundError(
                    f"no valid committed checkpoints in {self.directory}")
        else:
            meta, arrays = self._load_validated(step)
        if like is None:
            tree = dict(arrays)
        else:
            flat, treedef = _flatten_with_paths(like)
            leaves = []
            for key in flat:
                if key not in arrays:
                    raise KeyError(f"checkpoint missing leaf {key!r}")
                leaves.append(arrays[key])
            # order must match tree_flatten order of `like`
            paths_in_order = list(flat.keys())
            restored = dict(zip(paths_in_order, leaves))
            tree = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(like),
                [restored[k] for k in paths_in_order])
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, meta["aux"], meta["step"]

    # -- internals -----------------------------------------------------------------
    def _load_validated(self, step: int):
        """Load + validate one committed checkpoint → (meta, {key: array}).

        Validation: COMMIT marker present, structure.json parses, arrays.npz
        opens and every member decompresses (the zip layer checks its own
        crc), and — for checkpoints that recorded them — per-leaf crc32
        matches. Pre-checksum checkpoints (no "checksums" key) stay
        restorable. Any failure raises CheckpointCorruptionError.
        """
        d = self._step_dir(step)
        if not os.path.exists(os.path.join(d, COMMIT_MARKER)):
            raise CheckpointCorruptionError(f"step {step}: no COMMIT marker")
        try:
            with open(os.path.join(d, "structure.json")) as f:
                meta = json.load(f)
        except (OSError, ValueError) as e:
            raise CheckpointCorruptionError(
                f"step {step}: unreadable structure.json ({e})") from e
        try:
            with np.load(os.path.join(d, "arrays.npz")) as npz:
                arrays = {k: npz[k] for k in npz.files}
        except Exception as e:
            raise CheckpointCorruptionError(
                f"step {step}: unreadable arrays.npz ({e})") from e
        checksums = meta.get("checksums")
        if checksums is not None:
            for key, want in checksums.items():
                if key not in arrays:
                    raise CheckpointCorruptionError(
                        f"step {step}: leaf {key!r} missing from arrays.npz")
                got = _leaf_crc32(arrays[key])
                if got != want:
                    raise CheckpointCorruptionError(
                        f"step {step}: crc mismatch on leaf {key!r} "
                        f"(recorded {want}, found {got})")
        return meta, arrays

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def _committed_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, name, COMMIT_MARKER)):
                steps.append(int(name.split("_")[1]))
        return steps

    def _gc_partial(self):
        for name in os.listdir(self.directory):
            path = os.path.join(self.directory, name)
            is_partial = (name.startswith(".tmp_") or
                          (name.startswith("step_") and
                           not os.path.exists(os.path.join(path, COMMIT_MARKER))))
            if is_partial:
                shutil.rmtree(path, ignore_errors=True)

    def _gc_old(self):
        steps = sorted(self._committed_steps())
        for step in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(step), ignore_errors=True)

    @staticmethod
    def _to_host(tree):
        flat, _ = _flatten_with_paths(tree)
        arrays = {}
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            arrays[key] = arr
        return arrays, list(flat.keys())
