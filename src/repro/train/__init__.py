"""Training runtime: Trainer, chunked scan engine, checkpointing, fault
tolerance."""
from repro.train.trainer import Trainer, TrainState
from repro.train.engine import TrainEngine, discover_sparse_tables
from repro.train.checkpoints import (CheckpointCorruptionError,
                                     CheckpointManager, select_replica,
                                     stack_replicas)
from repro.train.fault_tolerance import (PreemptionHandler, StepWatchdog,
                                         drop_slowest_aggregate,
                                         run_with_restarts)

__all__ = [
    "Trainer",
    "TrainState",
    "TrainEngine",
    "discover_sparse_tables",
    "CheckpointManager",
    "CheckpointCorruptionError",
    "select_replica",
    "stack_replicas",
    "PreemptionHandler",
    "StepWatchdog",
    "drop_slowest_aggregate",
    "run_with_restarts",
]
