"""Training runtime: Trainer, chunked scan engine, checkpointing, fault
tolerance."""
from repro.train.trainer import Trainer, TrainState
from repro.train.engine import TrainEngine, discover_sparse_tables
from repro.train.checkpoints import (CheckpointManager, select_replica,
                                     stack_replicas)
from repro.train.fault_tolerance import PreemptionHandler, drop_slowest_aggregate

__all__ = [
    "Trainer",
    "TrainState",
    "TrainEngine",
    "discover_sparse_tables",
    "CheckpointManager",
    "select_replica",
    "stack_replicas",
    "PreemptionHandler",
    "drop_slowest_aggregate",
]
