"""Training runtime: Trainer, checkpointing, fault tolerance."""
from repro.train.trainer import Trainer, TrainState
from repro.train.checkpoints import CheckpointManager
from repro.train.fault_tolerance import PreemptionHandler, drop_slowest_aggregate

__all__ = [
    "Trainer",
    "TrainState",
    "CheckpointManager",
    "PreemptionHandler",
    "drop_slowest_aggregate",
]
