"""Fused multi-batch training engine: scan-jitted steps, device-resident
loss accumulation, data-parallel sharding, and sparse embedding updates.

The per-batch Python loop (one jit dispatch + one blocking ``float(loss)``
host round-trip per step) starves the accelerator: the vectorized recursions
and the streaming store produce batches faster than the host can dispatch
them one at a time. The engine replaces it with a chunked execution core:

* **Chunked scan** — :class:`repro.data.DevicePrefetcher` with
  ``chunk_batches=N`` stacks N host batches into one ``(N, B, ...)`` device
  array; ``TrainEngine.step`` runs a single jit'd ``lax.scan`` over the
  chunk with donated ``(params, opt_state)``. One dispatch per N optimizer
  steps, per-step losses accumulated on device as an ``(N,)`` array the
  caller fetches asynchronously (one chunk behind — see ``Trainer.train``).
* **Data parallelism** — given a ``mesh`` (see
  :func:`repro.launch.mesh.make_data_parallel_mesh`), batches get a
  ``P(None, 'data')`` NamedSharding (chunk axis replicated, batch rows
  split) and params/opt-state get :func:`repro.distrib.shardings.clax_param_rule`
  shardings, so the same scanned step runs SPMD across all local devices.
  With ``mesh=None`` nothing is placed and the math is bit-exact with the
  historical per-batch loop (pinned by tests/test_engine.py).
* **Sparse tables** — with ``sparse_tables=True``, gradients of every
  :class:`~repro.core.parameterization.EmbeddingParameter` table part are
  routed through :mod:`repro.optim.sparse` lazy AdamW: the optimizer
  read-modify-writes only the batch's unique rows (O(U·d) state traffic
  instead of the dense 3×O(R·d) moment update), all other params keep the
  trainer's dense optimizer. Requires explicit hyperparameters
  (``sparse_table_kwargs``) because gradient-transformation chains cannot
  be introspected; lr schedules are not supported on the sparse side.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro import optim as optim_lib
from repro.core.parameterization import Compression, EmbeddingParameter
from repro.optim.sparse import (init_sparse_table_state, sparse_adamw_update,
                                unique_rows_with_sentinel)

SPARSE_PATH_SEP = "/"


def _tree_get(tree, path: Tuple[str, ...]):
    for key in path:
        tree = tree[key]
    return tree


def _tree_set(tree, path: Tuple[str, ...], value):
    """Functionally replace ``tree[path]`` (nested dicts) with ``value``."""
    if not path:
        return value
    out = dict(tree)
    out[path[0]] = _tree_set(tree[path[0]], path[1:], value)
    return out


def discover_sparse_tables(model) -> Dict[Tuple[str, ...], EmbeddingParameter]:
    """Map param path -> EmbeddingParameter for every table part of ``model``.

    Only single-table parameterizations qualify: QR compression splits each
    logical row across two tables and has no single row-id stream.
    """
    parts = getattr(model, "parts", None) or {}
    out = {}
    for name, part in parts.items():
        if isinstance(part, EmbeddingParameter):
            if part.config.compression == Compression.QR:
                raise NotImplementedError(
                    f"sparse_tables: part {name!r} uses quotient-remainder "
                    "compression (two coupled tables, no single row-id "
                    "stream) — train it with the dense optimizer")
            out[(name, "table")] = part
    if not out:
        raise ValueError(
            "sparse_tables=True but the model has no EmbeddingParameter "
            "parts — nothing to update sparsely")
    return out


class TrainEngine:
    """Chunked, optionally data-parallel and table-sparse, train-step core.

    Usage (what ``Trainer.train`` does)::

        engine = TrainEngine(model, optimizer, chunk_batches=16, mesh=mesh)
        opt_state = engine.init_opt_state(params)
        params, opt_state = engine.place(params, opt_state)
        for chunk, loader_state, n in DevicePrefetcher(
                loader, chunk_batches=engine.chunk_batches,
                device=engine.batch_sharding()):
            params, opt_state, losses = engine.step(params, opt_state, chunk)
            # losses: (n,) device array — fetch it one chunk behind

    ``step`` retraces per distinct chunk shape: full chunks plus one
    compile per tail shape (a shorter trailing chunk, and the odd-sized
    ``drop_last=False`` batch in its own chunk).
    """

    def __init__(self, model, optimizer, *, chunk_batches: int = 1,
                 mesh=None, sparse_tables: bool = False,
                 sparse_table_kwargs: Optional[Dict[str, Any]] = None,
                 loss_fn: Optional[Callable] = None):
        if chunk_batches < 1:
            raise ValueError(f"chunk_batches must be >= 1, got {chunk_batches}")
        self.model = model
        self.optimizer = optimizer
        self.chunk_batches = int(chunk_batches)
        self.mesh = mesh
        self.loss_fn = loss_fn or model.compute_loss
        self.sparse_parts = discover_sparse_tables(model) if sparse_tables else {}
        if self.sparse_parts:
            kwargs = dict(sparse_table_kwargs or {})
            missing = [k for k in ("lr", "weight_decay") if k not in kwargs]
            if missing:
                # Gradient-transformation chains can't be introspected, and
                # the defaults disagree (optim.adamw decays at 1e-4,
                # sparse_adamw_update at 0.0) — silence here would quietly
                # break the touched-rows == dense-AdamW guarantee.
                raise ValueError(
                    f"sparse_tables=True needs sparse_table_kwargs with "
                    f"{missing} mirroring the dense optimizer (pass b1/b2/"
                    f"eps too if the dense optimizer overrides them)")
            self.sparse_kwargs = kwargs
        else:
            self.sparse_kwargs = {}
        self._step = jax.jit(self._chunk_step, donate_argnums=(0, 1))

    # -- optimizer state -------------------------------------------------------
    def init_opt_state(self, params):
        """Dense optimizer state, or ``{"dense": ..., "sparse": {...}}`` when
        table grads are routed through the lazy-AdamW path (table leaves are
        masked to ``None`` in the dense subtree so dense moments never
        materialize for them)."""
        if not self.sparse_parts:
            return self.optimizer.init(params)
        dense_params = params
        sparse = {}
        for path in self.sparse_parts:
            sparse[SPARSE_PATH_SEP.join(path)] = init_sparse_table_state(
                _tree_get(params, path))
            dense_params = _tree_set(dense_params, path, None)
        return {"dense": self.optimizer.init(dense_params), "sparse": sparse}

    # -- sharding --------------------------------------------------------------
    def batch_sharding(self):
        """NamedSharding for a stacked ``(chunk, batch, ...)`` array: chunk
        axis replicated (it is scanned over), batch rows split over the data
        axes. ``None`` (single-device) when no mesh is configured."""
        if self.mesh is None:
            return None
        from jax.sharding import NamedSharding

        from repro.distrib.shardings import chunked_batch_spec

        return NamedSharding(self.mesh, chunked_batch_spec(self.mesh))

    def data_parallel_size(self) -> int:
        if self.mesh is None:
            return 1
        from repro.distrib.shardings import data_parallel_size

        return data_parallel_size(self.mesh)

    def place(self, params, opt_state):
        """device_put params/opt-state with ``clax_param_rule`` shardings
        (big tables row-sharded over 'model', everything else replicated).
        No-op without a mesh."""
        if self.mesh is None:
            return params, opt_state
        from repro.distrib.shardings import clax_param_rule, make_shardings

        rule = clax_param_rule(self.mesh)
        params = jax.device_put(params, make_shardings(self.mesh, params, rule))
        opt_state = jax.device_put(
            opt_state, make_shardings(self.mesh, opt_state, rule))
        return params, opt_state

    # -- the scanned step ------------------------------------------------------
    def _one_step(self, params, opt_state, batch):
        loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
        if not self.sparse_parts:
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            params = optim_lib.apply_updates(params, updates)
            return params, opt_state, loss
        # Sparse route: mask table leaves out of the dense update (None is an
        # empty pytree node, so the dense optimizer never touches them), then
        # scatter-update each table from the batch's unique rows.
        dense_params, dense_grads = params, grads
        for path in self.sparse_parts:
            dense_params = _tree_set(dense_params, path, None)
            dense_grads = _tree_set(dense_grads, path, None)
        updates, dense_state = self.optimizer.update(
            dense_grads, opt_state["dense"], dense_params)
        new_params = optim_lib.apply_updates(dense_params, updates)
        sparse_state = {}
        for path, part in self.sparse_parts.items():
            key = SPARSE_PATH_SEP.join(path)
            table = _tree_get(params, path)
            d_table = _tree_get(grads, path)
            # Autodiff already summed duplicate lookups into d_table's rows;
            # dedupe the id stream and gather exactly those row-grads. Pad
            # slots use an out-of-range sentinel whose writes the scatter
            # drops (see optim/sparse.py).
            rows = unique_rows_with_sentinel(part.row_ids(batch),
                                             table.shape[0])
            new_table, st = sparse_adamw_update(
                table, opt_state["sparse"][key], rows,
                d_table.at[rows].get(mode="clip"), **self.sparse_kwargs)
            new_params = _tree_set(new_params, path, new_table)
            sparse_state[key] = st
        return new_params, {"dense": dense_state, "sparse": sparse_state}, loss

    def _chunk_step(self, params, opt_state, chunk):
        def body(carry, batch):
            params, opt_state = carry
            params, opt_state, loss = self._one_step(params, opt_state, batch)
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), chunk)
        return params, opt_state, losses

    def step(self, params, opt_state, chunk):
        """One fused dispatch: ``n = chunk.shape[0]`` optimizer steps.

        Donates ``(params, opt_state)``; returns the new state plus the
        ``(n,)`` per-step loss array, still on device — do not block on it
        before dispatching the next chunk.
        """
        return self._step(params, opt_state, chunk)
