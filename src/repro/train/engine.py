"""Fused multi-batch training engine: scan-jitted steps, device-resident
loss accumulation, data-parallel sharding, and sparse embedding updates.

The per-batch Python loop (one jit dispatch + one blocking ``float(loss)``
host round-trip per step) starves the accelerator: the vectorized recursions
and the streaming store produce batches faster than the host can dispatch
them one at a time. The engine replaces it with a chunked execution core:

* **Chunked scan** — :class:`repro.data.DevicePrefetcher` with
  ``chunk_batches=N`` stacks N host batches into one ``(N, B, ...)`` device
  array (assembled and ``device_put`` on the prefetcher's staging thread,
  overlapped with compute); ``TrainEngine.step`` runs a single jit'd
  ``lax.scan`` over the chunk with donated ``(params, opt_state)``. One dispatch per N optimizer
  steps, per-step losses accumulated on device as an ``(N,)`` array the
  caller fetches asynchronously (one chunk behind — see ``Trainer.train``).
* **Data parallelism** — given a ``mesh`` (see
  :func:`repro.launch.mesh.make_data_parallel_mesh`), batches get a
  ``P(None, 'data')`` NamedSharding (chunk axis replicated, batch rows
  split) and params/opt-state get :func:`repro.distrib.shardings.clax_param_rule`
  shardings, so the same scanned step runs SPMD across all local devices.
  With ``mesh=None`` nothing is placed and the math is bit-exact with the
  historical per-batch loop (pinned by tests/test_engine.py).
* **Sparse tables** — with ``sparse_tables=True``, gradients of every
  :class:`~repro.core.parameterization.EmbeddingParameter` table part are
  routed through :mod:`repro.optim.sparse` lazy AdamW: the optimizer
  read-modify-writes only the batch's unique rows (O(U·d) state traffic
  instead of the dense 3×O(R·d) moment update), all other params keep the
  trainer's dense optimizer. Requires explicit hyperparameters
  (``sparse_table_kwargs``) because gradient-transformation chains cannot
  be introspected; lr schedules are not supported on the sparse side.
* **Replica sweeps** — ``TrainEngine(replicas=R)`` stacks R independent
  training runs on a leading replica axis of ``(params, opt_state)`` and
  ``jax.vmap``s the per-batch step over that axis while the data chunk is
  broadcast: one ``lax.scan`` dispatch advances all R runs per chunk with
  batched BLAS, so an R-way seed/lr sweep costs ~1 run of dispatch
  overhead instead of R. Per-replica seeds come from
  :meth:`TrainEngine.init_replica_params`; per-replica learning rates ride
  in the optimizer state via ``optim.adamw(lr, inject_lr=True)`` +
  :meth:`TrainEngine.set_replica_lrs`. ``step`` takes an optional
  ``active`` ``(R,)`` mask: inactive replicas' params/opt-state are frozen
  in place (per-replica early stopping without retracing the compiled
  step). Per-step losses come back as an ``(n, R)`` device array.
  Memory cost is R× params/opt-state but 1× data. The ``replicas=None``
  path is byte-for-byte the PR-4 engine (pinned by tests).
* **Non-finite guard** — ``nonfinite_guard=True`` hardens every scanned
  step: the loss and every gradient leaf are reduced to one on-device
  finiteness flag, and a per-leaf ``where`` carries the previous
  ``(params, opt_state)`` through unchanged when the flag is false. The
  step is *skipped*, not retried — one poisoned batch costs one step of
  progress instead of a dead run — and the skip flag rides back with the
  per-step losses (``{"loss", "skipped"}``) so the trainer can count
  skips without a host sync. Composes with every mode above: the scan
  carries the selected state, vmapped replicas each get their own flag,
  the mesh sees only elementwise selects, and the sparse path's scatter
  results are discarded by the same select. Guard off is byte-for-byte
  the unguarded engine.
* **On-device telemetry** — ``telemetry=True`` computes per-step scalars
  (global grad-norm, post-update param-norm, and the injected learning
  rate when the optimizer carries one) *inside* the scanned step and
  stacks them next to the per-step losses the caller already drains one
  chunk behind. The telemetry rides the existing chunk payload: enabling
  it adds **zero extra host syncs per step** and zero extra dispatches,
  does not retrace the compiled chunk across steps, and leaves the update
  math untouched (params are bit-identical to ``telemetry=False`` —
  pinned by tests/test_obs.py). The payload becomes a dict
  ``{"loss", "grad_norm", "param_norm"[, "lr"][, "skipped"]}`` of
  ``(n,)`` — or ``(n, R)`` — arrays; feed it to
  :class:`repro.obs.TelemetryDrain` to accumulate epoch stats and emit
  per-step events.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim as optim_lib
from repro.core.parameterization import Compression, EmbeddingParameter
from repro.optim.sparse import (init_sparse_table_state, sparse_adamw_update,
                                unique_rows_with_sentinel)

SPARSE_PATH_SEP = "/"


def _tree_get(tree, path: Tuple[str, ...]):
    for key in path:
        tree = tree[key]
    return tree


def _tree_set(tree, path: Tuple[str, ...], value):
    """Functionally replace ``tree[path]`` (nested dicts) with ``value``."""
    if not path:
        return value
    out = dict(tree)
    out[path[0]] = _tree_set(tree[path[0]], path[1:], value)
    return out


def discover_sparse_tables(model) -> Dict[Tuple[str, ...], EmbeddingParameter]:
    """Map param path -> EmbeddingParameter for every table part of ``model``.

    Only single-table parameterizations qualify: QR compression splits each
    logical row across two tables and has no single row-id stream.
    """
    parts = getattr(model, "parts", None) or {}
    out = {}
    for name, part in parts.items():
        if isinstance(part, EmbeddingParameter):
            if part.config.compression == Compression.QR:
                raise NotImplementedError(
                    f"sparse_tables: part {name!r} uses quotient-remainder "
                    "compression (two coupled tables, no single row-id "
                    "stream) — train it with the dense optimizer")
            out[(name, "table")] = part
    if not out:
        raise ValueError(
            "sparse_tables=True but the model has no EmbeddingParameter "
            "parts — nothing to update sparsely")
    return out


class TrainEngine:
    """Chunked, optionally data-parallel and table-sparse, train-step core.

    Usage (what ``Trainer.train`` does)::

        engine = TrainEngine(model, optimizer, chunk_batches=16, mesh=mesh)
        opt_state = engine.init_opt_state(params)
        params, opt_state = engine.place(params, opt_state)
        for chunk, loader_state, n in DevicePrefetcher(
                loader, chunk_batches=engine.chunk_batches,
                device=engine.batch_sharding()):
            params, opt_state, losses = engine.step(params, opt_state, chunk)
            # losses: (n,) device array — fetch it one chunk behind

    ``step`` retraces per distinct chunk shape: full chunks plus one
    compile per tail shape (a shorter trailing chunk, and the odd-sized
    ``drop_last=False`` batch in its own chunk).
    """

    def __init__(self, model, optimizer, *, chunk_batches: int = 1,
                 mesh=None, sparse_tables: bool = False,
                 sparse_table_kwargs: Optional[Dict[str, Any]] = None,
                 loss_fn: Optional[Callable] = None,
                 replicas: Optional[int] = None,
                 nonfinite_guard: bool = False,
                 telemetry: bool = False):
        if chunk_batches < 1:
            raise ValueError(f"chunk_batches must be >= 1, got {chunk_batches}")
        if replicas is not None and replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.model = model
        self.optimizer = optimizer
        self.chunk_batches = int(chunk_batches)
        self.mesh = mesh
        self.replicas = None if replicas is None else int(replicas)
        self.nonfinite_guard = bool(nonfinite_guard)
        self.telemetry = bool(telemetry)
        self.loss_fn = loss_fn or model.compute_loss
        self.sparse_parts = discover_sparse_tables(model) if sparse_tables else {}
        if self.sparse_parts:
            kwargs = dict(sparse_table_kwargs or {})
            missing = [k for k in ("lr", "weight_decay") if k not in kwargs]
            if missing:
                # Gradient-transformation chains can't be introspected, and
                # the defaults disagree (optim.adamw decays at 1e-4,
                # sparse_adamw_update at 0.0) — silence here would quietly
                # break the touched-rows == dense-AdamW guarantee.
                raise ValueError(
                    f"sparse_tables=True needs sparse_table_kwargs with "
                    f"{missing} mirroring the dense optimizer (pass b1/b2/"
                    f"eps too if the dense optimizer overrides them)")
            self.sparse_kwargs = kwargs
        else:
            self.sparse_kwargs = {}
        if self.replicas is None:
            chunk_fn = (self._chunk_step_guarded if self.nonfinite_guard
                        else self._chunk_step)
            self._step = jax.jit(chunk_fn, donate_argnums=(0, 1))
        else:
            # Two compiled variants: the all-active fast path skips the
            # per-leaf freeze select entirely (the whole sweep until the
            # first replica early-stops), the masked path freezes inactive
            # replicas in place. `step` picks host-side per call.
            self._step = jax.jit(self._replica_chunk_step,
                                 donate_argnums=(0, 1))
            self._step_masked = jax.jit(self._replica_chunk_step_masked,
                                        donate_argnums=(0, 1))

    # -- optimizer state -------------------------------------------------------
    def _init_opt_state_single(self, params):
        if not self.sparse_parts:
            return self.optimizer.init(params)
        dense_params = params
        sparse = {}
        for path in self.sparse_parts:
            sparse[SPARSE_PATH_SEP.join(path)] = init_sparse_table_state(
                _tree_get(params, path))
            dense_params = _tree_set(dense_params, path, None)
        return {"dense": self.optimizer.init(dense_params), "sparse": sparse}

    def init_opt_state(self, params):
        """Dense optimizer state, or ``{"dense": ..., "sparse": {...}}`` when
        table grads are routed through the lazy-AdamW path (table leaves are
        masked to ``None`` in the dense subtree so dense moments never
        materialize for them). With ``replicas=R``, ``params`` must carry the
        leading replica axis (see :meth:`init_replica_params`) and every
        state leaf comes back R-stacked."""
        if self.replicas is None:
            return self._init_opt_state_single(params)
        return jax.vmap(self._init_opt_state_single)(params)

    # -- replica sweeps --------------------------------------------------------
    def init_replica_params(self, seeds) -> Any:
        """Stacked params: replica i initialized from ``PRNGKey(seeds[i])``.

        Replica i's slice is exactly what ``model.init(PRNGKey(seeds[i]))``
        would produce standalone, so a vmapped sweep run is comparable
        leaf-for-leaf with a sequential run of the same seed.
        """
        if self.replicas is None:
            raise ValueError("init_replica_params needs TrainEngine(replicas=R)")
        seeds = jnp.asarray(seeds)
        if seeds.ndim != 1 or seeds.shape[0] != self.replicas:
            raise ValueError(f"need exactly {self.replicas} seeds, got "
                             f"shape {seeds.shape}")
        keys = jax.vmap(jax.random.PRNGKey)(seeds)
        return jax.vmap(self.model.init)(keys)

    def set_replica_lrs(self, opt_state, lrs):
        """Give every replica its own learning rate.

        Requires an optimizer built with ``inject_lr=True`` (the lr must be
        a state leaf to differ across the vmapped replica axis) and no
        sparse tables (the lazy-AdamW path takes its lr as a static
        hyperparameter shared by all replicas).
        """
        from repro.optim import set_injected_lr

        if self.replicas is None:
            raise ValueError("set_replica_lrs needs TrainEngine(replicas=R)")
        if self.sparse_parts:
            raise NotImplementedError(
                "per-replica learning rates are not supported with "
                "sparse_tables: sparse_table_kwargs['lr'] is a static "
                "hyperparameter shared across replicas")
        lrs = jnp.asarray(lrs, jnp.float32)
        if lrs.shape != (self.replicas,):
            raise ValueError(f"need exactly {self.replicas} learning rates, "
                             f"got shape {lrs.shape}")
        return set_injected_lr(opt_state, lrs)

    # -- sharding --------------------------------------------------------------
    def batch_sharding(self):
        """NamedSharding for a stacked ``(chunk, batch, ...)`` array: chunk
        axis replicated (it is scanned over), batch rows split over the data
        axes. ``None`` (single-device) when no mesh is configured."""
        if self.mesh is None:
            return None
        from jax.sharding import NamedSharding

        from repro.distrib.shardings import chunked_batch_spec

        return NamedSharding(self.mesh, chunked_batch_spec(self.mesh))

    def data_parallel_size(self) -> int:
        if self.mesh is None:
            return 1
        from repro.distrib.shardings import data_parallel_size

        return data_parallel_size(self.mesh)

    def place(self, params, opt_state):
        """device_put params/opt-state with ``clax_param_rule`` shardings
        (big tables row-sharded over 'model', everything else replicated).
        No-op without a mesh."""
        if self.mesh is None:
            return params, opt_state
        from repro.distrib.shardings import clax_param_rule, make_shardings

        # With replicas, every leaf carries a leading (R,) axis that stays
        # replicated; the row-sharding size test must look one dim deeper.
        rule = clax_param_rule(self.mesh,
                               leading_axes=0 if self.replicas is None else 1)
        params = jax.device_put(params, make_shardings(self.mesh, params, rule))
        opt_state = jax.device_put(
            opt_state, make_shardings(self.mesh, opt_state, rule))
        return params, opt_state

    # -- the scanned step ------------------------------------------------------
    def _apply_update(self, params, opt_state, grads, batch):
        if not self.sparse_parts:
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            return optim_lib.apply_updates(params, updates), opt_state
        # Sparse route: mask table leaves out of the dense update (None is an
        # empty pytree node, so the dense optimizer never touches them), then
        # scatter-update each table from the batch's unique rows.
        dense_params, dense_grads = params, grads
        for path in self.sparse_parts:
            dense_params = _tree_set(dense_params, path, None)
            dense_grads = _tree_set(dense_grads, path, None)
        updates, dense_state = self.optimizer.update(
            dense_grads, opt_state["dense"], dense_params)
        new_params = optim_lib.apply_updates(dense_params, updates)
        sparse_state = {}
        for path, part in self.sparse_parts.items():
            key = SPARSE_PATH_SEP.join(path)
            table = _tree_get(params, path)
            d_table = _tree_get(grads, path)
            # Autodiff already summed duplicate lookups into d_table's rows;
            # dedupe the id stream and gather exactly those row-grads. Pad
            # slots use an out-of-range sentinel whose writes the scatter
            # drops (see optim/sparse.py).
            rows = unique_rows_with_sentinel(part.row_ids(batch),
                                             table.shape[0])
            new_table, st = sparse_adamw_update(
                table, opt_state["sparse"][key], rows,
                d_table.at[rows].get(mode="clip"), **self.sparse_kwargs)
            new_params = _tree_set(new_params, path, new_table)
            sparse_state[key] = st
        return new_params, {"dense": dense_state, "sparse": sparse_state}

    def _telemetry_out(self, out, grads, params, opt_state):
        """Fill the per-step telemetry series (device scalars that stack
        into the scan's ys — they leave the device only when the caller
        drains the chunk payload, never per step). ``param_norm`` is taken
        post-update (and post-skip-select on the guarded path), so a
        skipped step reports the norm of the params it kept."""
        out["grad_norm"] = optim_lib.global_norm(grads)
        out["param_norm"] = optim_lib.global_norm(params)
        lr = optim_lib.get_injected_lr(opt_state)
        if lr is not None:
            out["lr"] = lr
        return out

    def _one_step(self, params, opt_state, batch):
        """One optimizer step. Returns the new state plus the per-step
        output dict: always ``{"loss"}``, extended with the telemetry
        series when ``telemetry=True``."""
        loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
        params, opt_state = self._apply_update(params, opt_state, grads, batch)
        out = {"loss": loss}
        if self.telemetry:
            out = self._telemetry_out(out, grads, params, opt_state)
        return params, opt_state, out

    def _guarded_one_step(self, params, opt_state, batch):
        """One step that survives a non-finite loss or gradient.

        Finiteness of the loss and of every gradient leaf is reduced to one
        on-device scalar ``ok``; the update is computed unconditionally (a
        ``cond`` would break vmap/batching) and a per-leaf ``where`` carries
        the *old* params and opt_state through when ``ok`` is false — the
        poisoned step is skipped in place, with no host sync and no retrace.
        The output dict carries the loss (non-finite on a skipped step —
        the trainer drains it as telemetry, not into the epoch mean) and
        the skip flag.
        """
        loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
        ok = jnp.isfinite(loss)
        for leaf in jax.tree_util.tree_leaves(grads):
            ok = ok & jnp.all(jnp.isfinite(leaf))
        new_params, new_opt = self._apply_update(params, opt_state, grads,
                                                 batch)

        def keep(new, old):
            return jnp.where(ok, new, old)

        params = jax.tree_util.tree_map(keep, new_params, params)
        opt_state = jax.tree_util.tree_map(keep, new_opt, opt_state)
        out = {"loss": loss, "skipped": ~ok}
        if self.telemetry:
            out = self._telemetry_out(out, grads, params, opt_state)
        return params, opt_state, out

    def _step_out_ys(self, out):
        """A bare-loss ys keeps the telemetry-off payload identical to the
        historical ``(n,)`` array; any extra key promotes it to a dict."""
        return out["loss"] if set(out) == {"loss"} else out

    def _chunk_step(self, params, opt_state, chunk):
        def body(carry, batch):
            params, opt_state = carry
            params, opt_state, out = self._one_step(params, opt_state, batch)
            return (params, opt_state), self._step_out_ys(out)

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), chunk)
        return params, opt_state, losses

    def _chunk_step_guarded(self, params, opt_state, chunk):
        def body(carry, batch):
            params, opt_state = carry
            params, opt_state, out = self._guarded_one_step(
                params, opt_state, batch)
            return (params, opt_state), out

        (params, opt_state), telemetry = jax.lax.scan(
            body, (params, opt_state), chunk)
        return params, opt_state, telemetry

    # -- the vmapped replica step ----------------------------------------------
    def _replica_one_step(self, params, opt_state, batch, active):
        if self.nonfinite_guard:
            # vmapping the guarded step gives each replica its own on-device
            # ok flag: a NaN batch (broadcast to all replicas) or a replica
            # whose own trajectory diverged skips only where it is non-finite.
            new_p, new_o, out = jax.vmap(
                self._guarded_one_step,
                in_axes=(0, 0, None))(params, opt_state, batch)
        else:
            new_p, new_o, out = jax.vmap(
                self._one_step, in_axes=(0, 0, None))(params, opt_state, batch)
        if active is None:
            return new_p, new_o, out

        def keep(new, old):
            # Freeze inactive replicas in place: expand the (R,) mask to the
            # leaf rank so params, moments, AND step counts all hold still —
            # an early-stopped replica's slice stays exactly the state it
            # stopped at, matching a sequential run that halted there.
            mask = active.reshape(active.shape + (1,) * (new.ndim - 1))
            return jnp.where(mask, new, old)

        params = jax.tree_util.tree_map(keep, new_p, params)
        opt_state = jax.tree_util.tree_map(keep, new_o, opt_state)
        if "skipped" in out:
            # A frozen replica attempted no update — don't report it skipped.
            out["skipped"] = out["skipped"] & active
        return params, opt_state, out

    def _replica_chunk_body(self, params, opt_state, chunk, active):
        def body(carry, batch):
            params, opt_state = carry
            params, opt_state, out = self._replica_one_step(
                params, opt_state, batch, active)
            return (params, opt_state), self._step_out_ys(out)

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), chunk)
        return params, opt_state, losses  # losses: (n, R)

    def _replica_chunk_step(self, params, opt_state, chunk):
        return self._replica_chunk_body(params, opt_state, chunk, None)

    def _replica_chunk_step_masked(self, params, opt_state, chunk, active):
        return self._replica_chunk_body(params, opt_state, chunk, active)

    def step(self, params, opt_state, chunk, active=None):
        """One fused dispatch: ``n = chunk.shape[0]`` optimizer steps.

        Donates ``(params, opt_state)``; returns the new state plus the
        per-step loss array — ``(n,)``, or ``(n, R)`` with ``replicas=R`` —
        still on device: do not block on it before dispatching the next
        chunk. With ``nonfinite_guard=True`` the loss payload is instead a
        dict ``{"loss": (n,)|(n, R), "skipped": same-shape bool}`` where
        ``skipped[i]`` marks a step whose non-finite loss/grads were
        discarded (params and opt_state carried through unchanged). With
        ``telemetry=True`` the dict additionally carries per-step
        ``grad_norm``/``param_norm`` (and ``lr`` for inject_lr optimizers)
        series of the same shape — drain it with
        :class:`repro.obs.TelemetryDrain`.

        With replicas, ``active`` is an optional ``(R,)`` bool mask (default
        all-on): inactive replicas' state is frozen in place. An all-true
        (or omitted) mask takes the select-free fast path; a partial mask is
        a traced argument, so flipping further replicas off never retraces.
        """
        if self.replicas is None:
            if active is not None:
                raise ValueError("active mask requires TrainEngine(replicas=R)")
            return self._step(params, opt_state, chunk)
        if active is None or bool(np.asarray(active).all()):
            return self._step(params, opt_state, chunk)
        return self._step_masked(params, opt_state, chunk, jnp.asarray(active))

    def roofline(self, params, opt_state, chunk) -> Dict[str, Any]:
        """Static per-dispatch cost of the compiled chunk step: lower +
        compile for these arg shapes and run the while-aware HLO cost model
        (:func:`repro.launch.hlo_cost.analyze_hlo`), so the scan body is
        scaled by its trip count. This is an extra AOT compile of the same
        program — gate it behind a flag (``Trainer(emit_roofline=True)``
        emits it once, as a ``roofline`` telemetry event)."""
        from repro.launch.hlo_cost import analyze_hlo

        hlo = self._step.lower(params, opt_state, chunk).compile().as_text()
        cost = analyze_hlo(hlo)
        n = jax.tree_util.tree_leaves(chunk)[0].shape[0]
        cost["chunk_batches"] = int(n)
        cost["flops_per_step"] = cost["flops"] / max(n, 1)
        return cost
