"""Fault tolerance: preemption handling, restarts, straggler mitigation.

At 1000+ node scale three failure classes dominate:

1. **Preemption / node loss** — handled by frequent atomic checkpoints
   (params + optimizer + loader + rng) and resume-on-restart. The
   :class:`PreemptionHandler` converts SIGTERM/SIGINT into a final checkpoint
   and a clean exit so the scheduler can reschedule the job;
   :func:`run_with_restarts` is the outer supervisor that relaunches a
   crashed training process so it resumes from its newest valid checkpoint
   (``repro.launch.train --max-restarts`` wires it to the CLI).

2. **Stragglers** — the step barrier (gradient all-reduce) runs at the speed
   of the slowest replica. Two host-side mitigations:
     * drop-slowest-k aggregation: aggregate the first (R - k) replica
       gradients and rescale — unbiased in expectation under random
       straggling (:func:`drop_slowest_aggregate`; on real pods the
       collection uses a timeout barrier).
     * :class:`StepWatchdog`: flags steps that blow a wall-clock budget (the
       Trainer's ``step_budget_seconds`` knob) so stuck collectives show up
       in telemetry instead of silently stretching the run.

3. **Elastic scaling** — checkpoints are mesh-agnostic (host numpy), so a job
   restarted on a different device count re-shards at restore time
   (see CheckpointManager.restore(shardings=...)).
"""
from __future__ import annotations

import signal
import subprocess
import sys
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp


class PreemptionHandler:
    """Converts SIGTERM/SIGINT into a ``should_stop`` flag the train loop
    polls. A context manager, so the previous signal handlers are restored
    even when the loop raises:

        with PreemptionHandler() as handler:
            for batch in loader:
                ...
                if handler.should_stop:   # checkpoint + exit cleanly
                    ckpt.save(step, state); break
    """

    def __init__(self,
                 signals: Sequence[int] = (signal.SIGTERM, signal.SIGINT)):
        self.should_stop = False
        self._prev = {}
        for sig in signals:
            self._prev[sig] = signal.signal(sig, self._handle)

    def _handle(self, signum, frame):
        del frame
        self.should_stop = True

    def restore(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        self._prev = {}

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.restore()
        return False


def run_with_restarts(argv: Sequence[str], max_restarts: int,
                      log_fn: Callable = print, env=None) -> int:
    """Supervise a training subprocess, relaunching it after crashes.

    Runs ``argv`` (e.g. ``[sys.executable, "-m", "repro.launch.train",
    ...]``); a non-zero exit — SIGKILL'd by the OOM killer, preempted,
    segfaulted — triggers a relaunch with the *same* argv, up to
    ``max_restarts`` times. The child is responsible for resuming from its
    checkpoint directory (``--ckpt-dir`` does this automatically), which is
    what makes blind relaunch correct: every attempt converges on the same
    deterministic run. Exit code 0 stops the loop; the final attempt's code
    is returned either way.
    """
    attempt = 0
    while True:
        proc = subprocess.run(list(argv), env=env)
        if proc.returncode == 0:
            if attempt:
                log_fn(f"[restarts] completed after {attempt} restart(s)")
            return 0
        if attempt >= max_restarts:
            log_fn(f"[restarts] attempt {attempt + 1} exited with code "
                   f"{proc.returncode}; restart budget ({max_restarts}) "
                   f"exhausted")
            return proc.returncode
        attempt += 1
        log_fn(f"[restarts] child exited with code {proc.returncode}; "
               f"relaunching (attempt {attempt + 1}/{max_restarts + 1})")


def drop_slowest_aggregate(replica_grads: Sequence, arrived: Sequence[bool]):
    """Aggregate gradients from replicas that met the step deadline.

    ``arrived[i]`` marks replica i as on-time. Returns the mean gradient over
    arrived replicas rescaled to be an unbiased estimate of the full mean
    (scale R_arrived/R cancels in the mean; we simply average the arrived
    set). Raises if no replica arrived.
    """
    n_arrived = sum(bool(a) for a in arrived)
    if n_arrived == 0:
        raise RuntimeError("no replica gradients arrived before deadline")
    picked = [g for g, a in zip(replica_grads, arrived) if a]
    return jax.tree_util.tree_map(
        lambda *gs: sum(gs) / float(n_arrived), *picked)


class StepWatchdog:
    """Detects stuck steps by wall-clock budget (host-side straggler guard).

    The Trainer creates one when ``step_budget_seconds`` is set and calls
    ``check`` with each chunk's mean per-step time; violations are counted
    into the epoch record (``watchdog_violations``), reported through
    ``on_violation``, and emitted as ``watchdog_violation`` telemetry
    events (with the measured seconds and the budget) on ``recorder`` —
    the global one by default — so a stuck collective shows up in the
    metrics stream, not just the log.
    """

    def __init__(self, budget_seconds: float,
                 on_violation: Optional[Callable] = None, recorder=None):
        self.budget = budget_seconds
        self.on_violation = on_violation
        self.recorder = recorder
        self.violations = 0

    def check(self, step_seconds: float, step: int):
        if step_seconds > self.budget:
            self.violations += 1
            if self.on_violation is not None:
                self.on_violation(step, step_seconds)
            rec = self.recorder
            if rec is None:
                from repro.obs import get_recorder

                rec = get_recorder()
            rec.event("watchdog_violation", float(step_seconds), step=int(step),
                      data={"budget_seconds": float(self.budget)})
            rec.add("watchdog_violations")
        return self.violations
