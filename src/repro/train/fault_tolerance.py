"""Fault tolerance: preemption handling + straggler mitigation.

At 1000+ node scale three failure classes dominate:

1. **Preemption / node loss** — handled by frequent atomic checkpoints
   (params + optimizer + loader + rng) and resume-on-restart. The
   :class:`PreemptionHandler` converts SIGTERM/SIGINT into a final checkpoint
   and a clean exit so the scheduler can reschedule the job.

2. **Stragglers** — the step barrier (gradient all-reduce) runs at the speed
   of the slowest replica. Mitigations implemented/designed here:
     * drop-slowest-k aggregation: aggregate the first (R - k) replica
       gradients and rescale by R/(R-k) — unbiased in expectation under
       random straggling (:func:`drop_slowest_aggregate` simulates the
       arithmetic; on real pods the collection uses a timeout barrier).
     * backup replicas: schedule cloned data shards on spare nodes, take the
       first result (design note — needs scheduler support, not simulatable
       in-process).

3. **Elastic scaling** — checkpoints are mesh-agnostic (host numpy), so a job
   restarted on a different device count re-shards at restore time
   (see CheckpointManager.restore(shardings=...)).
"""
from __future__ import annotations

import signal
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp


class PreemptionHandler:
    """Converts SIGTERM/SIGINT into a `should_stop` flag the train loop polls.

    Usage:
        handler = PreemptionHandler()
        for batch in loader:
            ...
            if handler.should_stop:   # checkpoint + exit cleanly
                ckpt.save(step, state); break
    """

    def __init__(self, signals: Sequence[int] = (signal.SIGTERM,)):
        self.should_stop = False
        self._prev = {}
        for sig in signals:
            self._prev[sig] = signal.signal(sig, self._handle)

    def _handle(self, signum, frame):
        del frame
        self.should_stop = True

    def restore(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


def drop_slowest_aggregate(replica_grads: Sequence, arrived: Sequence[bool]):
    """Aggregate gradients from replicas that met the step deadline.

    ``arrived[i]`` marks replica i as on-time. Returns the mean gradient over
    arrived replicas rescaled to be an unbiased estimate of the full mean
    (scale R_arrived/R cancels in the mean; we simply average the arrived
    set). Raises if no replica arrived.
    """
    n_arrived = sum(bool(a) for a in arrived)
    if n_arrived == 0:
        raise RuntimeError("no replica gradients arrived before deadline")
    picked = [g for g, a in zip(replica_grads, arrived) if a]
    return jax.tree_util.tree_map(
        lambda *gs: sum(gs) / float(n_arrived), *picked)


class StepWatchdog:
    """Detects stuck steps by wall-clock budget (host-side straggler guard).

    On real clusters this wraps the collective with a deadline; here it is the
    host-side reference implementation used by the Trainer to flag stragglers
    in logs and (optionally) trigger a checkpoint so the scheduler can
    migrate the job.
    """

    def __init__(self, budget_seconds: float, on_violation: Optional[Callable] = None):
        self.budget = budget_seconds
        self.on_violation = on_violation
        self.violations = 0

    def check(self, step_seconds: float, step: int):
        if step_seconds > self.budget:
            self.violations += 1
            if self.on_violation is not None:
                self.on_violation(step, step_seconds)
        return self.violations
