"""Trainer: the paper's Listing-1 entry point.

    trainer = Trainer(optimizer=adamw(0.003), epochs=50)
    history = trainer.train(model, train_loader, val_loader)
    results = trainer.test(model, test_loader)

Implements: chunked scan-jitted update steps through
:class:`repro.train.engine.TrainEngine` (one dispatch and zero host syncs
per ``chunk_batches`` steps; per-step losses accumulate on device and are
fetched one chunk behind the dispatch), optional data-parallel execution
over a mesh and sparse embedding-table updates, per-epoch validation with
the paper's click metrics (compiled eval step cached across epochs, one
host transfer per evaluate call), early stopping after the first epoch
without val-loss improvement (paper §6), periodic + preemption-triggered
atomic checkpoints at chunk granularity, and bit-exact resume (params +
optimizer + loader state + epoch counter).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.metrics import (ConditionalPerplexity, LogLikelihood, MultiMetric,
                                Perplexity)
from repro.data.loader import DevicePrefetcher
from repro.train.checkpoints import CheckpointManager
from repro.train.engine import TrainEngine
from repro.train.fault_tolerance import PreemptionHandler


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    epoch: int = 0
    global_step: int = 0


def default_metrics() -> MultiMetric:
    return MultiMetric({
        "ll": LogLikelihood(),
        "ppl": Perplexity(),
        "cond_ppl": ConditionalPerplexity(),
    })


class Trainer:
    def __init__(self, optimizer, epochs: int = 100, patience: int = 1,
                 seed: int = 0, checkpoint_dir: Optional[str] = None,
                 checkpoint_every_steps: Optional[int] = None,
                 keep_checkpoints: int = 3,
                 metrics_factory: Callable[[], MultiMetric] = default_metrics,
                 log_fn: Callable[[str], None] = print,
                 handle_preemption: bool = False,
                 chunk_batches: int = 1,
                 mesh=None,
                 sparse_tables: bool = False,
                 sparse_table_kwargs: Optional[Dict[str, Any]] = None):
        self.optimizer = optimizer
        self.epochs = epochs
        self.patience = patience
        self.seed = seed
        self.metrics_factory = metrics_factory
        self.log_fn = log_fn
        self.checkpoint_every_steps = checkpoint_every_steps
        self.ckpt = (CheckpointManager(checkpoint_dir, keep=keep_checkpoints)
                     if checkpoint_dir else None)
        self.handle_preemption = handle_preemption
        self.chunk_batches = chunk_batches
        self.mesh = mesh
        self.sparse_tables = sparse_tables
        self.sparse_table_kwargs = sparse_table_kwargs
        # Compiled eval step per model: _make_eval_step used to be re-jitted
        # (a fresh trace + compile) on every evaluate() call — epochs 2..n
        # now reuse the cached (metrics, compiled step) pair.
        self._eval_cache: Dict[Any, tuple] = {}

    def _make_engine(self, model) -> TrainEngine:
        return TrainEngine(model, self.optimizer,
                           chunk_batches=self.chunk_batches, mesh=self.mesh,
                           sparse_tables=self.sparse_tables,
                           sparse_table_kwargs=self.sparse_table_kwargs)

    def _make_eval_step(self, model, metrics):
        def eval_step(params, state, batch):
            log_probs = model.predict_clicks(params, batch)
            cond = model.predict_conditional_clicks(params, batch)
            return metrics.update(state, log_probs=log_probs,
                                  conditional_log_probs=cond,
                                  clicks=batch["clicks"], where=batch["mask"])

        return jax.jit(eval_step)

    def _get_eval_step(self, model):
        if model not in self._eval_cache:
            # bounded: a trainer reused across a sweep of models must not
            # pin every model's metrics + compiled executable forever
            while len(self._eval_cache) >= 4:
                self._eval_cache.pop(next(iter(self._eval_cache)))
            metrics = self.metrics_factory()
            self._eval_cache[model] = (metrics,
                                       self._make_eval_step(model, metrics))
        return self._eval_cache[model]

    # -- public API ----------------------------------------------------------------
    def train(self, model, train_loader, val_loader=None,
              state: Optional[TrainState] = None,
              resume: bool = False) -> List[Dict[str, float]]:
        engine = self._make_engine(model)
        if state is None:
            params = model.init(jax.random.PRNGKey(self.seed))
            state = TrainState(params=params,
                               opt_state=engine.init_opt_state(params))
        if resume and self.ckpt and self.ckpt.latest_step() is not None:
            tree = {"params": state.params, "opt_state": state.opt_state}
            tree, aux, _ = self.ckpt.restore(like=tree)
            state = TrainState(params=tree["params"], opt_state=tree["opt_state"],
                               epoch=int(aux["epoch"]),
                               global_step=int(aux["global_step"]))
            if aux.get("loader") is not None and hasattr(train_loader,
                                                         "load_state_dict"):
                train_loader.load_state_dict(aux["loader"])
            self.log_fn(f"[trainer] resumed at epoch={state.epoch} "
                        f"step={state.global_step}")
        state.params, state.opt_state = engine.place(state.params,
                                                     state.opt_state)
        dp = engine.data_parallel_size()
        batch_size = getattr(train_loader, "batch_size", None)
        if dp > 1 and batch_size is not None and batch_size % dp:
            raise ValueError(
                f"batch_size {batch_size} is not divisible by the "
                f"{dp}-way data-parallel mesh")
        if dp > 1 and getattr(train_loader, "drop_last", True) is False:
            raise ValueError(
                "data-parallel training requires drop_last=True: the "
                "tail batch generally cannot be split across the "
                f"{dp}-way data axis (same rule as multi-host streaming)")

        preempt = PreemptionHandler() if self.handle_preemption else None
        history: List[Dict[str, float]] = []
        best_val = float("inf")
        bad_epochs = 0

        while state.epoch < self.epochs:
            t0 = time.time()
            train_loss, n_batches = 0.0, 0
            # One jit dispatch per chunk of up to `chunk_batches` steps; the
            # previous chunk's on-device (n,) loss array is drained while the
            # current chunk runs, so the host never blocks on the step it
            # just dispatched. loader_state is the bit-exact resume point
            # after the chunk's last batch (the loader itself has run ahead
            # by the prefetch depth).
            pending_losses = None
            stop = False

            def drain(losses):
                # Per-element accumulation into the python float keeps the
                # sum bit-identical to the historical one-float(loss)-per-
                # step loop (a vectorized f32 sum would not).
                nonlocal train_loss
                for loss in np.asarray(losses):
                    train_loss += float(loss)

            for chunk, loader_state, n in DevicePrefetcher(
                    train_loader, chunk_batches=engine.chunk_batches,
                    device=engine.batch_sharding()):
                state.params, state.opt_state, losses = engine.step(
                    state.params, state.opt_state, chunk)
                if pending_losses is not None:
                    drain(pending_losses)
                pending_losses = losses
                n_batches += n
                prev_step = state.global_step
                state.global_step += n
                every = self.checkpoint_every_steps
                if (self.ckpt and every and
                        prev_step // every < state.global_step // every):
                    self._save(state, train_loader, loader_state)
                if preempt and preempt.should_stop:
                    if self.ckpt:
                        self._save(state, train_loader, loader_state)
                        self.log_fn("[trainer] preempted; checkpoint written")
                    else:
                        self.log_fn("[trainer] preempted; no checkpoint_dir "
                                    "configured — stopping without saving")
                    stop = True
                    break
            if pending_losses is not None:
                drain(pending_losses)
            if stop:
                # preempted: leave _final_state usable (test() after a
                # preempted train must not crash) and hand back history
                self._final_state = state
                return history
            state.epoch += 1
            record = {
                "epoch": state.epoch,
                "train_loss": train_loss / max(n_batches, 1),
                "seconds": time.time() - t0,
            }
            if val_loader is not None:
                val = self.evaluate(model, state.params, val_loader)
                record.update({f"val_{k}": v for k, v in val.items()})
                val_loss = -val["ll"]
                if val_loss < best_val - 1e-6:
                    best_val, bad_epochs = val_loss, 0
                else:
                    bad_epochs += 1
            history.append(record)
            self.log_fn(f"[trainer] {record}")
            if self.ckpt:
                self._save(state, train_loader)
            if val_loader is not None and bad_epochs >= self.patience:
                self.log_fn(f"[trainer] early stop at epoch {state.epoch}")
                break
        self._final_state = state
        return history

    def evaluate(self, model, params, loader, per_rank: bool = False):
        metrics, eval_step = self._get_eval_step(model)
        # On a mesh, shard full eval batches over the data axes so
        # validation scales with the mesh; only a batch the data axes do
        # not divide (the drop_last=False tail) falls back to replication.
        device = None
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec

            from repro.distrib.shardings import batch_spec, data_parallel_size

            dp = data_parallel_size(self.mesh)
            split = NamedSharding(self.mesh, batch_spec(self.mesh,
                                                        extra_dims=0))
            replicated = NamedSharding(self.mesh, PartitionSpec())

            def device(batch):
                rows = next(iter(batch.values())).shape[0]
                return split if rows % dp == 0 else replicated
        m_state = None
        for batch, _ in DevicePrefetcher(loader, device=device):
            if m_state is None:
                m_state = metrics.init_state(batch["positions"].shape[1])
            m_state = eval_step(params, m_state, batch)
        if m_state is None:
            raise ValueError(
                "evaluation loader produced no batches — dataset smaller than "
                "batch_size with drop_last=True? Pass drop_last=False.")
        # Metric state stayed on device for the whole pass; one blocking
        # device_get fetches every final scalar (and per-rank vector) at once.
        finals = metrics.compute(m_state)
        per = metrics.compute_per_rank(m_state) if per_rank else None
        finals, per = jax.device_get((finals, per))
        out = {k: float(v) for k, v in finals.items()}
        if per_rank:
            out["per_rank"] = {k: np.asarray(v).tolist()
                               for k, v in per.items()}
        return out

    def test(self, model, test_loader, params=None, per_rank: bool = True):
        if params is None:
            params = self._final_state.params
        return self.evaluate(model, params, test_loader, per_rank=per_rank)

    # -- internals -------------------------------------------------------------------
    def _save(self, state: TrainState, loader, loader_state=None):
        if loader_state is None:
            get_state = getattr(loader, "state_dict", lambda: None)
            loader_state = get_state()
        self.ckpt.save(state.global_step,
                       {"params": state.params, "opt_state": state.opt_state},
                       aux={"epoch": state.epoch, "global_step": state.global_step,
                            "loader": loader_state})
