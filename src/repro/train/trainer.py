"""Trainer: the paper's Listing-1 entry point.

    trainer = Trainer(optimizer=adamw(0.003), epochs=50)
    history = trainer.train(model, train_loader, val_loader)
    results = trainer.test(model, test_loader)

Implements: jit'd update step (donated state), per-epoch validation with the
paper's click metrics, early stopping after the first epoch without val-loss
improvement (paper §6), periodic + preemption-triggered atomic checkpoints,
and bit-exact resume (params + optimizer + loader state + epoch counter).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro import optim as optim_lib
from repro.core.metrics import (ConditionalPerplexity, LogLikelihood, MultiMetric,
                                Perplexity)
from repro.data.loader import DevicePrefetcher
from repro.train.checkpoints import CheckpointManager
from repro.train.fault_tolerance import PreemptionHandler


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    epoch: int = 0
    global_step: int = 0


def default_metrics() -> MultiMetric:
    return MultiMetric({
        "ll": LogLikelihood(),
        "ppl": Perplexity(),
        "cond_ppl": ConditionalPerplexity(),
    })


class Trainer:
    def __init__(self, optimizer, epochs: int = 100, patience: int = 1,
                 seed: int = 0, checkpoint_dir: Optional[str] = None,
                 checkpoint_every_steps: Optional[int] = None,
                 keep_checkpoints: int = 3,
                 metrics_factory: Callable[[], MultiMetric] = default_metrics,
                 log_fn: Callable[[str], None] = print,
                 handle_preemption: bool = False):
        self.optimizer = optimizer
        self.epochs = epochs
        self.patience = patience
        self.seed = seed
        self.metrics_factory = metrics_factory
        self.log_fn = log_fn
        self.checkpoint_every_steps = checkpoint_every_steps
        self.ckpt = (CheckpointManager(checkpoint_dir, keep=keep_checkpoints)
                     if checkpoint_dir else None)
        self.handle_preemption = handle_preemption

    # -- jit'd step --------------------------------------------------------------
    def _make_step(self, model):
        optimizer = self.optimizer

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.compute_loss)(params, batch)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            params = optim_lib.apply_updates(params, updates)
            return params, opt_state, loss

        return jax.jit(step, donate_argnums=(0, 1))

    def _make_eval_step(self, model, metrics):
        def eval_step(params, state, batch):
            log_probs = model.predict_clicks(params, batch)
            cond = model.predict_conditional_clicks(params, batch)
            return metrics.update(state, log_probs=log_probs,
                                  conditional_log_probs=cond,
                                  clicks=batch["clicks"], where=batch["mask"])

        return jax.jit(eval_step)

    # -- public API ----------------------------------------------------------------
    def train(self, model, train_loader, val_loader=None,
              state: Optional[TrainState] = None,
              resume: bool = False) -> List[Dict[str, float]]:
        if state is None:
            params = model.init(jax.random.PRNGKey(self.seed))
            state = TrainState(params=params, opt_state=self.optimizer.init(params))
        if resume and self.ckpt and self.ckpt.latest_step() is not None:
            tree = {"params": state.params, "opt_state": state.opt_state}
            tree, aux, _ = self.ckpt.restore(like=tree)
            state = TrainState(params=tree["params"], opt_state=tree["opt_state"],
                               epoch=int(aux["epoch"]),
                               global_step=int(aux["global_step"]))
            if aux.get("loader") is not None and hasattr(train_loader,
                                                         "load_state_dict"):
                train_loader.load_state_dict(aux["loader"])
            self.log_fn(f"[trainer] resumed at epoch={state.epoch} "
                        f"step={state.global_step}")

        step_fn = self._make_step(model)
        preempt = PreemptionHandler() if self.handle_preemption else None
        history: List[Dict[str, float]] = []
        best_val = float("inf")
        bad_epochs = 0

        while state.epoch < self.epochs:
            t0 = time.time()
            train_loss, n_batches = 0.0, 0
            # Prefetch keeps the next batch on device while the (async
            # dispatched) step runs; loader_state is the bit-exact resume
            # point for the batch being trained, since the loader itself has
            # run ahead by the prefetch depth.
            for batch, loader_state in DevicePrefetcher(train_loader):
                state.params, state.opt_state, loss = step_fn(
                    state.params, state.opt_state, batch)
                train_loss += float(loss)
                n_batches += 1
                state.global_step += 1
                if (self.ckpt and self.checkpoint_every_steps and
                        state.global_step % self.checkpoint_every_steps == 0):
                    self._save(state, train_loader, loader_state)
                if preempt and preempt.should_stop:
                    self._save(state, train_loader, loader_state)
                    self.log_fn("[trainer] preempted; checkpoint written")
                    return history
            state.epoch += 1
            record = {
                "epoch": state.epoch,
                "train_loss": train_loss / max(n_batches, 1),
                "seconds": time.time() - t0,
            }
            if val_loader is not None:
                val = self.evaluate(model, state.params, val_loader)
                record.update({f"val_{k}": v for k, v in val.items()})
                val_loss = -val["ll"]
                if val_loss < best_val - 1e-6:
                    best_val, bad_epochs = val_loss, 0
                else:
                    bad_epochs += 1
            history.append(record)
            self.log_fn(f"[trainer] {record}")
            if self.ckpt:
                self._save(state, train_loader)
            if val_loader is not None and bad_epochs >= self.patience:
                self.log_fn(f"[trainer] early stop at epoch {state.epoch}")
                break
        self._final_state = state
        return history

    def evaluate(self, model, params, loader, per_rank: bool = False):
        metrics = self.metrics_factory()
        eval_step = self._make_eval_step(model, metrics)
        m_state = None
        for batch, _ in DevicePrefetcher(loader):
            if m_state is None:
                m_state = metrics.init_state(batch["positions"].shape[1])
            m_state = eval_step(params, m_state, batch)
        if m_state is None:
            raise ValueError(
                "evaluation loader produced no batches — dataset smaller than "
                "batch_size with drop_last=True? Pass drop_last=False.")
        out = {k: float(v) for k, v in metrics.compute(m_state).items()}
        if per_rank:
            out["per_rank"] = {k: np.asarray(v).tolist()
                               for k, v in metrics.compute_per_rank(m_state).items()}
        return out

    def test(self, model, test_loader, params=None, per_rank: bool = True):
        if params is None:
            params = self._final_state.params
        return self.evaluate(model, params, test_loader, per_rank=per_rank)

    # -- internals -------------------------------------------------------------------
    def _save(self, state: TrainState, loader, loader_state=None):
        if loader_state is None:
            get_state = getattr(loader, "state_dict", lambda: None)
            loader_state = get_state()
        self.ckpt.save(state.global_step,
                       {"params": state.params, "opt_state": state.opt_state},
                       aux={"epoch": state.epoch, "global_step": state.global_step,
                            "loader": loader_state})
