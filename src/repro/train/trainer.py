"""Trainer: the paper's Listing-1 entry point.

    trainer = Trainer(optimizer=adamw(0.003), epochs=50)
    history = trainer.train(model, train_loader, val_loader)
    results = trainer.test(model, test_loader)

Implements: chunked scan-jitted update steps through
:class:`repro.train.engine.TrainEngine` (one dispatch and zero host syncs
per ``chunk_batches`` steps; per-step losses accumulate on device and are
fetched one chunk behind the dispatch), optional data-parallel execution
over a mesh and sparse embedding-table updates, per-epoch validation with
the paper's click metrics (compiled eval step cached LRU across epochs and
models, scanned over prefetched chunks, one host transfer per evaluate
call), early stopping after the first epoch without val-loss improvement
(paper §6), periodic + preemption-triggered atomic checkpoints at chunk
granularity, and bit-exact resume (params + optimizer + loader state +
epoch counter).

Sweep mode (``Trainer(replicas=R)``): R independent runs — distinct init
seeds always (``replica_seeds``, default ``seed + i``), distinct learning
rates optionally (``replica_lrs``, requires an ``inject_lr=True``
optimizer) — train inside one vmapped engine. Validation runs one compiled
step over an R-stacked metric state, early stopping is tracked per replica
(finished replicas freeze in place via the engine's active mask while the
rest keep training), history records carry per-replica lists, and
checkpoints hold the R-stacked trees (`repro.train.select_replica`
extracts any run for standalone resume/test).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.metrics import (ConditionalPerplexity, LogLikelihood, MultiMetric,
                                Perplexity)
from repro.data.loader import DevicePrefetcher
from repro.obs import (ProfileWindow, TelemetryDrain, get_recorder, make_event,
                       parse_profile_steps)
from repro.train.checkpoints import CheckpointManager
from repro.train.engine import TrainEngine
from repro.train.fault_tolerance import PreemptionHandler, StepWatchdog


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    epoch: int = 0
    global_step: int = 0


def default_metrics() -> MultiMetric:
    return MultiMetric({
        "ll": LogLikelihood(),
        "ppl": Perplexity(),
        "cond_ppl": ConditionalPerplexity(),
    })


class Trainer:
    def __init__(self, optimizer, epochs: int = 100, patience: int = 1,
                 seed: int = 0, checkpoint_dir: Optional[str] = None,
                 checkpoint_every_steps: Optional[int] = None,
                 keep_checkpoints: int = 3,
                 metrics_factory: Callable[[], MultiMetric] = default_metrics,
                 log_fn: Callable[[str], None] = print,
                 handle_preemption: bool = False,
                 chunk_batches: int = 1,
                 mesh=None,
                 sparse_tables: bool = False,
                 sparse_table_kwargs: Optional[Dict[str, Any]] = None,
                 replicas: Optional[int] = None,
                 replica_lrs: Optional[List[float]] = None,
                 replica_seeds: Optional[List[int]] = None,
                 nonfinite_guard: bool = False,
                 step_budget_seconds: Optional[float] = None,
                 telemetry: bool = False,
                 recorder=None,
                 obs_every: int = 1,
                 profile_steps: Optional[str] = None,
                 profile_dir: Optional[str] = None,
                 emit_roofline: bool = False):
        self.optimizer = optimizer
        self.epochs = epochs
        self.patience = patience
        self.seed = seed
        self.metrics_factory = metrics_factory
        self.log_fn = log_fn
        self.checkpoint_every_steps = checkpoint_every_steps
        self.ckpt = (CheckpointManager(checkpoint_dir, keep=keep_checkpoints,
                                       log_fn=log_fn)
                     if checkpoint_dir else None)
        self.handle_preemption = handle_preemption
        self.nonfinite_guard = nonfinite_guard
        self.step_budget_seconds = step_budget_seconds
        # Observability (see repro.obs): `telemetry` turns on the engine's
        # on-device per-step series; `recorder` pins a Recorder (default:
        # the process-global one, resolved at use so a later
        # obs.configure() still takes effect); `obs_every` rate-limits
        # per-step metric events; `profile_steps` ("A:B") opens a
        # jax.profiler window into `profile_dir` around those global
        # steps; `emit_roofline` emits the chunk step's static HLO cost
        # once per train() (one extra AOT compile).
        self.telemetry = bool(telemetry)
        self.recorder = recorder
        self.obs_every = int(obs_every)
        self.profile_steps = (parse_profile_steps(profile_steps)
                              if isinstance(profile_steps, str)
                              else profile_steps)
        self.profile_dir = profile_dir
        self.emit_roofline = bool(emit_roofline)
        self.chunk_batches = chunk_batches
        self.mesh = mesh
        self.sparse_tables = sparse_tables
        self.sparse_table_kwargs = sparse_table_kwargs
        if replicas is None and (replica_lrs is not None
                                 or replica_seeds is not None):
            raise ValueError("replica_lrs/replica_seeds require replicas=R")
        for name, knob in (("replica_lrs", replica_lrs),
                           ("replica_seeds", replica_seeds)):
            if knob is not None and len(knob) != replicas:
                raise ValueError(f"{name} has {len(knob)} entries for "
                                 f"replicas={replicas}")
        self.replicas = replicas
        self.replica_lrs = replica_lrs
        self.replica_seeds = replica_seeds
        # Compiled eval step per (model, replicas): _make_eval_step used to
        # be re-jitted (a fresh trace + compile) on every evaluate() call —
        # repeat evaluations reuse the cached (metrics, compiled steps)
        # entry. The cache is LRU (move-to-end on hit, evict front): the
        # model being evaluated every epoch survives a >4-model sweep.
        self._eval_cache: Dict[Any, tuple] = {}

    def _rec(self):
        """The recorder events go to: the pinned one, else the global."""
        return self.recorder if self.recorder is not None else get_recorder()

    def _make_engine(self, model) -> TrainEngine:
        return TrainEngine(model, self.optimizer,
                           chunk_batches=self.chunk_batches, mesh=self.mesh,
                           sparse_tables=self.sparse_tables,
                           sparse_table_kwargs=self.sparse_table_kwargs,
                           replicas=self.replicas,
                           nonfinite_guard=self.nonfinite_guard,
                           telemetry=self.telemetry)

    def _eval_update_fn(self, model, metrics, replicas=None):
        def eval_step(params, state, batch):
            log_probs = model.predict_clicks(params, batch)
            cond = model.predict_conditional_clicks(params, batch)
            return metrics.update(state, log_probs=log_probs,
                                  conditional_log_probs=cond,
                                  clicks=batch["clicks"], where=batch["mask"])

        if replicas is None:
            return eval_step
        # R-stacked (params, metric state), one broadcast batch: a single
        # compiled step advances every replica's evaluation.
        return jax.vmap(eval_step, in_axes=(0, 0, None))

    def _make_eval_step(self, model, metrics, replicas=None):
        return jax.jit(self._eval_update_fn(model, metrics, replicas))

    def _make_eval_chunk_step(self, model, metrics, replicas=None):
        """Scanned eval step over a stacked ``(n, B, ...)`` chunk: one jit
        dispatch per ``chunk_batches`` eval batches, metric state as the
        scan carry (loss-free analogue of the training engine's chunk
        step)."""
        update = self._eval_update_fn(model, metrics, replicas)

        def chunk_step(params, state, chunk):
            def body(state, batch):
                return update(params, state, batch), None

            state, _ = jax.lax.scan(body, state, chunk)
            return state

        return jax.jit(chunk_step)

    def _get_eval_step(self, model, replicas=None):
        key = (model, replicas)
        if key in self._eval_cache:
            # LRU hit: move to the back of the eviction order.
            self._eval_cache[key] = self._eval_cache.pop(key)
            return self._eval_cache[key]
        # bounded: a trainer reused across a sweep of models must not
        # pin every model's metrics + compiled executable forever
        while len(self._eval_cache) >= 4:
            self._eval_cache.pop(next(iter(self._eval_cache)))
        metrics = self.metrics_factory()
        self._eval_cache[key] = (metrics,
                                 self._make_eval_step(model, metrics, replicas),
                                 self._make_eval_chunk_step(model, metrics,
                                                            replicas))
        return self._eval_cache[key]

    # -- public API ----------------------------------------------------------------
    def train(self, model, train_loader, val_loader=None,
              state: Optional[TrainState] = None,
              resume: bool = False) -> List[Dict[str, float]]:
        engine = self._make_engine(model)
        R = self.replicas
        if state is None:
            if R is None:
                params = model.init(jax.random.PRNGKey(self.seed))
                opt_state = engine.init_opt_state(params)
            else:
                seeds = (self.replica_seeds if self.replica_seeds is not None
                         else [self.seed + i for i in range(R)])
                params = engine.init_replica_params(seeds)
                opt_state = engine.init_opt_state(params)
                if self.replica_lrs is not None:
                    opt_state = engine.set_replica_lrs(opt_state,
                                                       self.replica_lrs)
            state = TrainState(params=params, opt_state=opt_state)
        resumed_early_stop = None
        resume_accum = None
        history: List[Dict[str, float]] = []
        if resume and self.ckpt and self.ckpt.latest_step() is not None:
            tree = {"params": state.params, "opt_state": state.opt_state}
            tree, aux, _ = self.ckpt.restore(like=tree)
            state = TrainState(params=tree["params"], opt_state=tree["opt_state"],
                               epoch=int(aux["epoch"]),
                               global_step=int(aux["global_step"]))
            resumed_early_stop = aux.get("early_stop")
            # Mid-epoch crash recovery: the checkpoint carries the epoch's
            # running loss accumulators and the completed-epoch history, so
            # the resumed run's returned history is identical to an
            # uninterrupted run's — not just from-here-on.
            resume_accum = aux.get("epoch_accum")
            history = [dict(r) for r in aux.get("history") or []]
            if aux.get("loader") is not None and hasattr(train_loader,
                                                         "load_state_dict"):
                train_loader.load_state_dict(aux["loader"])
            self.log_fn(f"[trainer] resumed at epoch={state.epoch} "
                        f"step={state.global_step}")
        state.params, state.opt_state = engine.place(state.params,
                                                     state.opt_state)
        dp = engine.data_parallel_size()
        batch_size = getattr(train_loader, "batch_size", None)
        if dp > 1 and batch_size is not None and batch_size % dp:
            raise ValueError(
                f"batch_size {batch_size} is not divisible by the "
                f"{dp}-way data-parallel mesh")
        if dp > 1 and getattr(train_loader, "drop_last", True) is False:
            raise ValueError(
                "data-parallel training requires drop_last=True: the "
                "tail batch generally cannot be split across the "
                f"{dp}-way data axis (same rule as multi-host streaming)")

        preempt = PreemptionHandler() if self.handle_preemption else None
        watchdog = (StepWatchdog(
            self.step_budget_seconds,
            on_violation=lambda step, sec: self.log_fn(
                f"[trainer] watchdog: step ~{step} averaged {sec:.3f}s/step, "
                f"over budget {self.step_budget_seconds}s"),
            recorder=self.recorder)
            if self.step_budget_seconds else None)
        rec = self._rec()
        profile = (ProfileWindow(*self.profile_steps,
                                 log_dir=self.profile_dir or "profile",
                                 recorder=self.recorder)
                   if self.profile_steps else None)
        roofline_pending = self.emit_roofline
        if R is None:
            best_val = float("inf")
            bad_epochs = 0
        else:
            # Per-replica early-stopping state: a replica that exhausts its
            # patience goes inactive — the engine's update mask freezes its
            # params/opt-state in place while the others keep training, so
            # the single compiled step never retraces.
            best_val = np.full(R, np.inf)
            bad_epochs = np.zeros(R, dtype=int)
            active = np.ones(R, dtype=bool)
        if resumed_early_stop is not None:
            # Without this a resumed sweep would reactivate already-stopped
            # replicas (breaking the freeze-in-place == sequential-run
            # guarantee) and a resumed scalar run would forget its patience
            # counter.
            if R is None:
                best_val = float(resumed_early_stop["best_val"])
                bad_epochs = int(resumed_early_stop["bad_epochs"])
            else:
                best_val = np.asarray(resumed_early_stop["best_val"],
                                      np.float64)
                bad_epochs = np.asarray(resumed_early_stop["bad_epochs"], int)
                active = np.asarray(resumed_early_stop["active"], bool)

        def snapshot_early_stop():
            # JSON-able early-stop state for checkpoint aux. Counters only
            # move at epoch boundaries, so a mid-epoch checkpoint correctly
            # carries the state the epoch started with.
            if R is None:
                self._early_stop_aux = {"best_val": best_val,
                                        "bad_epochs": bad_epochs}
            else:
                self._early_stop_aux = {"best_val": best_val.tolist(),
                                        "bad_epochs": bad_epochs.tolist(),
                                        "active": active.tolist()}

        snapshot_early_stop()

        # Signal handlers must not outlive the loop they guard:
        # restore on every exit path (completion, early stop,
        # preemption return, exception).
        try:
            while state.epoch < self.epochs:
                t0 = time.time()
                # The epoch's single source of truth for loss/skip/batch
                # accumulation AND per-step metric events: one TelemetryDrain,
                # fed one device_get per chunk. The trainer no longer keeps
                # its own parallel accumulators.
                acc = TelemetryDrain(replicas=R, recorder=self.recorder,
                                     every=self.obs_every, epoch=state.epoch)
                wd_epoch_start = watchdog.violations if watchdog else 0
                if resume_accum is not None:
                    # First epoch after a mid-epoch resume: start from the
                    # checkpointed accumulators so the epoch's recorded loss
                    # covers every batch, not just the post-crash ones.
                    acc.load(resume_accum)
                    resume_accum = None
                epoch_active = None if R is None else active.copy()
                epoch_span = rec.span("epoch", epoch=state.epoch)
                epoch_span.__enter__()
                # One jit dispatch per chunk of up to `chunk_batches` steps; the
                # previous chunk's on-device (n,) — or (n, R) — loss payload is
                # drained while the current chunk runs, so the host never blocks
                # on the step it just dispatched. loader_state is the bit-exact
                # resume point after the chunk's last batch (the loader itself
                # has run ahead by the prefetch depth).
                pending = None  # (payload, first global step of its chunk)
                stop = False

                chunk_t0 = time.time()
                for chunk, loader_state, n in DevicePrefetcher(
                        train_loader, chunk_batches=engine.chunk_batches,
                        device=engine.batch_sharding()):
                    if roofline_pending:
                        # One extra AOT compile of the already-traced program;
                        # emitted once, before the first dispatch donates the
                        # argument buffers.
                        roofline_pending = False
                        with rec.span("roofline"):
                            cost = engine.roofline(state.params,
                                                   state.opt_state, chunk)
                        rec.emit(make_event("roofline", "chunk_step",
                                            data=cost,
                                            step=state.global_step))
                    if profile is not None:
                        profile.before_chunk(state.global_step)
                    if R is None:
                        state.params, state.opt_state, losses = engine.step(
                            state.params, state.opt_state, chunk)
                    else:
                        state.params, state.opt_state, losses = engine.step(
                            state.params, state.opt_state, chunk,
                            active=epoch_active)
                    if pending is not None:
                        acc.drain(*pending)
                    pending = (losses, state.global_step)
                    prev_step = state.global_step
                    state.global_step += n
                    if profile is not None:
                        profile.after_chunk(state.global_step)
                    if watchdog is not None:
                        now = time.time()
                        watchdog.check((now - chunk_t0) / max(n, 1),
                                       state.global_step)
                        chunk_t0 = now
                    every = self.checkpoint_every_steps
                    save_now = bool(self.ckpt and every and
                                    prev_step // every < state.global_step // every)
                    preempted = preempt is not None and preempt.should_stop
                    if save_now or (preempted and self.ckpt):
                        # A mid-epoch checkpoint's accumulators must cover
                        # exactly the batches its loader cursor has passed:
                        # drain the in-flight chunk before snapshotting (the
                        # one host sync a checkpoint costs).
                        acc.drain(*pending)
                        pending = None
                        with rec.span("checkpoint", step=state.global_step):
                            self._save(state, train_loader, loader_state,
                                       epoch_accum=acc.aux(),
                                       history=history)
                    if preempted:
                        if self.ckpt:
                            self.log_fn("[trainer] preempted; checkpoint written")
                        else:
                            self.log_fn("[trainer] preempted; no checkpoint_dir "
                                        "configured — stopping without saving")
                        stop = True
                        break
                if pending is not None:
                    acc.drain(*pending)
                if stop:
                    # preempted: leave _final_state usable (test() after a
                    # preempted train must not crash) and hand back history
                    epoch_span.__exit__(None, None, None)
                    if profile is not None:
                        profile.close(state.global_step)
                    self._final_state = state
                    return history
                epoch_span.__exit__(None, None, None)
                state.epoch += 1
                n_batches, skipped_steps = acc.n_batches, acc.skipped_steps
                # Skipped (non-finite) steps contributed no loss; the mean is
                # over the steps that actually updated (TelemetryDrain holds
                # the exact-round-trip python-float sum).
                mean_loss = acc.mean_loss()
                record = {
                    "epoch": state.epoch,
                    "train_loss": (mean_loss if R is None else mean_loss.tolist()),
                    "seconds": time.time() - t0,
                }
                if self.nonfinite_guard:
                    record["skipped_steps"] = (int(skipped_steps) if R is None
                                               else np.asarray(skipped_steps)
                                               .tolist())
                if watchdog is not None:
                    record["watchdog_violations"] = (watchdog.violations
                                                     - wd_epoch_start)
                if R is not None:
                    record["active"] = epoch_active.tolist()
                if val_loader is not None:
                    with rec.span("eval", epoch=state.epoch):
                        val = self.evaluate(model, state.params, val_loader,
                                            replicas=R)
                    record.update({f"val_{k}": v for k, v in val.items()})
                    if R is None:
                        val_loss = -val["ll"]
                        if val_loss < best_val - 1e-6:
                            best_val, bad_epochs = val_loss, 0
                        else:
                            bad_epochs += 1
                    else:
                        # Same rule as the scalar path, applied elementwise to
                        # the replicas still training; finished replicas keep
                        # their counters (their metrics no longer move).
                        val_loss = -np.asarray(val["ll"], np.float64)
                        improved = val_loss < best_val - 1e-6
                        best_val = np.where(improved & active, val_loss, best_val)
                        bad_epochs = np.where(improved & active, 0,
                                              bad_epochs + active.astype(int))
                history.append(record)
                self.log_fn(f"[trainer] {record}")
                if rec.enabled:
                    # The full epoch record as one structured event, plus the
                    # counter snapshot and process stats — the per-epoch
                    # heartbeat a dashboard tails.
                    rec.emit(make_event("epoch", "epoch_record", data=record,
                                        epoch=state.epoch - 1,
                                        step=state.global_step))
                    rec.flush_counters(epoch=state.epoch - 1,
                                       step=state.global_step)
                    rec.process_stats(epoch=state.epoch - 1,
                                      step=state.global_step)
                # Resolve stopping BEFORE the end-of-epoch checkpoint so the
                # saved early-stop state (incl. the updated active mask) is the
                # one the next epoch would train under.
                stop_now = False
                if val_loader is not None:
                    if R is None:
                        stop_now = bad_epochs >= self.patience
                    else:
                        stopping = active & (bad_epochs >= self.patience)
                        if stopping.any():
                            active = active & ~stopping
                            self.log_fn(
                                f"[trainer] replicas "
                                f"{np.flatnonzero(stopping).tolist()} early-stop "
                                f"at epoch {state.epoch} "
                                f"({int(active.sum())}/{R} still training)")
                        stop_now = not active.any()
                snapshot_early_stop()
                if self.ckpt:
                    # End-of-epoch: loader cursor is at the next epoch's start,
                    # so the saved accumulators are a fresh epoch's (None).
                    with rec.span("checkpoint", step=state.global_step):
                        self._save(state, train_loader, history=history)
                if stop_now:
                    self.log_fn(f"[trainer] early stop at epoch {state.epoch}"
                                if R is None else
                                f"[trainer] all replicas stopped at epoch "
                                f"{state.epoch}")
                    break
            self._final_state = state
            return history
        finally:
            if profile is not None:
                # idempotent: a window still open past the last trained step
                # (or an exception inside it) is flushed here
                profile.close(state.global_step)
            if preempt is not None:
                preempt.restore()

    def evaluate(self, model, params, loader, per_rank: bool = False,
                 replicas: Optional[int] = None):
        """Stream ``loader`` through the cached compiled eval step.

        Off-mesh with ``chunk_batches > 1``, eval batches ride the same
        chunked ``DevicePrefetcher`` + scanned step as training (one jit
        dispatch per chunk, metric state as the scan carry) instead of one
        dispatch per batch. With ``replicas=R``, ``params`` must be
        R-stacked and every returned metric is a length-R list.
        """
        metrics, eval_step, eval_chunk_step = self._get_eval_step(model,
                                                                  replicas)
        m_state = None
        if self.mesh is None and self.chunk_batches > 1:
            for chunk, _, _ in DevicePrefetcher(
                    loader, chunk_batches=self.chunk_batches):
                if m_state is None:
                    m_state = metrics.init_state(chunk["positions"].shape[2],
                                                 replicas=replicas)
                m_state = eval_chunk_step(params, m_state, chunk)
        else:
            # On a mesh, shard full eval batches over the data axes so
            # validation scales with the mesh; only a batch the data axes do
            # not divide (the drop_last=False tail) falls back to
            # replication. (Chunk mode takes one fixed sharding, which the
            # odd-shaped tail chunk could not satisfy — so mesh eval stays
            # on the per-batch path.)
            device = None
            if self.mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec

                from repro.distrib.shardings import (batch_spec,
                                                     data_parallel_size)

                dp = data_parallel_size(self.mesh)
                split = NamedSharding(self.mesh, batch_spec(self.mesh,
                                                            extra_dims=0))
                replicated = NamedSharding(self.mesh, PartitionSpec())

                def device(batch):
                    rows = next(iter(batch.values())).shape[0]
                    return split if rows % dp == 0 else replicated
            for batch, _ in DevicePrefetcher(loader, device=device):
                if m_state is None:
                    m_state = metrics.init_state(batch["positions"].shape[1],
                                                 replicas=replicas)
                m_state = eval_step(params, m_state, batch)
        if m_state is None:
            raise ValueError(
                "evaluation loader produced no batches — dataset smaller than "
                "batch_size with drop_last=True? Pass drop_last=False.")
        # Metric state stayed on device for the whole pass; one blocking
        # device_get fetches every final scalar (and per-rank vector) at once.
        if replicas is None:
            finals = metrics.compute(m_state)
            per = metrics.compute_per_rank(m_state) if per_rank else None
        else:
            finals = jax.vmap(metrics.compute)(m_state)
            per = (jax.vmap(metrics.compute_per_rank)(m_state)
                   if per_rank else None)
        finals, per = jax.device_get((finals, per))
        if replicas is None:
            out = {k: float(v) for k, v in finals.items()}
        else:
            out = {k: np.asarray(v, np.float64).tolist()
                   for k, v in finals.items()}
        if per_rank:
            out["per_rank"] = {k: np.asarray(v).tolist()
                               for k, v in per.items()}
        return out

    def test(self, model, test_loader, params=None, per_rank: bool = True,
             replicas="auto"):
        """Evaluate on the test split. With no explicit ``params``, the
        trainer's own final state is used (R-stacked on a sweep trainer, so
        metrics come back as length-R lists). Explicitly passed ``params``
        are treated as a single unstacked run — the ``select_replica``
        workflow — unless ``replicas=R`` says otherwise."""
        if replicas == "auto":
            replicas = self.replicas if params is None else None
        if params is None:
            params = self._final_state.params
        return self.evaluate(model, params, test_loader, per_rank=per_rank,
                             replicas=replicas)

    # -- internals -------------------------------------------------------------------
    def _save(self, state: TrainState, loader, loader_state=None,
              epoch_accum=None, history=None):
        if loader_state is None:
            get_state = getattr(loader, "state_dict", lambda: None)
            loader_state = get_state()
        self.ckpt.save(state.global_step,
                       {"params": state.params, "opt_state": state.opt_state},
                       aux={"epoch": state.epoch, "global_step": state.global_step,
                            "loader": loader_state,
                            "early_stop": getattr(self, "_early_stop_aux",
                                                  None),
                            "epoch_accum": epoch_accum,
                            "history": history or []})
