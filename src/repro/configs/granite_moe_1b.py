"""granite-moe-1b-a400m [moe] 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.configs.lm_common import SHAPES, build_lm_cell
from repro.models.lm import LMConfig

FULL = LMConfig(
    name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
    n_kv_heads=8, d_ff=512, vocab=49155, head_dim=64,
    rope_theta=10_000.0,
    moe=True, n_experts=32, top_k=8, d_ff_moe=512, moe_layer_step=1,
    microbatches=1,
)


def reduced() -> LMConfig:
    return LMConfig(name="granite-moe-smoke", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab=211, head_dim=16,
                    moe=True, n_experts=8, top_k=2, d_ff_moe=64,
                    moe_layer_step=1, attn_chunk=16)


def build_cell(shape: str, mesh):
    return build_lm_cell(FULL, shape, mesh)
