"""graphsage-reddit [gnn] n_layers=2 d_hidden=128 aggregator=mean
sample_sizes=25-10 [arXiv:1706.02216; paper].

Shapes:
  full_graph_sm  Cora-scale full-batch (2708 nodes / 10556 edges / 1433 feats)
  minibatch_lg   Reddit sampled-training (232965 nodes, batch 1024, fanout 15-10)
  ogb_products   full-batch-large (2.45M nodes / 61.9M edges / 100 feats)
  molecule       128 batched 30-node graphs (graph classification)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import optim as optim_lib
from repro.configs.common import Cell, dp_axes, named, sds
from repro.models.gnn import (SAGEConfig, init_params, make_full_graph_train_step,
                              make_sampled_train_step)
from repro.models.gnn.graphsage import (full_graph_forward,
                                        node_classification_loss)

FULL = SAGEConfig(name="graphsage-reddit", n_layers=2, d_in=602, d_hidden=128,
                  n_classes=41, sample_sizes=(25, 10))

SHAPES = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                          n_classes=7, kind="full"),
    "minibatch_lg": dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                         fanout=(15, 10), d_feat=602, n_classes=41,
                         kind="sampled"),
    "ogb_products": dict(n_nodes=2449029, n_edges=61859140, d_feat=100,
                         n_classes=47, kind="full"),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, d_feat=32,
                     n_classes=2, kind="molecule"),
}


def reduced() -> SAGEConfig:
    return SAGEConfig(name="graphsage-smoke", n_layers=2, d_in=16,
                      d_hidden=32, n_classes=5, sample_sizes=(5, 3))


def _pad_edges(n_edges: int, mesh) -> int:
    n_dev = 1
    for a in mesh.axis_names:
        n_dev *= mesh.shape[a]
    return -(-n_edges // n_dev) * n_dev


def _params_opt(cfg, optimizer):
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    opt_state = jax.eval_shape(optimizer.init, params)
    pspecs = jax.tree_util.tree_map(lambda _: P(), params)
    ospecs = jax.tree_util.tree_map(lambda _: P(), opt_state)
    return params, opt_state, pspecs, ospecs


def _flops_full(cfg, n_nodes, n_edges, d_feat):
    dims = [d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    total = 0.0
    for l in range(cfg.n_layers):
        total += 2.0 * 2 * n_nodes * dims[l] * dims[l + 1]  # self + neigh matmuls
        total += 2.0 * n_edges * dims[l]                    # gather-adds
    return 3 * total  # fwd + bwd(2x)


def build_cell(shape: str, mesh) -> Cell:
    info = SHAPES[shape]
    all_axes = tuple(mesh.axis_names)
    optimizer = optim_lib.adam(1e-2)

    if info["kind"] in ("full", "molecule"):
        if info["kind"] == "molecule":
            n_nodes = info["n_nodes"] * info["batch"]
            n_edges_raw = info["n_edges"] * info["batch"]
            n_classes = info["n_classes"]
        else:
            n_nodes, n_edges_raw = info["n_nodes"], info["n_edges"]
            n_classes = info["n_classes"]
        cfg = SAGEConfig(name=FULL.name, n_layers=FULL.n_layers,
                         d_in=info["d_feat"], d_hidden=FULL.d_hidden,
                         n_classes=n_classes, sample_sizes=FULL.sample_sizes)
        n_edges = _pad_edges(n_edges_raw, mesh)
        graph = {
            "features": sds((n_nodes, info["d_feat"]), jnp.float32),
            "src": sds((n_edges,), jnp.int32),
            "dst": sds((n_edges,), jnp.int32),
            "edge_weight": sds((n_edges,), jnp.float32),
            "degree_inv": sds((n_nodes,), jnp.float32),
            "labels": sds((n_nodes,), jnp.int32),
        }
        gspecs = {
            "features": P(None, None), "src": P(all_axes), "dst": P(all_axes),
            "edge_weight": P(all_axes), "degree_inv": P(None),
            "labels": P(None),
        }
        if info["kind"] == "molecule":
            graph["graph_ids"] = sds((n_nodes,), jnp.int32)
            gspecs["graph_ids"] = P(None)
            fn = _make_molecule_step(cfg, optimizer, mesh, info["batch"])
        else:
            fn = make_full_graph_train_step(cfg, optimizer, mesh)
        params, opt_state, pspecs, ospecs = _params_opt(cfg, optimizer)
        return Cell(
            arch=FULL.name, shape=shape, kind="train", fn=fn,
            args=(params, opt_state, graph),
            in_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                          named(mesh, gspecs)),
            out_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                           named(mesh, P())),
            model_flops=_flops_full(cfg, n_nodes, n_edges_raw, info["d_feat"]),
            donate=(0, 1),
            notes=f"edges padded {n_edges_raw}->{n_edges}, sharded over "
                  f"{all_axes}; nodes replicated + psum",
        )

    # sampled minibatch (Reddit)
    cfg = SAGEConfig(name=FULL.name, n_layers=FULL.n_layers,
                     d_in=info["d_feat"], d_hidden=FULL.d_hidden,
                     n_classes=info["n_classes"],
                     sample_sizes=info["fanout"])
    B = info["batch_nodes"]
    f1, f2 = info["fanout"]
    dp = dp_axes(mesh)
    batch = {
        "feats_hop_0": sds((B, info["d_feat"]), jnp.float32),
        "feats_hop_1": sds((B, f1, info["d_feat"]), jnp.float32),
        "feats_hop_2": sds((B, f1, f2, info["d_feat"]), jnp.float32),
        "labels": sds((B,), jnp.int32),
    }
    bspecs = {
        "feats_hop_0": P(dp, None), "feats_hop_1": P(dp, None, None),
        "feats_hop_2": P(dp, None, None, None), "labels": P(dp),
    }
    fn = make_sampled_train_step(cfg, optimizer)
    params, opt_state, pspecs, ospecs = _params_opt(cfg, optimizer)
    gathered = B * (1 + f1 + f1 * f2)
    flops = 3 * (2.0 * 2 * gathered * info["d_feat"] * cfg.d_hidden
                 + 2.0 * 2 * B * cfg.d_hidden * cfg.n_classes)
    return Cell(
        arch=FULL.name, shape=shape, kind="train", fn=fn,
        args=(params, opt_state, batch),
        in_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                      named(mesh, bspecs)),
        out_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                       named(mesh, P())),
        model_flops=flops,
        donate=(0, 1),
        notes=f"host NeighborSampler feeds fixed fanout {info['fanout']}",
    )


def _make_molecule_step(cfg, optimizer, mesh, n_graphs):
    def step(params, opt_state, graph):
        def loss_fn(p):
            node_logits = full_graph_forward(cfg, p, graph, mesh)
            pooled = jax.ops.segment_sum(node_logits, graph["graph_ids"],
                                         num_segments=n_graphs)
            counts = jax.ops.segment_sum(
                jnp.ones_like(graph["graph_ids"], jnp.float32),
                graph["graph_ids"], num_segments=n_graphs)
            pooled = pooled / jnp.maximum(counts[:, None], 1.0)
            labels = graph["labels"][::graph["labels"].shape[0] // n_graphs]
            return node_classification_loss(pooled, labels[:n_graphs])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return optim_lib.apply_updates(params, updates), opt_state, loss

    return step
