"""Shared cell builder for the recsys archs (4 archs x 4 shapes).

Shapes: train_batch (65536, training), serve_p99 (512, online),
serve_bulk (262144, offline scoring), retrieval_cand (1 query x 1M candidates).
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import optim as optim_lib
from repro.configs.common import Cell, dp_axes, named, sds

SHAPES = {
    "train_batch": dict(batch=65536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262144, kind="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=1_000_000, kind="retrieval"),
}


def build_recsys_cell(model, shape: str, mesh, *, batch_factory: Callable,
                      flops_per_example: float, retrieval_flops: float,
                      arch_name: str) -> Cell:
    info = SHAPES[shape]
    dp = dp_axes(mesh)
    pspecs = model.param_specs(mesh)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))

    batch, bspecs = batch_factory(info, dp)

    if info["kind"] == "train":
        optimizer = optim_lib.adamw(1e-3)
        opt_state = jax.eval_shape(optimizer.init, params)
        from repro.optim.optimizers import ScaleByAdamState
        ospecs = (ScaleByAdamState(count=P(), mu=pspecs, nu=pspecs), (), ())
        fn = model.make_train_step(optimizer)
        return Cell(
            arch=arch_name, shape=shape, kind="train", fn=fn,
            args=(params, opt_state, batch),
            in_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                          named(mesh, bspecs)),
            out_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                           named(mesh, P())),
            model_flops=3.0 * flops_per_example * info["batch"],
            donate=(0, 1),
            notes="tables row-sharded over 'model'; towers replicated",
        )

    if info["kind"] == "serve":
        fn = model.serve
        out_spec = P(dp) if info["batch"] % _dp_size(mesh) == 0 else P(None)
        return Cell(
            arch=arch_name, shape=shape, kind="serve", fn=fn,
            args=(params, batch),
            in_shardings=(named(mesh, pspecs), named(mesh, bspecs)),
            out_shardings=named(mesh, out_spec),
            model_flops=flops_per_example * info["batch"],
            notes="forward only",
        )

    # retrieval
    fn = model.retrieval_score
    out_shape = jax.eval_shape(fn, params, batch)
    out_spec = jax.tree_util.tree_map(
        lambda s: P(tuple(dp) if s.shape and s.shape[0] % _dp_size(mesh) == 0
                    else None, *([None] * (max(len(s.shape) - 1, 0)))),
        out_shape)
    return Cell(
        arch=arch_name, shape=shape, kind="retrieval", fn=fn,
        args=(params, batch),
        in_shardings=(named(mesh, pspecs), named(mesh, bspecs)),
        out_shardings=named(mesh, out_spec),
        model_flops=retrieval_flops,
        notes="single batched program over 1M candidates (no host loop)",
    )


def _dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out


def tabular_batch_factory(n_fields: int):
    """deepfm / autoint: (B, n_fields) ids + labels; retrieval expands the
    candidate rows into the field matrix (one batched forward)."""
    def factory(info, dp):
        if info["kind"] == "retrieval":
            C = info["n_candidates"]
            batch = {"field_ids": sds((C, n_fields), jnp.int32)}
            bspecs = {"field_ids": P(dp, None)}
            return batch, bspecs
        B = info["batch"]
        batch = {"field_ids": sds((B, n_fields), jnp.int32)}
        bspecs = {"field_ids": P(dp, None)}
        if info["kind"] == "train":
            batch["labels"] = sds((B,), jnp.float32)
            bspecs["labels"] = P(dp)
        return batch, bspecs

    return factory


def sequence_batch_factory(history_len: int, with_target: bool = True):
    """bst / mind: history ids + target id; retrieval = 1 user x candidates."""
    def factory(info, dp):
        if info["kind"] == "retrieval":
            batch = {
                "history_ids": sds((1, history_len), jnp.int32),
                "candidate_ids": sds((info["n_candidates"],), jnp.int32),
            }
            bspecs = {"history_ids": P(None, None), "candidate_ids": P(dp)}
            return batch, bspecs
        B = info["batch"]
        batch = {"history_ids": sds((B, history_len), jnp.int32)}
        bspecs = {"history_ids": P(dp, None)}
        if with_target:
            batch["target_ids"] = sds((B,), jnp.int32)
            bspecs["target_ids"] = P(dp)
        if info["kind"] == "train":
            batch["labels"] = sds((B,), jnp.float32)
            bspecs["labels"] = P(dp)
        return batch, bspecs

    return factory
