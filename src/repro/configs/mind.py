"""mind [recsys] embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest [arXiv:1904.08030; unverified]."""
from repro.configs.recsys_common import SHAPES, build_recsys_cell, sequence_batch_factory
from repro.models.recsys import MIND, MINDConfig

FULL = MINDConfig(name="mind", embed_dim=64, n_interests=4, capsule_iters=3,
                  history_len=50, item_vocab=10_000_000)


def reduced() -> MINDConfig:
    return MINDConfig(name="mind-smoke", embed_dim=8, n_interests=2,
                      capsule_iters=2, history_len=10, item_vocab=500)


def _flops_per_example(cfg: MINDConfig) -> float:
    L, D, K = cfg.history_len, cfg.embed_dim, cfg.n_interests
    bilinear = 2.0 * L * D * D
    routing = cfg.capsule_iters * (2 * 2.0 * L * K * D)
    label_aware = 2.0 * K * D
    return bilinear + routing + label_aware


def build_cell(shape: str, mesh):
    model = MIND(FULL)
    f = _flops_per_example(FULL)
    return build_recsys_cell(
        model, shape, mesh,
        batch_factory=sequence_batch_factory(FULL.history_len),
        flops_per_example=f,
        retrieval_flops=f + 2.0 * 1_000_000 * FULL.n_interests * FULL.embed_dim,
        arch_name=FULL.name)
