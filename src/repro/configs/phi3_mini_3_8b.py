"""phi3-mini-3.8b [dense] 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU GQA [arXiv:2404.14219; unverified]."""
from repro.configs.lm_common import SHAPES, build_lm_cell
from repro.models.lm import LMConfig

FULL = LMConfig(
    name="phi3-mini-3.8b", n_layers=32, d_model=3072, n_heads=32,
    n_kv_heads=32, d_ff=8192, vocab=32064, head_dim=96,
    rope_theta=10_000.0, microbatches=4, scan_chunks=4,
)


def reduced() -> LMConfig:
    return LMConfig(name="phi3-mini-smoke", n_layers=4, d_model=96,
                    n_heads=4, n_kv_heads=4, d_ff=192, vocab=307,
                    head_dim=24, attn_chunk=16)


def build_cell(shape: str, mesh):
    return build_lm_cell(FULL, shape, mesh)
