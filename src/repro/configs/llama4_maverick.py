"""llama4-maverick-400b-a17b [moe] 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128e top-1, interleaved (every 2nd layer) + shared expert —
MoE, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

The early-fusion modality frontend is a stub per the brief: input_specs
provide token ids for the backbone.
"""
import jax.numpy as jnp

from repro.configs.lm_common import SHAPES, build_lm_cell
from repro.models.lm import LMConfig

FULL = LMConfig(
    name="llama4-maverick-400b-a17b", n_layers=48, d_model=5120, n_heads=40,
    n_kv_heads=8, d_ff=8192, vocab=202048, head_dim=128,
    rope_theta=500_000.0,
    moe=True, n_experts=128, top_k=1, d_ff_moe=8192, moe_layer_step=2,
    n_shared_experts=1,
    opt_dtype=jnp.bfloat16, grad_accum_dtype=jnp.bfloat16,
    microbatches=8, scan_chunks=4, attn_chunk=512,
)


def reduced() -> LMConfig:
    return LMConfig(name="llama4-maverick-smoke", n_layers=4, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
                    moe=True, n_experts=8, top_k=1, d_ff_moe=128,
                    moe_layer_step=2, n_shared_experts=1, attn_chunk=16)


def build_cell(shape: str, mesh):
    return build_lm_cell(FULL, shape, mesh)
