"""Shared cell builder for the LM-family architectures (5 archs x 4 shapes).

Shapes (assigned set):
  train_4k     seq 4096,   global_batch 256  -> train_step (AdamW, microbatched)
  prefill_32k  seq 32768,  global_batch 32   -> prefill (logits + KV cache out)
  decode_32k   seq 32768,  global_batch 128  -> serve_step (1 new token vs cache)
  long_500k    seq 524288, global_batch 1    -> serve_step (decode is O(S), so
               full-attention archs run it; see DESIGN.md long_500k note)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import optim as optim_lib
from repro.configs.common import Cell, dp_axes, named, sds
from repro.models.lm import (LMConfig, cache_specs, forward, init_cache,
                             init_params, make_decode_step, make_prefill_step,
                             make_train_step, param_specs)

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def _attn_flops(cfg: LMConfig, batch: int, seq: int, causal: bool) -> float:
    per_layer = 4.0 * batch * seq * seq * cfg.n_heads * cfg.head_dim
    if causal:
        per_layer /= 2
    return per_layer * cfg.n_layers


def _params_sds(cfg: LMConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def _opt_specs(pspecs):
    from repro.optim.optimizers import ScaleByAdamState

    return (ScaleByAdamState(count=P(), mu=pspecs, nu=pspecs), (), ())


def build_lm_cell(cfg: LMConfig, shape: str, mesh) -> Cell:
    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    dp = dp_axes(mesh)
    pspecs = param_specs(cfg, mesh)
    params = _params_sds(cfg)

    if info["kind"] == "train":
        optimizer = optim_lib.adamw(3e-4, moment_dtype=cfg.opt_dtype)
        opt_state = jax.eval_shape(optimizer.init, params)
        ospecs = _opt_specs(pspecs)
        batch = {"tokens": sds((B, S), jnp.int32),
                 "targets": sds((B, S), jnp.int32)}
        bspecs = {"tokens": P(dp, None), "targets": P(dp, None)}
        fn = make_train_step(cfg, optimizer, mesh)
        return Cell(
            arch=cfg.name, shape=shape, kind="train", fn=fn,
            args=(params, opt_state, batch),
            in_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                          named(mesh, bspecs)),
            out_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                           named(mesh, P())),
            model_flops=6.0 * cfg.active_param_count() * B * S
            + 3 * _attn_flops(cfg, B, S, causal=True),
            donate=(0, 1),
            notes=f"microbatches={cfg.microbatches} scan_chunks={cfg.scan_chunks}",
        )

    if info["kind"] == "prefill":
        tokens = sds((B, S), jnp.int32)
        fn = make_prefill_step(cfg, mesh)
        cspecs = {"k": P(None, None, dp, "model", None, None),
                  "v": P(None, None, dp, "model", None, None)}
        return Cell(
            arch=cfg.name, shape=shape, kind="prefill", fn=fn,
            args=(params, tokens),
            in_shardings=(named(mesh, pspecs), named(mesh, P(dp, None))),
            out_shardings=(named(mesh, P(dp, None, "model")),
                           named(mesh, cspecs)),
            model_flops=2.0 * cfg.active_param_count() * B * S
            + _attn_flops(cfg, B, S, causal=True),
            notes="emits KV cache + last-position logits only",
        )

    # decode
    import dataclasses as _dc

    batch_shardable = B % (mesh.shape.get("data", 1) *
                           mesh.shape.get("pod", 1)) == 0
    dec_dp = dp if batch_shardable else ()
    seq_axes = ("model",) if batch_shardable else tuple(mesh.axis_names)
    cfg = _dc.replace(cfg, decode_seq_axes=seq_axes)
    cache = {
        "k": sds((cfg.n_units, cfg.layers_per_unit, B, S,
                  cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
        "v": sds((cfg.n_units, cfg.layers_per_unit, B, S,
                  cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
    }
    cspec = P(None, None, dec_dp if dec_dp else None, seq_axes, None, None)
    cspecs = {"k": cspec, "v": cspec}
    tokens = sds((B, 1), jnp.int32)
    index = sds((), jnp.int32)
    fn = make_decode_step(cfg, mesh, dp_axes=dec_dp)
    tok_spec = P(dec_dp if dec_dp else None, None)
    return Cell(
        arch=cfg.name, shape=shape, kind="decode", fn=fn,
        args=(params, cache, tokens, index),
        in_shardings=(named(mesh, pspecs), named(mesh, cspecs),
                      named(mesh, tok_spec), named(mesh, P())),
        out_shardings=(named(mesh, P(tok_spec[0], None, "model")),
                       named(mesh, cspecs)),
        model_flops=2.0 * cfg.active_param_count() * B
        + 4.0 * B * S * cfg.n_heads * cfg.head_dim * cfg.n_layers,
        donate=(1,),
        notes=f"KV cache {S} tokens; seq sharded over {seq_axes}",
    )


def lm_smoke_batch(cfg: LMConfig, batch: int = 2, seq: int = 16):
    tok = jax.random.randint(jax.random.PRNGKey(0), (batch, seq), 0, cfg.vocab)
    return {"tokens": tok, "targets": tok}
