"""Shared cell-building machinery for the dry-run / roofline harness.

Every architecture module exposes:
  * ``FULL``       — the exact published configuration,
  * ``reduced()``  — a small same-family config for CPU smoke tests,
  * ``SHAPES``     — its assigned input-shape set,
  * ``build_cell(shape, mesh)`` -> :class:`Cell` — the jit-able function,
    ShapeDtypeStruct args, shardings, and the analytic MODEL_FLOPS.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                      # train | prefill | decode | serve | retrieval
    fn: Callable                   # jit target
    args: Tuple[Any, ...]          # ShapeDtypeStructs (+ static python values)
    in_shardings: Any
    out_shardings: Any
    model_flops: float             # analytic useful FLOPs per call
    notes: str = ""
    donate: tuple = ()             # argnums to donate (params/opt/cache)


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out


def eval_shape_tree(fn, *args):
    """Shapes of fn's params pytree without allocating (for init trees)."""
    return jax.eval_shape(fn, *args)


def divisible_batch_spec(mesh, batch: int) -> P:
    """Batch dim over as many data axes as divide it (1 -> replicated)."""
    axes = []
    remaining = batch
    for a in dp_axes(mesh):
        size = mesh.shape[a]
        if remaining % size == 0:
            axes.append(a)
            remaining //= size
    return P(tuple(axes)) if axes else P(None)
