"""llama3.2-1b [dense] 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3 [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.configs.lm_common import SHAPES, build_lm_cell
from repro.models.lm import LMConfig

FULL = LMConfig(
    name="llama3.2-1b", n_layers=16, d_model=2048, n_heads=32,
    n_kv_heads=8, d_ff=8192, vocab=128256, head_dim=64,
    rope_theta=500_000.0, microbatches=2,
)


def reduced() -> LMConfig:
    return LMConfig(name="llama3.2-1b-smoke", n_layers=3, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=256, vocab=499,
                    head_dim=16, attn_chunk=16)


def build_cell(shape: str, mesh):
    return build_lm_cell(FULL, shape, mesh)
