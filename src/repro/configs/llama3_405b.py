"""llama3-405b [dense] 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA 128k vocab [arXiv:2407.21783; unverified]."""
import jax.numpy as jnp

from repro.configs.lm_common import SHAPES, build_lm_cell
from repro.models.lm import LMConfig

FULL = LMConfig(
    name="llama3-405b", n_layers=126, d_model=16384, n_heads=128,
    n_kv_heads=8, d_ff=53248, vocab=128256, head_dim=128,
    rope_theta=500_000.0,
    opt_dtype=jnp.bfloat16,      # 405B AdamW moments in bf16 (DESIGN.md)
    grad_accum_dtype=jnp.bfloat16,
    microbatches=16, scan_chunks=9, attn_chunk=512,
)


def reduced() -> LMConfig:
    return LMConfig(name="llama3-405b-smoke", n_layers=4, d_model=128,
                    n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
                    head_dim=16, attn_chunk=16, scan_chunks=2)


def build_cell(shape: str, mesh):
    return build_lm_cell(FULL, shape, mesh)
