"""The paper's own workload: CLAX click models at Baidu-ULTR scale.

2^31 query-document pairs hashed 10x down (the paper's Figure 3 setting) to a
~214.7M-row scalar-logit table, row-sharded over the ``model`` mesh axis;
sessions data-parallel. Not part of the assigned-40 grid — recorded as extra
cells in EXPERIMENTS.md because the paper technique itself is the most
representative hillclimb target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import optim as optim_lib
from repro.configs.common import Cell, dp_axes, named, sds
from repro.core import (EmbeddingParameterConfig, Compression,
                        DynamicBayesianNetwork, UserBrowsingModel)

POSITIONS = 10
# 2^31 ids hashed 10x, rounded to divide the model axis (16) and 512 devices.
TABLE_ROWS = 214_748_160

SHAPES = {
    "train_batch": dict(batch=65536, kind="train"),
    "serve_bulk": dict(batch=262144, kind="serve"),
}


def _make_model(kind: str):
    attraction = EmbeddingParameterConfig(
        parameters=1 << 31, compression=Compression.HASH,
        compression_ratio=10.0, baseline_correction=True,
        init_logit=-2.0)
    if kind == "ubm":
        model = UserBrowsingModel(positions=POSITIONS, attraction=attraction)
    else:
        model = DynamicBayesianNetwork(positions=POSITIONS,
                                       attraction=attraction,
                                       satisfaction=attraction)
    return model


def _param_specs(model):
    like = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))

    def rule(path, leaf):
        # huge hashed tables row-sharded; everything else replicated
        if leaf.ndim >= 1 and leaf.shape[0] >= 1_000_000:
            return P("model", *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(rule, like), like


def build_cell(shape: str, mesh, kind: str = "ubm") -> Cell:
    info = SHAPES[shape]
    B = info["batch"]
    dp = dp_axes(mesh)
    model = _make_model(kind)
    pspecs, params = _param_specs(model)

    batch = {
        "positions": sds((B, POSITIONS), jnp.int32),
        "query_doc_ids": sds((B, POSITIONS), jnp.int32),
        "clicks": sds((B, POSITIONS), jnp.float32),
        "mask": sds((B, POSITIONS), jnp.bool_),
    }
    bspecs = {k: P(dp, None) for k in batch}

    if info["kind"] == "train":
        optimizer = optim_lib.adamw(3e-3, weight_decay=1e-4)
        opt_state = jax.eval_shape(optimizer.init, params)
        from repro.optim.optimizers import ScaleByAdamState
        ospecs = (ScaleByAdamState(count=P(), mu=pspecs, nu=pspecs), (), ())

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.compute_loss)(params, batch)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            return optim_lib.apply_updates(params, updates), opt_state, loss

        return Cell(
            arch=f"clax-{kind}-baidu", shape=shape, kind="train",
            fn=train_step, args=(params, opt_state, batch),
            in_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                          named(mesh, bspecs)),
            out_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                           named(mesh, P())),
            # log-space chain: ~60 flops/item fwd, 3x for bwd — gather-bound.
            model_flops=3.0 * 60 * B * POSITIONS,
            donate=(0, 1),
            notes="2^31 ids hashed 10x -> 214.7M rows P('model'); AdamW",
        )

    def serve(params, batch):
        return model.predict_clicks(params, batch)

    return Cell(
        arch=f"clax-{kind}-baidu", shape=shape, kind="serve",
        fn=serve, args=(params, batch),
        in_shardings=(named(mesh, pspecs), named(mesh, bspecs)),
        out_shardings=named(mesh, P(dp, None)),
        model_flops=1.0 * 60 * B * POSITIONS * POSITIONS,
        notes="unconditional click prediction (UBM marginalization O(K^2))",
    )
