"""deepfm [recsys] n_sparse=39 embed_dim=10 mlp=400-400-400 interaction=fm
[arXiv:1703.04247; paper]. Criteo-scale unified hashed table (8e7 rows)."""
from repro.configs.recsys_common import SHAPES, build_recsys_cell, tabular_batch_factory
from repro.models.recsys import DeepFM, DeepFMConfig

FULL = DeepFMConfig(name="deepfm", n_sparse=39, embed_dim=10,
                    mlp=(400, 400, 400), table_rows=80_000_000)


def reduced() -> DeepFMConfig:
    return DeepFMConfig(name="deepfm-smoke", n_sparse=8, embed_dim=4,
                        mlp=(16, 16), table_rows=1000)


def _flops_per_example(cfg: DeepFMConfig) -> float:
    mlp_in = cfg.n_sparse * cfg.embed_dim
    dims = [mlp_in, *cfg.mlp, 1]
    mlp = sum(2.0 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
    fm = 3.0 * cfg.n_sparse * cfg.embed_dim
    return mlp + fm


def build_cell(shape: str, mesh):
    model = DeepFM(FULL)
    f = _flops_per_example(FULL)
    return build_recsys_cell(
        model, shape, mesh,
        batch_factory=tabular_batch_factory(FULL.n_sparse),
        flops_per_example=f, retrieval_flops=f * 1_000_000,
        arch_name=FULL.name)
