"""bst [recsys] embed_dim=32 seq_len=20 n_blocks=1 n_heads=8 mlp=1024-512-256
interaction=transformer-seq — Behavior Sequence Transformer (Alibaba)
[arXiv:1905.06874; paper]."""
from repro.configs.recsys_common import SHAPES, build_recsys_cell, sequence_batch_factory
from repro.models.recsys import BST, BSTConfig

FULL = BSTConfig(name="bst", embed_dim=32, seq_len=20, n_blocks=1, n_heads=8,
                 d_ff=128, mlp=(1024, 512, 256), item_vocab=20_000_000)


def reduced() -> BSTConfig:
    return BSTConfig(name="bst-smoke", embed_dim=8, seq_len=6, n_blocks=1,
                     n_heads=2, d_ff=16, mlp=(32, 16), item_vocab=500)


def _flops_per_example(cfg: BSTConfig) -> float:
    S, D = cfg.total_len, cfg.embed_dim
    attn = cfg.n_blocks * (4 * 2.0 * S * D * D + 2 * 2.0 * S * S * D
                           + 2 * 2.0 * S * D * cfg.d_ff)
    dims = [S * D, *cfg.mlp, 1]
    mlp = sum(2.0 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
    return attn + mlp


def build_cell(shape: str, mesh):
    model = BST(FULL)
    f = _flops_per_example(FULL)
    # retrieval path is the factorized dot: 2 * C * D
    return build_recsys_cell(
        model, shape, mesh,
        batch_factory=sequence_batch_factory(FULL.seq_len),
        flops_per_example=f,
        retrieval_flops=2.0 * 1_000_000 * FULL.embed_dim,
        arch_name=FULL.name)
