"""Architecture registry: --arch <id> resolution for launch/dryrun/train."""
from __future__ import annotations

import functools
from typing import Dict, List, Tuple

from repro.configs import (autoint, bst, clax_baidu, deepfm, graphsage_reddit,
                           granite_moe_1b, llama3_2_1b, llama3_405b,
                           llama4_maverick, mind, phi3_mini_3_8b)
from repro.configs.lm_common import SHAPES as LM_SHAPES
from repro.configs.recsys_common import SHAPES as RECSYS_SHAPES

ARCHS = {
    "llama3-405b": llama3_405b,
    "phi3-mini-3.8b": phi3_mini_3_8b,
    "llama3.2-1b": llama3_2_1b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "llama4-maverick-400b-a17b": llama4_maverick,
    "graphsage-reddit": graphsage_reddit,
    "deepfm": deepfm,
    "mind": mind,
    "bst": bst,
    "autoint": autoint,
}

LM_ARCHS = ("llama3-405b", "phi3-mini-3.8b", "llama3.2-1b",
            "granite-moe-1b-a400m", "llama4-maverick-400b-a17b")
RECSYS_ARCHS = ("deepfm", "mind", "bst", "autoint")

# Extra (beyond the assigned 40): the paper's own workload.
EXTRA_CELLS = [
    ("clax-ubm-baidu", "train_batch",
     functools.partial(clax_baidu.build_cell, "train_batch", kind="ubm")),
    ("clax-ubm-baidu", "serve_bulk",
     functools.partial(clax_baidu.build_cell, "serve_bulk", kind="ubm")),
    ("clax-dbn-baidu", "train_batch",
     functools.partial(clax_baidu.build_cell, "train_batch", kind="dbn")),
]


def get_arch(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def arch_shapes(arch_id: str) -> List[str]:
    if arch_id in LM_ARCHS:
        return list(LM_SHAPES)
    if arch_id == "graphsage-reddit":
        return list(graphsage_reddit.SHAPES)
    return list(RECSYS_SHAPES)


def list_cells(include_extra: bool = False) -> List[Tuple[str, str]]:
    """The assigned 40 (arch, shape) cells (+ optional paper-own extras)."""
    cells = [(a, s) for a in ARCHS for s in arch_shapes(a)]
    if include_extra:
        cells += [(a, s) for a, s, _ in EXTRA_CELLS]
    return cells


def build_cell(arch_id: str, shape: str, mesh):
    for a, s, fn in EXTRA_CELLS:
        if (a, s) == (arch_id, shape):
            return fn(mesh)
    return get_arch(arch_id).build_cell(shape, mesh)
