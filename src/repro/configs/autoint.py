"""autoint [recsys] n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2
d_attn=32 interaction=self-attn [arXiv:1810.11921; paper]."""
from repro.configs.recsys_common import SHAPES, build_recsys_cell, tabular_batch_factory
from repro.models.recsys import AutoInt, AutoIntConfig

FULL = AutoIntConfig(name="autoint", n_sparse=39, embed_dim=16,
                     n_attn_layers=3, n_heads=2, d_attn=32,
                     table_rows=80_000_000)


def reduced() -> AutoIntConfig:
    return AutoIntConfig(name="autoint-smoke", n_sparse=8, embed_dim=8,
                         n_attn_layers=2, n_heads=2, d_attn=8,
                         table_rows=1000)


def _flops_per_example(cfg: AutoIntConfig) -> float:
    F = cfg.n_sparse
    dims = [cfg.embed_dim] + [cfg.d_attn] * cfg.n_attn_layers
    total = 0.0
    for l in range(cfg.n_attn_layers):
        d_in, d_out = dims[l], dims[l + 1]
        total += 4 * 2.0 * F * d_in * d_out          # q,k,v,res projections
        total += 2 * 2.0 * F * F * d_out             # scores + weighted sum
    total += 2.0 * F * dims[-1]                      # head
    return total


def build_cell(shape: str, mesh):
    model = AutoInt(FULL)
    f = _flops_per_example(FULL)
    return build_recsys_cell(
        model, shape, mesh,
        batch_factory=tabular_batch_factory(FULL.n_sparse),
        flops_per_example=f, retrieval_flops=f * 1_000_000,
        arch_name=FULL.name)
