"""ClickModel base API (paper §4.1, Listing 2).

Every model implements five methods over a padded batch dict:

  * ``compute_loss(params, batch)``     — masked mean NLL of observed clicks
    under the session marginal likelihood (chain-rule factorized:
    sum_k log P(c_k | c_<k)). For position-independent models conditional and
    unconditional click probabilities coincide.
  * ``predict_clicks(params, batch)``   — log P(C=1 | d, k).
  * ``predict_conditional_clicks(...)`` — log P(C=1 | d, k, c_<k).
  * ``predict_relevance(params, batch)``— ranking scores (log-space).
  * ``sample(params, batch, rng)``      — click sequences + latent draws.

Batch layout (all (batch, K)):
  positions: int32 starting at 1; query_doc_ids: int32; clicks: float;
  mask: bool (True = real item); optional feature arrays (batch, K, F).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.nn.module import Module
from repro.stable import log_bce

Batch = Dict[str, jax.Array]

REQUIRED_KEYS = ("positions", "clicks", "mask")


def validate_batch(batch: Batch) -> None:
    for key in REQUIRED_KEYS:
        if key not in batch:
            raise ValueError(f"batch missing required key {key!r}")
    shape = batch["positions"].shape
    if len(shape) != 2:
        raise ValueError(f"batch arrays must be 2D (batch, positions), got {shape}")
    for key, arr in batch.items():
        if arr.shape[:2] != shape:
            raise ValueError(f"batch[{key!r}] leading shape {arr.shape[:2]} != {shape}")


def masked_mean(values: jax.Array, mask: jax.Array) -> jax.Array:
    mask = mask.astype(values.dtype)
    return jnp.sum(values * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def last_click_positions(clicks: jax.Array, positions: jax.Array) -> jax.Array:
    """Rank (1-based) of the most recent click strictly before each position.

    Returns 0 where no click occurred before. Assumes positions are sorted
    ascending within a session (top-down browsing).
    """
    clicked_rank = jnp.where(clicks > 0, positions, 0)
    # exclusive cumulative max over the position axis
    cummax = jax.lax.associative_scan(jnp.maximum, clicked_rank, axis=1)
    exclusive = jnp.concatenate(
        [jnp.zeros_like(cummax[:, :1]), cummax[:, :-1]], axis=1)
    return exclusive


def clicks_before(clicks: jax.Array) -> jax.Array:
    """Number of clicks strictly before each position."""
    csum = jnp.cumsum(clicks, axis=1)
    return csum - clicks


class ClickModel(Module):
    """Base class: loss defaults to BCE over conditional click log-probs."""

    positions: int = 10

    # -- API -----------------------------------------------------------------
    def compute_loss(self, params, batch: Batch) -> jax.Array:
        logits = self.predict_conditional_logits(params, batch)
        if logits is not None:
            # CTR-family fast path: one fused kernel from raw logits to the
            # scalar loss, no (B, K) log-probability intermediates.
            from repro.kernels import session_nll

            return session_nll(logits, batch["clicks"], batch["mask"])
        log_probs = self.predict_conditional_clicks(params, batch)
        nll = log_bce(log_probs, batch["clicks"])
        return masked_mean(nll, batch["mask"])

    def predict_conditional_logits(self, params, batch: Batch):
        """Raw logits x with log P(C=1 | d, k, c_<k) = log sigmoid(x), or None.

        Models whose conditional click probability is a single sigmoid (the
        CTR family) override this; ``compute_loss`` then routes through the
        fused ``session_nll`` kernel instead of log-space BCE.
        """
        del params, batch
        return None

    def predict_clicks(self, params, batch: Batch) -> jax.Array:
        raise NotImplementedError

    def predict_conditional_clicks(self, params, batch: Batch) -> jax.Array:
        # default: position-independent model
        return self.predict_clicks(params, batch)

    def predict_relevance(self, params, batch: Batch) -> jax.Array:
        raise NotImplementedError

    def sample(self, params, batch: Batch, rng: jax.Array) -> Dict[str, jax.Array]:
        raise NotImplementedError

    # -- conveniences ----------------------------------------------------------
    def init(self, rng: jax.Array):
        raise NotImplementedError

    def loss_and_grad(self, params, batch: Batch):
        return jax.value_and_grad(self.compute_loss)(params, batch)
