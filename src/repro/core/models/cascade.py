"""Cascade model (paper A.5): click the first attractive doc, then stop."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.base import clicks_before
from repro.core.models.ctr import _PartsModel
from repro.core.parameterization import EmbeddingParameterConfig, build_parameter
from repro.stable import MIN_LOG_PROB, log1mexp, log_sigmoid


class CascadeModel(_PartsModel):
    def __init__(self, query_doc_pairs: int = None, positions: int = 10,
                 attraction=None, init_prob: float = 0.5, **_):
        self.positions = positions
        logit = math.log(init_prob) - math.log1p(-init_prob)
        if attraction is None:
            attraction = EmbeddingParameterConfig(parameters=query_doc_pairs,
                                                  init_logit=logit)
        self.parts = {"attraction": build_parameter(attraction)}

    def _log_attr(self, params, batch):
        return log_sigmoid(self.parts["attraction"](params["attraction"], batch))

    def predict_clicks(self, params, batch):
        """Eq. 23: log gamma_d + sum_{i<k} log(1 - gamma_{d_i})."""
        la = self._log_attr(params, batch)
        log_no_click = log1mexp(la)
        csum = jnp.cumsum(log_no_click, axis=1)
        exclusive = jnp.concatenate([jnp.zeros_like(csum[:, :1]), csum[:, :-1]], axis=1)
        return la + exclusive

    def predict_conditional_clicks(self, params, batch):
        """Eq. 24: gamma_d until the first click, MIN_LOG_PROB afterwards."""
        la = self._log_attr(params, batch)
        any_click_before = clicks_before(batch["clicks"]) > 0
        return jnp.where(any_click_before, MIN_LOG_PROB, la)

    def predict_relevance(self, params, batch):
        return self.parts["attraction"](params["attraction"], batch)

    def sample(self, params, batch, rng):
        la = self._log_attr(params, batch)
        attracted = (jax.random.uniform(rng, la.shape) < jnp.exp(la)).astype(jnp.float32)

        def step(still_browsing, a_k):
            click = still_browsing * a_k
            return still_browsing * (1.0 - a_k), (click, still_browsing)

        _, (clicks, examined) = jax.lax.scan(
            step, jnp.ones(la.shape[0]), jnp.moveaxis(attracted, 1, 0))
        clicks = jnp.moveaxis(clicks, 0, 1) * batch["mask"].astype(jnp.float32)
        examined = jnp.moveaxis(examined, 0, 1)
        return {"clicks": clicks, "attraction": attracted, "examination": examined}
