"""Examination-chain models: DCM (A.7), CCM (A.8), DBN (A.9), SDBN.

All share the structure: log P(C_k=1 | .) = log eps_k + log gamma_{d_k} with a
model-specific examination chain eps. The chains run fully vectorized through
``repro.core.recursions`` — marginal eps is a closed-form exclusive cumsum
over per-position log continuation factors; conditional eps is an affine
associative scan in death-odds space (clicks reset the odds, skips apply a
Bayes growth factor) with saturation bounds documented there. Sessions are
right-padded so padded tail positions never influence real ones.

The former ``lax.scan`` implementations are kept as ``predict_clicks_scan`` /
``predict_conditional_clicks_scan``: they are the equivalence oracles for
tests/test_recursions.py and the baselines for benchmarks/bench_recursions.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.models.ctr import _PartsModel
from repro.core.parameterization import (
    EmbeddingParameterConfig,
    PositionParameter,
    ScalarParameter,
    ScalarParameterConfig,
    build_parameter,
)
from repro.core.recursions import (conditional_examination_odds,
                                   marginal_examination)
from repro.stable import (log1mexp, log_add_exp, log_sigmoid, sigmoid_core,
                          sigmoid_parts)


def _scan_positions(step, init, *arrays):
    """Scan ``step`` over axis 1 of the given (B, K) arrays (oracle path)."""
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in arrays)
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1)


class _ChainModel(_PartsModel):
    """Shared vectorized prediction plumbing for examination-chain models.

    Subclasses provide ``_marginal_log_cont`` (per-position log continuation
    factor f_k of the marginal chain) and ``_conditional_terms`` (the reset /
    skip-continuation probabilities of the conditional chain). Both receive
    the raw attraction logits: factors are assembled as positive sums of
    sigmoids (sigma(-x) for complements), then a single log enters the
    engine's cross-position accumulation."""

    def _attr_logits(self, params, batch):
        return self.parts["attraction"](params["attraction"], batch)

    def _log_attr(self, params, batch):
        return log_sigmoid(self._attr_logits(params, batch))

    def _marginal_log_cont(self, params, batch, g, gn):
        """Per-position log f_k from attraction gamma (g) / 1-gamma (gn)."""
        raise NotImplementedError

    def _conditional_terms(self, params, batch, g, gn):
        """Returns (p_skip_survive, p_death, p_reset, p_reset_not)."""
        raise NotImplementedError

    def predict_clicks(self, params, batch):
        g, gn, la, _ = sigmoid_parts(self._attr_logits(params, batch))
        return marginal_examination(
            self._marginal_log_cont(params, batch, g, gn)) + la

    def compute_loss(self, params, batch):
        # Chain-family fast path: hand the raw logits and probability-space
        # factors to the fused examination_nll kernel (factors -> capped
        # death-odds scan -> NLL in one pass, impl via the dispatch
        # registry). Its custom VJP differentiates the ref composition, so
        # gradients match predict_conditional_clicks -> log_bce exactly.
        from repro.kernels import examination_nll

        x = self._attr_logits(params, batch)
        e, t, pos = sigmoid_core(x)
        g = jnp.where(pos, t, e * t)
        gn = jnp.where(pos, e * t, t)
        clicks = batch["clicks"].astype(jnp.float32)
        terms = self._conditional_terms(params, batch, g, gn)
        return examination_nll(x, clicks, batch["mask"], *terms)

    def predict_conditional_clicks(self, params, batch):
        x = self._attr_logits(params, batch)
        # sigmoid_core exposes the shared exp so the fused output reuses it:
        # log eps + log gamma = -log1p(r) + min(x,0) - log1p(e) collapses to
        # min(x,0) - log1p(r + e + r*e) — one log1p for the whole path.
        e, t, pos = sigmoid_core(x)
        g = jnp.where(pos, t, e * t)
        gn = jnp.where(pos, e * t, t)
        clicks = batch["clicks"].astype(jnp.float32)
        r = conditional_examination_odds(
            clicks, *self._conditional_terms(params, batch, g, gn))
        return jnp.minimum(x, 0.0) - jnp.log1p(r + e + r * e)


class DependentClickModel(_ChainModel):
    """DCM: after a click, continue browsing with rank-dependent lambda_k."""

    def __init__(self, query_doc_pairs: int = None, positions: int = 10,
                 attraction=None, continuation=None, init_prob: float = 0.5, **_):
        self.positions = positions
        logit = math.log(init_prob) - math.log1p(-init_prob)
        if attraction is None:
            attraction = EmbeddingParameterConfig(parameters=query_doc_pairs,
                                                  init_logit=logit)
        if continuation is None:
            continuation = PositionParameter(positions, init_logit=0.0)
        self.parts = {
            "attraction": build_parameter(attraction),
            "continuation": build_parameter(continuation, positions=positions),
        }

    def _log_terms(self, params, batch):
        la = self._log_attr(params, batch)
        ll = log_sigmoid(self.parts["continuation"](params["continuation"], batch))
        return la, ll

    def _continuation_parts(self, params, batch):
        """(lambda, 1-lambda) per position. For the default rank table the
        sigmoids run on the (K,) table and the results are gathered — K
        transcendentals instead of B*K."""
        cont = self.parts["continuation"]
        if isinstance(cont, PositionParameter):
            lam_t, lam_not_t, _, _ = sigmoid_parts(
                params["continuation"]["table"])
            return cont.gather(lam_t, batch), cont.gather(lam_not_t, batch)
        lam, lam_not, _, _ = sigmoid_parts(cont(params["continuation"], batch))
        return lam, lam_not

    def _marginal_log_cont(self, params, batch, g, gn):
        """Eq. 27: f_k = gamma*lambda + (1-gamma)."""
        lam, _ = self._continuation_parts(params, batch)
        return jnp.log(g * lam + gn)

    def _conditional_terms(self, params, batch, g, gn):
        """Eq. 28: click -> eps = lambda_k; skip -> Bayes posterior (always
        continue after a skip, so the skip chain never dies)."""
        lam, lam_not = self._continuation_parts(params, batch)
        return gn, jnp.zeros_like(gn), lam, lam_not

    # -- scan oracles ----------------------------------------------------------
    def predict_clicks_scan(self, params, batch):
        la, ll = self._log_terms(params, batch)

        def step(log_eps, xs):
            la_k, ll_k = xs
            log_p = log_eps + la_k
            log_eps_next = log_eps + log_add_exp(la_k + ll_k, log1mexp(la_k))
            return log_eps_next, log_p

        return _scan_positions(step, jnp.zeros(la.shape[0]), la, ll)

    def predict_conditional_clicks_scan(self, params, batch):
        la, ll = self._log_terms(params, batch)
        clicks = batch["clicks"].astype(jnp.float32)

        def step(log_eps, xs):
            la_k, ll_k, c_k = xs
            log_p = log_eps + la_k
            click_branch = ll_k
            skip_branch = log1mexp(la_k) + log_eps - log1mexp(la_k + log_eps)
            log_eps_next = jnp.where(c_k > 0, click_branch, skip_branch)
            return log_eps_next, log_p

        return _scan_positions(step, jnp.zeros(la.shape[0]), la, ll, clicks)

    def predict_relevance(self, params, batch):
        return self.parts["attraction"](params["attraction"], batch)

    def sample(self, params, batch, rng):
        la, ll = self._log_terms(params, batch)
        k1, k2 = jax.random.split(rng)
        attracted = (jax.random.uniform(k1, la.shape) < jnp.exp(la)).astype(jnp.float32)
        cont_u = jax.random.uniform(k2, la.shape)

        def step(examining, xs):
            a_k, lam_logp, u = xs
            click = examining * a_k
            keep = jnp.where(click > 0, (u < jnp.exp(lam_logp)).astype(jnp.float32), 1.0)
            return examining * keep, (click, examining)

        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (attracted, ll, cont_u))
        _, (clicks, examined) = jax.lax.scan(step, jnp.ones(la.shape[0]), xs)
        clicks = jnp.moveaxis(clicks, 0, 1) * batch["mask"].astype(jnp.float32)
        return {"clicks": clicks, "attraction": attracted,
                "examination": jnp.moveaxis(examined, 0, 1)}


class ClickChainModel(_ChainModel):
    """CCM: three continuation scenarios tau_1/2/3 (Eq. 29-30)."""

    def __init__(self, query_doc_pairs: int = None, positions: int = 10,
                 attraction=None, init_prob: float = 0.5,
                 tau_init=(0.7, 0.4, 0.2), **_):
        self.positions = positions
        logit = math.log(init_prob) - math.log1p(-init_prob)
        if attraction is None:
            attraction = EmbeddingParameterConfig(parameters=query_doc_pairs,
                                                  init_logit=logit)
        self.parts = {
            "attraction": build_parameter(attraction),
            "tau_1": ScalarParameter(ScalarParameterConfig(init_prob=tau_init[0])),
            "tau_2": ScalarParameter(ScalarParameterConfig(init_prob=tau_init[1])),
            "tau_3": ScalarParameter(ScalarParameterConfig(init_prob=tau_init[2])),
        }

    def _tau_logits(self, params, batch):
        return tuple(self.parts[f"tau_{i}"](params[f"tau_{i}"], batch)
                     for i in (1, 2, 3))

    def _tau_logits_raw(self, params):
        """0-d tau logits for the vectorized paths: transcendentals run on
        the scalar, broadcasting happens after (the per-batch broadcast of
        ``ScalarParameter`` would cost B*K identical sigmoids)."""
        return tuple(params[f"tau_{i}"]["value"] for i in (1, 2, 3))

    def _log_terms(self, params, batch):
        la = self._log_attr(params, batch)
        lts = tuple(log_sigmoid(t) for t in self._tau_logits(params, batch))
        return la, lts

    def _marginal_log_cont(self, params, batch, g, gn):
        """f_k = gamma*((1-gamma)tau2 + gamma*tau3) + (1-gamma)*tau1."""
        x1, x2, x3 = self._tau_logits_raw(params)
        inner = gn * jax.nn.sigmoid(x2) + g * jax.nn.sigmoid(x3)
        return jnp.log(g * inner + gn * jax.nn.sigmoid(x1))

    def _conditional_terms(self, params, batch, g, gn):
        """Click -> restart with gamma*tau3 + (1-gamma)*tau2; skip -> continue
        with tau1 before the Bayes update."""
        x1, x2, x3 = self._tau_logits_raw(params)
        t1, t1n, _, _ = sigmoid_parts(x1)
        t2, t2n, _, _ = sigmoid_parts(x2)
        t3, t3n, _, _ = sigmoid_parts(x3)
        return (gn * t1, gn * t1n,
                g * t3 + gn * t2, g * t3n + gn * t2n)

    # -- scan oracles ----------------------------------------------------------
    def predict_clicks_scan(self, params, batch):
        la, (lt1, lt2, lt3) = self._log_terms(params, batch)

        def step(log_eps, xs):
            la_k, lt1_k, lt2_k, lt3_k = xs
            log_p = log_eps + la_k
            inner = log_add_exp(log1mexp(la_k) + lt2_k, la_k + lt3_k)
            log_eps_next = log_eps + log_add_exp(la_k + inner,
                                                 log1mexp(la_k) + lt1_k)
            return log_eps_next, log_p

        return _scan_positions(step, jnp.zeros(la.shape[0]), la, lt1, lt2, lt3)

    def predict_conditional_clicks_scan(self, params, batch):
        la, (lt1, lt2, lt3) = self._log_terms(params, batch)
        clicks = batch["clicks"].astype(jnp.float32)

        def step(log_eps, xs):
            la_k, lt1_k, lt2_k, lt3_k, c_k = xs
            log_p = log_eps + la_k
            click_branch = log_add_exp(la_k + lt3_k, log1mexp(la_k) + lt2_k)
            skip_branch = (log1mexp(la_k) + log_eps + lt1_k
                           - log1mexp(la_k + log_eps))
            log_eps_next = jnp.where(c_k > 0, click_branch, skip_branch)
            return log_eps_next, log_p

        return _scan_positions(step, jnp.zeros(la.shape[0]), la, lt1, lt2, lt3, clicks)

    def predict_relevance(self, params, batch):
        return self.parts["attraction"](params["attraction"], batch)

    def sample(self, params, batch, rng):
        la, (lt1, lt2, lt3) = self._log_terms(params, batch)
        k1, k2, k3 = jax.random.split(rng, 3)
        attracted = (jax.random.uniform(k1, la.shape) < jnp.exp(la)).astype(jnp.float32)
        satisfied = (jax.random.uniform(k2, la.shape) < jnp.exp(la)).astype(jnp.float32)
        cont_u = jax.random.uniform(k3, la.shape)

        def step(examining, xs):
            a_k, s_k, lt1_k, lt2_k, lt3_k, u = xs
            click = examining * a_k
            log_cont = jnp.where(click > 0,
                                 jnp.where(s_k > 0, lt3_k, lt2_k),
                                 lt1_k)
            keep = (u < jnp.exp(log_cont)).astype(jnp.float32)
            return examining * keep, (click, examining)

        xs = tuple(jnp.moveaxis(a, 1, 0)
                   for a in (attracted, satisfied, lt1, lt2, lt3, cont_u))
        _, (clicks, examined) = jax.lax.scan(step, jnp.ones(la.shape[0]), xs)
        clicks = jnp.moveaxis(clicks, 0, 1) * batch["mask"].astype(jnp.float32)
        return {"clicks": clicks, "attraction": attracted, "satisfaction": satisfied,
                "examination": jnp.moveaxis(examined, 0, 1)}


class DynamicBayesianNetwork(_ChainModel):
    """DBN (Eq. 31-32): separate attraction and satisfaction, global lambda."""

    fixed_continuation = False  # SDBN overrides

    def __init__(self, query_doc_pairs: int = None, positions: int = 10,
                 attraction=None, satisfaction=None, init_prob: float = 0.5,
                 lambda_init: float = 0.9, **_):
        self.positions = positions
        logit = math.log(init_prob) - math.log1p(-init_prob)
        if attraction is None:
            attraction = EmbeddingParameterConfig(parameters=query_doc_pairs,
                                                  init_logit=logit)
        if satisfaction is None:
            satisfaction = EmbeddingParameterConfig(parameters=query_doc_pairs,
                                                    init_logit=logit)
        self.parts = {
            "attraction": build_parameter(attraction),
            "satisfaction": build_parameter(satisfaction),
        }
        if not self.fixed_continuation:
            self.parts["continuation"] = ScalarParameter(
                ScalarParameterConfig(init_prob=lambda_init))

    def _lambda_logit(self, params, batch):
        if self.fixed_continuation:
            return None
        return self.parts["continuation"](params["continuation"], batch)

    def _lambda_logit_raw(self, params):
        """0-d lambda logit for the vectorized paths (see _tau_logits_raw)."""
        if self.fixed_continuation:
            return None
        return params["continuation"]["value"]

    def _log_terms(self, params, batch):
        la = self._log_attr(params, batch)
        ls = log_sigmoid(self.parts["satisfaction"](params["satisfaction"], batch))
        lam = self._lambda_logit(params, batch)
        lc = jnp.zeros_like(la) if lam is None else log_sigmoid(lam)
        return la, ls, lc

    def _marginal_log_cont(self, params, batch, g, gn):
        """Eq. 31: f_k = lambda * (1 - gamma*sigma)."""
        x_sat = self.parts["satisfaction"](params["satisfaction"], batch)
        # 1 - gamma*sigma = (1-gamma) + gamma*(1-sigma): a stable positive sum.
        no_sat = gn + g * jax.nn.sigmoid(-x_sat)
        lam = self._lambda_logit_raw(params)
        if lam is None:  # SDBN: lambda = 1
            return jnp.log(no_sat)
        return jnp.log(jax.nn.sigmoid(lam) * no_sat)

    def _conditional_terms(self, params, batch, g, gn):
        """Eq. 32: click -> restart with lambda*(1-sigma); skip -> continue
        with lambda before the Bayes update."""
        x_sat = self.parts["satisfaction"](params["satisfaction"], batch)
        sat, no_sat, _, _ = sigmoid_parts(x_sat)
        lam = self._lambda_logit_raw(params)
        if lam is None:  # SDBN: lambda = 1
            return gn, jnp.zeros_like(gn), no_sat, sat
        c, c_not, _, _ = sigmoid_parts(lam)
        reset = c * no_sat
        reset_not = c_not + c * sat  # 1 - lambda(1-sigma)
        return gn * c, gn * c_not, reset, reset_not

    # -- scan oracles ----------------------------------------------------------
    def predict_clicks_scan(self, params, batch):
        la, ls, lc = self._log_terms(params, batch)

        def step(log_eps, xs):
            la_k, ls_k, lc_k = xs
            log_p = log_eps + la_k
            log_eps_next = log_eps + lc_k + log1mexp(la_k + ls_k)
            return log_eps_next, log_p

        return _scan_positions(step, jnp.zeros(la.shape[0]), la, ls, lc)

    def predict_conditional_clicks_scan(self, params, batch):
        la, ls, lc = self._log_terms(params, batch)
        clicks = batch["clicks"].astype(jnp.float32)

        def step(log_eps, xs):
            la_k, ls_k, lc_k, c_k = xs
            log_p = log_eps + la_k
            click_branch = log1mexp(ls_k)
            skip_branch = (log1mexp(la_k) + log_eps - log1mexp(la_k + log_eps))
            log_eps_next = lc_k + jnp.where(c_k > 0, click_branch, skip_branch)
            return log_eps_next, log_p

        return _scan_positions(step, jnp.zeros(la.shape[0]), la, ls, lc, clicks)

    def predict_relevance(self, params, batch):
        """DBN ranks by attractiveness * satisfaction (paper §4.1)."""
        la = log_sigmoid(self.parts["attraction"](params["attraction"], batch))
        ls = log_sigmoid(self.parts["satisfaction"](params["satisfaction"], batch))
        return la + ls

    def sample(self, params, batch, rng):
        la, ls, lc = self._log_terms(params, batch)
        k1, k2, k3 = jax.random.split(rng, 3)
        attracted = (jax.random.uniform(k1, la.shape) < jnp.exp(la)).astype(jnp.float32)
        satisfied_draw = (jax.random.uniform(k2, ls.shape) < jnp.exp(ls)).astype(jnp.float32)
        cont_u = jax.random.uniform(k3, la.shape)

        def step(examining, xs):
            a_k, s_k, lc_k, u = xs
            click = examining * a_k
            satisfied = click * s_k
            cont = (u < jnp.exp(lc_k)).astype(jnp.float32)
            return examining * (1.0 - satisfied) * cont, (click, examining, satisfied)

        xs = tuple(jnp.moveaxis(a, 1, 0)
                   for a in (attracted, satisfied_draw, lc, cont_u))
        _, (clicks, examined, satisfied) = jax.lax.scan(
            step, jnp.ones(la.shape[0]), xs)
        clicks = jnp.moveaxis(clicks, 0, 1) * batch["mask"].astype(jnp.float32)
        return {"clicks": clicks, "attraction": attracted,
                "satisfaction": jnp.moveaxis(satisfied, 0, 1),
                "examination": jnp.moveaxis(examined, 0, 1)}


class SimplifiedDBN(DynamicBayesianNetwork):
    """SDBN: DBN with lambda fixed at 1 (always continue unless satisfied)."""

    fixed_continuation = True
