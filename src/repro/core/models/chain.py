"""Examination-chain models: DCM (A.7), CCM (A.8), DBN (A.9), SDBN.

All share the structure: log P(C_k=1 | .) = log eps_k + log gamma_{d_k} with a
model-specific log-space recursion for the examination chain eps. The
recursions run as lax.scan over the position axis; sessions are right-padded
so padded tail positions never influence real ones.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.models.ctr import _PartsModel
from repro.core.parameterization import (
    EmbeddingParameterConfig,
    PositionParameter,
    ScalarParameter,
    ScalarParameterConfig,
    build_parameter,
)
from repro.stable import log1mexp, log_sigmoid, logsumexp


def _scan_positions(step, init, *arrays):
    """Scan ``step`` over axis 1 of the given (B, K) arrays."""
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in arrays)
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1)


def _lse2(a, b):
    """Elementwise log(exp(a) + exp(b)), stable."""
    return logsumexp(jnp.stack([a, b], axis=-1), axis=-1)


class DependentClickModel(_PartsModel):
    """DCM: after a click, continue browsing with rank-dependent lambda_k."""

    def __init__(self, query_doc_pairs: int = None, positions: int = 10,
                 attraction=None, continuation=None, init_prob: float = 0.5, **_):
        self.positions = positions
        logit = math.log(init_prob) - math.log1p(-init_prob)
        if attraction is None:
            attraction = EmbeddingParameterConfig(parameters=query_doc_pairs,
                                                  init_logit=logit)
        if continuation is None:
            continuation = PositionParameter(positions, init_logit=0.0)
        self.parts = {
            "attraction": build_parameter(attraction),
            "continuation": build_parameter(continuation, positions=positions),
        }

    def _log_terms(self, params, batch):
        la = log_sigmoid(self.parts["attraction"](params["attraction"], batch))
        ll = log_sigmoid(self.parts["continuation"](params["continuation"], batch))
        return la, ll

    def predict_clicks(self, params, batch):
        """Eq. 27: eps_{k+1} = eps_k * (gamma*lambda + (1-gamma))."""
        la, ll = self._log_terms(params, batch)

        def step(log_eps, xs):
            la_k, ll_k = xs
            log_p = log_eps + la_k
            log_eps_next = log_eps + _lse2(la_k + ll_k, log1mexp(la_k))
            return log_eps_next, log_p

        return _scan_positions(step, jnp.zeros(la.shape[0]), la, ll)

    def predict_conditional_clicks(self, params, batch):
        """Eq. 28: click -> eps = lambda_k; skip -> Bayes posterior."""
        la, ll = self._log_terms(params, batch)
        clicks = batch["clicks"].astype(jnp.float32)

        def step(log_eps, xs):
            la_k, ll_k, c_k = xs
            log_p = log_eps + la_k
            click_branch = ll_k
            skip_branch = log1mexp(la_k) + log_eps - log1mexp(la_k + log_eps)
            log_eps_next = jnp.where(c_k > 0, click_branch, skip_branch)
            return log_eps_next, log_p

        return _scan_positions(step, jnp.zeros(la.shape[0]), la, ll, clicks)

    def predict_relevance(self, params, batch):
        return self.parts["attraction"](params["attraction"], batch)

    def sample(self, params, batch, rng):
        la, ll = self._log_terms(params, batch)
        k1, k2 = jax.random.split(rng)
        attracted = (jax.random.uniform(k1, la.shape) < jnp.exp(la)).astype(jnp.float32)
        cont_u = jax.random.uniform(k2, la.shape)

        def step(examining, xs):
            a_k, lam_logp, u = xs
            click = examining * a_k
            keep = jnp.where(click > 0, (u < jnp.exp(lam_logp)).astype(jnp.float32), 1.0)
            return examining * keep, (click, examining)

        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (attracted, ll, cont_u))
        _, (clicks, examined) = jax.lax.scan(step, jnp.ones(la.shape[0]), xs)
        clicks = jnp.moveaxis(clicks, 0, 1) * batch["mask"].astype(jnp.float32)
        return {"clicks": clicks, "attraction": attracted,
                "examination": jnp.moveaxis(examined, 0, 1)}


class ClickChainModel(_PartsModel):
    """CCM: three continuation scenarios tau_1/2/3 (Eq. 29-30)."""

    def __init__(self, query_doc_pairs: int = None, positions: int = 10,
                 attraction=None, init_prob: float = 0.5,
                 tau_init=(0.7, 0.4, 0.2), **_):
        self.positions = positions
        logit = math.log(init_prob) - math.log1p(-init_prob)
        if attraction is None:
            attraction = EmbeddingParameterConfig(parameters=query_doc_pairs,
                                                  init_logit=logit)
        self.parts = {
            "attraction": build_parameter(attraction),
            "tau_1": ScalarParameter(ScalarParameterConfig(init_prob=tau_init[0])),
            "tau_2": ScalarParameter(ScalarParameterConfig(init_prob=tau_init[1])),
            "tau_3": ScalarParameter(ScalarParameterConfig(init_prob=tau_init[2])),
        }

    def _log_terms(self, params, batch):
        la = log_sigmoid(self.parts["attraction"](params["attraction"], batch))
        lts = tuple(log_sigmoid(self.parts[f"tau_{i}"](params[f"tau_{i}"], batch))
                    for i in (1, 2, 3))
        return la, lts

    def predict_clicks(self, params, batch):
        la, (lt1, lt2, lt3) = self._log_terms(params, batch)

        def step(log_eps, xs):
            la_k, lt1_k, lt2_k, lt3_k = xs
            log_p = log_eps + la_k
            # gamma*((1-gamma)tau2 + gamma*tau3) + (1-gamma)*tau1
            inner = _lse2(log1mexp(la_k) + lt2_k, la_k + lt3_k)
            log_eps_next = log_eps + _lse2(la_k + inner, log1mexp(la_k) + lt1_k)
            return log_eps_next, log_p

        return _scan_positions(step, jnp.zeros(la.shape[0]), la, lt1, lt2, lt3)

    def predict_conditional_clicks(self, params, batch):
        la, (lt1, lt2, lt3) = self._log_terms(params, batch)
        clicks = batch["clicks"].astype(jnp.float32)

        def step(log_eps, xs):
            la_k, lt1_k, lt2_k, lt3_k, c_k = xs
            log_p = log_eps + la_k
            click_branch = _lse2(la_k + lt3_k, log1mexp(la_k) + lt2_k)
            skip_branch = (log1mexp(la_k) + log_eps + lt1_k
                           - log1mexp(la_k + log_eps))
            log_eps_next = jnp.where(c_k > 0, click_branch, skip_branch)
            return log_eps_next, log_p

        return _scan_positions(step, jnp.zeros(la.shape[0]), la, lt1, lt2, lt3, clicks)

    def predict_relevance(self, params, batch):
        return self.parts["attraction"](params["attraction"], batch)

    def sample(self, params, batch, rng):
        la, (lt1, lt2, lt3) = self._log_terms(params, batch)
        k1, k2, k3 = jax.random.split(rng, 3)
        attracted = (jax.random.uniform(k1, la.shape) < jnp.exp(la)).astype(jnp.float32)
        satisfied = (jax.random.uniform(k2, la.shape) < jnp.exp(la)).astype(jnp.float32)
        cont_u = jax.random.uniform(k3, la.shape)

        def step(examining, xs):
            a_k, s_k, lt1_k, lt2_k, lt3_k, u = xs
            click = examining * a_k
            log_cont = jnp.where(click > 0,
                                 jnp.where(s_k > 0, lt3_k, lt2_k),
                                 lt1_k)
            keep = (u < jnp.exp(log_cont)).astype(jnp.float32)
            return examining * keep, (click, examining)

        xs = tuple(jnp.moveaxis(a, 1, 0)
                   for a in (attracted, satisfied, lt1, lt2, lt3, cont_u))
        _, (clicks, examined) = jax.lax.scan(step, jnp.ones(la.shape[0]), xs)
        clicks = jnp.moveaxis(clicks, 0, 1) * batch["mask"].astype(jnp.float32)
        return {"clicks": clicks, "attraction": attracted, "satisfaction": satisfied,
                "examination": jnp.moveaxis(examined, 0, 1)}


class DynamicBayesianNetwork(_PartsModel):
    """DBN (Eq. 31-32): separate attraction and satisfaction, global lambda."""

    fixed_continuation = False  # SDBN overrides

    def __init__(self, query_doc_pairs: int = None, positions: int = 10,
                 attraction=None, satisfaction=None, init_prob: float = 0.5,
                 lambda_init: float = 0.9, **_):
        self.positions = positions
        logit = math.log(init_prob) - math.log1p(-init_prob)
        if attraction is None:
            attraction = EmbeddingParameterConfig(parameters=query_doc_pairs,
                                                  init_logit=logit)
        if satisfaction is None:
            satisfaction = EmbeddingParameterConfig(parameters=query_doc_pairs,
                                                    init_logit=logit)
        self.parts = {
            "attraction": build_parameter(attraction),
            "satisfaction": build_parameter(satisfaction),
        }
        if not self.fixed_continuation:
            self.parts["continuation"] = ScalarParameter(
                ScalarParameterConfig(init_prob=lambda_init))

    def _log_terms(self, params, batch):
        la = log_sigmoid(self.parts["attraction"](params["attraction"], batch))
        ls = log_sigmoid(self.parts["satisfaction"](params["satisfaction"], batch))
        if self.fixed_continuation:
            lc = jnp.zeros_like(la)  # log(1)
        else:
            lc = log_sigmoid(self.parts["continuation"](params["continuation"], batch))
        return la, ls, lc

    def predict_clicks(self, params, batch):
        """Eq. 31: eps_{k+1} = eps_k * lambda * (1 - gamma*sigma)."""
        la, ls, lc = self._log_terms(params, batch)

        def step(log_eps, xs):
            la_k, ls_k, lc_k = xs
            log_p = log_eps + la_k
            log_eps_next = log_eps + lc_k + log1mexp(la_k + ls_k)
            return log_eps_next, log_p

        return _scan_positions(step, jnp.zeros(la.shape[0]), la, ls, lc)

    def predict_conditional_clicks(self, params, batch):
        """Eq. 32."""
        la, ls, lc = self._log_terms(params, batch)
        clicks = batch["clicks"].astype(jnp.float32)

        def step(log_eps, xs):
            la_k, ls_k, lc_k, c_k = xs
            log_p = log_eps + la_k
            click_branch = log1mexp(ls_k)
            skip_branch = (log1mexp(la_k) + log_eps - log1mexp(la_k + log_eps))
            log_eps_next = lc_k + jnp.where(c_k > 0, click_branch, skip_branch)
            return log_eps_next, log_p

        return _scan_positions(step, jnp.zeros(la.shape[0]), la, ls, lc, clicks)

    def predict_relevance(self, params, batch):
        """DBN ranks by attractiveness * satisfaction (paper §4.1)."""
        la = log_sigmoid(self.parts["attraction"](params["attraction"], batch))
        ls = log_sigmoid(self.parts["satisfaction"](params["satisfaction"], batch))
        return la + ls

    def sample(self, params, batch, rng):
        la, ls, lc = self._log_terms(params, batch)
        k1, k2, k3 = jax.random.split(rng, 3)
        attracted = (jax.random.uniform(k1, la.shape) < jnp.exp(la)).astype(jnp.float32)
        satisfied_draw = (jax.random.uniform(k2, ls.shape) < jnp.exp(ls)).astype(jnp.float32)
        cont_u = jax.random.uniform(k3, la.shape)

        def step(examining, xs):
            a_k, s_k, lc_k, u = xs
            click = examining * a_k
            satisfied = click * s_k
            cont = (u < jnp.exp(lc_k)).astype(jnp.float32)
            return examining * (1.0 - satisfied) * cont, (click, examining, satisfied)

        xs = tuple(jnp.moveaxis(a, 1, 0)
                   for a in (attracted, satisfied_draw, lc, cont_u))
        _, (clicks, examined, satisfied) = jax.lax.scan(
            step, jnp.ones(la.shape[0]), xs)
        clicks = jnp.moveaxis(clicks, 0, 1) * batch["mask"].astype(jnp.float32)
        return {"clicks": clicks, "attraction": attracted,
                "satisfaction": jnp.moveaxis(satisfied, 0, 1),
                "examination": jnp.moveaxis(examined, 0, 1)}


class SimplifiedDBN(DynamicBayesianNetwork):
    """SDBN: DBN with lambda fixed at 1 (always continue unless satisfied)."""

    fixed_continuation = True
