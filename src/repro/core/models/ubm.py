"""User browsing model (paper A.6): examination depends on (rank, last click).

Conditional prediction is a table lookup (Eq. 25); unconditional prediction
marginalizes over all possible last-click positions (Eq. 26) with an O(K^2)
log-space recursion.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.base import last_click_positions
from repro.core.models.ctr import _PartsModel
from repro.core.parameterization import (
    EmbeddingParameterConfig,
    UBMExaminationParameter,
    build_parameter,
)
from repro.core.recursions import ubm_marginal_clicks
from repro.stable import log1mexp, log_sigmoid, logsumexp


class UserBrowsingModel(_PartsModel):
    def __init__(self, query_doc_pairs: int = None, positions: int = 10,
                 attraction=None, examination=None, init_prob: float = 0.5, **_):
        self.positions = positions
        logit = math.log(init_prob) - math.log1p(-init_prob)
        if attraction is None:
            attraction = EmbeddingParameterConfig(parameters=query_doc_pairs,
                                                  init_logit=logit)
        if examination is None:
            examination = UBMExaminationParameter(positions, init_logit=2.0)
        self.parts = {
            "attraction": build_parameter(attraction),
            "examination": examination,
        }

    # -- helpers ---------------------------------------------------------------
    def _log_attr(self, params, batch):
        return log_sigmoid(self.parts["attraction"](params["attraction"], batch))

    def _log_exam_table(self, params, batch):
        """lt[b, k_idx, kp] = log theta at 0-based rank k_idx given last click
        at 1-based rank kp (kp = 0 encodes no previous click)."""
        table = params["examination"]["table"]  # (K, K) logits
        lt = log_sigmoid(table)
        b = batch["positions"].shape[0]
        return jnp.broadcast_to(lt, (b,) + lt.shape)

    # -- API -------------------------------------------------------------------
    def predict_conditional_clicks(self, params, batch):
        """Eq. 25: log theta_{k,k'} + log gamma_d with observed last click k'."""
        la = self._log_attr(params, batch)
        exam = self.parts["examination"]
        k_prime = last_click_positions(batch["clicks"], batch["positions"])
        logit_e = exam.logit(params["examination"], batch["positions"], k_prime)
        return log_sigmoid(logit_e) + la

    def predict_clicks(self, params, batch):
        """Eq. 26: marginalize over last-click paths — masked (B, K, K)
        cumulative sums + one batched triangular solve (repro.core.recursions),
        O(1) graph ops instead of the former O(K^2) unrolled double loop."""
        attr_logits = self.parts["attraction"](params["attraction"], batch)
        return ubm_marginal_clicks(attr_logits, params["examination"]["table"])

    def predict_clicks_loop(self, params, batch):
        """Former unrolled O(K^2) log-space recursion; the test oracle for
        ``predict_clicks`` (tests/test_recursions.py)."""
        la = self._log_attr(params, batch)  # (B, K)
        lt = self._log_exam_table(params, batch)  # (B, K, K) [rank, last_click]
        K = la.shape[1]
        # log(1 - theta_{j,i} gamma_j) for every (rank j, last-click i) pair
        lg_no_click = log1mexp(lt + la[:, :, None])  # (B, K, K)
        # cumulative over rank j (inclusive): cs[b, j, i] = sum_{m<=j} lg[b, m, i]
        cs = jnp.cumsum(lg_no_click, axis=1)

        lu = []  # lu[r] = log P(C_r = 1), unconditional
        for r in range(K):
            terms = []
            # path i = 0: no click before r -> skip-run from rank 0..r-1 at kp=0
            run0 = cs[:, r - 1, 0] if r > 0 else jnp.zeros_like(la[:, 0])
            terms.append(run0 + lt[:, r, 0] + la[:, r])
            # paths: last click at 0-based rank q (kp = q + 1)
            for q in range(r):
                kp = q + 1
                run = cs[:, r - 1, kp] - cs[:, q, kp]  # ranks q+1 .. r-1
                terms.append(lu[q] + run + lt[:, r, kp] + la[:, r])
            lu.append(logsumexp(jnp.stack(terms, axis=-1), axis=-1))
        return jnp.stack(lu, axis=1)

    def predict_relevance(self, params, batch):
        return self.parts["attraction"](params["attraction"], batch)

    def sample(self, params, batch, rng):
        la = self._log_attr(params, batch)
        table_logp = log_sigmoid(params["examination"]["table"])  # (K, K)
        ka, ke = jax.random.split(rng)
        attracted = (jax.random.uniform(ka, la.shape) < jnp.exp(la)).astype(jnp.float32)
        exam_u = jax.random.uniform(ke, la.shape)

        def step(last_click, xs):
            r, a_k, u_k = xs
            lt_k = table_logp[r][last_click.astype(jnp.int32)]  # (B,)
            examined = (u_k < jnp.exp(lt_k)).astype(jnp.float32)
            click = examined * a_k
            new_last = jnp.where(click > 0, jnp.float32(r + 1), last_click)
            return new_last, (click, examined)

        B, K = la.shape
        xs = (jnp.arange(K), jnp.moveaxis(attracted, 1, 0), jnp.moveaxis(exam_u, 1, 0))
        _, (clicks, examined) = jax.lax.scan(step, jnp.zeros(B), xs)
        clicks = jnp.moveaxis(clicks, 0, 1) * batch["mask"].astype(jnp.float32)
        return {"clicks": clicks, "attraction": attracted,
                "examination": jnp.moveaxis(examined, 0, 1)}
