"""CTR baselines: GCTR (A.1), RCTR (A.2), DCTR (A.3)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.base import ClickModel
from repro.core.parameterization import (
    EmbeddingParameterConfig,
    PositionParameter,
    ScalarParameter,
    ScalarParameterConfig,
    build_parameter,
)
from repro.nn.module import split_rngs
from repro.stable import log_sigmoid


class _PartsModel(ClickModel):
    """Shared plumbing: init/apply over the ``parts`` slot dict."""

    def init(self, rng):
        keys = split_rngs(rng, len(self.parts))
        return {name: mod.init(k) for (name, mod), k in zip(self.parts.items(), keys)}


class GlobalCTR(_PartsModel):
    """log P(C=1|d,k) = log rho (paper Eq. 19)."""

    def __init__(self, positions: int = 10, init_prob: float = 0.5, **_):
        self.positions = positions
        self.parts = {"rho": ScalarParameter(ScalarParameterConfig(init_prob=init_prob))}

    def predict_clicks(self, params, batch):
        return log_sigmoid(self.parts["rho"](params["rho"], batch))

    def predict_conditional_logits(self, params, batch):
        return self.parts["rho"](params["rho"], batch)

    def predict_relevance(self, params, batch):
        return self.predict_clicks(params, batch)

    def sample(self, params, batch, rng):
        log_p = self.predict_clicks(params, batch)
        clicks = (jax.random.uniform(rng, log_p.shape) < jnp.exp(log_p)).astype(jnp.float32)
        clicks = clicks * batch["mask"].astype(jnp.float32)
        return {"clicks": clicks}


class RankCTR(_PartsModel):
    """log P(C=1|d,k) = log theta_k (paper Eq. 20)."""

    def __init__(self, positions: int = 10, init_prob: float = 0.5, **_):
        import math

        self.positions = positions
        logit = math.log(init_prob) - math.log1p(-init_prob)
        self.parts = {"theta": PositionParameter(positions, init_logit=logit)}

    def predict_clicks(self, params, batch):
        return log_sigmoid(self.parts["theta"](params["theta"], batch))

    def predict_conditional_logits(self, params, batch):
        return self.parts["theta"](params["theta"], batch)

    def predict_relevance(self, params, batch):
        # rank-only model: no document signal; all docs tie.
        return jnp.zeros_like(batch["positions"], dtype=jnp.float32)

    def sample(self, params, batch, rng):
        log_p = self.predict_clicks(params, batch)
        clicks = (jax.random.uniform(rng, log_p.shape) < jnp.exp(log_p)).astype(jnp.float32)
        return {"clicks": clicks * batch["mask"].astype(jnp.float32)}


class DocumentCTR(_PartsModel):
    """log P(C=1|d,k) = log gamma_d (paper Eq. 21)."""

    def __init__(self, query_doc_pairs: int = None, positions: int = 10,
                 attraction=None, init_prob: float = 0.5, **_):
        import math

        self.positions = positions
        logit = math.log(init_prob) - math.log1p(-init_prob)
        if attraction is None:
            attraction = EmbeddingParameterConfig(parameters=query_doc_pairs,
                                                  init_logit=logit)
        self.parts = {"attraction": build_parameter(attraction)}

    def predict_clicks(self, params, batch):
        return log_sigmoid(self.parts["attraction"](params["attraction"], batch))

    def predict_conditional_logits(self, params, batch):
        return self.parts["attraction"](params["attraction"], batch)

    def predict_relevance(self, params, batch):
        return self.parts["attraction"](params["attraction"], batch)

    def sample(self, params, batch, rng):
        log_p = self.predict_clicks(params, batch)
        clicks = (jax.random.uniform(rng, log_p.shape) < jnp.exp(log_p)).astype(jnp.float32)
        return {"clicks": clicks * batch["mask"].astype(jnp.float32)}
