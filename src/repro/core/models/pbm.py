"""Position-based model (paper §3, Eq. 22): P(C) = theta_k * gamma_d."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.models.ctr import _PartsModel
from repro.core.parameterization import (
    EmbeddingParameterConfig,
    PositionParameter,
    build_parameter,
)
from repro.stable import log_sigmoid


class PositionBasedModel(_PartsModel):
    """PBM: two-tower in its neural form (paper Listing 4).

    attraction / examination accept any parameterization config or module;
    defaults are the classic embedding-table + rank-table CLAX setup.
    """

    def __init__(self, query_doc_pairs: int = None, positions: int = 10,
                 attraction=None, examination=None, init_prob: float = 0.5, **_):
        self.positions = positions
        logit = math.log(init_prob) - math.log1p(-init_prob)
        if attraction is None:
            attraction = EmbeddingParameterConfig(parameters=query_doc_pairs,
                                                  init_logit=logit)
        if examination is None:
            examination = PositionParameter(positions, init_logit=2.0)
        self.parts = {
            "attraction": build_parameter(attraction),
            "examination": build_parameter(examination, positions=positions),
        }

    def _log_probs(self, params, batch):
        la = log_sigmoid(self.parts["attraction"](params["attraction"], batch))
        le = log_sigmoid(self.parts["examination"](params["examination"], batch))
        return la, le

    def predict_clicks(self, params, batch):
        la, le = self._log_probs(params, batch)
        return la + le

    def predict_relevance(self, params, batch):
        return self.parts["attraction"](params["attraction"], batch)

    def sample(self, params, batch, rng):
        la, le = self._log_probs(params, batch)
        ka, ke = jax.random.split(rng)
        attracted = (jax.random.uniform(ka, la.shape) < jnp.exp(la)).astype(jnp.float32)
        examined = (jax.random.uniform(ke, le.shape) < jnp.exp(le)).astype(jnp.float32)
        clicks = attracted * examined * batch["mask"].astype(jnp.float32)
        return {"clicks": clicks, "attraction": attracted, "examination": examined}
