"""The ten CLAX click models (paper Appendix A) + the mixture meta-model."""
from repro.core.models.ctr import GlobalCTR, RankCTR, DocumentCTR
from repro.core.models.pbm import PositionBasedModel
from repro.core.models.cascade import CascadeModel
from repro.core.models.ubm import UserBrowsingModel
from repro.core.models.chain import (
    DependentClickModel,
    ClickChainModel,
    DynamicBayesianNetwork,
    SimplifiedDBN,
)
from repro.core.models.mixture import MixtureModel

MODEL_REGISTRY = {
    "gctr": GlobalCTR,
    "rctr": RankCTR,
    "dctr": DocumentCTR,
    "pbm": PositionBasedModel,
    "cm": CascadeModel,
    "ubm": UserBrowsingModel,
    "dcm": DependentClickModel,
    "ccm": ClickChainModel,
    "dbn": DynamicBayesianNetwork,
    "sdbn": SimplifiedDBN,
}

__all__ = [
    "GlobalCTR",
    "RankCTR",
    "DocumentCTR",
    "PositionBasedModel",
    "CascadeModel",
    "UserBrowsingModel",
    "DependentClickModel",
    "ClickChainModel",
    "DynamicBayesianNetwork",
    "SimplifiedDBN",
    "MixtureModel",
    "MODEL_REGISTRY",
]
