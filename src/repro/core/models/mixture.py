"""Mixture meta-model (paper §4.3, Eq. 12).

Learns a prior P(m) over M click models; the session loss is the temperature-
scaled log-sum-exp of per-model session log-losses. Parameter *sharing*
between member models (paper Listing 5) works by identity: if two models hold
the same parameter-module object, its parameters are stored once in a
canonical ``store`` and referenced by both — gradient contributions from every
use accumulate on the single copy automatically under autodiff.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.core.base import ClickModel
from repro.nn.module import split_rngs
from repro.stable import log_bce, logsumexp


class MixtureModel(ClickModel):
    def __init__(self, models: Sequence[ClickModel], temperature: float = 1.0):
        self.models = list(models)
        self.temperature = temperature
        self.positions = max(m.positions for m in self.models)
        # Deduplicate parameter modules by object identity.
        self.store_keys: List[dict] = []  # per model: slot -> store key
        self.store_modules = {}  # store key -> module
        seen = {}
        for i, model in enumerate(self.models):
            slot_map = {}
            for slot, module in model.parts.items():
                key = seen.get(id(module))
                if key is None:
                    key = f"m{i}_{slot}"
                    seen[id(module)] = key
                    self.store_modules[key] = module
                slot_map[slot] = key
            self.store_keys.append(slot_map)

    def init(self, rng):
        keys = split_rngs(rng, len(self.store_modules) + 1)
        store = {k: mod.init(kk)
                 for (k, mod), kk in zip(self.store_modules.items(), keys[:-1])}
        return {
            "prior_logits": jnp.zeros((len(self.models),), jnp.float32),
            "store": store,
        }

    def _model_params(self, params, i):
        return {slot: params["store"][key] for slot, key in self.store_keys[i].items()}

    def _log_prior(self, params):
        return jax.nn.log_softmax(params["prior_logits"])

    # -- losses ------------------------------------------------------------------
    def session_losses(self, params, batch):
        """Per-model per-session NLL: (M, B)."""
        mask = batch["mask"].astype(jnp.float32)
        losses = []
        for i, model in enumerate(self.models):
            lp = model.predict_conditional_clicks(self._model_params(params, i), batch)
            nll = log_bce(lp, batch["clicks"]) * mask
            losses.append(jnp.sum(nll, axis=1))
        return jnp.stack(losses, axis=0)

    def compute_loss(self, params, batch):
        """Eq. 12, normalized per item so scale matches member models."""
        log_prior = self._log_prior(params)  # (M,)
        nll = self.session_losses(params, batch)  # (M, B)
        mix = -logsumexp(log_prior[:, None] - nll / self.temperature, axis=0)
        n_items = jnp.maximum(jnp.sum(batch["mask"]), 1.0)
        return jnp.sum(mix) / n_items

    # -- predictions ---------------------------------------------------------------
    def predict_clicks(self, params, batch):
        """Prior-weighted mixture: log sum_m P(m) P_m(C=1|d,k)."""
        log_prior = self._log_prior(params)
        preds = jnp.stack([
            m.predict_clicks(self._model_params(params, i), batch)
            for i, m in enumerate(self.models)
        ], axis=0)  # (M, B, K)
        return logsumexp(log_prior[:, None, None] + preds, axis=0)

    def predict_conditional_clicks(self, params, batch):
        """Posterior-weighted: weights from each model's prefix likelihood.

        w_m(k) ∝ P(m) * P_m(c_<k); strictly causal (uses clicks before k only).
        """
        log_prior = self._log_prior(params)
        mask = batch["mask"].astype(jnp.float32)
        cond, prefix = [], []
        for i, m in enumerate(self.models):
            lp = m.predict_conditional_clicks(self._model_params(params, i), batch)
            cond.append(lp)
            ll = -log_bce(lp, batch["clicks"]) * mask  # (B, K) per-item log-lik
            csum = jnp.cumsum(ll, axis=1)
            prefix.append(jnp.concatenate(
                [jnp.zeros_like(csum[:, :1]), csum[:, :-1]], axis=1))
        cond = jnp.stack(cond, axis=0)  # (M, B, K)
        prefix = jnp.stack(prefix, axis=0)  # (M, B, K)
        log_w = log_prior[:, None, None] + prefix / self.temperature
        log_w = log_w - logsumexp(log_w, axis=0, keepdims=True)
        return logsumexp(log_w + cond, axis=0)

    def predict_relevance(self, params, batch):
        log_prior = self._log_prior(params)
        scores = jnp.stack([
            m.predict_relevance(self._model_params(params, i), batch)
            for i, m in enumerate(self.models)
        ], axis=0)
        return jnp.sum(jnp.exp(log_prior)[:, None, None] * scores, axis=0)

    def sample(self, params, batch, rng):
        k_pick, k_sample = jax.random.split(rng)
        log_prior = self._log_prior(params)
        b = batch["positions"].shape[0]
        choice = jax.random.categorical(k_pick, log_prior, shape=(b,))
        samples = [m.sample(self._model_params(params, i), batch,
                            jax.random.fold_in(k_sample, i))["clicks"]
                   for i, m in enumerate(self.models)]
        stacked = jnp.stack(samples, axis=0)  # (M, B, K)
        clicks = jnp.take_along_axis(
            stacked, choice[None, :, None].astype(jnp.int32), axis=0)[0]
        return {"clicks": clicks, "model_choice": choice}
