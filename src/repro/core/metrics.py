"""Click-prediction + ranking metrics (paper §4.4), mask-aware and batched.

Click metrics are streaming accumulators: ``state = metric.init_state(K)``,
``state = metric.update(state, **batch_outputs)``, ``metric.compute(state)``.
``MultiMetric`` routes inputs by name so all metrics update in one call
(paper Listing 6). Ranking metrics are pure functions in the Rax style
(paper Listing 7): ``metric(scores, labels, where=mask, top_n=...)``.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.stable import log1mexp

LOG2 = 0.6931471805599453


def _bce_bits(log_probs, clicks):
    """Per-item log2-loss: -[c log2 p + (1-c) log2 (1-p)]."""
    clicks = clicks.astype(log_probs.dtype)
    ll = clicks * log_probs + (1.0 - clicks) * log1mexp(log_probs)
    return -ll / LOG2


class _StreamingMetric:
    """Accumulates per-rank (sum, count) to support global + per-rank views."""

    requires = ("log_probs", "clicks", "where")
    use_log2 = False
    negate = False

    def init_state(self, positions: int):
        return {
            "sum": jnp.zeros((positions,), jnp.float64 if jax.config.jax_enable_x64
                             else jnp.float32),
            "count": jnp.zeros((positions,), jnp.float32),
        }

    def _values(self, **kwargs):
        raise NotImplementedError

    def update(self, state, **kwargs):
        where = kwargs.get("where")
        values = self._values(**kwargs)
        if where is None:
            where = jnp.ones_like(values, dtype=bool)
        w = where.astype(values.dtype)
        return {
            "sum": state["sum"] + jnp.sum(values * w, axis=0),
            "count": state["count"] + jnp.sum(w, axis=0),
        }

    def compute(self, state):
        mean = jnp.sum(state["sum"]) / jnp.maximum(jnp.sum(state["count"]), 1.0)
        return self._finalize(mean)

    def compute_per_rank(self, state):
        mean = state["sum"] / jnp.maximum(state["count"], 1.0)
        return self._finalize(mean)

    def _finalize(self, mean):
        return mean


class LogLikelihood(_StreamingMetric):
    """Eq. 13: mean conditional log-likelihood (higher = better)."""

    requires = ("conditional_log_probs", "clicks", "where")

    def _values(self, conditional_log_probs=None, clicks=None, **_):
        clicks = clicks.astype(conditional_log_probs.dtype)
        return (clicks * conditional_log_probs
                + (1.0 - clicks) * log1mexp(conditional_log_probs))


class Perplexity(_StreamingMetric):
    """Eq. 14 with unconditional click predictions."""

    requires = ("log_probs", "clicks", "where")

    def _values(self, log_probs=None, clicks=None, **_):
        return _bce_bits(log_probs, clicks)

    def _finalize(self, mean):
        return jnp.exp2(mean)


class ConditionalPerplexity(_StreamingMetric):
    """Eq. 14 with conditional click predictions."""

    requires = ("conditional_log_probs", "clicks", "where")

    def _values(self, conditional_log_probs=None, clicks=None, **_):
        return _bce_bits(conditional_log_probs, clicks)

    def _finalize(self, mean):
        return jnp.exp2(mean)


class MultiMetric:
    """Bundle of named metrics with automatic input routing (Listing 6)."""

    def __init__(self, metrics: Dict[str, object]):
        self.metrics = dict(metrics)

    def init_state(self, positions: int, replicas: int = None):
        """Fresh accumulator state; with ``replicas=R`` every leaf gains a
        leading replica axis so one ``jax.vmap``-ed update call advances R
        independent evaluations (the sweep engine's vmapped eval step).
        Stacked states must be reduced with ``jax.vmap(self.compute)``."""
        state = {name: m.init_state(positions)
                 for name, m in self.metrics.items()}
        if replicas is None:
            return state
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (replicas,) + x.shape), state)

    def update(self, state, **kwargs):
        out = {}
        for name, metric in self.metrics.items():
            routed = {k: v for k, v in kwargs.items() if k in metric.requires}
            out[name] = metric.update(state[name], **routed)
        return out

    def compute(self, state):
        return {name: m.compute(state[name]) for name, m in self.metrics.items()}

    def compute_per_rank(self, state):
        return {name: m.compute_per_rank(state[name])
                for name, m in self.metrics.items()}


# ---------------------------------------------------------------------------
# Ranking metrics (Rax-style pure functions).
# ---------------------------------------------------------------------------

def _rank_by_score(scores, where):
    """Ranks (1-based) of each item when sorted by descending score."""
    scores = jnp.where(where, scores, -jnp.inf)
    order = jnp.argsort(-scores, axis=-1)
    ranks = jnp.take_along_axis(
        jnp.broadcast_to(jnp.arange(1, scores.shape[-1] + 1), scores.shape),
        jnp.argsort(order, axis=-1), axis=-1)
    return ranks


def dcg_metric(scores, labels, where=None, top_n=None):
    """DCG@top_n = sum gain/log2(1+rank); gain = 2^label - 1."""
    if where is None:
        where = jnp.ones_like(scores, dtype=bool)
    ranks = _rank_by_score(scores, where)
    gains = (jnp.exp2(labels.astype(jnp.float32)) - 1.0) * where
    discounts = 1.0 / jnp.log2(1.0 + ranks.astype(jnp.float32))
    if top_n is not None:
        discounts = jnp.where(ranks <= top_n, discounts, 0.0)
    per_list = jnp.sum(gains * discounts, axis=-1)
    return jnp.mean(per_list)


def ndcg_metric(scores, labels, where=None, top_n=None):
    if where is None:
        where = jnp.ones_like(scores, dtype=bool)
    ranks = _rank_by_score(scores, where)
    gains = (jnp.exp2(labels.astype(jnp.float32)) - 1.0) * where
    discounts = 1.0 / jnp.log2(1.0 + ranks.astype(jnp.float32))
    if top_n is not None:
        discounts = jnp.where(ranks <= top_n, discounts, 0.0)
    dcg = jnp.sum(gains * discounts, axis=-1)
    ideal_ranks = _rank_by_score(labels.astype(jnp.float32), where)
    ideal_discounts = 1.0 / jnp.log2(1.0 + ideal_ranks.astype(jnp.float32))
    if top_n is not None:
        ideal_discounts = jnp.where(ideal_ranks <= top_n, ideal_discounts, 0.0)
    idcg = jnp.sum(gains * ideal_discounts, axis=-1)
    return jnp.mean(jnp.where(idcg > 0, dcg / jnp.maximum(idcg, 1e-12), 0.0))


def mrr_metric(scores, labels, where=None, top_n=None):
    """Mean reciprocal rank of the first relevant (label > 0) item."""
    if where is None:
        where = jnp.ones_like(scores, dtype=bool)
    ranks = _rank_by_score(scores, where)
    relevant = (labels > 0) & where
    rr = jnp.where(relevant, 1.0 / ranks.astype(jnp.float32), 0.0)
    if top_n is not None:
        rr = jnp.where(ranks <= top_n, rr, 0.0)
    return jnp.mean(jnp.max(rr, axis=-1))


def average_precision_metric(scores, labels, where=None, top_n=None):
    """AP = mean over relevant items of precision@rank."""
    if where is None:
        where = jnp.ones_like(scores, dtype=bool)
    ranks = _rank_by_score(scores, where)
    relevant = ((labels > 0) & where).astype(jnp.float32)
    K = scores.shape[-1]
    # rel_at_rank[b, r] = is the item ranked (r+1) relevant?
    order = jnp.argsort(jnp.where(where, -scores, jnp.inf), axis=-1)
    rel_sorted = jnp.take_along_axis(relevant, order, axis=-1)
    cum_rel = jnp.cumsum(rel_sorted, axis=-1)
    prec_at = cum_rel / jnp.arange(1, K + 1, dtype=jnp.float32)
    contrib = prec_at * rel_sorted
    if top_n is not None:
        contrib = jnp.where(jnp.arange(1, K + 1) <= top_n, contrib, 0.0)
    n_rel = jnp.maximum(jnp.sum(relevant, axis=-1), 1.0)
    return jnp.mean(jnp.sum(contrib, axis=-1) / n_rel)


class RaxMetric:
    """Adapter matching the paper's Listing 7 RaxMetric(fn, top_n=...)."""

    requires = ("scores", "labels", "where")

    def __init__(self, fn, top_n=None):
        self.fn = fn
        self.top_n = top_n

    def init_state(self, positions: int):
        del positions
        return {"sum": jnp.zeros((), jnp.float32), "count": jnp.zeros((), jnp.float32)}

    def update(self, state, scores=None, labels=None, where=None, **_):
        value = self.fn(scores, labels, where=where, top_n=self.top_n)
        return {"sum": state["sum"] + value, "count": state["count"] + 1.0}

    def compute(self, state):
        return state["sum"] / jnp.maximum(state["count"], 1.0)

    def compute_per_rank(self, state):
        return self.compute(state)
