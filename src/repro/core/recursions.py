"""Vectorized log-space examination recursions for chain click models.

De Ruijt & Bhulai (2021, "The Generalized Cascade Click Model") observe that
DCM, CCM, DBN and SDBN share one examination-chain structure. This module
exploits that: both the marginal and the conditional examination probability
of every chain model reduce to closed forms over per-position log factors, so
the per-position ``lax.scan`` (K sequential steps of ~3 flops each) in the hot
path is replaced by a handful of batched cumsum / gather / logsumexp ops.

Marginal chain (``marginal_examination``)
    eps_1 = 1 and eps_{k+1} = eps_k * f_k for a model-specific continuation
    factor f_k, hence log eps_k = sum_{m<k} log f_m — one exclusive cumsum.

Conditional chain (``conditional_examination``)
    Clicks are regeneration points: given a click at position q the chain
    restarts with examination probability rho_q (the model's post-click
    reset), and skips evolve the posterior by Bayes' rule. Within the segment
    after the last click, write

        A_k = rho_q * prod_{q<m<k} (1-gamma_m) c_m     (survive every skip)
        D_k = (1-rho_q) + sum_{q<j<k} A_j (1-gamma_j)(1-c_j)   (chain died)

    where gamma is attraction and c the model's skip-continuation. In
    death-odds space r = D / A the whole chain is ONE affine recurrence
    solved by a single associative scan (log2 K parallel combine rounds of
    fused multiply-adds, vs K sequential lax.scan steps), with exactly one
    transcendental at the end: log eps = -log1p(r). Per-position factors are
    positive products of sigmoids assembled via ``stable.sigmoid_parts``
    (one exp + one log1p yields sigma(x), sigma(-x) and both log-sigmoids),
    which cuts the hot path's transcendental count ~3x vs the log-space
    scan. Exact while death odds stay below _ODDS_CAP (eps above ~1e-9);
    beyond that the recurrence saturates to a finite value with zero
    gradient (see the bound derivation at _ODDS_CAP) instead of tracking
    probabilities no click log could ever resolve.

UBM marginal (``ubm_marginal_clicks``)
    Eq. 26's marginalization over last-click paths is a strictly triangular
    linear recurrence lu = T0 + W @ lu. The path weights W are built with one
    masked (B, K, K) cumulative sum; the recurrence is solved with a single
    batched unit-triangular solve — O(1) graph ops instead of the former
    O(K^2) Python double loop.

The scan-based implementations remain on the models as ``*_scan`` methods and
act as test oracles (tests/test_recursions.py) until the vectorized paths
have soaked.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.stable import exclusive_cumsum, sigmoid_parts


def marginal_examination(log_cont: jax.Array) -> jax.Array:
    """log eps over positions from per-position log continuation factors.

    log_cont: (B, K) with log f_k = log P(E_{k+1}=1 | E_k=1) marginalized over
    the model's latents at position k. Returns (B, K) log eps, log eps_1 = 0.
    """
    return exclusive_cumsum(log_cont, axis=1)


# Saturation bounds for the death-odds recurrence.
#
# * _ODDS_FLOOR floors probabilities entering a denominator, bounding each
#   per-position growth factor at 1/floor = 1e9. Probabilities below 1e-9
#   are unmeasurable in any realistic click log.
# * _ODDS_CAP caps the odds value z (and reverse-mode cotangents): saturated
#   sessions get a finite log-probability (>= -log1p(cap) ~ -20.7, still
#   well below the repo's MIN_LOG_PROB = -13.8 floor convention) with zero
#   gradient, never inf/NaN.
# * _GROWTH_CAP caps only the *composite* growth products inside the scan's
#   combine. It must be far above _ODDS_CAP: capping composites at the odds
#   cap would break associativity for sub-cap results (a large composite
#   applied to a tiny upstream z can land well below _ODDS_CAP and must stay
#   exact). 1e28 keeps every product finite in float32 — composite * odds
#   <= 1e37 forward and backward, and cotangent chains stay <= cap^2/floor^2
#   = 1e36 — while only binding when z itself saturates or sits below 1e-19
#   (odds no real session reaches).
_ODDS_CAP = 1e9
_ODDS_FLOOR = 1e-9
_GROWTH_CAP = 1e28

# Public aliases: the fused examination_nll lowerings (repro.kernels) must
# saturate with exactly these bounds to stay conformant with this module.
ODDS_CAP = _ODDS_CAP
ODDS_FLOOR = _ODDS_FLOOR
GROWTH_CAP = _GROWTH_CAP


def _affine_scan_impl(a, b, signed_b=False):
    """Capped inclusive solve of z_k = a_k * z_{k-1} + b_k (z_{-1} = 0).

    One jax.lax.associative_scan — log2(K) parallel combine rounds, vs K
    sequential lax.scan steps. The combine saturates at _ODDS_CAP: inputs
    are pre-clamped, so every product stays below float32 max and saturated
    spans give the same capped result for any combination tree. ``a`` must
    be non-negative; ``b`` too unless ``signed_b`` (the reverse-mode pass,
    whose cotangents carry sign and saturate two-sided).
    """
    cap = jnp.asarray(_ODDS_CAP, a.dtype)
    growth_cap = jnp.asarray(_GROWTH_CAP, a.dtype)
    clamp_b = (lambda x: jnp.clip(x, -cap, cap)) if signed_b else \
        (lambda x: jnp.minimum(x, cap))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return jnp.minimum(a1 * a2, growth_cap), clamp_b(a2 * b1 + b2)

    _, z = jax.lax.associative_scan(combine, (a, b), axis=1)
    return z


@jax.custom_vjp
def _affine_scan(a, b):
    """Capped affine recurrence with a saturating custom VJP.

    The reverse pass of an affine recurrence is itself an affine recurrence
    in the cotangents (u_k = cot_k + a_{k+1} u_{k+1}); running it through
    the same capped scan keeps out-of-domain gradients at a large finite
    value where naive autodiff would form inf * 0 = NaN (the cotangent
    chain multiplies the raw a factors, which overflow for skip runs past
    float32's probability range even though the primal saturates).
    """
    return _affine_scan_impl(a, b)


def _affine_scan_fwd(a, b):
    z = _affine_scan_impl(a, b)
    return z, (a, z)


def _affine_scan_bwd(res, cot):
    a, z = res
    # A saturated output sits on the cap's flat region: its true sensitivity
    # is zero. Zeroing its cotangent both encodes that and blocks the
    # astronomical chain products that would otherwise flow through the
    # saturated span (capped forward + capped reverse do NOT reproduce the
    # true cancellation — they overshoot by the ratio of true odds to cap).
    # Saturation is absorbing within a segment (a >= 1, b >= 0 between
    # resets), so every un-capped z_k has an exact, fully un-capped prefix
    # and its gradient stays exact.
    cap = jnp.asarray(_ODDS_CAP, a.dtype)
    cot = jnp.where(z >= cap, 0.0, cot)
    ones = jnp.ones_like(a[:, :1])
    a_next = jnp.concatenate([a[:, 1:], ones], axis=1)       # a_{k+1}
    u = _affine_scan_impl(a_next[:, ::-1], cot[:, ::-1],
                          signed_b=True)[:, ::-1]
    z_prev = jnp.pad(z[:, :-1], ((0, 0), (1, 0)))            # z_{k-1}
    return u * z_prev, u


_affine_scan.defvjp(_affine_scan_fwd, _affine_scan_bwd)


def conditional_examination(clicks: jax.Array,
                            p_skip_survive: jax.Array,
                            p_death: jax.Array,
                            p_reset: jax.Array,
                            p_reset_not: jax.Array) -> jax.Array:
    """Closed-form log P(E_k=1 | c_<k) for generalized cascade chains.

    Works in death-odds space r_k = D_k / A_k, which collapses the whole
    conditional chain to ONE affine recurrence with no transcendentals:

      after a skip at k:   r_{k+1} = (r_k + p_death_k) / p_skip_survive_k
      after a click at k:  r_{k+1} = p_reset_not_k / p_reset_k

    and log eps_k = -log1p(r_k). Arguments (all (B, K)) arrive in
    *probability* space — each is a positive product/sum of sigmoids the
    model assembles to full relative precision from raw logits (sigma(-x)
    for complements, never 1 - sigma(x)):

      clicks          observed click indicators c_k.
      p_skip_survive  (1-gamma_k) c_k: examined, skipped, kept browsing.
      p_death         (1-gamma_k)(1-c_k): examined, skipped, abandoned.
      p_reset         rho_k = P(E_{k+1}=1 | C_k=1), the post-click restart.
      p_reset_not     1 - rho_k.

    The virtual pre-session state is a sure click with rho = 1 (r_1 = 0).
    Odds stay exact because every operation is a positive multiply-add;
    beyond _ODDS_CAP the recurrence saturates finitely (zero gradient)
    rather than overflowing.
    """
    return -jnp.log1p(conditional_examination_odds(
        clicks, p_skip_survive, p_death, p_reset, p_reset_not))


def conditional_examination_odds(clicks, p_skip_survive, p_death, p_reset,
                                 p_reset_not):
    """Death odds r_k = (1 - eps_k) / eps_k of ``conditional_examination``.

    Exposed separately so callers can fuse the final log1p with other log
    terms (log eps + log gamma = -log1p(r) + log sigma(x) folds into a
    single log1p — see _ChainModel.predict_conditional_clicks).
    """
    floor = jnp.asarray(_ODDS_FLOOR, p_skip_survive.dtype)
    cap = jnp.asarray(_ODDS_CAP, p_skip_survive.dtype)
    clicked = (clicks > 0).astype(p_skip_survive.dtype)
    keep = 1.0 - clicked
    # z_k = r_{k+1}: every factor is used at its own position, and the result
    # shifts right once at the end (r_0 = 0, the virtual sure-reset).
    inv_s = keep / jnp.maximum(p_skip_survive, floor)
    reset_odds = p_reset_not / jnp.maximum(p_reset, floor)
    b = jnp.minimum(inv_s * p_death + clicked * reset_odds, cap)
    z = _affine_scan(inv_s, b)
    return jnp.pad(z[:, :-1], ((0, 0), (1, 0)))


def ubm_marginal_clicks(attr_logits: jax.Array, exam_logits: jax.Array
                        ) -> jax.Array:
    """Vectorized UBM Eq. 26: log P(C_r=1) marginalized over last-click paths.

    attr_logits: (B, K) attraction logits. exam_logits: (K, K) or (B, K, K)
    examination logits theta[rank, last click], column 0 = no previous click,
    column q+1 = last click at 0-based rank q. Returns (B, K) log click
    probabilities.
    """
    b, k = attr_logits.shape
    g, gn, log_attr, _ = sigmoid_parts(attr_logits)
    th, th_not, log_exam, _ = sigmoid_parts(exam_logits)
    if exam_logits.ndim == 2:
        th_not = th_not[None]
        log_exam = jnp.broadcast_to(log_exam[None], (b, k, k))
    # log(1 - theta_{j,i} gamma_j), assembled as the stable positive sum
    # (1-gamma) + gamma (1-theta) — one log, no (B, K, K) log1mexp chain.
    lg_no_click = jnp.log(gn[:, :, None] + g[:, :, None] * th_not)
    # Exclusive cumulative sum over rank j as one strict-tril matmul — on CPU
    # a batched (K, K) GEMM is ~3x faster than XLA's strided-axis cumsum.
    strict_tril = jnp.tril(jnp.ones((k, k), lg_no_click.dtype), -1)
    ex_cs = jnp.einsum("jm,bmi->bji", strict_tril, lg_no_click)

    # Source terms: no click before r — skip-run at column 0 from the top.
    log_t0 = ex_cs[:, :, 0] + log_exam[:, :, 0] + log_attr

    # Path weights W[r, q] (q < r): click at q, skip q+1..r-1 at column q+1,
    # then click at r. The skip run is ex_cs[r, q+1] - cs[q, q+1]; the
    # subtrahend is a diagonal of the inclusive sum ex_cs + lg, shifted one
    # column right.
    cs_diag = (jnp.diagonal(ex_cs[:, :, 1:], axis1=1, axis2=2)
               + jnp.diagonal(lg_no_click[:, :, 1:], axis1=1, axis2=2))
    cs_diag = jnp.pad(cs_diag, ((0, 0), (0, 1)))               # (B, K)
    log_w = (ex_cs[:, :, 1:] - cs_diag[:, None, :-1]
             + log_exam[:, :, 1:] + log_attr[:, :, None])      # (B, K, K-1)
    log_w = jnp.pad(log_w, ((0, 0), (0, 0), (0, 1)), constant_values=-jnp.inf)

    tri = jnp.arange(k)[None, :, None] > jnp.arange(k)[None, None, :]  # q < r
    w = jnp.where(tri, jnp.exp(jnp.where(tri, log_w, -jnp.inf)), 0.0)

    # lu = T0 + W @ lu with strictly lower-triangular W: one batched
    # unit-triangular solve replaces the sequential recurrence. The solve
    # runs in probability space, so sessions past float32's exp range
    # saturate: flooring at tiny keeps the log finite and its gradient zero
    # (instead of -inf forward / NaN backward) — the probability-space
    # counterpart of the conditional chain's saturating odds cap.
    eye = jnp.eye(k, dtype=w.dtype)[None]
    lu = jax.scipy.linalg.solve_triangular(
        eye - w, jnp.exp(log_t0)[:, :, None], lower=True, unit_diagonal=True)
    return jnp.log(jnp.maximum(lu[:, :, 0], jnp.finfo(lu.dtype).tiny))
