"""EM / MLE reference optimizers (the PyClick-style baselines of §3 & §7).

These full-batch estimators are what CLAX replaces with SGD. We keep them as
(a) correctness oracles — gradient training must reach the same fit — and
(b) the speed baseline in ``benchmarks/bench_em_vs_grad.py`` (Figure 1).

All estimators consume flat padded arrays: positions (B,K) 1-based, doc ids
(B,K), clicks (B,K), mask (B,K). Fitted probabilities can be injected into the
matching CLAX model's embedding tables via :func:`to_logits` so both pipelines
share evaluation code.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

EPS = 1e-8


def to_logits(p: jax.Array) -> jax.Array:
    p = jnp.clip(p, EPS, 1.0 - EPS)
    return jnp.log(p) - jnp.log1p(-p)


def _flatten(batch):
    pos = batch["positions"].reshape(-1) - 1  # 0-based ranks
    docs = batch["query_doc_ids"].reshape(-1)
    clicks = batch["clicks"].reshape(-1).astype(jnp.float32)
    mask = batch["mask"].reshape(-1).astype(jnp.float32)
    return pos, docs, clicks, mask


# ---------------------------------------------------------------------------
# MLE (counting) estimators for CTR models — PyClick's fast path.
# ---------------------------------------------------------------------------

def fit_gctr(batch) -> jax.Array:
    _, _, clicks, mask = _flatten(batch)
    return jnp.sum(clicks * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def fit_rctr(batch, positions: int) -> jax.Array:
    pos, _, clicks, mask = _flatten(batch)
    num = jax.ops.segment_sum(clicks * mask, pos, num_segments=positions)
    den = jax.ops.segment_sum(mask, pos, num_segments=positions)
    return num / jnp.maximum(den, 1.0)


def fit_dctr(batch, n_docs: int, prior: float = 0.5, prior_weight: float = 0.0):
    """Per-document CTR with optional Beta-prior smoothing."""
    _, docs, clicks, mask = _flatten(batch)
    num = jax.ops.segment_sum(clicks * mask, docs, num_segments=n_docs)
    den = jax.ops.segment_sum(mask, docs, num_segments=n_docs)
    return (num + prior * prior_weight) / jnp.maximum(den + prior_weight, EPS)


def fit_sdbn_mle(batch, n_docs: int):
    """SDBN MLE counting (PyClick's fast path): within each session, items at
    or before the LAST click are certainly examined, so
      attractiveness_d = clicks(d) / impressions-at-or-before-last-click(d)
      satisfaction_d   = last-clicks(d) / clicks(d).
    Returns (gamma[n_docs], sigma[n_docs])."""
    positions = batch["positions"]
    clicks = batch["clicks"].astype(jnp.float32)
    mask = batch["mask"].astype(jnp.float32)
    docs = batch["query_doc_ids"].reshape(-1)
    clicked_rank = jnp.where(clicks > 0, positions, 0)
    last_rank = jnp.max(clicked_rank, axis=1, keepdims=True)  # (B, 1)
    examined = ((positions <= last_rank) & (last_rank > 0)).astype(jnp.float32)
    examined = (examined * mask).reshape(-1)
    c = (clicks * mask).reshape(-1)
    is_last = ((clicked_rank == last_rank) & (clicks > 0)).astype(jnp.float32)
    is_last = (is_last * mask).reshape(-1)
    imp = jax.ops.segment_sum(examined, docs, num_segments=n_docs)
    clk = jax.ops.segment_sum(c, docs, num_segments=n_docs)
    lst = jax.ops.segment_sum(is_last, docs, num_segments=n_docs)
    gamma = clk / jnp.maximum(imp, 1.0)
    sigma = lst / jnp.maximum(clk, 1.0)
    return gamma, sigma


def sdbn_params_from_mle(gamma, sigma) -> Dict:
    return {
        "attraction": {"table": to_logits(gamma)[:, None]},
        "satisfaction": {"table": to_logits(sigma)[:, None]},
    }


# ---------------------------------------------------------------------------
# PBM expectation-maximization (paper Eqs. 3-6).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("positions", "n_docs"))
def _pbm_em_iteration(theta, gamma, pos, docs, clicks, mask, *, positions, n_docs):
    th = theta[pos]
    ga = gamma[docs]
    denom = jnp.maximum(1.0 - th * ga, EPS)
    # E-step (Eqs. 3-4)
    e_hat = clicks + (1.0 - clicks) * th * (1.0 - ga) / denom
    a_hat = clicks + (1.0 - clicks) * ga * (1.0 - th) / denom
    # M-step (Eq. 6)
    theta_new = (jax.ops.segment_sum(e_hat * mask, pos, num_segments=positions)
                 / jnp.maximum(jax.ops.segment_sum(mask, pos, num_segments=positions), EPS))
    gamma_new = (jax.ops.segment_sum(a_hat * mask, docs, num_segments=n_docs)
                 / jnp.maximum(jax.ops.segment_sum(mask, docs, num_segments=n_docs), EPS))
    return theta_new, gamma_new


def fit_pbm_em(batch, positions: int, n_docs: int, n_iters: int = 50,
               init: float = 0.5) -> Tuple[jax.Array, jax.Array]:
    """Returns (theta[positions], gamma[n_docs]) in probability space."""
    pos, docs, clicks, mask = _flatten(batch)
    theta = jnp.full((positions,), init, jnp.float32)
    gamma = jnp.full((n_docs,), init, jnp.float32)
    for _ in range(n_iters):
        theta, gamma = _pbm_em_iteration(theta, gamma, pos, docs, clicks, mask,
                                         positions=positions, n_docs=n_docs)
    return theta, gamma


# ---------------------------------------------------------------------------
# UBM expectation-maximization. E-step conditions on the observed last click
# (standard Chuklin et al. derivation); theta is indexed by the pair
# (rank k, last-click rank k') with k' = 0 meaning "no previous click".
# ---------------------------------------------------------------------------

def _last_click_flat(batch):
    clicks = batch["clicks"]
    positions = batch["positions"]
    clicked_rank = jnp.where(clicks > 0, positions, 0)
    cummax = jax.lax.associative_scan(jnp.maximum, clicked_rank, axis=1)
    exclusive = jnp.concatenate([jnp.zeros_like(cummax[:, :1]), cummax[:, :-1]], axis=1)
    return exclusive.reshape(-1)  # 1-based rank of last click, 0 = none


@partial(jax.jit, static_argnames=("positions", "n_docs"))
def _ubm_em_iteration(theta, gamma, pair_idx, docs, clicks, mask, *, positions, n_docs):
    th = theta.reshape(-1)[pair_idx]
    ga = gamma[docs]
    denom = jnp.maximum(1.0 - th * ga, EPS)
    e_hat = clicks + (1.0 - clicks) * th * (1.0 - ga) / denom
    a_hat = clicks + (1.0 - clicks) * ga * (1.0 - th) / denom
    n_pairs = positions * positions
    theta_new = (jax.ops.segment_sum(e_hat * mask, pair_idx, num_segments=n_pairs)
                 / jnp.maximum(jax.ops.segment_sum(mask, pair_idx, num_segments=n_pairs), EPS))
    # Unobserved (k, k') pairs keep their previous value instead of collapsing.
    counts = jax.ops.segment_sum(mask, pair_idx, num_segments=n_pairs)
    theta_new = jnp.where(counts > 0, theta_new, theta.reshape(-1))
    gamma_new = (jax.ops.segment_sum(a_hat * mask, docs, num_segments=n_docs)
                 / jnp.maximum(jax.ops.segment_sum(mask, docs, num_segments=n_docs), EPS))
    return theta_new.reshape(positions, positions), gamma_new


def fit_ubm_em(batch, positions: int, n_docs: int, n_iters: int = 50,
               init: float = 0.5) -> Tuple[jax.Array, jax.Array]:
    """Returns (theta[K, K] indexed [rank-1, last-click-rank], gamma[n_docs])."""
    pos, docs, clicks, mask = _flatten(batch)
    last = _last_click_flat(batch)
    pair_idx = pos * positions + jnp.clip(last, 0, positions - 1).astype(pos.dtype)
    theta = jnp.full((positions, positions), init, jnp.float32)
    gamma = jnp.full((n_docs,), init, jnp.float32)
    for _ in range(n_iters):
        theta, gamma = _ubm_em_iteration(theta, gamma, pair_idx, docs, clicks, mask,
                                         positions=positions, n_docs=n_docs)
    return theta, gamma


# ---------------------------------------------------------------------------
# Injection helpers: EM/MLE fits -> CLAX model params for shared evaluation.
# ---------------------------------------------------------------------------

def pbm_params_from_em(theta, gamma) -> Dict:
    return {
        "attraction": {"table": to_logits(gamma)[:, None]},
        "examination": {"table": to_logits(theta)},
    }


def ubm_params_from_em(theta, gamma) -> Dict:
    return {
        "attraction": {"table": to_logits(gamma)[:, None]},
        "examination": {"table": to_logits(theta)},
    }


def dctr_params_from_mle(ctr) -> Dict:
    return {"attraction": {"table": to_logits(ctr)[:, None]}}


def rctr_params_from_mle(ctr) -> Dict:
    return {"theta": {"table": to_logits(ctr)}}


def gctr_params_from_mle(ctr) -> Dict:
    return {"rho": {"value": to_logits(ctr)}}
