"""Parameterizations: the "how" of each click-model variable (paper §4.2).

A parameterization maps a batch to per-item *logits*; the click model turns
logits into log-probabilities via the stable log-sigmoid (paper Eq. 17).
Decoupling structure from parameterization is the paper's flexibility story:
the same PBM can be a classic embedding-table model or a DeepCrossV2 two-tower.

Supported:
  * EmbeddingParameter — classic table, optional baseline correction,
    hashing-trick [Weinberger 2009] or quotient-remainder [Shi 2020]
    compression.
  * PositionParameter — rank-indexed table (θ_k).
  * UBMExaminationParameter — (rank, last-click-rank) table θ_{k,k'}.
  * ScalarParameter — single shared logit (GCTR ρ, CCM τ, DBN λ).
  * FeatureParameter — Linear / MLP / DeepCrossV2 towers over feature vectors.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.nn import Dense, DeepCrossV2, MLP, init as initializers
from repro.nn.module import Module, split_rngs


# Compressed tables round up to a multiple of this so row-sharding over any
# production mesh axis (16 / 512) divides evenly. Harmless for hashing (the
# modulus just grows) and for QR (quotient table padding rows are unused).
SHARD_MULTIPLE = 512


def _round_up(n: int, multiple: int = SHARD_MULTIPLE) -> int:
    return -(-n // multiple) * multiple


class Compression(str, enum.Enum):
    NONE = "none"
    HASH = "hash"
    QR = "quotient_remainder"


class Combination(str, enum.Enum):
    STACKED = "stacked"
    PARALLEL = "parallel"


# ---------------------------------------------------------------------------
# Config dataclasses (mirror the paper's Listing 3/4 API).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EmbeddingParameterConfig:
    parameters: int
    use_feature: str = "query_doc_ids"
    compression: Compression = Compression.NONE
    compression_ratio: float = 1.0
    baseline_correction: bool = False
    features: int = 1  # output logits per item (1 for classic scalar models)
    init_logit: float = 0.0


@dataclasses.dataclass
class ScalarParameterConfig:
    init_prob: float = 0.5
    features: int = 1


@dataclasses.dataclass
class LinearParameterConfig:
    features: int
    use_feature: str = "query_doc_features"
    out_features: int = 1


@dataclasses.dataclass
class MLPParameterConfig:
    features: int
    hidden: Sequence[int] = (64, 64)
    use_feature: str = "query_doc_features"
    out_features: int = 1


@dataclasses.dataclass
class DeepCrossParameterConfig:
    features: int
    cross_layers: int = 2
    deep_layers: int = 2
    use_feature: str = "query_doc_features"
    combination: Combination = Combination.STACKED
    out_features: int = 1


# ---------------------------------------------------------------------------
# Integer hashing (multiply-xorshift, SplitMix64 finalizer) for the
# hashing-trick. Works on int32/int64 ids, vectorized, jit-safe.
# ---------------------------------------------------------------------------

def _splitmix(ids: jax.Array, salt: int = 0) -> jax.Array:
    """64-bit avalanche hash of integer ids (jnp, overflow wraps as intended)."""
    x = ids.astype(jnp.uint32)
    salt_arr = jnp.uint32(salt * 0x9E3779B9 + 0x85EBCA6B)
    x = x ^ salt_arr
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def hash_ids(ids: jax.Array, table_size: int, salt: int = 0) -> jax.Array:
    return (_splitmix(ids, salt) % jnp.uint32(table_size)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Parameter modules. Each returns logits of shape ids.shape (+ trailing
# features dim squeezed when features == 1).
# ---------------------------------------------------------------------------

class EmbeddingParameter(Module):
    """Table-based parameter with optional compression + baseline correction.

    Baseline correction (paper §4.2): a shared scalar is added to every row's
    logit; rows init at zero so unseen/rare ids start at the global baseline.
    """

    def __init__(self, config: EmbeddingParameterConfig, name: str = "embedding"):
        self.config = config
        self.name = name
        c = config
        self.features = c.features
        if c.compression == Compression.NONE:
            self.table_rows = c.parameters
        elif c.compression == Compression.HASH:
            self.table_rows = _round_up(
                max(int(c.parameters / max(c.compression_ratio, 1.0)), 2))
        elif c.compression == Compression.QR:
            # Two tables of ~sqrt-scaled sizes: remainder table of size m,
            # quotient table of ceil(N/m). Choose m so total rows shrink by
            # ~compression_ratio: m + N/m = 2N/ratio at m = N/ratio... we pick
            # m = max(parameters / ratio / 2, 2) and q_rows = ceil(N/m).
            m = _round_up(max(int(c.parameters / max(c.compression_ratio, 1.0) / 2), 2))
            self.rem_rows = m
            self.quot_rows = _round_up(int(-(-c.parameters // m)))  # ceil div
        else:
            raise ValueError(f"unknown compression {c.compression}")

    def init(self, rng):
        c = self.config
        k1, k2, k3 = jax.random.split(rng, 3)
        if c.baseline_correction:
            row_init = initializers.zeros
        else:
            row_init = initializers.constant(c.init_logit)
        params = {}
        if c.compression == Compression.QR:
            params["quotient"] = row_init(k1, (self.quot_rows, c.features), jnp.float32)
            params["remainder"] = initializers.ones(k2, (self.rem_rows, c.features), jnp.float32)
        else:
            params["table"] = row_init(k1, (self.table_rows, c.features), jnp.float32)
        if c.baseline_correction:
            params["baseline"] = jnp.full((c.features,), c.init_logit, jnp.float32)
        return params

    def row_ids(self, batch):
        """Table rows the batch gathers — the single home of this
        parameterization's index math (forward lookup and the sparse-optimizer
        row stream must agree row-for-row). QR has no single row-id table
        (each logical row is a product of two table rows)."""
        c = self.config
        ids = batch[c.use_feature]
        if c.compression == Compression.NONE:
            return jnp.clip(ids, 0, self.table_rows - 1)
        if c.compression == Compression.HASH:
            return hash_ids(ids, self.table_rows)
        raise NotImplementedError(
            "quotient-remainder compression has no single row-id stream")

    def __call__(self, params, batch):
        c = self.config
        if c.compression in (Compression.NONE, Compression.HASH):
            logits = jnp.take(params["table"], self.row_ids(batch), axis=0)
        else:  # QR: element-wise product of quotient and remainder rows
            ids = batch[c.use_feature]
            q = jnp.take(params["quotient"], (ids // self.rem_rows) % self.quot_rows, axis=0)
            r = jnp.take(params["remainder"], ids % self.rem_rows, axis=0)
            logits = q * r
        if c.baseline_correction:
            logits = logits + params["baseline"]
        if c.features == 1:
            logits = jnp.squeeze(logits, axis=-1)
        return logits


class PositionParameter(Module):
    """Rank-indexed logit table θ_k. Positions in batches are 1-based."""

    def __init__(self, positions: int, init_logit: float = 0.0,
                 use_feature: str = "positions"):
        self.positions = positions
        self.init_logit = init_logit
        self.use_feature = use_feature

    def init(self, rng):
        del rng
        return {"table": jnp.full((self.positions,), self.init_logit, jnp.float32)}

    def gather(self, values, batch):
        """Index per-rank ``values`` (the logit table or any array derived
        from it row-for-row) by the batch's 1-based positions. The single
        home of this parameterization's index math — vectorized model paths
        that transform the table before gathering must use it too."""
        pos = batch[self.use_feature] - 1  # 1-based -> 0-based
        return jnp.take(values, jnp.clip(pos, 0, self.positions - 1), axis=0)

    def __call__(self, params, batch):
        return self.gather(params["table"], batch)


class UBMExaminationParameter(Module):
    """θ_{k,k'} table: examination at rank k given last click at rank k'.

    k' == 0 encodes "no previous click". Table shape (K, K): entry
    [k-1, k'] for k in 1..K, k' in 0..K-1 (k' < k always).
    """

    def __init__(self, positions: int, init_logit: float = 0.0):
        self.positions = positions
        self.init_logit = init_logit

    def init(self, rng):
        del rng
        return {"table": jnp.full((self.positions, self.positions), self.init_logit,
                                  jnp.float32)}

    def logit(self, params, k, k_prime):
        """k: 1-based rank array; k_prime: 0-based last-click rank (0=none)."""
        k_idx = jnp.clip(k - 1, 0, self.positions - 1)
        kp_idx = jnp.clip(k_prime, 0, self.positions - 1)
        return params["table"][k_idx, kp_idx]

    def __call__(self, params, batch):  # pragma: no cover - UBM calls .logit
        raise NotImplementedError("UBMExaminationParameter is indexed via .logit")


class ScalarParameter(Module):
    """Single shared logit, broadcast to the batch shape."""

    def __init__(self, config: ScalarParameterConfig = None, name: str = "scalar"):
        self.config = config or ScalarParameterConfig()
        self.name = name

    def init(self, rng):
        import math

        p = min(max(self.config.init_prob, 1e-6), 1 - 1e-6)
        v = math.log(p) - math.log1p(-p)
        return {"value": jnp.full((), v, jnp.float32)}

    def __call__(self, params, batch):
        ref = batch["positions"]
        return jnp.broadcast_to(params["value"], ref.shape)


class FeatureParameter(Module):
    """Feature-vector tower: Linear / MLP / DeepCrossV2 -> logit per item."""

    def __init__(self, config):
        self.config = config
        if isinstance(config, LinearParameterConfig):
            self.net = Dense(config.features, config.out_features)
        elif isinstance(config, MLPParameterConfig):
            self.net = MLP(config.features, list(config.hidden), config.out_features)
        elif isinstance(config, DeepCrossParameterConfig):
            self.net = DeepCrossV2(config.features, config.cross_layers,
                                   config.deep_layers,
                                   out_features=config.out_features,
                                   combination=config.combination.value)
        else:
            raise ValueError(f"unsupported feature config {config}")

    def init(self, rng):
        return self.net.init(rng)

    def __call__(self, params, batch):
        feats = batch[self.config.use_feature]
        logits = self.net(params, feats)
        if self.config.out_features == 1:
            logits = jnp.squeeze(logits, axis=-1)
        return logits


def build_parameter(config, positions: Optional[int] = None):
    """Factory: config dataclass -> parameter module."""
    if isinstance(config, EmbeddingParameterConfig):
        return EmbeddingParameter(config)
    if isinstance(config, ScalarParameterConfig):
        return ScalarParameter(config)
    if isinstance(config, (LinearParameterConfig, MLPParameterConfig,
                           DeepCrossParameterConfig)):
        return FeatureParameter(config)
    if isinstance(config, Module):
        return config
    raise ValueError(f"cannot build parameter from {config!r}")
