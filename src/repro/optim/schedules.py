"""Learning-rate schedules (step-count -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(value: float):
    def schedule(count):
        return jnp.asarray(value, jnp.float32)

    return schedule


def linear_decay(init_value: float, end_value: float, decay_steps: int):
    def schedule(count):
        frac = jnp.clip(count.astype(jnp.float32) / decay_steps, 0.0, 1.0)
        return init_value + (end_value - init_value) * frac

    return schedule


def cosine_decay(init_value: float, decay_steps: int, alpha: float = 0.0):
    def schedule(count):
        frac = jnp.clip(count.astype(jnp.float32) / decay_steps, 0.0, 1.0)
        cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return init_value * ((1 - alpha) * cosine + alpha)

    return schedule


def warmup_cosine(peak_value: float, warmup_steps: int, decay_steps: int,
                  end_value: float = 0.0):
    def schedule(count):
        count = count.astype(jnp.float32)
        warm = peak_value * count / jnp.maximum(warmup_steps, 1)
        frac = jnp.clip((count - warmup_steps) / jnp.maximum(decay_steps - warmup_steps, 1), 0.0, 1.0)
        cosine = end_value + (peak_value - end_value) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(count < warmup_steps, warm, cosine)

    return schedule
