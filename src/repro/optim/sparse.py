"""Sparse-row (lazy) AdamW for huge embedding tables — beyond-paper opt.

The paper notes ("Our implementation currently lacks support for sparse
embeddings") that dense optimizers touch the ENTIRE table every step even
though only the batch's rows have non-zero gradient. At 2^31-scale tables the
dense AdamW read-modify-write dominates the memory roofline term.

This module implements the production fix (torch SparseAdam / DLRM-style):
the train step computes gradients **with respect to the gathered rows** (a
(B*K, d) tensor), and the optimizer scatter-updates only those rows of the
parameter/moment tables:

    emb = take(table, ids)               # forward gather (unchanged)
    d_emb = grad wrt emb                 # (N_lookups, d), NOT (R, d)
    rows = segment_sum(d_emb, ids)       # dedupe duplicate ids in the batch
    m[ids], v[ids], table[ids] updated via .at[rows]

**Lazy-Adam semantics** (standard for sparse training, torch SparseAdam):

* A row is *touched* on a step iff it appears in that step's batch (for
  click models: in ``EmbeddingParameter.row_ids(batch)``, including rows
  reached only through masked padding items — exactly the rows whose dense
  gradient can be non-zero).
* Touched rows update exactly like dense AdamW with the same
  hyperparameters: on a table whose every row is touched every step, lazy
  and dense AdamW produce bit-identical params and moments
  (tests/test_engine.py pins this).
* Untouched rows are left **entirely** alone: their moments do not decay,
  they receive no weight decay, and they do not catch up on missed bias
  correction when next touched (the correction uses the global step count,
  not a per-row count).

Fixed-size dedupe pads the unique-row buffer with an **out-of-range
sentinel** (``n_rows``): scatter updates at out-of-bounds indices are
dropped (``mode="drop"``), so padding slots are true no-ops — they cannot
alias row 0 and decay its moments (the old ``fill_value=0`` convention did
exactly that whenever row 0 sat out a batch).

HBM traffic of the optimizer state update drops from 3×O(R·d) dense
read-modify-writes (params, mu, nu) per step to O(unique_batch_rows·d).
Two integration points: :func:`make_sparse_embedding_train_step` (fully
lazy — differentiates w.r.t. the gathered rows, never materializes an
(R, d) gradient) and ``TrainEngine(sparse_tables=True)`` (takes the rows
of the autodiff table gradient, so the scatter-shaped gradient still
materializes but the optimizer state update is O(U·d)).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class SparseTableState(NamedTuple):
    count: jax.Array  # global step (for bias correction)
    mu: jax.Array     # (R, d) first moment
    nu: jax.Array     # (R, d) second moment


def init_sparse_table_state(table: jax.Array,
                            moment_dtype=jnp.float32) -> SparseTableState:
    return SparseTableState(
        count=jnp.zeros((), jnp.int32),
        mu=jnp.zeros_like(table, dtype=moment_dtype),
        nu=jnp.zeros_like(table, dtype=moment_dtype),
    )


def unique_rows_with_sentinel(ids: jax.Array, n_rows: int, *,
                              return_inverse: bool = False,
                              max_unique: int | None = None):
    """Fixed-size dedupe of a row-id stream, padded with the out-of-range
    sentinel ``n_rows``.

    The single home of the sentinel convention: every producer of a row
    buffer for :func:`sparse_adamw_update` must pad with exactly ``n_rows``
    (an index the ``mode="drop"`` scatters discard) — any in-range fill
    value would alias a real row and decay its moments.
    """
    flat = ids.reshape(-1)
    return jnp.unique(flat, return_inverse=return_inverse,
                      size=max_unique or flat.shape[0], fill_value=n_rows)


def sparse_row_grads(row_grads: jax.Array, ids: jax.Array, n_rows: int,
                     max_unique: int | None = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """Dedupe (N, d) per-lookup grads into (U, d) per-unique-row grads.

    Returns (unique_ids (U,), grads (U, d)) with U = min(N, max_unique or N);
    surplus slots hold the out-of-range sentinel ``n_rows`` (zero gradient),
    which :func:`sparse_adamw_update` scatters with ``mode="drop"`` — a true
    no-op that touches no real row.
    """
    flat_ids = ids.reshape(-1)
    g = row_grads.reshape(flat_ids.shape[0], -1)
    unique_ids, inv = unique_rows_with_sentinel(
        flat_ids, n_rows, return_inverse=True, max_unique=max_unique)
    grads = jax.ops.segment_sum(g, inv.reshape(-1),
                                num_segments=unique_ids.shape[0])
    return unique_ids, grads


def sparse_adamw_update(table: jax.Array, state: SparseTableState,
                        unique_ids: jax.Array, grads: jax.Array, *,
                        lr: float, b1: float = 0.9, b2: float = 0.999,
                        eps: float = 1e-8, weight_decay: float = 0.0
                        ) -> Tuple[jax.Array, SparseTableState]:
    """Scatter-update only the touched rows of (table, mu, nu).

    ``unique_ids`` may contain out-of-range sentinel entries (padding from a
    fixed-size dedupe): their gathers clamp to the last row (the computed
    garbage is discarded) and their scatters are dropped, so sentinel slots
    modify nothing.
    """
    count = state.count + 1
    g32 = grads.astype(jnp.float32)
    rows = unique_ids
    mu_rows = state.mu.at[rows].get(mode="clip").astype(jnp.float32)
    nu_rows = state.nu.at[rows].get(mode="clip").astype(jnp.float32)
    mu_new = b1 * mu_rows + (1 - b1) * g32
    nu_new = b2 * nu_rows + (1 - b2) * jnp.square(g32)
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)
    update = (mu_new / c1) / (jnp.sqrt(nu_new / c2) + eps)
    p_rows = table.at[rows].get(mode="clip").astype(jnp.float32)
    if weight_decay:
        update = update + weight_decay * p_rows
    new_rows = (p_rows - lr * update).astype(table.dtype)
    return (
        table.at[rows].set(new_rows, mode="drop"),
        SparseTableState(
            count=count,
            mu=state.mu.at[rows].set(mu_new.astype(state.mu.dtype), mode="drop"),
            nu=state.nu.at[rows].set(nu_new.astype(state.nu.dtype), mode="drop"),
        ),
    )


def make_sparse_embedding_train_step(forward_from_rows, gather_rows, *,
                                     lr: float, n_rows: int,
                                     weight_decay: float = 0.0,
                                     dense_optimizer=None):
    """Build a train step that is sparse in the table and dense elsewhere.

    * ``gather_rows(table, batch) -> (rows, ids)`` — the forward gather,
      returning the gathered row values and their ids.
    * ``forward_from_rows(dense_params, rows, batch) -> loss`` — the rest of
      the model, treating the gathered rows as an input.
    * ``dense_optimizer`` — repro.optim transformation for the dense params.
    """
    from repro import optim as optim_lib

    def init(table, dense_params):
        dense_opt = (dense_optimizer.init(dense_params)
                     if dense_optimizer else None)
        return init_sparse_table_state(table), dense_opt

    def step(table, sparse_state, dense_params, dense_opt, batch):
        rows, ids = gather_rows(table, batch)

        def loss_fn(rows_in, dense_in):
            return forward_from_rows(dense_in, rows_in, batch)

        loss, (d_rows, d_dense) = jax.value_and_grad(
            loss_fn, argnums=(0, 1))(rows, dense_params)
        uids, ugrads = sparse_row_grads(d_rows, ids, n_rows)
        table, sparse_state = sparse_adamw_update(
            table, sparse_state, uids, ugrads, lr=lr,
            weight_decay=weight_decay)
        if dense_optimizer is not None:
            updates, dense_opt = dense_optimizer.update(
                d_dense, dense_opt, dense_params)
            dense_params = optim_lib.apply_updates(dense_params, updates)
        return table, sparse_state, dense_params, dense_opt, loss

    return init, step
