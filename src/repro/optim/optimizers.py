"""Gradient transformations in the optax style: (init_fn, update_fn) pairs.

update_fn(grads, state, params) -> (updates, new_state); parameters are then
``params + updates`` via :func:`apply_updates`. All states are pytrees, so the
whole optimizer composes with jit/pjit and checkpointing.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


class GradientTransformation(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Optional[Any]], Tuple[Any, Any]]


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(params, updates):
    return _tree_map(lambda p, u: (p + u.astype(p.dtype)) if p is not None else None,
                     params, updates)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


def scale(factor: float) -> GradientTransformation:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        return _tree_map(lambda g: g * factor, grads), state

    return GradientTransformation(init, update)


def scale_by_schedule(schedule: Callable[[jax.Array], jax.Array]) -> GradientTransformation:
    def init(params):
        del params
        return jnp.zeros((), jnp.int32)

    def update(grads, count, params=None):
        del params
        factor = schedule(count)
        return _tree_map(lambda g: g * factor, grads), count + 1

    return GradientTransformation(init, update)


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        norm = global_norm(grads)
        factor = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        return _tree_map(lambda g: g * factor, grads), state

    return GradientTransformation(init, update)


class ScaleByAdamState(NamedTuple):
    count: jax.Array
    mu: Any
    nu: Any


def scale_by_adam(b1=0.9, b2=0.999, eps=1e-8, moment_dtype=jnp.float32
                  ) -> GradientTransformation:
    def init(params):
        mu = _tree_map(lambda p: jnp.zeros_like(p, dtype=moment_dtype), params)
        nu = _tree_map(lambda p: jnp.zeros_like(p, dtype=moment_dtype), params)
        return ScaleByAdamState(jnp.zeros((), jnp.int32), mu, nu)

    def update(grads, state, params=None):
        del params
        count = state.count + 1
        mu = _tree_map(lambda m, g: (b1 * m.astype(jnp.float32)
                                     + (1 - b1) * g.astype(jnp.float32)
                                     ).astype(moment_dtype), state.mu, grads)
        nu = _tree_map(lambda v, g: (b2 * v.astype(jnp.float32)
                                     + (1 - b2) * jnp.square(g.astype(jnp.float32))
                                     ).astype(moment_dtype), state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        updates = _tree_map(lambda m, v: (m.astype(jnp.float32) / c1)
                            / (jnp.sqrt(v.astype(jnp.float32) / c2) + eps), mu, nu)
        return updates, ScaleByAdamState(count, mu, nu)

    return GradientTransformation(init, update)


def add_decayed_weights(weight_decay: float) -> GradientTransformation:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        if weight_decay == 0.0 or params is None:
            return grads, state
        return _tree_map(lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params), state

    return GradientTransformation(init, update)


def adam(learning_rate, b1=0.9, b2=0.999, eps=1e-8,
         moment_dtype=jnp.float32, inject_lr: bool = False
         ) -> GradientTransformation:
    return chain(scale_by_adam(b1, b2, eps, moment_dtype),
                 _scale_by_lr(learning_rate, inject=inject_lr))


def adamw(learning_rate, b1=0.9, b2=0.999, eps=1e-8, weight_decay=1e-4,
          moment_dtype=jnp.float32, inject_lr: bool = False
          ) -> GradientTransformation:
    """AdamW (decoupled weight decay) — the paper's default optimizer.

    ``moment_dtype=bf16`` halves optimizer-state memory for 400B-class runs
    (updates still computed in fp32). ``inject_lr=True`` stores the lr in
    the optimizer state (see :class:`InjectLRState`) so vmapped replica
    sweeps can run one lr per replica."""
    return chain(scale_by_adam(b1, b2, eps, moment_dtype),
                 add_decayed_weights(weight_decay),
                 _scale_by_lr(learning_rate, inject=inject_lr))


class ScaleByAdagradState(NamedTuple):
    accum: Any


def adagrad(learning_rate, eps=1e-10, initial_accumulator=0.1) -> GradientTransformation:
    def init(params):
        return ScaleByAdagradState(
            _tree_map(lambda p: jnp.full_like(p, initial_accumulator, dtype=jnp.float32), params))

    def update(grads, state, params=None):
        del params
        accum = _tree_map(lambda a, g: a + jnp.square(g.astype(jnp.float32)), state.accum, grads)
        updates = _tree_map(lambda g, a: g.astype(jnp.float32) / (jnp.sqrt(a) + eps), grads, accum)
        inner = ScaleByAdagradState(accum)
        return updates, inner

    return chain(GradientTransformation(init, update), _scale_by_lr(learning_rate))


class TraceState(NamedTuple):
    trace: Any


def sgd(learning_rate, momentum: float = 0.0, nesterov: bool = False) -> GradientTransformation:
    if momentum == 0.0:
        return _scale_by_lr(learning_rate)

    def init(params):
        return TraceState(_tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params))

    def update(grads, state, params=None):
        del params
        trace = _tree_map(lambda t, g: momentum * t + g.astype(jnp.float32), state.trace, grads)
        if nesterov:
            updates = _tree_map(lambda t, g: momentum * t + g.astype(jnp.float32), trace, grads)
        else:
            updates = trace
        return updates, TraceState(trace)

    return chain(GradientTransformation(init, update), _scale_by_lr(learning_rate))


class InjectLRState(NamedTuple):
    """Learning rate carried as optimizer *state* instead of a baked-in
    constant — the injected-hyperparam pattern (optax.inject_hyperparams).

    Because ``lr`` is a traced leaf, ``jax.vmap`` over a stacked state gives
    every replica of a sweep its own learning rate inside one compiled
    update, and :func:`set_injected_lr` can retune a run without retracing.
    """
    lr: jax.Array


def inject_lr(learning_rate: float) -> GradientTransformation:
    """Like ``scale(-learning_rate)`` but with the lr as a state leaf."""
    if callable(learning_rate):
        raise ValueError("inject_lr takes a constant, not a schedule — "
                         "compose scale_by_schedule for scheduled lrs")

    def init(params):
        del params
        return InjectLRState(lr=jnp.asarray(learning_rate, jnp.float32))

    def update(grads, state, params=None):
        del params
        return _tree_map(lambda g: g * (-state.lr), grads), state

    return GradientTransformation(init, update)


def _is_inject_state(node) -> bool:
    return isinstance(node, InjectLRState)


def set_injected_lr(opt_state, lr):
    """Replace the lr of every :class:`InjectLRState` leaf in ``opt_state``.

    ``lr`` may be a scalar or an array (e.g. an ``(R,)`` vector over the
    stacked replica axis of a vmapped sweep state). Raises if the optimizer
    was not built with ``inject_lr=True`` — silently returning the input
    would quietly train every replica at the constructor lr.
    """
    found = []

    def visit(node):
        if _is_inject_state(node):
            found.append(node)
            return InjectLRState(lr=jnp.asarray(lr, jnp.float32))
        return node

    out = jax.tree_util.tree_map(visit, opt_state, is_leaf=_is_inject_state)
    if not found:
        raise ValueError(
            "optimizer state has no InjectLRState — build the optimizer "
            "with inject_lr=True (e.g. optim.adamw(lr, inject_lr=True)) "
            "to set per-run learning rates")
    return out


def get_injected_lr(opt_state):
    """The lr array of the first InjectLRState leaf, or None."""
    for node in jax.tree_util.tree_leaves(opt_state, is_leaf=_is_inject_state):
        if _is_inject_state(node):
            return node.lr
    return None


def _scale_by_lr(learning_rate, inject: bool = False) -> GradientTransformation:
    if inject:
        return inject_lr(learning_rate)
    if callable(learning_rate):
        return scale_by_schedule(lambda count: -learning_rate(count))
    return scale(-learning_rate)


class AccumulatorState(NamedTuple):
    step: jax.Array
    acc: Any
    inner: Any


def accumulate_gradients(inner: GradientTransformation, every: int) -> GradientTransformation:
    """Gradient accumulation: apply ``inner`` once per ``every`` microbatches."""
    def init(params):
        acc = _tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AccumulatorState(jnp.zeros((), jnp.int32), acc, inner.init(params))

    def update(grads, state, params=None):
        acc = _tree_map(lambda a, g: a + g.astype(jnp.float32), state.acc, grads)
        step = state.step + 1
        is_update = (step % every) == 0

        def do_update(_):
            mean_grads = _tree_map(lambda a: a / every, acc)
            updates, inner_state = inner.update(mean_grads, state.inner, params)
            zero = _tree_map(jnp.zeros_like, acc)
            return updates, inner_state, zero

        def skip(_):
            zero_updates = _tree_map(jnp.zeros_like, acc)
            return zero_updates, state.inner, acc

        updates, inner_state, acc_out = jax.lax.cond(is_update, do_update, skip, None)
        return updates, AccumulatorState(step, acc_out, inner_state)

    return GradientTransformation(init, update)
