"""Optax-equivalent optimizer subset (optax unavailable offline)."""
from repro.optim.optimizers import (
    GradientTransformation,
    adam,
    adamw,
    adagrad,
    sgd,
    chain,
    clip_by_global_norm,
    scale,
    scale_by_schedule,
    apply_updates,
    global_norm,
    accumulate_gradients,
)
from repro.optim.schedules import constant_schedule, cosine_decay, warmup_cosine, linear_decay

__all__ = [
    "GradientTransformation",
    "adam",
    "adamw",
    "adagrad",
    "sgd",
    "chain",
    "clip_by_global_norm",
    "scale",
    "scale_by_schedule",
    "apply_updates",
    "global_norm",
    "accumulate_gradients",
    "constant_schedule",
    "cosine_decay",
    "warmup_cosine",
    "linear_decay",
]
