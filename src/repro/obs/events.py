"""Structured telemetry events: the one record type every sink speaks.

An event is a flat JSON-able dict. Required fields:

* ``kind`` — one of :data:`EVENT_KINDS`:
    - ``"metric"``   — one numeric sample (``value``) of a named series,
      e.g. per-step loss/grad-norm drained from the engine;
    - ``"span"``     — a completed wall-time span (``value`` = seconds);
    - ``"event"``    — a discrete occurrence (quarantine, watchdog restart,
      watchdog violation, profiler window open/close);
    - ``"counters"`` — a snapshot of monotonically accumulated counters and
      last-value gauges (``data``);
    - ``"process"``  — host/device process stats (RSS, device memory);
    - ``"roofline"`` — static HLO cost of a compiled program (``data``);
    - ``"epoch"``    — one trainer epoch record (``data`` mirrors history).
* ``name`` — the series/span/occurrence name (``"train_step"``,
  ``"shard_read"``, ...).
* ``t`` — host wall-clock seconds (``time.time()``).

Optional, uniform across kinds so downstream tooling can group/filter:
``value`` (float), ``step``/``epoch``/``replica`` (ints — the engine's
global step, the trainer epoch, the sweep replica index), ``data`` (a
JSON-able dict payload), plus free-form scalar ``tags``.

``validate_event`` is the schema contract: tests and the CI obs-smoke job
run every JSONL line through it.
"""
from __future__ import annotations

import numbers
import time
from typing import Any, Dict, Optional

EVENT_KINDS = ("metric", "span", "event", "counters", "process", "roofline",
               "epoch")

_INT_FIELDS = ("step", "epoch", "replica")


def make_event(kind: str, name: str, value: Optional[float] = None, *,
               step: Optional[int] = None, epoch: Optional[int] = None,
               replica: Optional[int] = None,
               data: Optional[Dict[str, Any]] = None,
               t: Optional[float] = None, **tags) -> Dict[str, Any]:
    """Build a schema-valid event dict (unset optional fields are omitted)."""
    e: Dict[str, Any] = {"kind": kind, "name": name,
                         "t": time.time() if t is None else float(t)}
    if value is not None:
        e["value"] = float(value)
    for field, v in (("step", step), ("epoch", epoch), ("replica", replica)):
        if v is not None:
            e[field] = int(v)
    if data is not None:
        e["data"] = data
    if tags:
        e["tags"] = {k: _scalarize(v) for k, v in tags.items()}
    return e


def _scalarize(v):
    """Coerce numpy scalars etc. into JSON-able python scalars."""
    if isinstance(v, (str, bool)) or v is None:
        return v
    if isinstance(v, numbers.Integral):
        return int(v)
    if isinstance(v, numbers.Real):
        return float(v)
    return str(v)


def validate_event(e: Dict[str, Any]) -> Dict[str, Any]:
    """Raise ``ValueError`` unless ``e`` is a schema-valid event; returns it."""
    if not isinstance(e, dict):
        raise ValueError(f"event must be a dict, got {type(e).__name__}")
    for field in ("kind", "name", "t"):
        if field not in e:
            raise ValueError(f"event missing required field {field!r}: {e}")
    if e["kind"] not in EVENT_KINDS:
        raise ValueError(f"unknown event kind {e['kind']!r} "
                         f"(expected one of {EVENT_KINDS})")
    if not isinstance(e["name"], str) or not e["name"]:
        raise ValueError(f"event name must be a non-empty string: {e}")
    if not isinstance(e["t"], numbers.Real):
        raise ValueError(f"event t must be a number: {e}")
    if "value" in e and not isinstance(e["value"], numbers.Real):
        raise ValueError(f"event value must be a number: {e}")
    for field in _INT_FIELDS:
        if field in e and not isinstance(e[field], numbers.Integral):
            raise ValueError(f"event {field} must be an int: {e}")
    if "data" in e and not isinstance(e["data"], dict):
        raise ValueError(f"event data must be a dict: {e}")
    if "tags" in e and not isinstance(e["tags"], dict):
        raise ValueError(f"event tags must be a dict: {e}")
    return e
