"""``repro.obs`` — zero-sync observability: on-device metrics drained with
the loss stream, host wall-time spans with Chrome-trace export, pluggable
event sinks, counters/gauges, process stats, and programmatic profiler
windows. See README "Observability"."""
from repro.obs.events import EVENT_KINDS, make_event, validate_event
from repro.obs.profiler import ProfileWindow, parse_profile_steps
from repro.obs.recorder import (Recorder, configure, get_recorder,
                                set_recorder, span)
from repro.obs.sinks import (ConsoleReporter, JsonlSink, MemorySink,
                             MetricsSink, read_jsonl)
from repro.obs.spans import Span, SpanTracer
from repro.obs.telemetry import TelemetryDrain

__all__ = [
    "EVENT_KINDS",
    "make_event",
    "validate_event",
    "Recorder",
    "configure",
    "get_recorder",
    "set_recorder",
    "span",
    "MetricsSink",
    "MemorySink",
    "JsonlSink",
    "ConsoleReporter",
    "read_jsonl",
    "Span",
    "SpanTracer",
    "TelemetryDrain",
    "ProfileWindow",
    "parse_profile_steps",
]
