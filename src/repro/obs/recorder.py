"""The Recorder: one object tying sinks, spans, counters, and gauges together.

Design rules (the "zero-sync" contract):

* A recorder with no sinks is **disabled**: ``emit`` is a no-op, spans only
  touch the host ring buffer, counters are plain float adds. Nothing in the
  default configuration can slow a hot path by more than a dict lookup.
* Recorders only ever see host values. Device telemetry is drained by the
  training loop on its own schedule (once per chunk, one ``device_get`` —
  see :class:`repro.obs.telemetry.TelemetryDrain`); the recorder is handed
  numpy, never a live ``jax.Array``.
* Everything is thread-safe: the streaming loader's read-ahead producer
  emits from its own thread.

A process-global default recorder (``get_recorder()``/``configure(...)``)
lets deep layers (the streaming loader, the watchdog) emit without
plumbing a recorder argument through every constructor; tests inject their
own recorder + :class:`~repro.obs.sinks.MemorySink` instead.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Dict, Iterable, Optional

from repro.obs.events import make_event
from repro.obs.sinks import MetricsSink
from repro.obs.spans import SpanTracer


class Recorder:
    def __init__(self, sinks: Iterable[MetricsSink] = (),
                 span_capacity: int = 8192):
        self.sinks = list(sinks)
        self.tracer = SpanTracer(capacity=span_capacity)
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self._lock = threading.Lock()

    # -- emission ----------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return bool(self.sinks)

    def emit(self, event: Dict[str, Any]) -> None:
        for sink in self.sinks:
            sink.emit(event)

    def metric(self, name: str, value, **fields) -> None:
        if self.sinks:
            self.emit(make_event("metric", name, value, **fields))

    def event(self, name: str, value=None, **fields) -> None:
        if self.sinks:
            self.emit(make_event("event", name, value, **fields))

    # -- spans -------------------------------------------------------------
    def span(self, name: str, **tags):
        """Wall-time a block (see :class:`SpanTracer`). Always recorded in
        the ring buffer; forwarded to sinks as a ``span`` event (value =
        seconds) when any are attached."""
        on_close = self._span_to_sinks if self.sinks else None
        return self.tracer.span(name, on_close=on_close, **tags)

    def _span_to_sinks(self, s):
        self.emit(make_event("span", s.name, s.duration, t=s.t_start,
                             **s.tags))

    def export_chrome_trace(self, path: str) -> int:
        return self.tracer.export_chrome_trace(path)

    # -- counters / gauges ---------------------------------------------------
    def add(self, counter: str, amount=1) -> None:
        """Accumulate a monotone counter (bytes read, retries, ...)."""
        with self._lock:
            self.counters[counter] = self.counters.get(counter, 0) + amount

    def gauge(self, name: str, value) -> None:
        """Record the last observed value (queue depth, ...)."""
        with self._lock:
            self.gauges[name] = value

    def counters_snapshot(self) -> Dict[str, float]:
        with self._lock:
            out = dict(self.counters)
            out.update({f"{k}:gauge": v for k, v in self.gauges.items()})
        return out

    def flush_counters(self, name: str = "counters", **fields) -> None:
        """Emit one ``counters`` event with the current snapshot."""
        if self.sinks:
            snap = self.counters_snapshot()
            if snap:
                self.emit(make_event("counters", name, data=snap, **fields))

    # -- process stats -------------------------------------------------------
    def process_stats(self, name: str = "process", emit: bool = True,
                      **fields) -> Dict[str, Any]:
        """Host RSS + device-0 memory stats (where the backend reports them:
        ``jax.local_devices()[0].memory_stats()`` is ``None`` on CPU)."""
        stats: Dict[str, Any] = {"rss_bytes": _rss_bytes()}
        try:
            import jax

            dev = jax.local_devices()[0]
            mem = dev.memory_stats()
            if mem:
                for key in ("bytes_in_use", "peak_bytes_in_use",
                            "bytes_limit"):
                    if key in mem:
                        stats[f"device_{key}"] = int(mem[key])
        except Exception:  # no backend / no stats — host stats still count
            pass
        if emit and self.sinks:
            self.emit(make_event("process", name, data=stats, **fields))
        return stats

    def close(self) -> None:
        for sink in self.sinks:
            sink.close()


def _rss_bytes() -> int:
    """Resident set size; /proc on Linux, ru_maxrss (peak) as the fallback."""
    try:
        with open("/proc/self/statm") as f:
            return int(f.read().split()[1]) * os.sysconf("SC_PAGE_SIZE")
    except (OSError, IndexError, ValueError):
        import resource

        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024


# -- the process-global default recorder -------------------------------------
_global_recorder = Recorder()


def get_recorder() -> Recorder:
    return _global_recorder


def set_recorder(recorder: Recorder) -> Recorder:
    global _global_recorder
    _global_recorder = recorder
    return recorder


def configure(sinks: Iterable[MetricsSink] = (),
              span_capacity: int = 8192) -> Recorder:
    """Replace the global recorder (e.g. from a CLI's ``--metrics-out``)."""
    return set_recorder(Recorder(sinks=sinks, span_capacity=span_capacity))


def span(name: str, **tags):
    """Module-level convenience: a span on the current global recorder."""
    return get_recorder().span(name, **tags)
