"""Host-side consumer of the engine's per-chunk device telemetry.

:class:`TelemetryDrain` is the **single source of truth** for everything the
trainer used to double-bookkeep by hand: the per-epoch ``train_loss`` sum,
``n_batches``, and ``skipped_steps`` are accumulated here, from exactly one
``jax.device_get`` per chunk (the same drain the loss history always
needed — telemetry keys ride along in the same transfer, which is the
"zero extra host syncs per step" guarantee made concrete), and the same
drained numpy feeds per-step metric events to the recorder's sinks.

Accumulation semantics are bit-compatible with the historical trainer loop:

* scalar runs accumulate per-element ``float(loss)`` additions into a
  python float (a vectorized f32 sum would round differently), which also
  round-trips JSON exactly for crash-exact resume;
* sweep runs accumulate an ``(R,)`` float64 vector, with skipped steps
  contributing zero loss and one skip count.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from repro.obs.recorder import Recorder, get_recorder

#: telemetry payload keys that are not per-step metric series
_STRUCTURAL_KEYS = ("loss", "skipped")


class TelemetryDrain:
    """Accumulate one epoch's drained chunk payloads; emit per-step events.

    ``payload`` is whatever ``TrainEngine.step`` returned: an ``(n,)`` (or
    ``(n, R)``) loss array, or a dict of same-shaped arrays (``loss``,
    optional ``skipped`` bool mask, optional telemetry series such as
    ``grad_norm``/``param_norm``/``lr``). ``drain`` performs the chunk's
    single host transfer and never blocks anywhere else.
    """

    def __init__(self, replicas: Optional[int] = None,
                 recorder: Optional[Recorder] = None, every: int = 1,
                 epoch: Optional[int] = None):
        self.R = replicas
        self.recorder = recorder
        self.every = max(int(every), 1)
        self.epoch = epoch
        self.n_batches = 0
        if replicas is None:
            self.train_loss: Any = 0.0
            self.skipped_steps: Any = 0
        else:
            self.train_loss = np.zeros(replicas, np.float64)
            self.skipped_steps = np.zeros(replicas, np.int64)

    def _rec(self) -> Recorder:
        return self.recorder if self.recorder is not None else get_recorder()

    # -- resume ------------------------------------------------------------
    def load(self, accum: Dict[str, Any]) -> None:
        """Restore mid-epoch accumulators from checkpoint aux (the
        ``epoch_accum`` dict written by :meth:`aux`)."""
        self.n_batches = int(accum["n_batches"])
        if self.R is None:
            self.train_loss = float(accum["train_loss"])
            self.skipped_steps = int(accum.get("skipped", 0))
        else:
            self.train_loss = np.asarray(accum["train_loss"], np.float64)
            self.skipped_steps = np.asarray(
                accum.get("skipped", [0] * self.R), np.int64)

    def aux(self) -> Dict[str, Any]:
        """JSON-able mid-epoch accumulators for checkpoint aux. Python
        floats round-trip json exactly (repr-based), so a resumed epoch's
        loss sum stays bit-identical to an uninterrupted run's."""
        if self.R is None:
            return {"train_loss": self.train_loss,
                    "n_batches": int(self.n_batches),
                    "skipped": int(self.skipped_steps)}
        return {"train_loss": np.asarray(self.train_loss,
                                         np.float64).tolist(),
                "n_batches": int(self.n_batches),
                "skipped": np.asarray(self.skipped_steps).tolist()}

    # -- the drain ---------------------------------------------------------
    def drain(self, payload, first_step: Optional[int] = None) -> None:
        """Fetch one chunk's telemetry (ONE ``jax.device_get`` for every
        leaf at once) and fold it into the epoch accumulators + sinks.
        ``first_step`` is the global index of the chunk's first step, used
        only to tag emitted events."""
        # Deferred so importing repro.obs (and through it repro.data — the
        # parallel-ingest worker processes) stays jax-free; only the one
        # method that touches device memory pays the jax import.
        import jax
        data = jax.device_get(payload)
        if isinstance(data, dict):
            losses = np.asarray(data["loss"])
            skipped = (np.asarray(data["skipped"])
                       if "skipped" in data else None)
            extras = {k: np.asarray(v) for k, v in data.items()
                      if k not in _STRUCTURAL_KEYS}
        else:
            losses, skipped, extras = np.asarray(data), None, {}
        n = losses.shape[0]
        if self.R is None:
            # Per-element accumulation into the python float keeps the sum
            # bit-identical to the historical one-float(loss)-per-step loop.
            if skipped is None:
                for loss in losses:
                    self.train_loss += float(loss)
            else:
                for loss, skip in zip(losses, skipped):
                    if skip:
                        self.skipped_steps += 1
                    else:
                        self.train_loss += float(loss)
        else:
            arr = np.asarray(losses, np.float64)
            if skipped is None:
                self.train_loss += arr.sum(axis=0)
            else:
                self.train_loss += np.where(skipped, 0.0, arr).sum(axis=0)
                self.skipped_steps += skipped.sum(axis=0)
        start = self.n_batches if first_step is None else first_step
        self.n_batches += n
        rec = self._rec()
        if rec.enabled:
            self._emit(rec, losses, skipped, extras, start)

    def _emit(self, rec, losses, skipped, extras, start) -> None:
        for i in range(losses.shape[0]):
            step = start + i
            if self.R is None:
                if step % self.every == 0:
                    rec.metric("train_step", losses[i], step=step,
                               epoch=self.epoch,
                               data=self._extras_at(extras, i, None))
                if skipped is not None and skipped[i]:
                    rec.event("skipped_step", step=step, epoch=self.epoch)
            else:
                for r in range(self.R):
                    if step % self.every == 0:
                        rec.metric("train_step", losses[i, r], step=step,
                                   epoch=self.epoch, replica=r,
                                   data=self._extras_at(extras, i, r))
                    if skipped is not None and skipped[i, r]:
                        rec.event("skipped_step", step=step,
                                  epoch=self.epoch, replica=r)

    @staticmethod
    def _extras_at(extras, i, r) -> Optional[Dict[str, float]]:
        if not extras:
            return None
        if r is None:
            return {k: float(v[i]) for k, v in extras.items()}
        return {k: float(v[i, r]) for k, v in extras.items()}

    # -- derived views -----------------------------------------------------
    def mean_loss(self):
        """Epoch mean over the steps that actually updated (skipped steps
        contributed no loss; guard off means skipped is identically 0 and
        this is the historical denominator)."""
        if self.R is None:
            return self.train_loss / max(self.n_batches - self.skipped_steps,
                                         1)
        return self.train_loss / np.maximum(
            self.n_batches - self.skipped_steps, 1)
