"""Programmatic ``jax.profiler`` windows keyed on the global step counter.

``--profile-steps A:B`` opens a profiler trace just before the chunk
dispatch that contains global step A and closes it after the first chunk
boundary at or past B — profiling exactly the steady-state steps you asked
for instead of hand-timing around warmup/compile. The trace lands in
``log_dir`` in TensorBoard/Perfetto format (``jax.profiler.start_trace``).

The window piggybacks on the train loop's existing chunk boundaries: it
adds zero host syncs and zero dispatches of its own. Profiler availability
is probed lazily — when the runtime has no profiler support, the window
degrades to emitting its open/close telemetry events only (never crashes
the run).
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.obs.recorder import Recorder, get_recorder


def parse_profile_steps(spec: str) -> Tuple[int, int]:
    """``"A:B"`` -> (A, B), validated (0 <= A < B)."""
    try:
        a_txt, b_txt = spec.split(":")
        a, b = int(a_txt), int(b_txt)
    except ValueError:
        raise ValueError(
            f"--profile-steps wants 'START:STOP' (global steps), got {spec!r}")
    if a < 0 or b <= a:
        raise ValueError(
            f"--profile-steps needs 0 <= START < STOP, got {spec!r}")
    return a, b


class ProfileWindow:
    """Open a ``jax.profiler`` trace around chosen chunk dispatches.

    The trainer calls :meth:`before_chunk` with the global step the next
    chunk starts at, and :meth:`after_chunk` with the step it ended at; the
    window starts the trace at the first chunk containing ``start_step``
    and stops it at the first boundary >= ``stop_step`` (or on ``close``,
    so a profile window spanning the end of training still flushes).
    """

    def __init__(self, start_step: int, stop_step: int, log_dir: str,
                 recorder: Optional[Recorder] = None):
        if not 0 <= start_step < stop_step:
            raise ValueError(f"need 0 <= start < stop, got "
                             f"({start_step}, {stop_step})")
        self.start_step = int(start_step)
        self.stop_step = int(stop_step)
        self.log_dir = log_dir
        self.recorder = recorder
        self.active = False
        self.done = False

    def _rec(self) -> Recorder:
        return self.recorder if self.recorder is not None else get_recorder()

    def before_chunk(self, next_step: int) -> None:
        if self.done or self.active or next_step < self.start_step:
            return
        self.active = True
        try:
            import jax.profiler

            jax.profiler.start_trace(self.log_dir)
            started = True
        except Exception as e:  # no profiler in this runtime — degrade
            started = False
            self._rec().event("profile_unavailable", step=next_step,
                              error=repr(e))
        self._started = started
        self._rec().event("profile_start", step=next_step,
                          log_dir=self.log_dir)

    def after_chunk(self, reached_step: int) -> None:
        if not self.active or reached_step < self.stop_step:
            return
        self._stop(reached_step)

    def close(self, reached_step: Optional[int] = None) -> None:
        """Stop a still-open trace (training ended inside the window)."""
        if self.active:
            self._stop(self.stop_step if reached_step is None
                       else reached_step)

    def _stop(self, step: int) -> None:
        self.active = False
        self.done = True
        if getattr(self, "_started", False):
            try:
                import jax.profiler

                jax.profiler.stop_trace()
            except Exception as e:
                self._rec().event("profile_stop_failed", step=step,
                                  error=repr(e))
                return
        self._rec().event("profile_stop", step=step, log_dir=self.log_dir)
