"""Pluggable event sinks: where telemetry events land.

All sinks speak one method, ``emit(event_dict)``, and are safe to call from
multiple threads (the streaming loader's read-ahead producer emits from its
own thread). None of them ever touch a device buffer — events are built
from values the caller already drained to host, which is what keeps the
whole observability layer zero-sync by construction.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Callable, Dict, List, Optional

from repro.obs.events import validate_event


class MetricsSink:
    """Base sink: ``emit`` one structured event; ``close`` flushes/releases."""

    def emit(self, event: Dict[str, Any]) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False


class MemorySink(MetricsSink):
    """Collects events in a list — the test sink."""

    def __init__(self, validate: bool = True):
        self.events: List[Dict[str, Any]] = []
        self.validate = validate
        self._lock = threading.Lock()

    def emit(self, event):
        if self.validate:
            validate_event(event)
        with self._lock:
            self.events.append(event)

    # -- query helpers (tests) --------------------------------------------
    def by_kind(self, kind: str) -> List[Dict[str, Any]]:
        with self._lock:
            return [e for e in self.events if e["kind"] == kind]

    def by_name(self, name: str, kind: Optional[str] = None):
        with self._lock:
            return [e for e in self.events if e["name"] == name
                    and (kind is None or e["kind"] == kind)]

    def series(self, name: str, replica: Optional[int] = None) -> List[float]:
        """The ``value`` sequence of a metric series, in emission order."""
        return [e["value"] for e in self.by_name(name, kind="metric")
                if replica is None or e.get("replica") == replica]

    def __len__(self):
        return len(self.events)


class JsonlSink(MetricsSink):
    """One JSON line per event, appended to ``path``.

    Lines are flushed every ``flush_every`` events (and on ``close``), so a
    crashed run still leaves a usable stream behind — the observability
    analogue of the checkpoint story.
    """

    def __init__(self, path: str, flush_every: int = 64,
                 validate: bool = False):
        self.path = path
        self.flush_every = max(int(flush_every), 1)
        self.validate = validate
        self._f = open(path, "a", encoding="utf-8")
        self._lock = threading.Lock()
        self._since_flush = 0

    def emit(self, event):
        if self.validate:
            validate_event(event)
        line = json.dumps(event, separators=(",", ":"))
        with self._lock:
            if self._f.closed:
                return  # late emit after close (daemon reader thread)
            self._f.write(line + "\n")
            self._since_flush += 1
            if self._since_flush >= self.flush_every:
                self._f.flush()
                self._since_flush = 0

    def close(self):
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                self._f.close()


def read_jsonl(path: str, validate: bool = True) -> List[Dict[str, Any]]:
    """Load (and by default schema-check) a JSONL event stream."""
    out = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            e = json.loads(line)
            if validate:
                validate_event(e)
            out.append(e)
    return out


class ConsoleReporter(MetricsSink):
    """Human-readable periodic reporter.

    Prints every non-metric event as it happens, and one line per
    ``every`` metric samples of each series (per-step metrics at full rate
    would drown a terminal).
    """

    def __init__(self, log_fn: Callable[[str], None] = print,
                 every: int = 100):
        self.log_fn = log_fn
        self.every = max(int(every), 1)
        self._counts: Dict[str, int] = {}
        self._lock = threading.Lock()

    def emit(self, event):
        kind, name = event["kind"], event["name"]
        if kind == "metric":
            with self._lock:
                n = self._counts.get(name, 0)
                self._counts[name] = n + 1
            if n % self.every:
                return
        where = "".join(f" {k}={event[k]}" for k in ("step", "epoch",
                                                     "replica")
                        if k in event)
        value = (f" {event['value']:.6g}" if "value" in event else "")
        data = f" {event['data']}" if "data" in event else ""
        self.log_fn(f"[obs] {kind}/{name}{where}{value}{data}")
