"""Nested wall-time spans with a bounded ring buffer and Chrome-trace export.

``SpanTracer.span("epoch")`` is a context manager timing host wall-clock
only — no device syncs, no ``block_until_ready`` — so wrapping the train
loop in spans cannot serialize the dispatch pipeline it is measuring. What a
span *sees* is therefore host-side time: an epoch span covers dispatch +
drain, not device busy time (use the ``jax.profiler`` hooks in
:mod:`repro.obs.profiler` for device timelines).

Completed spans land in a ``deque(maxlen=capacity)`` ring buffer (old spans
fall off; a week-long run cannot OOM on its own telemetry) and are
exportable as Chrome-trace JSON (``chrome://tracing`` / Perfetto's
"Open trace file").
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class Span:
    name: str
    t_start: float        # wall-clock seconds (time.time epoch)
    duration: float       # seconds, from perf_counter
    thread_id: int
    tags: Dict[str, Any]


class SpanTracer:
    def __init__(self, capacity: int = 8192):
        self.capacity = int(capacity)
        self.spans: deque = deque(maxlen=self.capacity)
        self._depth = threading.local()

    @contextmanager
    def span(self, name: str, on_close=None, **tags):
        """Time a block; record a :class:`Span` on exit (even on error).

        ``on_close(span)`` lets the recorder forward the completed span to
        its sinks without this module depending on them.
        """
        depth = getattr(self._depth, "d", 0)
        self._depth.d = depth + 1
        t_wall = time.time()
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dur = time.perf_counter() - t0
            self._depth.d = depth
            s = Span(name=name, t_start=t_wall, duration=dur,
                     thread_id=threading.get_ident(), tags=dict(tags))
            self.spans.append(s)
            if on_close is not None:
                on_close(s)

    def clear(self):
        self.spans.clear()

    def chrome_trace(self) -> Dict[str, Any]:
        """The ring buffer as a Chrome-trace/Perfetto ``traceEvents`` dict.

        Complete events (``"ph": "X"``) with microsecond timestamps; the
        recording thread becomes the trace ``tid``, so loader read-ahead
        spans land on their own track next to the train loop's.
        """
        pid = os.getpid()
        events: List[Dict[str, Any]] = []
        for s in list(self.spans):
            events.append({
                "name": s.name, "ph": "X", "pid": pid, "tid": s.thread_id,
                "ts": s.t_start * 1e6, "dur": s.duration * 1e6,
                "cat": "clax", "args": s.tags,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> int:
        """Write the Chrome-trace JSON to ``path``; returns #events."""
        trace = self.chrome_trace()
        with open(path, "w", encoding="utf-8") as f:
            json.dump(trace, f)
        return len(trace["traceEvents"])
