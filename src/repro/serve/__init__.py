"""``repro.serve`` — the resilient serving engine.

A bounded admission queue with load shedding and backpressure, a
deadline-aware dynamic batcher over pre-compiled bucket shapes, a warm
multi-model registry with an int8-quantized degraded tier and a constant
CTR-prior fallback, per-model circuit breakers, fail-closed per-request
validation, and SIGTERM drain. See README "Serving".
"""
from repro.serve.batcher import BatchPlan, DeadlineBatcher
from repro.serve.breaker import (CLOSED, HALF_OPEN, OPEN, CircuitBreaker,
                                 DegradationLadder)
from repro.serve.clock import ServiceModel, VirtualClock, WallClock
from repro.serve.engine import ServeEngine
from repro.serve.queue import (ADMIT, ADMIT_BACKPRESSURE, AdmissionQueue,
                               SHED_OVERLOAD, SHED_QUEUE_FULL)
from repro.serve.registry import (DEFAULT_BUCKETS, ModelEntry, ModelRegistry,
                                  pad_batch)
from repro.serve.request import (OK, REJECTED, SHED, TIERS, ServeRequest,
                                 ServeResult, make_request, poisson_trace)
from repro.serve.validation import validate_request

__all__ = [
    "ServeEngine",
    "ServeRequest",
    "ServeResult",
    "make_request",
    "poisson_trace",
    "validate_request",
    "AdmissionQueue",
    "DeadlineBatcher",
    "BatchPlan",
    "CircuitBreaker",
    "DegradationLadder",
    "ModelRegistry",
    "ModelEntry",
    "pad_batch",
    "ServiceModel",
    "VirtualClock",
    "WallClock",
    "TIERS",
    "OK",
    "REJECTED",
    "SHED",
    "ADMIT",
    "ADMIT_BACKPRESSURE",
    "SHED_OVERLOAD",
    "SHED_QUEUE_FULL",
    "CLOSED",
    "OPEN",
    "HALF_OPEN",
    "DEFAULT_BUCKETS",
]
