"""Request/response records for the serving engine.

A :class:`ServeRequest` is one session to score: a (K,) ranking for one
model, plus a latency budget. The engine answers every submitted request
with exactly one :class:`ServeResult` — answered, rejected (failed
validation / draining), or shed (admission control / unmeetable deadline).
"Zero dropped requests" is checked by matching result ids against
submitted ids, so results are the unit of every serving guarantee.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# Result statuses.
OK = "ok"              # answered (possibly on a degraded tier)
REJECTED = "rejected"  # failed validation, unknown model, or draining
SHED = "shed"          # admission control or unmeetable deadline

# Degradation ladder tiers, best first.
TIERS = ("primary", "int8", "prior")


@dataclasses.dataclass
class ServeRequest:
    """One session to score. Arrays are host numpy, shape (K,)."""

    request_id: int
    model: str
    positions: np.ndarray       # int, 1-based ranks
    query_doc_ids: np.ndarray   # int, in [0, query_doc_pairs)
    mask: np.ndarray            # bool, True = real item
    features: Optional[np.ndarray] = None   # (K, F) for neural towers
    deadline_s: float = 0.2     # latency budget relative to arrival
    arrival_s: float = 0.0      # trace timestamp (engine clock domain)

    # stamped by the engine at admission
    admit_s: Optional[float] = None

    def deadline_abs(self) -> float:
        # The budget starts at *arrival*, not admission: time spent queued
        # behind a busy engine counts against the deadline.
        return self.arrival_s + self.deadline_s


@dataclasses.dataclass
class ServeResult:
    request_id: int
    model: str
    status: str                    # OK | REJECTED | SHED
    reason: Optional[str] = None   # set when status != OK
    tier: Optional[str] = None     # which ladder tier answered
    log_ctr: Optional[np.ndarray] = None  # (K,) log P(click) when OK
    latency_s: float = 0.0
    deadline_hit: bool = False

    @property
    def answered(self) -> bool:
        return self.status == OK

    @property
    def degraded(self) -> bool:
        return self.status == OK and self.tier != "primary"


def make_request(request_id: int, model: str, positions_k: int, rng,
                 n_pairs: int, deadline_s: float = 0.2,
                 arrival_s: float = 0.0) -> ServeRequest:
    """A well-formed random request (trace generators, warmup, tests)."""
    return ServeRequest(
        request_id=request_id,
        model=model,
        positions=np.arange(1, positions_k + 1, dtype=np.int32),
        query_doc_ids=rng.integers(0, n_pairs, positions_k).astype(np.int32),
        mask=np.ones(positions_k, dtype=bool),
        deadline_s=deadline_s,
        arrival_s=arrival_s,
    )


def poisson_trace(n_requests: int, qps: float, models, positions_k: int,
                  n_pairs: int, deadline_s: float = 0.2, seed: int = 0):
    """Seeded Poisson arrival trace: exponential interarrivals at ``qps``,
    models drawn round-robin-free (uniform) from ``models``. Deterministic
    in (seed, qps, n_requests)."""
    rng = np.random.default_rng(seed)
    models = list(models)
    t = 0.0
    trace = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / qps))
        model = models[int(rng.integers(0, len(models)))]
        trace.append(make_request(i, model, positions_k, rng, n_pairs,
                                  deadline_s=deadline_s, arrival_s=t))
    return trace
