"""Deadline-aware dynamic batching policy.

The batcher answers one question per model per loop iteration: *dispatch
now, or wait for more batch-mates — and if waiting, until when?* Three
dispatch triggers:

1. **Slack exhausted** — the oldest queued request's remaining slack is
   down to the estimated service time of the bucket we would use (plus a
   safety margin): waiting any longer risks its deadline. This is the
   invariant behind the deadline-hit guarantee: a batch is never
   dispatched so late that its *oldest* member cannot be answered in time
   (to the accuracy of the service-time estimate; exact under a
   :class:`~repro.serve.clock.VirtualClock`).
2. **Full batch** — the queue holds a max-bucket's worth of requests;
   waiting buys nothing.
3. **Max wait** — a light-traffic bound so a lone request is never held
   hostage for batch-mates that aren't coming.

Requests whose deadline cannot be met *even if dispatched alone right
now* are reaped before planning and shed with ``deadline_unmeetable`` —
running a batch we already know is late would only make every later
request later.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.serve.queue import AdmissionQueue
from repro.serve.registry import ModelRegistry
from repro.serve.request import ServeRequest


@dataclasses.dataclass
class BatchPlan:
    model: str
    tier: str
    bucket: int
    requests: List[ServeRequest]


class DeadlineBatcher:
    def __init__(self, registry: ModelRegistry, max_wait_s: float = 0.005,
                 slack_margin_s: float = 0.001):
        self.registry = registry
        self.max_wait_s = float(max_wait_s)
        self.slack_margin_s = float(slack_margin_s)

    # -- deadline reaping ----------------------------------------------------
    def reap_unmeetable(self, queue: AdmissionQueue, model: str, tier: str,
                        now: float) -> List[ServeRequest]:
        """Remove queued requests that cannot meet their deadline even in
        the smallest bucket dispatched immediately."""
        floor = self.registry[model].estimate(tier, self.registry.buckets[0])
        return queue.remove_if(
            model, lambda r: r.deadline_abs() - now < floor)

    # -- dispatch decision ---------------------------------------------------
    def plan(self, queue: AdmissionQueue, model: str, tier: str, now: float,
             flush: bool = False) -> Optional[BatchPlan]:
        """A BatchPlan if ``model`` should dispatch now, else ``None``.
        ``flush`` (drain mode) dispatches whatever is queued immediately."""
        depth = queue.depth_of(model)
        if depth == 0:
            return None
        entry = self.registry[model]
        n = min(depth, self.registry.max_bucket)
        bucket = self.registry.choose_bucket(n)
        oldest = queue.peek(model)
        est = entry.estimate(tier, bucket)
        # Trigger times are computed with the *same expressions* as
        # next_decision_time so that advancing the clock to a returned
        # decision time always fires (float addition is not associative:
        # (admit + wait) - admit can round below wait).
        slack_trigger = oldest.deadline_abs() - est - self.slack_margin_s
        wait_trigger = oldest.admit_s + self.max_wait_s
        if (flush
                or depth >= self.registry.max_bucket
                or now >= slack_trigger
                or now >= wait_trigger):
            return BatchPlan(model=model, tier=tier, bucket=bucket,
                             requests=queue.pop(model, n))
        return None

    def next_decision_time(self, queue: AdmissionQueue, model: str,
                           tier: str, now: float) -> Optional[float]:
        """Earliest future time at which :meth:`plan` would fire for
        ``model`` with no further arrivals (the event loop's sleep bound)."""
        depth = queue.depth_of(model)
        if depth == 0:
            return None
        entry = self.registry[model]
        bucket = self.registry.choose_bucket(
            min(depth, self.registry.max_bucket))
        oldest = queue.peek(model)
        est = entry.estimate(tier, bucket)
        slack_trigger = oldest.deadline_abs() - est - self.slack_margin_s
        wait_trigger = oldest.admit_s + self.max_wait_s
        return max(now, min(slack_trigger, wait_trigger))
