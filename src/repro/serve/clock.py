"""Time sources for the serving engine.

The engine is an event loop over (arrival time, request) pairs; everything
time-dependent — admission, batch dispatch timing, deadline accounting,
breaker trips — goes through one clock object, which comes in two flavors:

* :class:`WallClock` — real time. ``advance_to`` sleeps until the next
  arrival, ``charge`` is a no-op (real work already took real time). This
  is what production serving and ``benchmarks/bench_serve.py`` use.
* :class:`VirtualClock` — simulated time. ``advance_to`` jumps, ``charge``
  adds the model's *modeled* service time (see :class:`ServiceModel`).
  Model execution still really runs (predictions are real); only the
  latency bookkeeping is simulated, so a chaos drill's shed/degrade/miss
  counters are bit-deterministic across runs and platforms.

The split is the serving counterpart of the data plane's seeded fault
injectors: chaos tests pin exact counter values, the wall benchmark
measures real percentiles.
"""
from __future__ import annotations

import time
from typing import Dict, Tuple


class WallClock:
    virtual = False

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def advance_to(self, t: float) -> None:
        delta = t - self.now()
        if delta > 0:
            time.sleep(delta)

    def charge(self, seconds: float) -> None:
        del seconds  # real execution already advanced the wall clock


class VirtualClock:
    virtual = True

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float) -> None:
        if t > self._now:
            self._now = float(t)

    def charge(self, seconds: float) -> None:
        if seconds > 0:
            self._now += float(seconds)


class ServiceModel:
    """Modeled service time per (tier, bucket): ``base + per_item * bucket``.

    Used by :class:`VirtualClock` runs as both the batcher's estimate and
    the charged execution time (exact, hence deterministic). The defaults
    encode the ladder's *intent* — the int8 tier moves 4x fewer table bytes
    so it is modeled faster, the prior tier is a constant lookup — which is
    what lets a drill's breaker trip on a slow primary and recover on a
    degraded tier.
    """

    DEFAULT: Dict[str, Tuple[float, float]] = {
        "primary": (2.0e-3, 2.0e-5),
        "int8": (1.2e-3, 1.2e-5),
        "prior": (5.0e-5, 0.0),
    }

    def __init__(self, costs: Dict[str, Tuple[float, float]] = None):
        self.costs = dict(self.DEFAULT)
        if costs:
            self.costs.update(costs)

    def __call__(self, tier: str, bucket: int) -> float:
        base, per_item = self.costs[tier]
        return base + per_item * int(bucket)
