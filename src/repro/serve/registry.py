"""Warm multi-model registry: one process serves many models, three tiers.

Each :class:`ModelEntry` owns the three rungs of its degradation ladder:

* ``primary`` — the f32 checkpoint params through the model's jit'd
  unconditional-click path.
* ``int8`` — a 4x-smaller resident copy where every large table leaf is
  int8-quantized (:func:`repro.distrib.compression.quantize_tree`); the
  jit'd program dequantizes in-graph, so worst-case per-logit error is
  ``scale/2`` per quantized factor (documented tolerance; pinned in tests
  and measured in ``BENCH_serve.json``).
* ``prior`` — a constant log-CTR, pure host numpy: the answer of last
  resort that cannot fail and costs nothing.

Every (tier, bucket) program is compiled at :meth:`ModelRegistry.warmup`,
before the first request, and each compile bumps a per-tier trace counter
— the *no-retrace* guarantee ("serving traffic never eats a compile") is a
counter equality, pinned in tests/test_serve.py. Warmup also seeds the
per-bucket service-time estimates (EMA of measured wall time, or the exact
:class:`~repro.serve.clock.ServiceModel` under virtual time) that the
deadline-aware batcher plans with.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Optional

import jax
import numpy as np

from repro.distrib.compression import quantize_tree, tree_nbytes
from repro.serve.request import TIERS, make_request

DEFAULT_BUCKETS = (1, 4, 16, 64, 256)

# EMA weight for wall-mode service-time estimates: new = (1-a)*old + a*obs.
_EMA_ALPHA = 0.3


class ModelEntry:
    def __init__(self, name: str, model, params, n_pairs: int,
                 prior_ctr: float = 0.1, feature_dim: Optional[int] = None,
                 quantize_min_size: int = 512, service_model=None):
        self.name = name
        self.model = model
        self.params = params
        self.n_pairs = int(n_pairs)
        self.positions = int(model.positions)
        self.feature_dim = feature_dim
        self.prior_log_ctr = math.log(min(max(float(prior_ctr), 1e-6),
                                          1.0 - 1e-6))
        self.service_model = service_model
        self.trace_counts: Dict[str, int] = {"primary": 0, "int8": 0}
        self.dispatches = 0
        self.errors = 0
        self._estimates: Dict[tuple, float] = {}

        self.qparams = quantize_tree(params, min_size=quantize_min_size)
        self.primary_nbytes = tree_nbytes(params)
        self.int8_nbytes = tree_nbytes(self.qparams)

        def _primary(p, batch):
            self.trace_counts["primary"] += 1  # bumps only at trace time
            return model.predict_clicks(p, batch)

        def _int8(qp, batch):
            self.trace_counts["int8"] += 1
            from repro.distrib.compression import dequantize_tree

            return model.predict_clicks(dequantize_tree(qp), batch)

        self._fns = {"primary": jax.jit(_primary), "int8": jax.jit(_int8)}

    # -- execution -----------------------------------------------------------
    def run(self, tier: str, batch: Dict[str, np.ndarray]) -> np.ndarray:
        """Run one padded batch on ``tier``; blocks until the answer is on
        the host. Raises whatever the computation raises — the engine's
        ladder walk is the catch site."""
        if tier == "prior":
            return np.full(batch["positions"].shape, self.prior_log_ctr,
                           np.float32)
        params = self.params if tier == "primary" else self.qparams
        out = self._fns[tier](params, batch)
        return np.asarray(jax.block_until_ready(out))

    # -- service-time estimates ----------------------------------------------
    def estimate(self, tier: str, bucket: int) -> float:
        if self.service_model is not None:
            return self.service_model(tier, bucket)
        return self._estimates.get((tier, bucket), 0.0)

    def observe(self, tier: str, bucket: int, seconds: float) -> None:
        if self.service_model is not None:
            return
        key = (tier, bucket)
        old = self._estimates.get(key)
        self._estimates[key] = seconds if old is None else \
            (1.0 - _EMA_ALPHA) * old + _EMA_ALPHA * seconds

    def health(self) -> Dict:
        return {"dispatches": self.dispatches, "errors": self.errors,
                "trace_counts": dict(self.trace_counts),
                "primary_nbytes": self.primary_nbytes,
                "int8_nbytes": self.int8_nbytes}


class ModelRegistry:
    def __init__(self, buckets: Iterable[int] = DEFAULT_BUCKETS,
                 service_model=None):
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError("buckets must be positive ints")
        self.service_model = service_model
        self.entries: Dict[str, ModelEntry] = {}

    def add(self, name: str, model, params, n_pairs: int,
            prior_ctr: float = 0.1, feature_dim: Optional[int] = None,
            quantize_min_size: int = 512) -> ModelEntry:
        entry = ModelEntry(name, model, params, n_pairs,
                           prior_ctr=prior_ctr, feature_dim=feature_dim,
                           quantize_min_size=quantize_min_size,
                           service_model=self.service_model)
        self.entries[name] = entry
        return entry

    def __getitem__(self, name: str) -> ModelEntry:
        return self.entries[name]

    def __contains__(self, name: str) -> bool:
        return name in self.entries

    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    def choose_bucket(self, n: int) -> int:
        """Smallest pre-compiled bucket holding ``n`` requests."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def dummy_batch(self, entry: ModelEntry, bucket: int):
        """A well-formed padded batch for warmup compiles."""
        rng = np.random.default_rng(0)
        reqs = [make_request(-1 - i, entry.name, entry.positions, rng,
                             entry.n_pairs) for i in range(1)]
        return pad_batch(reqs, bucket, entry)

    def warmup(self, log_fn=None) -> Dict[str, float]:
        """Compile every (model, tier, bucket) program and seed service-time
        estimates. After this returns, a request can only ever hit a cached
        executable — first-compile latency is paid here, not by traffic."""
        import time

        seeded = {}
        for entry in self.entries.values():
            for tier in TIERS:
                for bucket in self.buckets:
                    batch = self.dummy_batch(entry, bucket)
                    t0 = time.perf_counter()
                    entry.run(tier, batch)
                    # compile + run; re-run for a compile-free estimate
                    t1 = time.perf_counter()
                    entry.run(tier, batch)
                    dt = time.perf_counter() - t1
                    entry.observe(tier, bucket, dt)
                    seeded[f"{entry.name}/{tier}/{bucket}"] = dt
                    if log_fn:
                        log_fn(f"[serve] warm {entry.name}/{tier} bucket "
                               f"{bucket}: compile {t1 - t0:.3f}s "
                               f"run {dt * 1e3:.2f}ms")
        return seeded


def pad_batch(requests, bucket: int, entry: ModelEntry):
    """Stack validated requests into a (bucket, K) batch dict; pad rows are
    fully masked out so they cannot influence real rows."""
    k = entry.positions
    positions = np.tile(np.arange(1, k + 1, dtype=np.int32), (bucket, 1))
    ids = np.zeros((bucket, k), np.int32)
    mask = np.zeros((bucket, k), bool)
    for i, req in enumerate(requests):
        positions[i] = np.asarray(req.positions, np.int32)
        ids[i] = np.asarray(req.query_doc_ids, np.int32)
        mask[i] = np.asarray(req.mask, bool)
    batch = {"positions": positions, "query_doc_ids": ids, "mask": mask,
             "clicks": np.zeros((bucket, k), np.float32)}
    if entry.feature_dim is not None:
        feats = np.zeros((bucket, k, entry.feature_dim), np.float32)
        for i, req in enumerate(requests):
            if req.features is not None:
                feats[i] = np.asarray(req.features, np.float32)
        batch["query_doc_features"] = feats
    return batch
