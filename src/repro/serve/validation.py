"""Fail-closed per-request validation.

One poisoned request must never poison a batch (NaN features would turn
the whole padded batch's predictions into garbage for every batch-mate)
and must never raise through the server loop. So validation is a *total*
function: it returns a rejection reason string or ``None``, catches every
exception class internally, and runs at admission — before a request can
reach the queue, the batcher, or a compiled program.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.serve.request import ServeRequest

# Stable reason strings (counters/tests key on them).
R_UNKNOWN_MODEL = "unknown_model"
R_BAD_SHAPE = "bad_shape"
R_BAD_DTYPE = "bad_dtype"
R_NONFINITE = "nonfinite_values"
R_IDS_RANGE = "ids_out_of_range"
R_POSITIONS_RANGE = "positions_out_of_range"
R_BAD_DEADLINE = "bad_deadline"
R_INTERNAL = "validator_error"


def _as_int_array(arr, shape):
    """Cast to int32 after proving the cast is lossless; returns (a, reason)."""
    a = np.asarray(arr)
    if a.shape != shape:
        return None, R_BAD_SHAPE
    if np.issubdtype(a.dtype, np.floating):
        if not np.isfinite(a).all():
            return None, R_NONFINITE
        if not np.equal(np.mod(a, 1), 0).all():
            return None, R_BAD_DTYPE
        a = a.astype(np.int64)
    elif np.issubdtype(a.dtype, np.bool_):
        a = a.astype(np.int64)
    elif not np.issubdtype(a.dtype, np.integer):
        return None, R_BAD_DTYPE
    return a.astype(np.int64), None


def validate_request(req: ServeRequest, *, positions: int, n_pairs: int,
                     feature_dim: Optional[int] = None) -> Optional[str]:
    """Reason string if ``req`` must be rejected, ``None`` if servable.

    Checks, in order: array shapes are (K,), dtypes are losslessly
    integral where the model indexes tables, every float is finite,
    query-doc ids lie in [0, n_pairs), positions in [1, K], the mask is
    boolean-like, optional features are (K, F) finite, and the deadline is
    a positive finite budget. Any internal surprise (a string array, a
    ragged object array, ...) is caught and reported as
    ``validator_error:<ExceptionName>`` — never raised.
    """
    try:
        shape = (int(positions),)

        pos, reason = _as_int_array(req.positions, shape)
        if reason:
            return f"{reason}:positions"
        if pos.min(initial=1) < 1 or pos.max(initial=1) > positions:
            return R_POSITIONS_RANGE

        ids, reason = _as_int_array(req.query_doc_ids, shape)
        if reason:
            return f"{reason}:query_doc_ids"
        if ids.min(initial=0) < 0 or ids.max(initial=0) >= n_pairs:
            return R_IDS_RANGE

        mask = np.asarray(req.mask)
        if mask.shape != shape:
            return f"{R_BAD_SHAPE}:mask"
        if np.issubdtype(mask.dtype, np.floating) and \
                not np.isfinite(mask).all():
            return f"{R_NONFINITE}:mask"
        if not np.isin(np.asarray(mask, dtype=np.float64), (0.0, 1.0)).all():
            return f"{R_BAD_DTYPE}:mask"

        if req.features is not None:
            feats = np.asarray(req.features)
            if feats.ndim != 2 or feats.shape[0] != positions or (
                    feature_dim is not None and feats.shape[1] != feature_dim):
                return f"{R_BAD_SHAPE}:features"
            if not np.issubdtype(feats.dtype, np.floating) and \
                    not np.issubdtype(feats.dtype, np.integer):
                return f"{R_BAD_DTYPE}:features"
            if not np.isfinite(feats.astype(np.float64)).all():
                return f"{R_NONFINITE}:features"
        elif feature_dim is not None:
            return f"{R_BAD_SHAPE}:features"

        deadline = float(req.deadline_s)
        if not np.isfinite(deadline) or deadline <= 0:
            return R_BAD_DEADLINE
        return None
    except Exception as e:  # fail closed, never raise through the loop
        return f"{R_INTERNAL}:{type(e).__name__}"
