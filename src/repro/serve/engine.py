"""The serving engine: one event loop tying every resilience layer together.

Request lifecycle::

    trace ──► admission ──► queue ──► batcher ──► ladder ──► result
              │  validation   │  watermarks │ deadline-aware │ breakers
              │  (fail closed)│  shed/back- │ bucket padding │ primary→int8
              │               │  pressure   │ pre-compiled   │ →prior
              SIGTERM ════════╪═ drain: stop admitting, flush in-flight ═►

Guarantees (each pinned in tests/test_serve.py):

* every submitted request gets exactly one :class:`ServeResult` — under
  overload, poison floods, injected model failures, and SIGTERM drain;
* a request that fails validation is rejected alone; its would-be
  batch-mates are answered normally;
* traffic never triggers a compile after :meth:`ModelRegistry.warmup`;
* model errors and deadline-miss storms trip the per-model breaker down
  the degradation ladder instead of surfacing to callers — the ``prior``
  rung cannot fail, so the engine never crashes and never returns an
  unvalidated answer;
* under a :class:`~repro.serve.clock.VirtualClock` the full outcome
  stream (statuses, tiers, counters) is bit-deterministic.

Telemetry rides the existing :class:`~repro.obs.recorder.Recorder`
schema: counters ``serve.requests / answered / shed / deadline_miss /
degraded / rejected_invalid / backpressure / breaker_transitions``, the
``serve.queue_depth`` gauge, per-dispatch ``serve_batch`` spans,
per-request ``serve_latency_ms`` metrics, and ``breaker_transition`` /
``drain_start`` events.
"""
from __future__ import annotations

import collections
import time
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.serve.batcher import BatchPlan, DeadlineBatcher
from repro.serve.breaker import DegradationLadder
from repro.serve.clock import VirtualClock, WallClock
from repro.serve.queue import (ADMIT, ADMIT_BACKPRESSURE, AdmissionQueue,
                               SHED_OVERLOAD, SHED_QUEUE_FULL)
from repro.serve.registry import ModelRegistry, pad_batch
from repro.serve.request import (OK, REJECTED, SHED, ServeRequest,
                                 ServeResult, TIERS)
from repro.serve.validation import validate_request
from repro.train.fault_tolerance import PreemptionHandler

_SHED_REASONS = {SHED_OVERLOAD: "shed_overload",
                 SHED_QUEUE_FULL: "shed_queue_full"}


class ServeEngine:
    def __init__(self, registry: ModelRegistry,
                 queue: Optional[AdmissionQueue] = None,
                 batcher: Optional[DeadlineBatcher] = None,
                 clock=None, recorder=None,
                 faults: Iterable = (),
                 force_tier: Optional[str] = None,
                 breaker_kwargs: Optional[Dict] = None,
                 log_fn=None):
        if force_tier is not None and force_tier not in TIERS:
            raise ValueError(f"force_tier must be one of {TIERS}")
        self.registry = registry
        self.queue = queue if queue is not None else AdmissionQueue()
        self.batcher = batcher if batcher is not None \
            else DeadlineBatcher(registry)
        self.clock = clock if clock is not None else WallClock()
        if recorder is None:
            from repro.obs import get_recorder

            recorder = get_recorder()
        self.recorder = recorder
        self.faults = list(faults)
        self.force_tier = force_tier
        self.log_fn = log_fn or (lambda *_: None)
        self.ladders: Dict[str, DegradationLadder] = {
            name: DegradationLadder(name, recorder=recorder,
                                    breaker_kwargs=breaker_kwargs)
            for name in registry.entries
        }
        self.stats = collections.Counter()
        self.draining = False
        self._admit_index = 0
        # Engine-local per-model dispatch indices: fault hooks key on these,
        # so a drill replays identically even on a registry warmed by
        # earlier runs (entry.dispatches keeps the lifetime health count).
        self._dispatch_counts = collections.Counter()

    # -- admission -----------------------------------------------------------
    def _finish(self, results: List[ServeResult], req: ServeRequest,
                status: str, reason: Optional[str] = None,
                tier: Optional[str] = None, log_ctr=None,
                latency_s: float = 0.0, deadline_hit: bool = False) -> None:
        results.append(ServeResult(
            request_id=req.request_id, model=req.model, status=status,
            reason=reason, tier=tier, log_ctr=log_ctr, latency_s=latency_s,
            deadline_hit=deadline_hit))

    def _count(self, key: str, amount: int = 1) -> None:
        self.stats[key] += amount
        self.recorder.add(key, amount)

    def _gauge_depth(self) -> None:
        self.recorder.gauge("serve.queue_depth", self.queue.depth)

    def _admit(self, req: ServeRequest, now: float,
               results: List[ServeResult]) -> None:
        index = self._admit_index
        self._admit_index += 1
        for fault in self.faults:
            on_admit = getattr(fault, "on_admit", None)
            if on_admit is not None:
                on_admit(index, req)
        self._count("serve.requests")
        if self.draining:
            self._count("serve.rejected_draining")
            self._finish(results, req, REJECTED, "draining")
            return
        if req.model not in self.registry:
            self._count("serve.rejected_invalid")
            self._finish(results, req, REJECTED, "unknown_model")
            return
        entry = self.registry[req.model]
        reason = validate_request(req, positions=entry.positions,
                                  n_pairs=entry.n_pairs,
                                  feature_dim=entry.feature_dim)
        if reason is not None:
            self._count("serve.rejected_invalid")
            self._finish(results, req, REJECTED, reason)
            return
        outcome = self.queue.offer(req, now)
        if outcome in _SHED_REASONS:
            self._count("serve.shed")
            self._finish(results, req, SHED, _SHED_REASONS[outcome])
        else:
            if outcome == ADMIT_BACKPRESSURE:
                self._count("serve.backpressure")
            assert outcome in (ADMIT, ADMIT_BACKPRESSURE)
        self._gauge_depth()

    # -- dispatch ------------------------------------------------------------
    def _consult_faults(self, model: str, tier: str, bucket: int,
                        dispatch_index: int):
        extra, err = 0.0, None
        for fault in self.faults:
            on_dispatch = getattr(fault, "on_dispatch", None)
            if on_dispatch is None:
                continue
            f_extra, f_err = on_dispatch(model, tier, bucket, dispatch_index)
            extra += f_extra
            err = err or f_err
        return extra, err

    def _execute(self, plan: BatchPlan, results: List[ServeResult]) -> None:
        entry = self.registry[plan.model]
        ladder = self.ladders[plan.model]
        dispatch_index = self._dispatch_counts[plan.model]
        self._dispatch_counts[plan.model] += 1
        entry.dispatches += 1
        batch = pad_batch(plan.requests, plan.bucket, entry)
        out, answered_tier = None, None
        attempted = set()
        for tier in ladder.walk_from(plan.tier):
            attempted.add(tier)
            ladder.begin_attempt(tier)
            extra_s, injected_err = self._consult_faults(
                plan.model, tier, plan.bucket, dispatch_index)
            wall0 = time.perf_counter()
            try:
                if injected_err is not None:
                    raise injected_err
                with self.recorder.span("serve_batch", model=plan.model,
                                        tier=tier, bucket=plan.bucket,
                                        n=len(plan.requests)):
                    out = entry.run(tier, batch)
                ran_ok = True
            except Exception as e:  # fail closed: fall down the ladder
                ran_ok = False
                entry.errors += 1
                self._count("serve.model_errors")
                self.recorder.event(
                    "model_error", data={"model": plan.model, "tier": tier,
                                         "error": type(e).__name__})
                self.log_fn(f"[serve] {plan.model}/{tier} failed "
                            f"({type(e).__name__}: {e}); degrading")
            if self.clock.virtual:
                self.clock.charge(entry.estimate(tier, plan.bucket) + extra_s)
            else:
                if extra_s > 0:
                    time.sleep(extra_s)
                entry.observe(tier, plan.bucket,
                              time.perf_counter() - wall0 + extra_s)
            if ran_ok:
                answered_tier = tier
                break
            ladder.record(tier, ok=False)

        completion = self.clock.now()
        if answered_tier is not None:
            ladder.finish_dispatch(answered_tier, attempted)
        if answered_tier is None:
            # Even the prior rung raised (only reachable via injected
            # faults on "prior"): fail closed per request, never crash.
            for req in plan.requests:
                self._count("serve.shed")
                self._finish(results, req, SHED, "model_failure")
            return
        misses = 0
        for i, req in enumerate(plan.requests):
            latency = completion - req.arrival_s
            hit = completion <= req.deadline_abs()
            misses += 0 if hit else 1
            self._count("serve.answered")
            if not hit:
                self._count("serve.deadline_miss")
            if answered_tier != TIERS[0]:
                self._count("serve.degraded")
            self.recorder.metric("serve_latency_ms", latency * 1e3,
                                 step=req.request_id,
                                 model=req.model, tier=answered_tier)
            self._finish(results, req, OK, tier=answered_tier,
                         log_ctr=out[i], latency_s=latency,
                         deadline_hit=hit)
        ladder.record(answered_tier, ok=(misses == 0))
        self._gauge_depth()

    def _dispatch_due(self, now: float, results: List[ServeResult]) -> bool:
        """Reap unmeetable requests and run every due batch; True if any
        batch was dispatched (time advanced)."""
        dispatched = False
        for model in self.queue.models():
            tier = self.ladders[model].select(self.force_tier)
            for req in self.batcher.reap_unmeetable(
                    self.queue, model, tier, now):
                self._count("serve.shed")
                self._count("serve.deadline_miss")
                self._finish(results, req, SHED, "deadline_unmeetable")
            plan = self.batcher.plan(self.queue, model, tier, now,
                                    flush=self.draining)
            if plan is not None:
                self._execute(plan, results)
                dispatched = True
        if dispatched:
            self._gauge_depth()
        return dispatched

    # -- the event loop ------------------------------------------------------
    def run_trace(self, trace: Iterable[ServeRequest],
                  handle_signals: bool = True) -> List[ServeResult]:
        """Serve a time-ordered arrival trace to completion (or drain).

        ``trace`` yields requests with monotone ``arrival_s``. With
        ``handle_signals`` a :class:`PreemptionHandler` converts
        SIGTERM/SIGINT into a drain: admission stops (remaining arrivals
        are rejected with ``"draining"``), queued requests are flushed
        through the batcher, and the loop exits with zero in-flight drops.
        """
        results: List[ServeResult] = []
        it = iter(trace)
        nxt = next(it, None)
        handler = PreemptionHandler() if handle_signals else None
        try:
            while True:
                now = self.clock.now()
                if (handler is not None and handler.should_stop
                        and not self.draining):
                    self._start_drain(now)
                if self.draining and nxt is not None:
                    # reject the rest of the trace immediately
                    while nxt is not None:
                        self._admit(nxt, now, results)
                        nxt = next(it, None)
                while nxt is not None and nxt.arrival_s <= now:
                    self._admit(nxt, now, results)
                    nxt = next(it, None)
                    if (handler is not None and handler.should_stop
                            and not self.draining):
                        self._start_drain(now)
                        break
                if self._dispatch_due(now, results):
                    continue
                if self.queue.depth == 0:
                    if nxt is None:
                        break
                    self.clock.advance_to(nxt.arrival_s)
                    continue
                candidates = []
                if nxt is not None:
                    candidates.append(nxt.arrival_s)
                for model in self.queue.models():
                    tier = self.ladders[model].select(self.force_tier)
                    t = self.batcher.next_decision_time(
                        self.queue, model, tier, now)
                    if t is not None:
                        candidates.append(t)
                self.clock.advance_to(min(candidates))
        finally:
            if handler is not None:
                handler.restore()
        self.recorder.flush_counters()
        return results

    def _start_drain(self, now: float) -> None:
        self.draining = True
        self._count("serve.drains")
        self.recorder.event("drain_start",
                            data={"queue_depth": self.queue.depth,
                                  "t": float(now)})
        self.log_fn(f"[serve] drain: admission stopped, "
                    f"{self.queue.depth} in flight")

    # -- reporting -----------------------------------------------------------
    def health(self) -> Dict[str, Dict]:
        return {name: dict(self.registry[name].health(),
                           breakers=self.ladders[name].state(),
                           tier=self.ladders[name].select(self.force_tier))
                for name in self.registry.entries}

    def summary(self, results: List[ServeResult]) -> Dict:
        answered = [r for r in results if r.answered]
        lat_ms = np.asarray([r.latency_s * 1e3 for r in answered])
        hits = sum(r.deadline_hit for r in answered)
        return {
            "requests": len(results),
            "answered": len(answered),
            "shed": sum(r.status == SHED for r in results),
            "rejected": sum(r.status == REJECTED for r in results),
            "degraded": sum(r.degraded for r in results),
            "deadline_hit_rate": (hits / len(answered)) if answered else 0.0,
            "p50_ms": float(np.percentile(lat_ms, 50)) if answered else None,
            "p99_ms": float(np.percentile(lat_ms, 99)) if answered else None,
        }
