"""Per-model circuit breakers and the graceful-degradation ladder.

Each model serves through a ladder of tiers — ``primary`` (f32 params) →
``int8`` (quantized embedding-table copy) → ``prior`` (constant CTR
fallback, host-side, cannot fail). The two upper tiers are each guarded by
a :class:`CircuitBreaker` driven by *batch* outcomes, where a batch counts
as failed if the model raised or any of its requests missed its deadline:

* **closed** — healthy; failures accumulate in a sliding outcome window.
  When the window's failure rate crosses ``threshold`` (with at least
  ``min_samples`` outcomes) the breaker opens.
* **open** — the tier is skipped; traffic flows to the next rung. Every
  dispatch of this model that bypasses the tier ticks the cooldown; after
  ``cooldown`` ticks the breaker goes half-open.
* **half-open** — exactly one probe batch is allowed back through the
  guarded tier. Success closes the breaker (window reset); failure
  re-opens it for another cooldown.

The API keeps *observation* and *mutation* apart so the engine's planner
can ask "which tier would serve now?" without perturbing breaker state:
:meth:`CircuitBreaker.available` is pure; :meth:`note_skipped` (cooldown
tick), :meth:`begin` (probe claim) and :meth:`record` (outcome) mutate,
and are called exactly once per executed dispatch. Because all of them
are driven by dispatch counts, not wall time, a seeded chaos drill trips
and recovers deterministically.

All transitions are counted (``serve.breaker_transitions``) and emitted as
``breaker_transition`` events so a degraded fleet is visible in telemetry.
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional

from repro.serve.request import TIERS

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    def __init__(self, name: str, window: int = 16,
                 threshold: float = 0.5, min_samples: int = 4,
                 cooldown: int = 8, recorder=None):
        self.name = name
        self.window = int(window)
        self.threshold = float(threshold)
        self.min_samples = int(min_samples)
        self.cooldown = int(cooldown)
        self.recorder = recorder
        self.state = CLOSED
        self.transitions = 0
        self._outcomes = collections.deque(maxlen=self.window)
        self._cooldown_ticks = 0
        self._probe_in_flight = False

    def _transition(self, new_state: str) -> None:
        if new_state == self.state:
            return
        old, self.state = self.state, new_state
        self.transitions += 1
        rec = self.recorder
        if rec is not None:
            rec.add("serve.breaker_transitions")
            rec.event("breaker_transition",
                      data={"breaker": self.name, "from": old,
                            "to": new_state})

    # -- observation (pure) --------------------------------------------------
    def available(self) -> bool:
        if self.state == CLOSED:
            return True
        if self.state == OPEN:
            return False
        return not self._probe_in_flight  # half-open: one probe at a time

    # -- mutation (once per executed dispatch) -------------------------------
    def note_skipped(self) -> None:
        """A dispatch of this model bypassed the guarded tier."""
        if self.state == OPEN:
            self._cooldown_ticks += 1
            if self._cooldown_ticks >= self.cooldown:
                self._transition(HALF_OPEN)
                self._probe_in_flight = False

    def begin(self) -> None:
        """A dispatch is about to run on the guarded tier."""
        if self.state == HALF_OPEN:
            self._probe_in_flight = True

    def record(self, ok: bool) -> None:
        """Feed one batch outcome for the guarded tier."""
        if self.state == HALF_OPEN:
            self._probe_in_flight = False
            if ok:
                self._outcomes.clear()
                self._transition(CLOSED)
            else:
                self._cooldown_ticks = 0
                self._transition(OPEN)
            return
        self._outcomes.append(bool(ok))
        if self.state == CLOSED and len(self._outcomes) >= self.min_samples:
            failure_rate = 1.0 - sum(self._outcomes) / len(self._outcomes)
            if failure_rate >= self.threshold:
                self._cooldown_ticks = 0
                self._transition(OPEN)


class DegradationLadder:
    """Routes one model's traffic down TIERS as its breakers open."""

    def __init__(self, model: str, recorder=None, breaker_kwargs=None):
        kw = dict(breaker_kwargs or {})
        self.model = model
        # The terminal tier has no breaker: the prior fallback is pure
        # host-side numpy and must always be available.
        self.breakers: Dict[str, CircuitBreaker] = {
            tier: CircuitBreaker(f"{model}/{tier}", recorder=recorder, **kw)
            for tier in TIERS[:-1]
        }

    def select(self, force_tier: Optional[str] = None) -> str:
        """The tier a dispatch would use right now (pure)."""
        if force_tier is not None:
            return force_tier
        for tier in TIERS[:-1]:
            if self.breakers[tier].available():
                return tier
        return TIERS[-1]

    def walk_from(self, tier: str) -> List[str]:
        """Fallback attempt order for a dispatch starting at ``tier``:
        the tier itself, then every *available* lower rung, then the
        terminal rung (which cannot fail)."""
        start = TIERS.index(tier)
        out = [tier]
        for t in TIERS[start + 1:-1]:
            if self.breakers[t].available():
                out.append(t)
        if TIERS[-1] != tier:
            out.append(TIERS[-1])
        return out

    def begin_attempt(self, tier: str) -> None:
        breaker = self.breakers.get(tier)
        if breaker is not None:
            breaker.begin()

    def record(self, tier: str, ok: bool) -> None:
        breaker = self.breakers.get(tier)
        if breaker is not None:
            breaker.record(ok)

    def finish_dispatch(self, answered_tier: str, attempted) -> None:
        """Tick the cooldown of every guarded tier the dispatch bypassed
        (above the answering tier and not attempted)."""
        limit = TIERS.index(answered_tier)
        for i, tier in enumerate(TIERS[:-1]):
            if i < limit and tier not in attempted:
                self.breakers[tier].note_skipped()

    def state(self) -> Dict[str, str]:
        return {tier: b.state for tier, b in self.breakers.items()}
