"""Bounded admission queue with load shedding and backpressure.

Admission control is the first line of overload defense: above the shed
watermark new requests are rejected immediately with a reason (cheap,
explicit, and keeps queueing delay bounded — a deep queue just converts
overload into deadline misses); between the backpressure watermark and the
shed watermark requests are admitted but flagged, which a closed-loop
client uses to slow its offered rate. Depth is tracked globally (one
process, one memory budget) while requests queue per model so the batcher
can form single-model batches.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, Optional

from repro.serve.request import ServeRequest

ADMIT = "admit"
ADMIT_BACKPRESSURE = "admit_backpressure"
SHED_OVERLOAD = "shed_overload"
SHED_QUEUE_FULL = "shed_queue_full"


class AdmissionQueue:
    def __init__(self, capacity: int = 256,
                 shed_watermark: Optional[int] = None,
                 backpressure_watermark: Optional[int] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.shed_watermark = int(shed_watermark if shed_watermark is not None
                                  else max(1, (capacity * 3) // 4))
        self.backpressure_watermark = int(
            backpressure_watermark if backpressure_watermark is not None
            else max(1, capacity // 2))
        if not (self.backpressure_watermark <= self.shed_watermark
                <= self.capacity):
            raise ValueError("watermarks must satisfy backpressure <= shed "
                             "<= capacity")
        self._queues: Dict[str, Deque[ServeRequest]] = {}
        self._depth = 0

    # -- admission -----------------------------------------------------------
    def offer(self, req: ServeRequest, now: float) -> str:
        """Admit or shed. Returns one of the ADMIT_*/SHED_* outcomes; on
        admit the request is stamped with ``admit_s = now`` and enqueued."""
        if self._depth >= self.capacity:
            return SHED_QUEUE_FULL
        if self._depth >= self.shed_watermark:
            return SHED_OVERLOAD
        req.admit_s = now
        self._queues.setdefault(req.model, collections.deque()).append(req)
        self._depth += 1
        if self._depth > self.backpressure_watermark:
            return ADMIT_BACKPRESSURE
        return ADMIT

    # -- consumption (batcher side) ------------------------------------------
    @property
    def depth(self) -> int:
        return self._depth

    def models(self):
        """Model names with queued requests, in insertion order."""
        return [m for m, q in self._queues.items() if q]

    def peek(self, model: str) -> Optional[ServeRequest]:
        q = self._queues.get(model)
        return q[0] if q else None

    def depth_of(self, model: str) -> int:
        return len(self._queues.get(model, ()))

    def pop(self, model: str, n: int):
        """Pop up to ``n`` oldest requests for ``model`` (FIFO)."""
        q = self._queues.get(model)
        out = []
        while q and len(out) < n:
            out.append(q.popleft())
        self._depth -= len(out)
        return out

    def remove_if(self, model: str, predicate):
        """Remove and return every queued request of ``model`` matching
        ``predicate`` (deadline reaping), preserving FIFO order of the
        survivors."""
        q = self._queues.get(model)
        if not q:
            return []
        removed = [r for r in q if predicate(r)]
        if removed:
            kept = [r for r in q if not predicate(r)]
            q.clear()
            q.extend(kept)
            self._depth -= len(removed)
        return removed
