"""Numerically stable log-space primitives (paper §5).

All CLAX probability computations run in log-space. The primitives here
implement the paper's Eq. 15-18: products become sums, complements use the
Mächler [2012] piecewise log1mexp, and logits map to log-probabilities via
stable log-sigmoid (Eq. 17).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Default floor used when a model must assign "impossible" events a small
# non-zero probability (e.g. clicks after the first click under the cascade
# model, Appendix A.5). exp(-13.8) ~= 1e-6.
MIN_LOG_PROB = -13.815510557964274


def log1mexp(a: jax.Array) -> jax.Array:
    """log(1 - exp(a)) for a <= 0, Mächler's piecewise form (paper Eq. 18).

    Switches at -log(2): `log(-expm1(a))` is accurate for a close to 0,
    `log1p(-exp(a))` for very negative a. Inputs are clipped to <= 0 so tiny
    positive rounding noise does not produce NaNs.
    """
    a = jnp.minimum(a, 0.0)
    log2 = jnp.log(2.0).astype(a.dtype)
    # Guard both branches against generating NaNs inside jnp.where.
    near_zero = a > -log2
    # branch 1: a in (-log2, 0]: -expm1(a) in (0, ~0.693]
    b1 = jnp.log(-jnp.expm1(jnp.where(near_zero, a, -log2)))
    # branch 2: a <= -log2: exp(a) in (0, 0.5]
    b2 = jnp.log1p(-jnp.exp(jnp.where(near_zero, -log2, a)))
    return jnp.where(near_zero, b1, b2)


def log_expm1(a: jax.Array) -> jax.Array:
    """log(exp(a) - 1) for a > 0 (softplus inverse), stable for large a."""
    # For large a: log(exp(a)-1) = a + log1p(-exp(-a)).
    return a + log1mexp(-a)


def log_sigmoid(x: jax.Array) -> jax.Array:
    """log(sigmoid(x)) = -log_sum_exp([0, -x]) = -softplus(-x) (paper Eq. 17)."""
    return -jax.nn.softplus(-x)


def sigmoid_core(x: jax.Array):
    """The shared pieces every sigmoid-family quantity derives from:
    (e, t, pos) with e = exp(-|x|), t = 1/(1+e), pos = x >= 0. Then
    sigma(x) = t or e*t by sign, log sigma(x) = min(x, 0) - log1p(e), and
    fused expressions can reuse e directly (e.g. the chain models' single
    log1p over r + e + r*e)."""
    e = jnp.exp(-jnp.abs(x))
    return e, 1.0 / (1.0 + e), x >= 0


def sigmoid_parts(x: jax.Array):
    """(sigma(x), sigma(-x), log sigma(x), log sigma(-x)) from one exp + one
    log1p.

    Every chain-model factor is a positive combination of sigmoids and their
    complements; computing the four quantities jointly (instead of two
    sigmoids plus two softpluses) roughly halves the transcendental count of
    the hot prediction paths. All four are exact: the complement is
    sigma(-x), never the cancellation-prone 1 - sigma(x).
    """
    e, t, pos = sigmoid_core(x)
    p = jnp.where(pos, t, e * t)
    p_not = jnp.where(pos, e * t, t)
    l = jnp.log1p(e)
    log_p = jnp.minimum(x, 0.0) - l
    log_p_not = -jnp.maximum(x, 0.0) - l
    return p, p_not, log_p, log_p_not


def log1m_sigmoid(x: jax.Array) -> jax.Array:
    """log(1 - sigmoid(x)) = log(sigmoid(-x)) = -softplus(x) (paper Eq. 17)."""
    return -jax.nn.softplus(x)


def logsumexp(a: jax.Array, axis=None, where=None, keepdims: bool = False) -> jax.Array:
    """Max-shifted log-sum-exp (paper Eq. 16), mask-aware.

    `where=False` entries contribute exp(-inf)=0 to the sum. A fully masked
    reduction yields -inf with a zero (not NaN) gradient, so the vectorized
    recursions can feed empty path sets straight through value_and_grad.
    """
    if where is not None:
        a = jnp.where(where, a, -jnp.inf)
    a_max = jnp.max(a, axis=axis, keepdims=True)
    # If every entry is masked the max is -inf; shift by 0 instead to avoid
    # (-inf) - (-inf) = nan. The result is then log(0) = -inf, as it should be.
    shift = jnp.where(jnp.isfinite(a_max), a_max, 0.0)
    total = jnp.sum(jnp.exp(a - shift), axis=axis, keepdims=True)
    empty = total == 0.0
    out = jnp.where(empty, -jnp.inf,
                    jnp.log(jnp.where(empty, 1.0, total)) + shift)
    if not keepdims:
        out = jnp.reshape(out, jnp.max(a, axis=axis).shape)
    return out


def log_add_exp(a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise log(exp(a) + exp(b)), the 2-ary logsumexp.

    Delegates to jnp.logaddexp, whose custom JVP keeps gradients finite at
    (-inf, -inf) — the "both events impossible" corner every chain recursion
    hits on its virtual start segment.
    """
    return jnp.logaddexp(a, b)


def exclusive_cumsum(a: jax.Array, axis: int = -1) -> jax.Array:
    """Cumulative sum shifted right along ``axis``: out_k = sum_{m<k} a_m.

    out_0 is exactly 0 (not incl_0 - a_0, which reintroduces rounding), so a
    chain recursion's first position carries the exact initial state.
    """
    incl = jnp.cumsum(a, axis=axis)
    n = a.shape[axis]
    head = jnp.zeros_like(jax.lax.slice_in_dim(incl, 0, 1, axis=axis))
    return jnp.concatenate(
        [head, jax.lax.slice_in_dim(incl, 0, n - 1, axis=axis)], axis=axis)


def log_cumsum(a: jax.Array, axis: int = -1) -> jax.Array:
    """Running log-sum-exp along ``axis``: the log-space cumulative sum of
    probabilities, out_k = log sum_{m<=k} exp(a_m). One XLA op (associative
    scan), no Python loop."""
    return jax.lax.cumlogsumexp(a, axis=axis)


def log_not(log_p: jax.Array) -> jax.Array:
    """log(1 - p) from log(p)."""
    return log1mexp(log_p)


def log_or(log_p: jax.Array, log_q: jax.Array) -> jax.Array:
    """log(p + q - p*q) for independent events = log(1 - (1-p)(1-q))."""
    return log1mexp(log1mexp(log_p) + log1mexp(log_q))


def log_bce(log_p: jax.Array, clicks: jax.Array) -> jax.Array:
    """Per-element negative log-likelihood of Bernoulli clicks, from log-probs.

    nll = -[c * log(p) + (1-c) * log(1-p)], with log(1-p) via log1mexp.
    """
    clicks = clicks.astype(log_p.dtype)
    return -(clicks * log_p + (1.0 - clicks) * log1mexp(log_p))


def logit_to_log_prob(x: jax.Array) -> jax.Array:
    """Alias of log_sigmoid: map a real logit to a log-probability."""
    return log_sigmoid(x)


def log_prob_to_logit(log_p: jax.Array) -> jax.Array:
    """Inverse sigmoid in log-space: logit = log_p - log(1-p)."""
    return log_p - log1mexp(log_p)
