"""Numerically stable log-probability operations (paper §5)."""
from repro.stable.logops import (
    log1mexp,
    log_sigmoid,
    log1m_sigmoid,
    logsumexp,
    log_bce,
    log_not,
    log_or,
    log_expm1,
    logit_to_log_prob,
    log_prob_to_logit,
    MIN_LOG_PROB,
)

__all__ = [
    "log1mexp",
    "log_sigmoid",
    "log1m_sigmoid",
    "logsumexp",
    "log_bce",
    "log_not",
    "log_or",
    "log_expm1",
    "logit_to_log_prob",
    "log_prob_to_logit",
    "MIN_LOG_PROB",
]
