"""Batched click-prediction serving driver.

    PYTHONPATH=src python -m repro.launch.serve --model dbn \
        [--ckpt-dir ckpts/dbn] [--requests 50] [--batch 512]

Loads the latest checkpoint (or fresh-initializes), then serves batched
request streams through the jit'd unconditional-click path, reporting
latency percentiles and throughput — the serve-side counterpart of
launch/train.py. The dry-run covers the sharded multi-pod variant.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Compression, EmbeddingParameterConfig, MODEL_REGISTRY
from repro.train import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dbn", choices=sorted(MODEL_REGISTRY))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--pairs", type=int, default=1_000_000)
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--positions", type=int, default=10)
    ap.add_argument("--metrics-out", default=None,
                    help="write per-request latency metric events and the "
                         "final serve summary as JSONL telemetry")
    ap.add_argument("--trace-out", default=None,
                    help="export per-request dispatch spans as Chrome-trace "
                         "JSON (Perfetto)")
    args = ap.parse_args()

    from repro import obs

    recorder = obs.get_recorder()
    if args.metrics_out:
        recorder = obs.configure(sinks=[obs.JsonlSink(args.metrics_out)])

    attraction = EmbeddingParameterConfig(
        parameters=args.pairs, compression=Compression.HASH,
        compression_ratio=10.0, baseline_correction=True, init_logit=-2.0)
    model = MODEL_REGISTRY[args.model](query_doc_pairs=args.pairs,
                                       positions=args.positions,
                                       attraction=attraction)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        if ckpt.latest_step() is not None:
            tree, _, step = ckpt.restore(like={"params": params})
            params = tree["params"]
            print(f"[serve] restored step {step} from {args.ckpt_dir}")

    serve = jax.jit(model.predict_clicks)
    rng = np.random.default_rng(0)

    def request(batch):
        return {
            "positions": jnp.asarray(np.tile(np.arange(1, args.positions + 1),
                                             (batch, 1)), jnp.int32),
            "query_doc_ids": jnp.asarray(
                rng.integers(0, args.pairs, (batch, args.positions)),
                jnp.int32),
            "clicks": jnp.zeros((batch, args.positions), jnp.float32),
            "mask": jnp.ones((batch, args.positions), bool),
        }

    # warmup compile
    with recorder.span("serve_warmup", batch=args.batch):
        jax.block_until_ready(serve(params, request(args.batch)))
    lat = []
    for i in range(args.requests):
        b = request(args.batch)
        t0 = time.perf_counter()
        with recorder.span("serve_batch", request=i, batch=args.batch):
            jax.block_until_ready(serve(params, b))
        ms = (time.perf_counter() - t0) * 1e3
        lat.append(ms)
        recorder.metric("serve_latency_ms", ms, step=i)
        recorder.add("serve.requests")
        recorder.add("serve.sessions", args.batch)
    lat = np.asarray(lat)
    summary = {"requests": args.requests, "batch": args.batch,
               "p50_ms": float(np.percentile(lat, 50)),
               "p99_ms": float(np.percentile(lat, 99)),
               "throughput_sessions_s": float(args.batch / lat.mean() * 1e3)}
    recorder.event("serve_summary", data=summary)
    recorder.flush_counters()
    if args.trace_out:
        n_spans = recorder.export_chrome_trace(args.trace_out)
        print(f"[serve] {n_spans} spans -> {args.trace_out}")
    recorder.close()
    print(f"[serve] {args.requests} requests x batch {args.batch}: "
          f"p50={summary['p50_ms']:.2f}ms "
          f"p99={summary['p99_ms']:.2f}ms "
          f"throughput={summary['throughput_sessions_s']:.0f} sessions/s")


if __name__ == "__main__":
    main()
