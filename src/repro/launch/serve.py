"""Batched click-prediction serving driver.

    PYTHONPATH=src python -m repro.launch.serve --model dbn \
        [--ckpt-dir ckpts/dbn] [--requests 50] [--batch 512]

Loads the latest checkpoint (or fresh-initializes), then serves batched
request streams through the jit'd unconditional-click path, reporting
latency percentiles and throughput — the serve-side counterpart of
launch/train.py. The dry-run covers the sharded multi-pod variant.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Compression, EmbeddingParameterConfig, MODEL_REGISTRY
from repro.train import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dbn", choices=sorted(MODEL_REGISTRY))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--pairs", type=int, default=1_000_000)
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--positions", type=int, default=10)
    args = ap.parse_args()

    attraction = EmbeddingParameterConfig(
        parameters=args.pairs, compression=Compression.HASH,
        compression_ratio=10.0, baseline_correction=True, init_logit=-2.0)
    model = MODEL_REGISTRY[args.model](query_doc_pairs=args.pairs,
                                       positions=args.positions,
                                       attraction=attraction)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        if ckpt.latest_step() is not None:
            tree, _, step = ckpt.restore(like={"params": params})
            params = tree["params"]
            print(f"[serve] restored step {step} from {args.ckpt_dir}")

    serve = jax.jit(model.predict_clicks)
    rng = np.random.default_rng(0)

    def request(batch):
        return {
            "positions": jnp.asarray(np.tile(np.arange(1, args.positions + 1),
                                             (batch, 1)), jnp.int32),
            "query_doc_ids": jnp.asarray(
                rng.integers(0, args.pairs, (batch, args.positions)),
                jnp.int32),
            "clicks": jnp.zeros((batch, args.positions), jnp.float32),
            "mask": jnp.ones((batch, args.positions), bool),
        }

    # warmup compile
    jax.block_until_ready(serve(params, request(args.batch)))
    lat = []
    for _ in range(args.requests):
        b = request(args.batch)
        t0 = time.perf_counter()
        jax.block_until_ready(serve(params, b))
        lat.append((time.perf_counter() - t0) * 1e3)
    lat = np.asarray(lat)
    print(f"[serve] {args.requests} requests x batch {args.batch}: "
          f"p50={np.percentile(lat, 50):.2f}ms "
          f"p99={np.percentile(lat, 99):.2f}ms "
          f"throughput={args.batch / lat.mean() * 1e3:.0f} sessions/s")


if __name__ == "__main__":
    main()
