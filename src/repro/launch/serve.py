"""Resilient click-prediction serving driver (the `repro.serve` engine).

    PYTHONPATH=src python -m repro.launch.serve --model pbm \
        [--models pbm,dbn,dctr] [--ckpt-dir ckpts/pbm] [--requests 200] \
        [--qps 200] [--deadline-ms 50] [--virtual-time] \
        [--fault-slow-model pbm --fault-slow-fail --fault-slow-at 0:8] \
        [--fault-poison-every 17] [--fault-sigterm-at 150]

Builds a warm multi-model registry (every model x tier x bucket compiled
before the first request), then serves a seeded Poisson arrival trace
through the full resilience stack: bounded admission queue with load
shedding, deadline-aware bucket batcher, per-model circuit breakers over
the primary -> int8 -> prior degradation ladder, fail-closed request
validation, and SIGTERM drain. Fault flags inject the chaos-drill
failures (slow/failing model, poisoned requests, mid-flight SIGTERM);
``--virtual-time`` runs the same drill on the simulated clock so its
counters are bit-deterministic. Telemetry (per-request latency metrics,
dispatch spans, breaker events, final ``serve_summary``) rides the
standard Recorder sinks.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.core import Compression, EmbeddingParameterConfig, MODEL_REGISTRY
from repro.serve import (ModelRegistry, ServeEngine, ServiceModel,
                         VirtualClock, WallClock, poisson_trace)
from repro.testing import PoisonTrace, ServeKillSwitch, SlowModel
from repro.train import CheckpointManager


def _parse_ints(text: str):
    return tuple(int(x) for x in text.split(",") if x)


def _parse_span(text):
    """"a:b" -> range(a, b); "a,b,c" -> those indices; None -> None."""
    if text is None:
        return None
    if ":" in text:
        lo, hi = text.split(":")
        return range(int(lo), int(hi))
    return _parse_ints(text)


def build_registry(args, log_fn=print) -> ModelRegistry:
    names = ([m for m in args.models.split(",") if m]
             if args.models else [args.model])
    buckets = (_parse_ints(args.buckets) if args.buckets
               else tuple(b for b in (1, 4, 16, 64, 256)
                          if b <= args.batch) + (args.batch,))
    buckets = tuple(sorted(set(buckets)))
    service_model = ServiceModel() if args.virtual_time else None
    registry = ModelRegistry(buckets=buckets, service_model=service_model)
    for name in names:
        attraction = EmbeddingParameterConfig(
            parameters=args.pairs, compression=Compression.HASH,
            compression_ratio=10.0, baseline_correction=True,
            init_logit=-2.0)
        model = MODEL_REGISTRY[name](query_doc_pairs=args.pairs,
                                     positions=args.positions,
                                     attraction=attraction)
        params = model.init(jax.random.PRNGKey(0))
        if args.ckpt_dir and len(names) == 1:
            ckpt = CheckpointManager(args.ckpt_dir)
            if ckpt.latest_step() is not None:
                tree, _, step = ckpt.restore(like={"params": params})
                params = tree["params"]
                log_fn(f"[serve] restored {name} step {step} "
                       f"from {args.ckpt_dir}")
        registry.add(name, model, params, n_pairs=args.pairs)
    return registry


def build_faults(args):
    faults = []
    if args.fault_slow_model:
        faults.append(SlowModel(
            model=args.fault_slow_model,
            delay_seconds=args.fault_slow_delay_ms * 1e-3,
            at_dispatches=_parse_span(args.fault_slow_at),
            fail=args.fault_slow_fail))
    if args.fault_sigterm_at is not None:
        faults.append(ServeKillSwitch(at_request=args.fault_sigterm_at))
    return faults


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dbn", choices=sorted(MODEL_REGISTRY))
    ap.add_argument("--models", default=None,
                    help="comma-separated list served by one process "
                         "(overrides --model)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--pairs", type=int, default=1_000_000)
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--batch", type=int, default=512,
                    help="largest batching bucket")
    ap.add_argument("--positions", type=int, default=10)
    ap.add_argument("--buckets", default=None,
                    help="explicit comma-separated bucket sizes")
    ap.add_argument("--qps", type=float, default=100.0,
                    help="Poisson arrival rate of the request trace")
    ap.add_argument("--deadline-ms", type=float, default=200.0)
    ap.add_argument("--queue-capacity", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--virtual-time", action="store_true",
                    help="simulated clock + modeled service times: "
                         "bit-deterministic counters (chaos drills)")
    ap.add_argument("--force-tier", default=None,
                    choices=["primary", "int8", "prior"])
    ap.add_argument("--fault-slow-model", default=None,
                    help="inject latency/failures into this model")
    ap.add_argument("--fault-slow-delay-ms", type=float, default=50.0)
    ap.add_argument("--fault-slow-at", default=None,
                    help="dispatch indices to hit: 'a:b' or 'i,j,k' "
                         "(default: every dispatch)")
    ap.add_argument("--fault-slow-fail", action="store_true",
                    help="raise instead of delaying (breaker trips)")
    ap.add_argument("--fault-poison-every", type=int, default=None,
                    help="poison every Nth request (validator drill)")
    ap.add_argument("--fault-sigterm-at", type=int, default=None,
                    help="SIGTERM this process when request N is admitted")
    ap.add_argument("--metrics-out", default=None,
                    help="write per-request latency metric events and the "
                         "final serve summary as JSONL telemetry")
    ap.add_argument("--trace-out", default=None,
                    help="export per-dispatch spans as Chrome-trace "
                         "JSON (Perfetto)")
    ap.add_argument("--summary-out", default=None,
                    help="write the final summary (plus health and "
                         "counters) as JSON")
    args = ap.parse_args(argv)

    from repro import obs

    recorder = obs.get_recorder()
    if args.metrics_out:
        recorder = obs.configure(sinks=[obs.JsonlSink(args.metrics_out)])

    from repro.serve.queue import AdmissionQueue

    registry = build_registry(args)
    with recorder.span("serve_warmup", buckets=str(registry.buckets)):
        registry.warmup(log_fn=print)

    models = list(registry.entries)
    trace = poisson_trace(args.requests, qps=args.qps, models=models,
                          positions_k=args.positions, n_pairs=args.pairs,
                          deadline_s=args.deadline_ms * 1e-3,
                          seed=args.seed)
    if args.fault_poison_every:
        trace = PoisonTrace(trace,
                            at=range(args.fault_poison_every - 1,
                                     args.requests,
                                     args.fault_poison_every),
                            seed=args.seed)

    clock = VirtualClock() if args.virtual_time else WallClock()
    engine = ServeEngine(
        registry,
        queue=AdmissionQueue(capacity=args.queue_capacity),
        clock=clock, recorder=recorder, faults=build_faults(args),
        force_tier=args.force_tier, log_fn=print)
    results = engine.run_trace(trace)

    summary = engine.summary(results)
    recorder.event("serve_summary", data=summary)
    recorder.flush_counters()
    if args.trace_out:
        n_spans = recorder.export_chrome_trace(args.trace_out)
        print(f"[serve] {n_spans} spans -> {args.trace_out}")
    recorder.close()
    if args.summary_out:
        with open(args.summary_out, "w") as f:
            json.dump({"summary": summary, "health": engine.health(),
                       "counters": dict(sorted(engine.stats.items()))},
                      f, indent=2, default=str)
    print(f"[serve] {summary['requests']} requests: "
          f"answered={summary['answered']} shed={summary['shed']} "
          f"rejected={summary['rejected']} degraded={summary['degraded']} "
          f"hit={summary['deadline_hit_rate']:.3f} "
          f"p50={summary['p50_ms'] if summary['p50_ms'] is None else round(summary['p50_ms'], 2)}ms "
          f"p99={summary['p99_ms'] if summary['p99_ms'] is None else round(summary['p99_ms'], 2)}ms")
    return summary


if __name__ == "__main__":
    sys.exit(0 if main() is not None else 1)
