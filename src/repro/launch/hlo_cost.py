"""While-aware static cost model over post-optimization HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, so any
scan-over-layers / microbatch-accumulation program is undercounted by the
trip count (>100x for a 126-layer scan). The CPU backend records
``"known_trip_count":{"n":...}`` in each while's backend_config, so we walk
the computation graph and multiply.

Counted per device (shapes in post-SPMD HLO are per-device):
  * flops            — 2 * result_elems * contracted_size for every dot
                       (MXU work; elementwise VPU flops are ignored — never
                       the binding term for these models),
  * bytes            — operands + results of every materialized top-level op
                       (fusion boundaries = buffer reads/writes; bitcast/
                       tuple/parameter/gte are free),
  * collective wire  — ring-model bytes per collective op
                       (all-gather (S-1)/S*out, all-reduce 2(S-1)/S*out,
                       reduce-scatter (S-1)*out, all-to-all (S-1)/S*out,
                       collective-permute out),
all scaled by the product of enclosing while trip counts.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=(?:\{\{([^}]*)\}|\[(\d+),(\d+)\]<=)")
_ARG_RE = re.compile(r"%([\w.\-]+)")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\],{}/* ]+))")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "iota", "partition-id", "replica-id"}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shapes_bytes(text: str) -> float:
    return sum(_shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_RE.findall(text))


class _Computation:
    def __init__(self, header: str):
        m = _COMP_HDR_RE.match(header)
        self.name = m.group(1)
        self.lines: List[str] = []
        self.shapes: Dict[str, str] = {}
        # parameters declared in the header: "pname: TYPE"
        for pm in re.finditer(r"([\w.\-]+):\s*", m.group(2)):
            pname = pm.group(1)
            rest = m.group(2)[pm.end():]
            # take the shape text up to the next ", name:" or end
            nxt = re.search(r",\s*[\w.\-]+:\s*", rest)
            self.shapes[pname] = rest[:nxt.start()] if nxt else rest

    def add(self, line: str):
        self.lines.append(line)
        m = _OP_RE.match(line)
        if m:
            name, result_part, _ = m.groups()
            self.shapes[name] = result_part


def _split_computations(hlo: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    current = None
    for line in hlo.splitlines():
        if line.rstrip().endswith("{") and _COMP_HDR_RE.match(line):
            current = _Computation(line)
            comps[current.name] = current
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
            else:
                current.add(line)
    return comps


def _dot_flops(line: str, comp: _Computation) -> float:
    eq = line.index("=")
    dot_at = line.index(" dot(")
    result_elems = sum(_shape_elems(d)
                       for _, d in _SHAPE_RE.findall(line[eq + 1:dot_at]))
    args_txt = line[dot_at + 5:line.index(")", dot_at)]
    arg_names = _ARG_RE.findall(args_txt)
    inline_shapes = _SHAPE_RE.findall(args_txt)
    if inline_shapes:
        lhs_dims = [int(d) for d in inline_shapes[0][1].split(",") if d]
    elif arg_names:
        lhs_shape = comp.shapes.get(arg_names[0], "")
        ms = _SHAPE_RE.search(lhs_shape)
        lhs_dims = [int(d) for d in ms.group(2).split(",") if d] if ms else []
    else:
        lhs_dims = []
    m = _CONTRACT_RE.search(line)
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                contract *= lhs_dims[int(idx)]
    return 2.0 * result_elems * contract


def _collective_wire(line: str, op: str) -> float:
    eq = line.index("=")
    paren = line.index(f" {op}", eq)
    out_bytes = _shapes_bytes(line[eq + 1:paren])
    g = _GROUPS_RE.search(line)
    group = 2
    if g:
        if g.group(1) is not None:
            group = max(len([x for x in g.group(1).split(",") if x.strip()]), 1)
        else:
            group = max(int(g.group(3)), 1)
    s = max(group, 2)
    ring = (s - 1) / s
    if op.startswith("all-reduce"):
        return 2 * ring * out_bytes
    if op.startswith("all-gather"):
        return ring * out_bytes
    if op.startswith("reduce-scatter"):
        return ring * out_bytes * s
    if op.startswith("all-to-all"):
        return ring * out_bytes
    return out_bytes  # collective-permute


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps = _split_computations(hlo_text)
        self._cache: Dict[Tuple[str, bool], tuple] = {}
        self.unknown_trip_loops = 0
        entry_m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.M)
        self.entry = entry_m.group(1) if entry_m else list(self.comps)[-1]

    def analyze(self) -> dict:
        flops, bytes_, wire, per_op = self._walk(self.entry, flops_only=False)
        return {
            "flops": flops,
            "bytes": bytes_,
            "collective_wire_bytes": wire,
            "collective_ops": dict(per_op),
            "unknown_trip_loops": self.unknown_trip_loops,
        }

    def _walk(self, comp_name: str, flops_only: bool):
        key = (comp_name, flops_only)
        if key in self._cache:
            return self._cache[key]
        self._cache[key] = (0.0, 0.0, 0.0, {})  # recursion guard
        comp = self.comps.get(comp_name)
        flops = bytes_ = wire = 0.0
        per_op: Dict[str, float] = defaultdict(float)
        if comp is None:
            return 0.0, 0.0, 0.0, per_op
        for line in comp.lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            _, result_part, op = m.groups()
            if op in _FREE_OPS:
                continue
            if op == "dot":
                flops += _dot_flops(line, comp)
                if not flops_only:
                    bytes_ += _shapes_bytes(result_part) * 2  # approx io
                continue
            if op == "while":
                trip = 1
                t = _TRIP_RE.search(line)
                if t:
                    trip = int(t.group(1))
                else:
                    self.unknown_trip_loops += 1
                b = _BODY_RE.search(line)
                if b:
                    f2, b2, w2, p2 = self._walk(b.group(1), flops_only)
                    flops += trip * f2
                    bytes_ += trip * b2
                    wire += trip * w2
                    for k, v in p2.items():
                        per_op[k] += trip * v
                continue
            if op == "fusion":
                called = _CALLS_RE.search(line)
                if called:
                    f2, _, _, _ = self._walk(called.group(1), True)
                    flops += f2
                if not flops_only:
                    bytes_ += _shapes_bytes(line)
                continue
            if op in ("call", "conditional", "async-start"):
                called = _CALLS_RE.search(line) or _CALLS_RE.search(line)
                target = (_CALLS_RE.search(line) or _BODY_RE.search(line))
                if target:
                    f2, b2, w2, p2 = self._walk(target.group(1), flops_only)
                    flops += f2
                    bytes_ += b2
                    wire += w2
                    for k, v in p2.items():
                        per_op[k] += v
                continue
            base_op = op.replace("-start", "").replace("-done", "")
            if base_op in COLLECTIVES:
                if not op.endswith("-done"):
                    w = _collective_wire(line, op)
                    wire += w
                    per_op[base_op] += w
                    if not flops_only:
                        bytes_ += _shapes_bytes(result_part)
                continue
            if not flops_only:
                bytes_ += _shapes_bytes(line)
        out = (flops, bytes_, wire, dict(per_op))
        self._cache[key] = out
        return out


def analyze_hlo(hlo_text: str) -> dict:
    return HloCost(hlo_text).analyze()
