"""Production training launcher for CLAX click models.

In-memory path (log must fit in host RAM):

    PYTHONPATH=src python -m repro.launch.train --model ubm \
        [--sessions 200000] [--epochs 20] [--ckpt-dir ckpts/ubm] \
        [--compression hash --ratio 10] [--host-id 0 --host-count 1]

Out-of-core path — ingest once into a sharded on-disk session store, then
stream batches from it (peak data memory is O(chunk + shard), so the log can
be far larger than RAM):

    PYTHONPATH=src python -m repro.launch.train --model ubm \
        --store-dir /data/clicklog --ingest --sessions 100000000 \
        [--chunk-sessions 1000000] [--shard-rows 1000000] \
        [--ingest-workers 8] [--store-codec auto]

``--ingest-workers N`` fans chunk synthesis + shard writing over N worker
processes (byte-identical output to serial); ``--store-codec auto``
compresses each column per shard (bitpack/zlib/raw, chosen from the bytes).

A directory that already holds ingested ``train/val/test`` stores is reused
when ``--ingest`` is omitted; the model is sized from the ``SyntheticConfig``
recorded in the store manifest, so train-from-store needs no generation
flags at all.

Training-engine knobs (see README "Training engine"): ``--chunk-batches N``
fuses N optimizer steps into one scan-jitted dispatch, ``--data-parallel``
shards the batch axis over all local devices, ``--sparse-tables`` switches
embedding tables to lazy-AdamW scatter updates. Sweep knobs (README
"Sweeps"): ``--replicas R`` trains R seed/lr variants in one vmapped run,
with ``--replica-seeds`` / ``--replica-lrs`` setting the per-replica knobs.

Fault tolerance (see README "Fault tolerance"): ``--max-restarts N``
supervises training in a child process and relaunches it after crashes
(resuming from ``--ckpt-dir``), ``--verify-store`` crc-checks shards at
read time with ``--corrupt-shards raise|skip`` deciding policy,
``--nonfinite-guard`` skips non-finite optimizer steps on-device, and
``--fault-kill-at-step`` arms a chaos-test kill switch.

Single-host here; at pod scale the same entry point runs per host with
--host-id/--host-count carving the data shard (rows of the in-memory dict,
or whole store shards for the streaming path) and jax.distributed
initializing the mesh — the dry-run (repro/launch/dryrun.py) proves the
sharded program compiles for the production meshes.
"""
from __future__ import annotations

import argparse
import os
import signal
import sys

from repro import optim
from repro.core import (Compression, EmbeddingParameterConfig, MODEL_REGISTRY)
from repro.data import (ClickLogLoader, SessionStore, StreamingClickLogLoader,
                        SyntheticConfig, generate_click_log, ingest_synthetic,
                        split_sessions)
from repro.train import Trainer


def _synthetic_config(args) -> SyntheticConfig:
    return SyntheticConfig(n_sessions=args.sessions,
                           n_queries=max(args.sessions // 100, 1),
                           docs_per_query=20, positions=10, behavior="dbn",
                           seed=args.seed)


def make_loaders(args):
    """Returns (train_loader, val_loader, test_loader, data_cfg) where
    data_cfg is the SyntheticConfig describing the data (for the store path,
    reconstructed from the manifest metadata, so models are sized against
    what was actually ingested)."""
    if args.store_dir:
        if args.ingest:
            cfg = _synthetic_config(args)
            chunk = args.chunk_sessions or max(args.sessions // 20, 1)
            print(f"[train] ingesting {cfg.n_sessions} sessions into "
                  f"{args.store_dir} (chunk={chunk}, "
                  f"shard_rows={args.shard_rows}, "
                  f"codec={args.store_codec}, "
                  f"workers={args.ingest_workers})")
            ingest_synthetic(cfg, args.store_dir, chunk_sessions=chunk,
                             shard_rows=args.shard_rows,
                             splits={"train": 0.8, "val": 0.1, "test": 0.1},
                             codec=args.store_codec,
                             workers=args.ingest_workers)
        train_store = SessionStore(os.path.join(args.store_dir, "train"))
        syn = train_store.metadata.get("synthetic_config")
        if syn is None:
            raise SystemExit(
                f"{args.store_dir}/train has no synthetic_config metadata — "
                "was it ingested with --ingest / ingest_synthetic?")
        data_cfg = SyntheticConfig(**syn)
        train = StreamingClickLogLoader(train_store, batch_size=args.batch,
                                        seed=args.seed, host_id=args.host_id,
                                        host_count=args.host_count,
                                        window_rows=args.window_rows,
                                        verify_checksums=args.verify_store,
                                        corrupt_policy=args.corrupt_shards,
                                        io_retries=args.io_retries)
        val = StreamingClickLogLoader(os.path.join(args.store_dir, "val"),
                                      batch_size=8192, shuffle=False,
                                      drop_last=False)
        test = StreamingClickLogLoader(os.path.join(args.store_dir, "test"),
                                       batch_size=8192, shuffle=False,
                                       drop_last=False)
        return train, val, test, data_cfg

    cfg = _synthetic_config(args)
    data, _ = generate_click_log(cfg)
    train, val, test = split_sessions(data, (0.8, 0.1, 0.1), seed=args.seed)
    return (ClickLogLoader(train, batch_size=args.batch, seed=args.seed,
                           host_id=args.host_id, host_count=args.host_count),
            ClickLogLoader(val, batch_size=8192, shuffle=False, drop_last=False),
            ClickLogLoader(test, batch_size=8192, shuffle=False, drop_last=False),
            cfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="ubm", choices=sorted(MODEL_REGISTRY))
    ap.add_argument("--sessions", type=int, default=200_000)
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--compression", default="none",
                    choices=["none", "hash", "quotient_remainder"])
    ap.add_argument("--ratio", type=float, default=10.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--host-count", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store-dir", default=None,
                    help="session-store directory; train via the streaming "
                         "out-of-core loader instead of in-memory arrays")
    ap.add_argument("--ingest", action="store_true",
                    help="synthesize --sessions sessions chunk-by-chunk into "
                         "--store-dir/{train,val,test} before training")
    ap.add_argument("--chunk-sessions", type=int, default=None,
                    help="ingest chunk size in sessions (default: sessions/20)")
    ap.add_argument("--shard-rows", type=int, default=1_000_000,
                    help="rows per store shard (unit of shuffle/host placement)")
    ap.add_argument("--ingest-workers", type=int, default=1,
                    help="worker processes for --ingest; each owns a "
                         "disjoint shard block per split, byte-identical "
                         "output to --ingest-workers 1")
    ap.add_argument("--store-codec", default="auto", choices=["auto", "raw"],
                    help="per-column store codec for --ingest: 'auto' picks "
                         "bitpack/zlib/raw per column per shard; 'raw' pins "
                         "the v1-byte-compatible memmap layout")
    ap.add_argument("--window-rows", type=int, default=None,
                    help="streaming read window within a shard (default: full "
                         "shard)")
    ap.add_argument("--chunk-batches", type=int, default=8,
                    help="batches fused into one scan-jitted dispatch "
                         "(1 = the historical per-batch loop, bit-exact)")
    ap.add_argument("--data-parallel", action="store_true",
                    help="shard the batch axis over all local devices "
                         "(requires --batch divisible by the device count)")
    ap.add_argument("--sparse-tables", action="store_true",
                    help="lazy-AdamW scatter updates for embedding tables: "
                         "optimizer state traffic O(unique batch rows) "
                         "instead of O(table rows)")
    ap.add_argument("--replicas", type=int, default=None,
                    help="train R independent replicas in one vmapped sweep "
                         "(R x params/opt-state memory, 1x data; one scan "
                         "dispatch advances all runs)")
    ap.add_argument("--replica-lrs", type=float, nargs="+", default=None,
                    help="one learning rate per replica (default: --lr for "
                         "all); switches the optimizer to inject_lr=True")
    ap.add_argument("--replica-seeds", type=int, nargs="+", default=None,
                    help="one init seed per replica (default: --seed + i)")
    ap.add_argument("--max-restarts", type=int, default=0,
                    help="supervise training in a child process and relaunch "
                         "it after crashes up to N times; resumes from "
                         "--ckpt-dir (required)")
    ap.add_argument("--verify-store", action="store_true",
                    help="crc32-verify every store shard's columns at read "
                         "time (streaming path)")
    ap.add_argument("--corrupt-shards", default="raise",
                    choices=["raise", "skip"],
                    help="what a corrupt train shard does under "
                         "--verify-store: fail the run, or quarantine the "
                         "shard and keep training deterministically")
    ap.add_argument("--io-retries", type=int, default=2,
                    help="transient shard-read failures retried with "
                         "exponential backoff (streaming path)")
    ap.add_argument("--nonfinite-guard", action="store_true",
                    help="detect non-finite loss/grads on-device and skip "
                         "those optimizer steps (counted in history as "
                         "skipped_steps)")
    ap.add_argument("--step-budget-seconds", type=float, default=None,
                    help="flag steps slower than this wall-clock budget "
                         "(watchdog_violations in history)")
    ap.add_argument("--fault-kill-at-step", type=int, default=None,
                    help="CHAOS TESTING: kill this process when train batch "
                         "N is produced — armed only while --ckpt-dir has "
                         "no committed checkpoint, so a restarted run "
                         "completes")
    ap.add_argument("--fault-kill-signal", default="KILL",
                    choices=["TERM", "KILL"],
                    help="signal --fault-kill-at-step sends (TERM exercises "
                         "graceful preemption, KILL an instant crash)")
    ap.add_argument("--kernel-impl", default=None,
                    choices=["pallas", "ref", "xla"],
                    help="force every repro.kernels op onto one "
                         "implementation (default: backend-resolved — "
                         "pallas on TPU, xla elsewhere); equivalent to "
                         "CLAX_KERNEL_IMPL but set before the engine traces")
    ap.add_argument("--metrics-out", default=None,
                    help="write structured telemetry events (JSONL, one per "
                         "line — see README 'Observability') to this file; "
                         "also enables the engine's on-device per-step "
                         "grad/param-norm series")
    ap.add_argument("--trace-out", default=None,
                    help="export host wall-time spans (epoch/eval/checkpoint/"
                         "shard_read/...) as a Chrome-trace JSON for Perfetto "
                         "at the end of the run")
    ap.add_argument("--obs-every", type=int, default=1,
                    help="emit every Nth per-step train metric event "
                         "(loss/grad-norm/...); skips and epoch records are "
                         "always emitted")
    ap.add_argument("--profile-steps", default=None, metavar="A:B",
                    help="open a jax.profiler trace window around the chunk "
                         "dispatches covering global steps A..B")
    ap.add_argument("--profile-dir", default="profile",
                    help="directory the --profile-steps trace is written to")
    ap.add_argument("--emit-roofline", action="store_true",
                    help="emit the compiled chunk step's static HLO cost "
                         "(flops/bytes, while-loops scaled by trip count) as "
                         "a roofline telemetry event (one extra AOT compile)")
    args = ap.parse_args()
    if args.ingest_workers < 1:
        ap.error(f"--ingest-workers must be >= 1, got {args.ingest_workers}")
    if (args.ingest_workers > 1 or args.store_codec != "auto") \
            and not args.store_dir:
        ap.error("--ingest-workers/--store-codec only apply to the store "
                 "path — pass --store-dir (and --ingest)")
    if args.max_restarts:
        if not args.ckpt_dir:
            ap.error("--max-restarts requires --ckpt-dir (the restarted "
                     "child resumes from it)")
        from repro.train import run_with_restarts

        # Re-run this exact invocation as a supervised child, minus the
        # --max-restarts flag itself (the child must not recurse).
        child_args, skip = [], False
        for a in sys.argv[1:]:
            if skip:
                skip = False
                continue
            if a == "--max-restarts":
                skip = True
                continue
            if a.startswith("--max-restarts="):
                continue
            child_args.append(a)
        raise SystemExit(run_with_restarts(
            [sys.executable, "-m", "repro.launch.train"] + child_args,
            args.max_restarts))
    if args.ingest and not args.store_dir:
        ap.error("--ingest requires --store-dir")
    if args.sparse_tables and args.compression == "quotient_remainder":
        # fail before a potentially hours-long ingest, not inside train()
        ap.error("--sparse-tables does not support quotient_remainder "
                 "compression (two coupled tables, no single row-id stream)")
    if args.replicas is None and (args.replica_lrs or args.replica_seeds):
        ap.error("--replica-lrs/--replica-seeds require --replicas")
    for name, knob in (("--replica-lrs", args.replica_lrs),
                       ("--replica-seeds", args.replica_seeds)):
        if knob is not None and len(knob) != args.replicas:
            ap.error(f"{name} needs exactly --replicas {args.replicas} values")
    if args.replica_lrs and args.sparse_tables:
        ap.error("--replica-lrs is not supported with --sparse-tables (the "
                 "lazy-AdamW lr is a static hyperparameter shared by all "
                 "replicas); per-seed sweeps (--replica-seeds) are fine")

    if args.kernel_impl:
        # Before anything traces: the dispatch registry resolves at trace
        # time, so the override must exist before the engine compiles.
        from repro.kernels import set_impl_override

        set_impl_override(args.kernel_impl)

    # Observability: configure the process-global recorder BEFORE the loaders
    # exist so the streaming data plane's spans/counters land in the same
    # stream. Spans are always captured in the host ring buffer (for
    # --trace-out); the JSONL sink is attached only under --metrics-out.
    from repro import obs

    recorder = obs.get_recorder()
    if args.metrics_out:
        recorder = obs.configure(sinks=[obs.JsonlSink(args.metrics_out)])
        print(f"[train] telemetry -> {args.metrics_out}")

    mesh = None
    if args.data_parallel:
        from repro.launch.mesh import make_data_parallel_mesh

        mesh = make_data_parallel_mesh()
        print(f"[train] data-parallel mesh: {dict(mesh.shape)}")

    train_loader, val_loader, test_loader, data_cfg = make_loaders(args)

    if args.fault_kill_at_step is not None:
        from repro.testing import KillSwitch

        has_ckpt = bool(args.ckpt_dir) and os.path.isdir(args.ckpt_dir) and any(
            n.startswith("step_") and
            os.path.exists(os.path.join(args.ckpt_dir, n, "COMMIT"))
            for n in os.listdir(args.ckpt_dir))
        if not has_ckpt:
            sig = (signal.SIGKILL if args.fault_kill_signal == "KILL"
                   else signal.SIGTERM)
            train_loader = KillSwitch(train_loader, args.fault_kill_at_step,
                                      sig=sig)
            print(f"[train] chaos: SIG{args.fault_kill_signal} armed at "
                  f"train batch {args.fault_kill_at_step}")

    attraction = EmbeddingParameterConfig(
        parameters=data_cfg.n_query_doc_pairs,
        compression=Compression(args.compression),
        compression_ratio=args.ratio,
        baseline_correction=True, init_logit=-2.0)
    model = MODEL_REGISTRY[args.model](
        query_doc_pairs=data_cfg.n_query_doc_pairs,
        positions=data_cfg.positions,
        attraction=attraction)

    optimizer = optim.adamw(args.lr, weight_decay=1e-4,
                            inject_lr=args.replica_lrs is not None)
    trainer = Trainer(optimizer=optimizer,
                      epochs=args.epochs, patience=1,
                      checkpoint_dir=args.ckpt_dir,
                      checkpoint_every_steps=200 if args.ckpt_dir else None,
                      handle_preemption=True,
                      chunk_batches=args.chunk_batches, mesh=mesh,
                      sparse_tables=args.sparse_tables,
                      # must mirror the dense optimizer above — the sparse
                      # path cannot introspect the transformation chain
                      sparse_table_kwargs=dict(lr=args.lr, weight_decay=1e-4),
                      replicas=args.replicas,
                      replica_lrs=args.replica_lrs,
                      replica_seeds=args.replica_seeds,
                      nonfinite_guard=args.nonfinite_guard,
                      step_budget_seconds=args.step_budget_seconds,
                      seed=args.seed,
                      telemetry=bool(args.metrics_out),
                      obs_every=args.obs_every,
                      profile_steps=args.profile_steps,
                      profile_dir=args.profile_dir,
                      emit_roofline=args.emit_roofline)
    try:
        trainer.train(model, train_loader, val_loader,
                      resume=bool(args.ckpt_dir))
        results = trainer.test(model, test_loader)
    finally:
        if args.trace_out:
            n_spans = recorder.export_chrome_trace(args.trace_out)
            print(f"[train] {n_spans} spans -> {args.trace_out} "
                  "(open in Perfetto / chrome://tracing)")
        recorder.flush_counters()
        recorder.close()
    if args.replicas is None:
        print("[train] test:", {k: round(v, 4) for k, v in results.items()
                                if k != "per_rank"})
    else:
        for i in range(args.replicas):
            print(f"[train] test replica {i}:",
                  {k: round(v[i], 4) for k, v in results.items()
                   if k != "per_rank"})


if __name__ == "__main__":
    main()
