"""Production training launcher for CLAX click models.

    PYTHONPATH=src python -m repro.launch.train --model ubm \
        [--sessions 200000] [--epochs 20] [--ckpt-dir ckpts/ubm] \
        [--compression hash --ratio 10] [--host-id 0 --host-count 1]

Single-host here; at pod scale the same entry point runs per host with
--host-id/--host-count carving the data shard (repro/data/loader.py) and
jax.distributed initializing the mesh — the dry-run (repro/launch/dryrun.py)
proves the sharded program compiles for the production meshes.
"""
from __future__ import annotations

import argparse

from repro import optim
from repro.core import (Compression, EmbeddingParameterConfig, MODEL_REGISTRY)
from repro.data import ClickLogLoader, SyntheticConfig, generate_click_log, split_sessions
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="ubm", choices=sorted(MODEL_REGISTRY))
    ap.add_argument("--sessions", type=int, default=200_000)
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--compression", default="none",
                    choices=["none", "hash", "quotient_remainder"])
    ap.add_argument("--ratio", type=float, default=10.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--host-count", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = SyntheticConfig(n_sessions=args.sessions, n_queries=args.sessions // 100,
                          docs_per_query=20, positions=10, behavior="dbn",
                          seed=args.seed)
    data, _ = generate_click_log(cfg)
    train, val, test = split_sessions(data, (0.8, 0.1, 0.1), seed=args.seed)

    attraction = EmbeddingParameterConfig(
        parameters=cfg.n_query_doc_pairs,
        compression=Compression(args.compression),
        compression_ratio=args.ratio,
        baseline_correction=True, init_logit=-2.0)
    model = MODEL_REGISTRY[args.model](
        query_doc_pairs=cfg.n_query_doc_pairs, positions=10,
        attraction=attraction)

    trainer = Trainer(optimizer=optim.adamw(args.lr, weight_decay=1e-4),
                      epochs=args.epochs, patience=1,
                      checkpoint_dir=args.ckpt_dir,
                      checkpoint_every_steps=200 if args.ckpt_dir else None,
                      handle_preemption=True)
    loader = ClickLogLoader(train, batch_size=args.batch, seed=args.seed,
                            host_id=args.host_id, host_count=args.host_count)
    trainer.train(model, loader,
                  ClickLogLoader(val, batch_size=8192, shuffle=False,
                                 drop_last=False),
                  resume=bool(args.ckpt_dir))
    results = trainer.test(model, ClickLogLoader(test, batch_size=8192, shuffle=False,
                                                 drop_last=False))
    print("[train] test:", {k: round(v, 4) for k, v in results.items()
                            if k != "per_rank"})


if __name__ == "__main__":
    main()
