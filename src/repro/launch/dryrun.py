import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production meshes and record memory/cost/collective analyses.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch deepfm --shape train_batch
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--extra]
#
# Outputs one JSON per cell under experiments/dryrun/ — consumed by
# benchmarks/roofline.py (EXPERIMENTS.md §Dry-run / §Roofline).
# (module docstring intentionally a comment: the XLA_FLAGS lines above must
# stay the first statements, and __future__ imports must lead the file.)

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import registry
from repro.launch.hlo_cost import analyze_hlo
from repro.compat import set_mesh
from repro.launch.mesh import make_production_mesh

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_OP_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=(?:\{\{([^}]*)\}|\[(\d+),(\d+)\]<=)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str):
    """Per-device wire-byte estimate per collective (ring algorithm model)."""
    totals = {op: 0.0 for op in COLLECTIVES}
    counts = {op: 0 for op in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_body, dtype, dims, op = m.groups()
        if tuple_body is not None:
            out_bytes = sum(_shape_bytes(dt, dm)
                            for dt, dm in _SHAPE_RE.findall(tuple_body))
        else:
            out_bytes = _shape_bytes(dtype, dims)
        g = _GROUPS_RE.search(line)
        group = 1
        if g:
            if g.group(1) is not None:
                # explicit form {{0,1,...},{...}}: first group's member count
                group = len([x for x in g.group(1).split(",") if x.strip()])
            else:
                # iota form [n_groups,group_size]<=[n_devices]
                group = max(int(g.group(3)), 1)
        s = max(group, 2)
        ring = (s - 1) / s
        if op == "all-reduce":
            wire = 2 * ring * out_bytes
        elif op == "all-gather":
            wire = ring * out_bytes
        elif op == "reduce-scatter":
            wire = ring * out_bytes * s  # input is s x output
        elif op == "all-to-all":
            wire = ring * out_bytes
        else:  # collective-permute
            wire = out_bytes
        totals[op] += wire
        counts[op] += 1
    return {"wire_bytes_per_device": totals, "op_counts": counts,
            "total_wire_bytes_per_device": sum(totals.values())}


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    with set_mesh(mesh):
        cell = registry.build_cell(arch, shape, mesh)
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # while-aware static cost walk (XLA cost_analysis counts loop bodies
    # once; scans make it useless — see repro/launch/hlo_cost.py)
    walk = analyze_hlo(hlo)
    coll = parse_collectives(hlo)
    record = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "kind": cell.kind, "notes": cell.notes,
        "model_flops": cell.model_flops,
        "lower_seconds": round(t_lower, 2),
        "compile_seconds": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
        },
        "cost": {
            "flops_per_device": walk["flops"],
            "bytes_accessed_per_device": walk["bytes"],
            "xla_flops_per_device_loopbody_once": cost.get("flops", 0.0),
            "xla_bytes_per_device_loopbody_once": cost.get("bytes accessed", 0.0),
            "unknown_trip_loops": walk["unknown_trip_loops"],
        },
        "collectives": {
            "wire_bytes_per_device": walk["collective_ops"],
            "total_wire_bytes_per_device": walk["collective_wire_bytes"],
            "op_counts_loopbody_once": coll["op_counts"],
        },
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    if save_hlo:
        with open(path.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo)
    print(f"[dryrun] OK {arch} x {shape} x {mesh_name}: "
          f"peak={record['memory']['peak_bytes_per_device']/2**30:.2f}GiB/dev "
          f"flops={record['cost']['flops_per_device']:.3e}/dev "
          f"wire={coll['total_wire_bytes_per_device']/2**20:.1f}MiB/dev "
          f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--extra", action="store_true",
                    help="also run the paper-own CLAX cells")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    cells = (registry.list_cells(include_extra=args.extra) if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp, args.out, save_hlo=args.save_hlo)
            except Exception as e:  # noqa: BLE001 - report and continue
                failures.append((arch, shape, mp, repr(e)))
                print(f"[dryrun] FAIL {arch} x {shape} "
                      f"multi_pod={mp}: {e}")
                traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", f)
        raise SystemExit(1)
    print(f"[dryrun] all {len(cells) * len(meshes)} cells compiled")


if __name__ == "__main__":
    main()
