"""Production mesh definitions (brief: 16x16 single pod, 2x16x16 multi-pod).

A function, not a module-level constant, so importing never touches jax
device state.
"""
from __future__ import annotations

from repro.compat import make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_smoke_mesh(n_devices: int = 1):
    """Single-host mesh for tests: (1, n) data x model."""
    return make_auto_mesh((1, n_devices), ("data", "model"))


def make_data_parallel_mesh(n_devices: int | None = None):
    """(n, 1) data x model mesh over all local devices: batches split over
    'data', params (``clax_param_rule``) land on the size-1 'model' axis —
    i.e. replicated across the data ranks. The shape every single-host
    ``--data-parallel`` training run uses."""
    import jax

    n = n_devices or jax.local_device_count()
    return make_auto_mesh((n, 1), ("data", "model"))
