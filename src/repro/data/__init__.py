"""Data pipeline: synthetic click-log simulation, out-of-core session store,
and sharded, resumable in-memory + streaming loading."""
from repro.data.loader import ClickLogLoader, DevicePrefetcher, split_sessions
from repro.data.store import (SessionStore, SessionStoreWriter,
                              ShardCorruptionError, ingest_synthetic,
                              write_session_store)
from repro.data.streaming import StreamingClickLogLoader, StreamingLoaderState
from repro.data.synthetic import (SyntheticConfig, generate_click_log,
                                  iter_click_log_chunks, make_features)

__all__ = [
    "SyntheticConfig",
    "generate_click_log",
    "iter_click_log_chunks",
    "make_features",
    "ClickLogLoader",
    "DevicePrefetcher",
    "split_sessions",
    "SessionStore",
    "SessionStoreWriter",
    "ShardCorruptionError",
    "write_session_store",
    "ingest_synthetic",
    "StreamingClickLogLoader",
    "StreamingLoaderState",
]
