"""Data pipeline: synthetic click-log simulation + sharded, resumable loading."""
from repro.data.synthetic import SyntheticConfig, generate_click_log, make_features
from repro.data.loader import ClickLogLoader, DevicePrefetcher, split_sessions

__all__ = [
    "SyntheticConfig",
    "generate_click_log",
    "make_features",
    "ClickLogLoader",
    "DevicePrefetcher",
    "split_sessions",
]
