"""Data pipeline: synthetic click-log simulation, out-of-core session store,
and sharded, resumable in-memory + streaming loading."""
from repro.data.loader import ClickLogLoader, DevicePrefetcher, split_sessions
from repro.data.store import (SessionStore, SessionStoreWriter,
                              ShardCorruptionError, write_session_store)
# the package-level ingest_synthetic is the worker-aware entrypoint
# (workers=1 == the serial reference implementation in repro.data.store)
from repro.data.ingest import ingest_chunks, ingest_synthetic
from repro.data.streaming import StreamingClickLogLoader, StreamingLoaderState
from repro.data.synthetic import (SyntheticConfig, generate_click_log,
                                  iter_click_log_chunks, make_features,
                                  synthesize_chunk)

__all__ = [
    "SyntheticConfig",
    "generate_click_log",
    "iter_click_log_chunks",
    "synthesize_chunk",
    "make_features",
    "ClickLogLoader",
    "DevicePrefetcher",
    "split_sessions",
    "SessionStore",
    "SessionStoreWriter",
    "ShardCorruptionError",
    "write_session_store",
    "ingest_synthetic",
    "ingest_chunks",
    "StreamingClickLogLoader",
    "StreamingLoaderState",
]
