"""Streaming loader over an on-disk :class:`repro.data.store.SessionStore`.

Trains on click logs far larger than host RAM with the same contract as the
in-memory ``ClickLogLoader``: deterministic shuffling, bit-exact mid-epoch
checkpoint/resume, host sharding for multi-host data parallelism, and an
iterator of numpy batch dicts that plugs straight into ``DevicePrefetcher``.

How the epoch stream is defined (all deterministic in ``(seed, epoch)``):

1. **Shard order** — the host's assigned shards (``shard_id % host_count ==
   host_id``: placement at shard granularity, no row-level coordination)
   are permuted by ``rng((seed, epoch, 0))``.
2. **In-shard order** — each shard's rows are permuted by
   ``rng((seed, epoch, 1 + shard_id))``. Row payloads are read only
   ``window_rows`` of that permutation at a time (default: one whole
   shard), so peak reader memory is O(window * (1 + read_ahead)) row
   payloads plus one O(shard_rows) index permutation (8 bytes/row, small
   next to the rows it orders) — never O(log).
3. **Batching** — batches of ``batch_size`` are cut sequentially from the
   concatenated stream, spanning shard boundaries; ``drop_last`` matches
   ``ClickLogLoader``.

A **single-shard** store (one host) uses in-shard seed ``(seed, epoch)`` —
exactly ``ClickLogLoader._epoch_order`` — so the streaming loader is a
drop-in replacement that reproduces the in-memory loader's batch stream
bit-for-bit (tested in tests/test_store.py). With ``shuffle=False`` the
stream is the store's row order for any shard count.

The cursor ``(epoch, shard, step)`` checkpoints like ``LoaderState``:
``step * batch_size`` locates the resume row inside the deterministic epoch
stream by pure arithmetic over the manifest's per-shard row counts, so
resume skips already-consumed shards without reading them.

A background read-ahead thread stages upcoming permuted windows into a
bounded queue so disk reads overlap compute; the consuming iterator (and
``DevicePrefetcher`` above it) sees plain numpy batches either way.

**Self-healing** (all opt-in, off by default so the fast path is
byte-identical to the unhardened loader):

* ``verify_checksums=True`` re-checks the manifest's crc32 for every column
  the loader reads, at shard-open time — the store has always *written*
  checksums; this is the read path that finally consumes them.
* ``io_retries=K`` retries a failed shard open/verify up to K times with
  exponential backoff (``io_retry_backoff * 2**attempt``) — transient
  ``OSError`` only; corruption is deterministic and never retried.
* ``corrupt_policy`` decides what a :class:`ShardCorruptionError` does:
  ``"raise"`` (default) surfaces it; ``"skip"`` **quarantines** the shard —
  it contributes zero rows from the moment of detection, the quarantine set
  rides in ``state_dict`` so resume excludes it from the cursor arithmetic,
  and every later epoch skips it up front. Corruption is detected at shard
  open, *before* any of its rows are delivered, so the delivered stream is
  exactly the fault-free stream minus the quarantined shard's rows —
  deterministic and replayable. Quarantine is per-host state; with
  ``host_count > 1`` the policy must stay ``"raise"`` (hosts dropping
  different shards would desync the step count).
* The consumer side watches the read-ahead producer: a producer that dies
  with a transient error is restarted once (``watchdog_restarts``) from the
  first window it had not yet delivered — already-queued windows are never
  re-read, so the batch stream is unchanged — before the error is surfaced
  with its original traceback. **Shutdown unconditionally wins over the
  watchdog**: after :meth:`StreamingClickLogLoader.close` (callable from
  any thread — e.g. the trainer thread while the overlapped
  ``DevicePrefetcher``'s staging thread consumes the epoch), a dying
  producer is never restarted, and a restart is also refused while the old
  producer thread is still alive after its join timeout (two producers
  feeding one queue would interleave windows nondeterministically).

Compressed stores (format v2) change none of the above: ``open_shard``
decodes in the read-ahead thread, checksum verification covers the stored
bytes, and a corrupt compressed column raises the same
``ShardCorruptionError`` through the same fail-closed / quarantine paths.
``stream.bytes_stored`` counts bytes as stored on disk next to
``stream.bytes_read``'s decoded bytes — their ratio is the live
compression factor of the read path.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.loader import MODEL_KEYS
from repro.data.store import SessionStore, ShardCorruptionError, _take_rows
from repro.obs import get_recorder

CORRUPT_POLICIES = ("raise", "skip")


@dataclasses.dataclass
class StreamingLoaderState:
    """Resumable cursor. ``epoch``/``step`` are authoritative (``step`` is the
    batch index within the epoch, as in ``LoaderState``); ``shard`` records
    the epoch-order position of the shard the last batch was drawn from
    (derived — kept for observability and log messages)."""
    epoch: int = 0
    step: int = 0
    shard: int = 0

    def to_dict(self):
        return {"epoch": self.epoch, "step": self.step, "shard": self.shard}

    @classmethod
    def from_dict(cls, d):
        return cls(epoch=int(d["epoch"]), step=int(d["step"]),
                   shard=int(d.get("shard", 0)))


class _WorkerError:
    def __init__(self, error: BaseException):
        self.error = error


_DONE = object()


class StreamingClickLogLoader:
    """Deterministic, checkpointable, out-of-core batch loader.

    Same surface as ``ClickLogLoader`` (``__iter__`` runs one epoch,
    ``epochs(n)``, ``batches_per_epoch``, ``state_dict``/``load_state_dict``)
    but backed by a :class:`SessionStore` instead of an in-memory dict.
    See the module docstring for the self-healing knobs
    (``verify_checksums``, ``io_retries``, ``corrupt_policy``,
    ``watchdog_restarts``).
    """

    def __init__(self, store, batch_size: int, shuffle: bool = True,
                 seed: int = 0, drop_last: bool = True,
                 host_id: int = 0, host_count: int = 1,
                 include_keys: Optional[Tuple[str, ...]] = None,
                 window_rows: Optional[int] = None, read_ahead: int = 2,
                 verify_checksums: bool = False,
                 corrupt_policy: str = "raise",
                 io_retries: int = 0, io_retry_backoff: float = 0.05,
                 watchdog_restarts: int = 1, log_fn=print, recorder=None):
        self.store = (SessionStore(store)
                      if isinstance(store, (str, os.PathLike)) else store)
        if host_count > 1 and self.store.n_shards < host_count:
            raise ValueError(
                f"store has {self.store.n_shards} shards but host_count="
                f"{host_count}: sharding is at shard granularity — re-ingest "
                "with smaller shard_rows")
        if host_count > 1 and not drop_last:
            raise ValueError(
                "drop_last=False with host_count > 1 would give hosts "
                "different final-batch shapes; multi-host training requires "
                "drop_last=True")
        if corrupt_policy not in CORRUPT_POLICIES:
            raise ValueError(f"corrupt_policy must be one of "
                             f"{CORRUPT_POLICIES}, got {corrupt_policy!r}")
        if corrupt_policy == "skip" and host_count > 1:
            raise ValueError(
                'corrupt_policy="skip" is per-host state: hosts quarantining '
                "different shards would run different step counts and desync "
                'collectives — use "raise" with host_count > 1')
        self.keys = tuple(include_keys or
                          (k for k in self.store.columns if k in MODEL_KEYS))
        missing = [k for k in self.keys if k not in self.store.columns]
        if missing:
            raise KeyError(f"store lacks columns {missing}")
        self.batch_size = int(batch_size)
        self.shuffle = shuffle
        self.seed = seed
        self.drop_last = drop_last
        self.host_id, self.host_count = host_id, host_count
        self.shard_ids = list(range(host_id, self.store.n_shards, host_count))
        self.n = sum(self.store.shard_rows(i) for i in self.shard_ids)
        # Shard-granular placement gives hosts unequal row counts; every host
        # must still run the same number of steps per epoch or collectives
        # desync (ClickLogLoader equalizes via n // host_count). Cap the
        # epoch at the smallest host's rows — pure manifest arithmetic.
        self._epoch_rows = min(
            sum(self.store.shard_rows(i)
                for i in range(h, self.store.n_shards, host_count))
            for h in range(host_count))
        if window_rows is not None and window_rows < 1:
            raise ValueError(f"window_rows must be >= 1, got {window_rows}")
        self.window_rows = window_rows
        self.read_ahead = int(read_ahead)
        self.verify_checksums = bool(verify_checksums)
        self.corrupt_policy = corrupt_policy
        self.io_retries = int(io_retries)
        self.io_retry_backoff = float(io_retry_backoff)
        self.watchdog_restarts = int(watchdog_restarts)
        self.log_fn = log_fn
        # Telemetry (repro.obs): spans around shard reads/crc verifies/retry
        # waits, `stream.*` counters (bytes_read, sessions, io_retries,
        # watchdog_restarts, queue_stall_s, quarantined_shards), a read-ahead
        # queue-depth gauge, and quarantine/watchdog_restart events. With no
        # recorder pinned, everything goes to the process-global one —
        # disabled (no sinks) means spans land only in the host ring buffer.
        self.recorder = recorder
        self.quarantined: set = set()
        # One shard spanning the whole loader degenerates to the in-memory
        # loader's order: in-shard seed (seed, epoch) == ClickLogLoader.
        self._single_shard = (self.store.n_shards == 1 and host_count == 1)
        self.state = StreamingLoaderState()
        self._closed = False
        self._iter_stop: Optional[threading.Event] = None

    def close(self) -> None:
        """Permanently shut the loader down, from any thread.

        Sets the active iteration's stop event (the read-ahead producer
        bails out of its next ``put``, the consumer loop stops waiting) and
        marks the loader closed — any further iteration raises. The
        watchdog never restarts a producer after close: shutdown wins the
        race against a worker dying mid-teardown."""
        self._closed = True
        stop = self._iter_stop
        if stop is not None:
            stop.set()

    # -- epoch geometry (pure arithmetic, no IO) -------------------------------
    def _quarantined_rows(self) -> int:
        return sum(self.store.shard_rows(s) for s in self.quarantined
                   if s in self.shard_ids)

    @property
    def batches_per_epoch(self) -> int:
        """Identical on every host (computed from the smallest host's rows).
        Quarantined shards' rows are excluded (single-host only — skip
        policy is refused with ``host_count > 1``)."""
        rows = self._epoch_rows - self._quarantined_rows()
        if self.drop_last:
            return rows // self.batch_size
        return -(-rows // self.batch_size)

    def _shard_order(self, epoch: int) -> List[int]:
        if not self.shuffle or len(self.shard_ids) <= 1:
            return list(self.shard_ids)
        perm = np.random.default_rng((self.seed, epoch, 0)).permutation(
            len(self.shard_ids))
        return [self.shard_ids[i] for i in perm]

    def _inshard_order(self, epoch: int, shard_id: int) -> np.ndarray:
        rows = self.store.shard_rows(shard_id)
        if not self.shuffle:
            return np.arange(rows)
        key = (self.seed, epoch) if self._single_shard else \
            (self.seed, epoch, 1 + shard_id)
        return np.random.default_rng(key).permutation(rows)

    def _epoch_plan(self, epoch: int) -> List[Tuple[int, int, int, int]]:
        """(shard_pos, shard_id, start, stop) windows in stream order.
        Already-quarantined shards are excluded up front; a shard that fails
        verification mid-epoch is quarantined at open time and its windows
        deliver zero rows (see ``_read_plan``)."""
        plan = []
        for pos, sid in enumerate(self._shard_order(epoch)):
            if sid in self.quarantined:
                continue
            rows = self.store.shard_rows(sid)
            w = self.window_rows or rows
            for start in range(0, rows, w):
                plan.append((pos, sid, start, min(start + w, rows)))
        return plan

    # -- reading ---------------------------------------------------------------
    def _rec(self):
        return self.recorder if self.recorder is not None else get_recorder()

    def _quarantine(self, sid: int, err: BaseException) -> None:
        self.quarantined.add(sid)
        rec = self._rec()
        rec.event("quarantine", data={"shard": int(sid), "error": repr(err)})
        rec.add("stream.quarantined_shards")
        self.log_fn(f"[streaming] QUARANTINED shard {sid}: {err} — its rows "
                    f"are dropped from this and every later epoch "
                    f"({self._quarantined_rows()} rows quarantined total)")

    def _read_shard(self, sid: int) -> Dict[str, np.ndarray]:
        """Open (and optionally crc-verify) one shard with transient-IO
        retries. :class:`ShardCorruptionError` is deterministic and
        propagates immediately; ``OSError`` backs off exponentially."""
        rec = self._rec()
        attempt = 0
        while True:
            try:
                with rec.span("shard_read", shard=sid):
                    cols = self.store.open_shard(sid, columns=self.keys)
                    if self.verify_checksums:
                        with rec.span("crc_verify", shard=sid):
                            self.store.verify(sid, columns=self.keys)
                rec.add("stream.bytes_read",
                        sum(np.asarray(v).nbytes for v in cols.values()))
                stored = getattr(self.store, "shard_stored_nbytes", None)
                if stored is not None:  # absent on bare-dict test doubles
                    rec.add("stream.bytes_stored",
                            sum(stored(sid, k) for k in cols))
                return cols
            except ShardCorruptionError:
                raise
            except OSError as e:
                if attempt >= self.io_retries:
                    raise
                delay = self.io_retry_backoff * (2 ** attempt)
                attempt += 1
                rec.add("stream.io_retries")
                self.log_fn(f"[streaming] transient IO error on shard {sid} "
                            f"(attempt {attempt}/{self.io_retries + 1}): "
                            f"{e!r}; retrying in {delay:.2f}s")
                with rec.span("io_retry_wait", shard=sid, attempt=attempt):
                    time.sleep(delay)

    def _read_plan(self, epoch: int,
                   entries: Sequence[Tuple[Tuple[int, int, int, int], int]],
                   start: int = 0
                   ) -> Iterator[Tuple[int, int, Dict[str, np.ndarray]]]:
        """Materialize plan windows in order; ``entries`` pairs each plan
        entry with how many leading rows to drop (resume skip). Yields
        ``(entry_index, shard_pos, block)`` so a restarted producer can
        resume from the first undelivered entry."""
        cached_sid, cols, perm = None, None, None
        for i in range(start, len(entries)):
            (pos, sid, win_start, win_stop), drop = entries[i]
            if sid != cached_sid:
                cached_sid = sid
                try:
                    cols = self._read_shard(sid)
                    perm = self._inshard_order(epoch, sid)
                except ShardCorruptionError as e:
                    if self.corrupt_policy != "skip":
                        raise
                    self._quarantine(sid, e)
                    cols = None
            if cols is None:  # quarantined mid-epoch: zero rows delivered
                continue
            rows = perm[win_start + drop:win_stop]
            if rows.size == 0:
                continue
            yield i, pos, {k: np.asarray(v[rows]) for k, v in cols.items()}

    def _block_stream(self, epoch, entries):
        """``_read_plan`` behind a bounded background read-ahead thread,
        with a consumer-side watchdog: a producer that dies is restarted
        (``watchdog_restarts`` times) from its first undelivered entry;
        after that the original exception propagates, traceback intact.
        :meth:`close` beats the watchdog unconditionally — no restart ever
        happens after it."""
        if self._closed:
            raise RuntimeError("StreamingClickLogLoader is closed")
        if self.read_ahead <= 0:
            for _, pos, block in self._read_plan(epoch, entries):
                if self._closed:
                    raise RuntimeError(
                        "StreamingClickLogLoader.close() was called "
                        "mid-epoch")
                yield pos, block
            return
        q: queue.Queue = queue.Queue(maxsize=self.read_ahead)
        stop = threading.Event()
        self._iter_stop = stop
        progress = {"next": 0}  # first entry index not yet queued

        def put(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def worker(start):
            try:
                for i, pos, block in self._read_plan(epoch, entries,
                                                     start=start):
                    if not put((pos, block)):
                        return
                    # After a successful put the only exception sources are
                    # in the next _read_plan iteration, so a restart from
                    # `next` never re-reads (or drops) a delivered window.
                    progress["next"] = i + 1
                put(_DONE)
            except BaseException as e:  # surfaced on the consumer side
                put(_WorkerError(e))

        def start_worker():
            t = threading.Thread(target=worker, args=(progress["next"],),
                                 daemon=True, name="store-read-ahead")
            t.start()
            return t

        thread = start_worker()
        restarts_left = self.watchdog_restarts
        rec = self._rec()
        try:
            while True:
                # Queue-stall time = how long the consumer sat waiting on the
                # producer: the direct measure of an IO-bound epoch. The
                # depth gauge after the get shows how much read-ahead is
                # actually banked.
                t_wait = time.monotonic()
                while True:
                    try:
                        item = q.get(timeout=0.2)
                        break
                    except queue.Empty:
                        # A cross-thread close() while the producer is gone
                        # must not leave this get() parked forever.
                        if stop.is_set():
                            raise RuntimeError(
                                "StreamingClickLogLoader.close() was "
                                "called mid-epoch — read-ahead shut down")
                rec.add("stream.queue_stall_s", time.monotonic() - t_wait)
                rec.gauge("stream.queue_depth", q.qsize())
                if item is _DONE:
                    return
                if isinstance(item, _WorkerError):
                    err = item.error
                    # Shutdown wins: after close() a dead producer is
                    # surfaced, never resurrected (a restart would read
                    # shards for an epoch nobody is consuming).
                    if (stop.is_set() or restarts_left <= 0
                            or isinstance(err, ShardCorruptionError)):
                        raise err
                    thread.join(timeout=5.0)
                    if thread.is_alive():
                        # The "dead" producer is actually wedged, not dead
                        # (its error came from a helper it spawned or it
                        # hung in teardown): starting a clone would race
                        # two producers into one queue. Fail loudly.
                        raise err
                    restarts_left -= 1
                    rec.event("watchdog_restart",
                              data={"error": repr(err),
                                    "plan_entry": progress["next"],
                                    "restarts_left": restarts_left})
                    rec.add("stream.watchdog_restarts")
                    self.log_fn(
                        f"[streaming] read-ahead producer died ({err!r});"
                        f" restarting from plan entry "
                        f"{progress['next']} "
                        f"({restarts_left} restarts left)")
                    thread = start_worker()
                    continue
                yield item
        finally:
            stop.set()
            # Abandoning the iterator mid-epoch must not leak the producer:
            # stop makes its pending put() bail, so the join is prompt.
            thread.join(timeout=10.0)

    # -- iteration -------------------------------------------------------------
    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        """One epoch per call, resuming from ``self.state`` (as in
        ``ClickLogLoader``); advances the cursor as batches are consumed."""
        epoch = self.state.epoch
        nb = self.batches_per_epoch
        if self.state.step < nb:
            # Resume arithmetic: skip whole windows that precede the cursor
            # row, and drop windows past the epoch's step cap (a host with
            # surplus rows — shard-granular placement — must neither read
            # nor buffer them). Pure arithmetic, no IO. Quarantined shards
            # are already absent from the plan, so the cursor row indexes
            # the *delivered* stream — a resume after a skip-policy
            # quarantine (persisted in state_dict) lands on the same batch.
            skip = self.state.step * self.batch_size
            need = (nb * self.batch_size if self.drop_last
                    else self.n - self._quarantined_rows())
            entries, cum = [], 0
            for entry in self._epoch_plan(epoch):
                rows = entry[3] - entry[2]
                if cum + rows <= skip:
                    cum += rows
                    continue
                if cum >= need:
                    break
                entries.append((entry, max(skip - cum, 0)))
                cum += rows
            parts: List[Dict[str, np.ndarray]] = []
            buffered = 0
            rec = self._rec()
            blocks = self._block_stream(epoch, entries)
            try:
                for shard_pos, block in blocks:
                    parts.append(block)
                    buffered += next(iter(block.values())).shape[0]
                    while buffered >= self.batch_size and self.state.step < nb:
                        batch = _take_rows(parts, self.batch_size)
                        buffered -= self.batch_size
                        self.state.step += 1
                        self.state.shard = shard_pos
                        rec.add("stream.sessions", self.batch_size)
                        yield batch
                    if self.state.step >= nb:
                        break  # epoch cap reached; don't read surplus windows
                if (not self.drop_last and buffered > 0
                        and self.state.step < nb):
                    batch = _take_rows(parts, buffered)
                    self.state.step += 1
                    rec.add("stream.sessions", buffered)
                    yield batch
            finally:
                blocks.close()  # stops the read-ahead thread
        self.state = StreamingLoaderState(epoch=epoch + 1, step=0, shard=0)

    def epochs(self, n_epochs: int):
        start = self.state.epoch
        while self.state.epoch < start + n_epochs:
            yield from iter(self)

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self):
        d = self.state.to_dict()
        if self.quarantined:
            # The quarantine set is part of the stream definition: a resume
            # that forgot it would re-count the corrupt shard's rows in the
            # cursor arithmetic and land on the wrong batch.
            d["quarantined"] = sorted(self.quarantined)
        return d

    def load_state_dict(self, d):
        self.state = StreamingLoaderState.from_dict(d)
        self.quarantined = set(int(s) for s in d.get("quarantined", ()))
