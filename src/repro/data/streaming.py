"""Streaming loader over an on-disk :class:`repro.data.store.SessionStore`.

Trains on click logs far larger than host RAM with the same contract as the
in-memory ``ClickLogLoader``: deterministic shuffling, bit-exact mid-epoch
checkpoint/resume, host sharding for multi-host data parallelism, and an
iterator of numpy batch dicts that plugs straight into ``DevicePrefetcher``.

How the epoch stream is defined (all deterministic in ``(seed, epoch)``):

1. **Shard order** — the host's assigned shards (``shard_id % host_count ==
   host_id``: placement at shard granularity, no row-level coordination)
   are permuted by ``rng((seed, epoch, 0))``.
2. **In-shard order** — each shard's rows are permuted by
   ``rng((seed, epoch, 1 + shard_id))``. Row payloads are read only
   ``window_rows`` of that permutation at a time (default: one whole
   shard), so peak reader memory is O(window * (1 + read_ahead)) row
   payloads plus one O(shard_rows) index permutation (8 bytes/row, small
   next to the rows it orders) — never O(log).
3. **Batching** — batches of ``batch_size`` are cut sequentially from the
   concatenated stream, spanning shard boundaries; ``drop_last`` matches
   ``ClickLogLoader``.

A **single-shard** store (one host) uses in-shard seed ``(seed, epoch)`` —
exactly ``ClickLogLoader._epoch_order`` — so the streaming loader is a
drop-in replacement that reproduces the in-memory loader's batch stream
bit-for-bit (tested in tests/test_store.py). With ``shuffle=False`` the
stream is the store's row order for any shard count.

The cursor ``(epoch, shard, step)`` checkpoints like ``LoaderState``:
``step * batch_size`` locates the resume row inside the deterministic epoch
stream by pure arithmetic over the manifest's per-shard row counts, so
resume skips already-consumed shards without reading them.

A background read-ahead thread stages upcoming permuted windows into a
bounded queue so disk reads overlap compute; the consuming iterator (and
``DevicePrefetcher`` above it) sees plain numpy batches either way.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.loader import MODEL_KEYS
from repro.data.store import SessionStore, _take_rows


@dataclasses.dataclass
class StreamingLoaderState:
    """Resumable cursor. ``epoch``/``step`` are authoritative (``step`` is the
    batch index within the epoch, as in ``LoaderState``); ``shard`` records
    the epoch-order position of the shard the last batch was drawn from
    (derived — kept for observability and log messages)."""
    epoch: int = 0
    step: int = 0
    shard: int = 0

    def to_dict(self):
        return {"epoch": self.epoch, "step": self.step, "shard": self.shard}

    @classmethod
    def from_dict(cls, d):
        return cls(epoch=int(d["epoch"]), step=int(d["step"]),
                   shard=int(d.get("shard", 0)))


class _WorkerError:
    def __init__(self, error: BaseException):
        self.error = error


_DONE = object()


class StreamingClickLogLoader:
    """Deterministic, checkpointable, out-of-core batch loader.

    Same surface as ``ClickLogLoader`` (``__iter__`` runs one epoch,
    ``epochs(n)``, ``batches_per_epoch``, ``state_dict``/``load_state_dict``)
    but backed by a :class:`SessionStore` instead of an in-memory dict.
    """

    def __init__(self, store, batch_size: int, shuffle: bool = True,
                 seed: int = 0, drop_last: bool = True,
                 host_id: int = 0, host_count: int = 1,
                 include_keys: Optional[Tuple[str, ...]] = None,
                 window_rows: Optional[int] = None, read_ahead: int = 2):
        self.store = store if isinstance(store, SessionStore) else SessionStore(store)
        if host_count > 1 and self.store.n_shards < host_count:
            raise ValueError(
                f"store has {self.store.n_shards} shards but host_count="
                f"{host_count}: sharding is at shard granularity — re-ingest "
                "with smaller shard_rows")
        if host_count > 1 and not drop_last:
            raise ValueError(
                "drop_last=False with host_count > 1 would give hosts "
                "different final-batch shapes; multi-host training requires "
                "drop_last=True")
        self.keys = tuple(include_keys or
                          (k for k in self.store.columns if k in MODEL_KEYS))
        missing = [k for k in self.keys if k not in self.store.columns]
        if missing:
            raise KeyError(f"store lacks columns {missing}")
        self.batch_size = int(batch_size)
        self.shuffle = shuffle
        self.seed = seed
        self.drop_last = drop_last
        self.host_id, self.host_count = host_id, host_count
        self.shard_ids = list(range(host_id, self.store.n_shards, host_count))
        self.n = sum(self.store.shard_rows(i) for i in self.shard_ids)
        # Shard-granular placement gives hosts unequal row counts; every host
        # must still run the same number of steps per epoch or collectives
        # desync (ClickLogLoader equalizes via n // host_count). Cap the
        # epoch at the smallest host's rows — pure manifest arithmetic.
        self._epoch_rows = min(
            sum(self.store.shard_rows(i)
                for i in range(h, self.store.n_shards, host_count))
            for h in range(host_count))
        if window_rows is not None and window_rows < 1:
            raise ValueError(f"window_rows must be >= 1, got {window_rows}")
        self.window_rows = window_rows
        self.read_ahead = int(read_ahead)
        # One shard spanning the whole loader degenerates to the in-memory
        # loader's order: in-shard seed (seed, epoch) == ClickLogLoader.
        self._single_shard = (self.store.n_shards == 1 and host_count == 1)
        self.state = StreamingLoaderState()

    # -- epoch geometry (pure arithmetic, no IO) -------------------------------
    @property
    def batches_per_epoch(self) -> int:
        """Identical on every host (computed from the smallest host's rows)."""
        if self.drop_last:
            return self._epoch_rows // self.batch_size
        return -(-self._epoch_rows // self.batch_size)

    def _shard_order(self, epoch: int) -> List[int]:
        if not self.shuffle or len(self.shard_ids) <= 1:
            return list(self.shard_ids)
        perm = np.random.default_rng((self.seed, epoch, 0)).permutation(
            len(self.shard_ids))
        return [self.shard_ids[i] for i in perm]

    def _inshard_order(self, epoch: int, shard_id: int) -> np.ndarray:
        rows = self.store.shard_rows(shard_id)
        if not self.shuffle:
            return np.arange(rows)
        key = (self.seed, epoch) if self._single_shard else \
            (self.seed, epoch, 1 + shard_id)
        return np.random.default_rng(key).permutation(rows)

    def _epoch_plan(self, epoch: int) -> List[Tuple[int, int, int, int]]:
        """(shard_pos, shard_id, start, stop) windows in stream order."""
        plan = []
        for pos, sid in enumerate(self._shard_order(epoch)):
            rows = self.store.shard_rows(sid)
            w = self.window_rows or rows
            for start in range(0, rows, w):
                plan.append((pos, sid, start, min(start + w, rows)))
        return plan

    # -- reading ---------------------------------------------------------------
    def _read_plan(self, epoch: int,
                   entries: Sequence[Tuple[Tuple[int, int, int, int], int]]
                   ) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        """Materialize plan windows in order; ``entries`` pairs each plan
        entry with how many leading rows to drop (resume skip)."""
        cached_sid, cols, perm = None, None, None
        for (pos, sid, start, stop), drop in entries:
            if sid != cached_sid:
                cols = self.store.open_shard(sid, columns=self.keys)
                perm = self._inshard_order(epoch, sid)
                cached_sid = sid
            rows = perm[start + drop:stop]
            if rows.size == 0:
                continue
            yield pos, {k: np.asarray(v[rows]) for k, v in cols.items()}

    def _block_stream(self, epoch, entries):
        """``_read_plan`` behind a bounded background read-ahead thread."""
        if self.read_ahead <= 0:
            yield from self._read_plan(epoch, entries)
            return
        q: queue.Queue = queue.Queue(maxsize=self.read_ahead)
        stop = threading.Event()

        def put(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for item in self._read_plan(epoch, entries):
                    if not put(item):
                        return
                put(_DONE)
            except BaseException as e:  # surfaced on the consumer side
                put(_WorkerError(e))

        thread = threading.Thread(target=worker, daemon=True,
                                  name="store-read-ahead")
        thread.start()
        try:
            while True:
                item = q.get()
                if item is _DONE:
                    return
                if isinstance(item, _WorkerError):
                    raise item.error
                yield item
        finally:
            stop.set()

    # -- iteration -------------------------------------------------------------
    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        """One epoch per call, resuming from ``self.state`` (as in
        ``ClickLogLoader``); advances the cursor as batches are consumed."""
        epoch = self.state.epoch
        nb = self.batches_per_epoch
        if self.state.step < nb:
            # Resume arithmetic: skip whole windows that precede the cursor
            # row, and drop windows past the epoch's step cap (a host with
            # surplus rows — shard-granular placement — must neither read
            # nor buffer them). Pure arithmetic, no IO.
            skip = self.state.step * self.batch_size
            need = nb * self.batch_size if self.drop_last else self.n
            entries, cum = [], 0
            for entry in self._epoch_plan(epoch):
                rows = entry[3] - entry[2]
                if cum + rows <= skip:
                    cum += rows
                    continue
                if cum >= need:
                    break
                entries.append((entry, max(skip - cum, 0)))
                cum += rows
            parts: List[Dict[str, np.ndarray]] = []
            buffered = 0
            blocks = self._block_stream(epoch, entries)
            try:
                for shard_pos, block in blocks:
                    parts.append(block)
                    buffered += next(iter(block.values())).shape[0]
                    while buffered >= self.batch_size and self.state.step < nb:
                        batch = _take_rows(parts, self.batch_size)
                        buffered -= self.batch_size
                        self.state.step += 1
                        self.state.shard = shard_pos
                        yield batch
                    if self.state.step >= nb:
                        break  # epoch cap reached; don't read surplus windows
                if (not self.drop_last and buffered > 0
                        and self.state.step < nb):
                    batch = _take_rows(parts, buffered)
                    self.state.step += 1
                    yield batch
            finally:
                blocks.close()  # stops the read-ahead thread
        self.state = StreamingLoaderState(epoch=epoch + 1, step=0, shard=0)

    def epochs(self, n_epochs: int):
        start = self.state.epoch
        while self.state.epoch < start + n_epochs:
            yield from iter(self)

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self):
        return self.state.to_dict()

    def load_state_dict(self, d):
        self.state = StreamingLoaderState.from_dict(d)
