"""Out-of-core session store: sharded, columnar, memory-mapped click logs.

The scale-defining input of a click-model system is the log itself (CLAX
trains on the billion-session Baidu-ULTR log); a log that must fit in host
RAM as one numpy dict caps every downstream component. This module gives the
log a durable on-disk representation:

    <dir>/manifest.json            schema + shard table (atomic, written last)
    <dir>/shard_00000/<col>.bin    one raw binary file per column per shard
    <dir>/shard_00001/<col>.bin    ...

Design points:

- **Columnar, fixed schema.** Every column has one dtype and per-row shape
  across the whole store (recorded in the manifest), so a shard file is
  exactly ``rows * prod(shape) * itemsize`` bytes and can be mapped with
  ``np.memmap`` — zero-copy reads, no deserialization, OS page cache does
  the caching.
- **Sharded.** Fixed ``shard_rows`` per shard (last shard partial). Shards
  are the unit of shuffling, host placement, and read-ahead for
  :class:`repro.data.streaming.StreamingClickLogLoader`; peak reader memory
  is O(shard) — or O(window) with windowed reads — never O(log).
- **Self-describing + verifiable.** The manifest carries dtypes (numpy
  ``dtype.str``, endianness included), per-row shapes, per-shard row counts,
  a crc32 per column file, and free-form user metadata (e.g. the
  ``SyntheticConfig`` that generated the log).
- **Crash-safe.** The manifest is written last via ``os.replace``; a
  directory without a committed manifest is not a store, so a crashed ingest
  can never be half-read.
- **Per-column compression (format v2).** Each shard entry records a codec
  per column (see :mod:`repro.data.codecs`); ``codec="auto"`` at write time
  picks ``bitpack`` for 0/1 columns (clicks, mask), ``zlib`` where DEFLATE
  pays, and ``raw`` otherwise. Checksums and size checks cover the *stored*
  bytes, so corruption fails closed on compressed columns exactly as on raw
  ones. ``raw`` columns keep the zero-copy ``np.memmap`` read path, and v1
  manifests (no codec field) read as all-``raw`` — byte-compatible.

``ingest_synthetic`` streams a :class:`repro.data.synthetic.SyntheticConfig`
log through :func:`repro.data.synthetic.iter_click_log_chunks` straight into
writers — optionally split into train/val/test stores — so logs far larger
than RAM are synthesized with peak memory O(chunk + shard). For multi-process
ingest over the same deterministic chunk stream see
:mod:`repro.data.ingest`.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.data import codecs as _codecs

MANIFEST_NAME = "manifest.json"
FORMAT_VERSION = 2
#: Manifest versions this reader accepts. v1 lacks per-column codec fields
#: (every column is implicitly ``raw``); v2 shard entries add ``codecs`` and
#: ``nbytes`` maps. v1 stores written by older builds stay readable forever.
READABLE_FORMAT_VERSIONS = (1, 2)
#: Writer-side codec modes: ``"raw"`` pins every column to raw bytes (v1
#: byte-compatible, memmap reads); ``"auto"`` picks per column per shard.
WRITER_CODECS = ("raw", "auto")


class ShardCorruptionError(ValueError):
    """A shard's bytes disagree with the manifest (bad crc32, or a column
    file whose size doesn't match the recorded row count). Distinct from
    transient ``OSError`` IO failures: corruption is deterministic, so
    callers retry the latter but quarantine (or raise) on the former."""


def _shard_dirname(index: int) -> str:
    return f"shard_{index:05d}"


def _crc32(arr: np.ndarray) -> str:
    return f"{zlib.crc32(np.ascontiguousarray(arr).view(np.uint8).reshape(-1)):08x}"


def _crc32_bytes(data: bytes) -> str:
    return f"{zlib.crc32(data):08x}"


def _write_shard_dir(directory: str, name: str, shard: Mapping[str, np.ndarray],
                     rows: int, codec: str) -> Dict:
    """Encode and write one shard's column files; return its manifest entry.

    The single place shard bytes are produced — shared by
    :class:`SessionStoreWriter` and the parallel-ingest workers
    (:mod:`repro.data.ingest`), so both paths emit byte-identical files and
    entries for the same rows. ``codec`` is a writer mode from
    :data:`WRITER_CODECS`; the per-column choice under ``"auto"`` is
    deterministic in the column values (see ``codecs.encode_auto``).
    """
    os.makedirs(directory, exist_ok=True)
    checksums, col_codecs, nbytes = {}, {}, {}
    for cname, arr in shard.items():
        arr = np.ascontiguousarray(arr)
        path = os.path.join(directory, f"{cname}.bin")
        chosen, stored = ("raw", None) if codec == "raw" \
            else _codecs.encode_auto(arr)
        if chosen == "raw":
            # tofile streams the buffer — no bytes copy; crc over the array
            # view IS the crc over the stored bytes for the raw codec.
            arr.tofile(path)
            checksums[cname] = _crc32(arr)
            nbytes[cname] = int(arr.nbytes)
        else:
            with open(path, "wb") as f:
                f.write(stored)
            checksums[cname] = _crc32_bytes(stored)
            nbytes[cname] = len(stored)
        col_codecs[cname] = chosen
    return {"name": name, "rows": int(rows), "checksums": checksums,
            "codecs": col_codecs, "nbytes": nbytes}


def _take_rows(parts: List[Dict[str, np.ndarray]], n: int
               ) -> Dict[str, np.ndarray]:
    """Pop the first ``n`` rows from a list of same-schema row blocks.

    Shared buffering primitive of ``SessionStoreWriter`` (chunks in, shards
    out) and ``StreamingClickLogLoader`` (windows in, batches out).
    """
    taken: Dict[str, list] = {}
    got = 0
    while got < n:
        part = parts[0]
        rows = next(iter(part.values())).shape[0]
        need = n - got
        if rows <= need:
            parts.pop(0)
            piece = part
            got += rows
        else:
            piece = {k: v[:need] for k, v in part.items()}
            parts[0] = {k: v[need:] for k, v in part.items()}
            got = n
        for k, v in piece.items():
            taken.setdefault(k, []).append(v)
    return {k: (v[0] if len(v) == 1 else np.concatenate(v, axis=0))
            for k, v in taken.items()}


@dataclasses.dataclass(frozen=True)
class ColumnSpec:
    """Schema of one column: numpy dtype string + per-row (trailing) shape."""
    dtype: str           # np.dtype.str, e.g. "<f4", "|b1"
    shape: Tuple[int, ...]  # per-row shape; () for scalar columns

    def to_json(self):
        return {"dtype": self.dtype, "shape": list(self.shape)}

    @classmethod
    def from_json(cls, d):
        return cls(dtype=d["dtype"], shape=tuple(int(s) for s in d["shape"]))

    @classmethod
    def of(cls, arr: np.ndarray) -> "ColumnSpec":
        return cls(dtype=np.dtype(arr.dtype).str, shape=tuple(arr.shape[1:]))

    @property
    def row_nbytes(self) -> int:
        return int(np.dtype(self.dtype).itemsize * int(np.prod(self.shape, dtype=np.int64)))


class SessionStoreWriter:
    """Append-only writer emitting fixed-size columnar shards.

    Usage::

        with SessionStoreWriter(path, shard_rows=1_000_000) as w:
            for chunk in chunks:          # dict of (rows, ...) arrays
                w.append(chunk)
        store = SessionStore(path)

    The schema (column set, dtypes, per-row shapes) is fixed by the first
    ``append``; later chunks must match it exactly. Buffered rows are flushed
    as full shards of ``shard_rows``; ``close()`` flushes the remainder as a
    final partial shard and commits the manifest atomically. Peak writer
    memory is O(shard_rows + largest chunk).
    """

    def __init__(self, directory: str, shard_rows: int = 1_000_000,
                 columns: Optional[Sequence[str]] = None,
                 metadata: Optional[Mapping] = None, codec: str = "raw"):
        if shard_rows < 1:
            raise ValueError(f"shard_rows must be >= 1, got {shard_rows}")
        if codec not in WRITER_CODECS:
            raise ValueError(f"codec must be one of {WRITER_CODECS}, "
                             f"got {codec!r}")
        self.directory = directory
        self.shard_rows = int(shard_rows)
        self.codec = codec
        self._columns = tuple(columns) if columns is not None else None
        self.metadata = dict(metadata or {})
        self._specs: Optional[Dict[str, ColumnSpec]] = None
        self._buffer: List[Dict[str, np.ndarray]] = []
        self._buffered_rows = 0
        self._shards: List[Dict] = []
        self._closed = False
        os.makedirs(directory, exist_ok=True)
        # Re-ingesting over a committed store: drop the old manifest first so
        # a crash mid-write can't leave it pointing at half-overwritten shard
        # files ("no manifest = not a store" must hold during the rewrite).
        stale = os.path.join(directory, MANIFEST_NAME)
        if os.path.exists(stale):
            os.remove(stale)

    # -- schema ----------------------------------------------------------------
    def _fix_schema(self, chunk: Mapping[str, np.ndarray]):
        keys = self._columns or tuple(sorted(chunk))
        missing = [k for k in keys if k not in chunk]
        if missing:
            raise KeyError(f"chunk missing columns {missing}")
        self._specs = {k: ColumnSpec.of(np.asarray(chunk[k])) for k in keys}
        self._buffer = []

    def _check_chunk(self, chunk: Mapping[str, np.ndarray]) -> Dict[str, np.ndarray]:
        if self._columns is None:
            extra = set(chunk) - set(self._specs)
            if extra:
                raise KeyError(
                    f"chunk carries columns {sorted(extra)} absent from the "
                    "schema fixed by the first append — they would be "
                    "silently dropped")
        out, rows = {}, None
        for name, spec in self._specs.items():
            if name not in chunk:
                raise KeyError(f"chunk missing column {name!r}")
            arr = np.asarray(chunk[name])
            if np.dtype(arr.dtype).str != spec.dtype or arr.shape[1:] != spec.shape:
                raise ValueError(
                    f"column {name!r}: got dtype={np.dtype(arr.dtype).str} "
                    f"shape={arr.shape[1:]}, store schema is dtype={spec.dtype} "
                    f"shape={spec.shape}")
            if rows is None:
                rows = arr.shape[0]
            elif arr.shape[0] != rows:
                raise ValueError(f"ragged chunk: column {name!r} has "
                                 f"{arr.shape[0]} rows, expected {rows}")
            out[name] = arr
        return out

    # -- writing ---------------------------------------------------------------
    def append(self, chunk: Mapping[str, np.ndarray]) -> None:
        if self._closed:
            raise RuntimeError("writer is closed")
        if self._specs is None:
            self._fix_schema(chunk)
        chunk = self._check_chunk(chunk)
        rows = next(iter(chunk.values())).shape[0] if chunk else 0
        if rows == 0:
            return
        self._buffer.append(chunk)
        self._buffered_rows += rows
        while self._buffered_rows >= self.shard_rows:
            self._flush_shard(self.shard_rows)

    def _flush_shard(self, rows: int) -> None:
        shard = _take_rows(self._buffer, rows)
        self._buffered_rows -= rows
        index = len(self._shards)
        sdir = os.path.join(self.directory, _shard_dirname(index))
        self._shards.append(_write_shard_dir(sdir, _shard_dirname(index),
                                             shard, rows, self.codec))

    # -- commit ----------------------------------------------------------------
    def close(self) -> Dict:
        """Flush the final partial shard and atomically commit the manifest."""
        if self._closed:
            return self._manifest
        if self._specs is None:
            raise RuntimeError("nothing was appended; refusing to write an "
                               "empty store")
        if self._buffered_rows > 0:
            self._flush_shard(self._buffered_rows)
        manifest = {
            "format_version": FORMAT_VERSION,
            "columns": {k: s.to_json() for k, s in self._specs.items()},
            "shards": self._shards,
            "rows": int(sum(s["rows"] for s in self._shards)),
            "shard_rows": self.shard_rows,
            "metadata": self.metadata,
        }
        tmp = os.path.join(self.directory, MANIFEST_NAME + ".tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
        os.replace(tmp, os.path.join(self.directory, MANIFEST_NAME))
        self._manifest = manifest
        self._closed = True
        return manifest

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self.close()
        # on error: leave no manifest — the directory is not a valid store
        return False


class SessionStore:
    """Read side: manifest + zero-copy ``np.memmap`` access to shard columns."""

    def __init__(self, directory: str, verify: bool = False):
        self.directory = directory
        path = os.path.join(directory, MANIFEST_NAME)
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"{directory!r} has no {MANIFEST_NAME} — not a committed "
                "session store (crashed ingest, or wrong path?)")
        with open(path) as f:
            self.manifest = json.load(f)
        if self.manifest.get("format_version") not in READABLE_FORMAT_VERSIONS:
            raise ValueError(
                f"store format_version={self.manifest.get('format_version')} "
                f"not supported (reader accepts {READABLE_FORMAT_VERSIONS})")
        self.columns: Dict[str, ColumnSpec] = {
            k: ColumnSpec.from_json(v)
            for k, v in self.manifest["columns"].items()}
        self.shards: List[Dict] = self.manifest["shards"]
        self.rows: int = int(self.manifest["rows"])
        self.metadata: Dict = self.manifest.get("metadata", {})
        if verify:
            self.verify()

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def shard_rows(self, index: int) -> int:
        return int(self.shards[index]["rows"])

    def _shard_path(self, index: int, column: str) -> str:
        return os.path.join(self.directory, self.shards[index]["name"],
                            f"{column}.bin")

    def shard_codec(self, index: int, column: str) -> str:
        """Codec of one column file. v1 manifests carry no codec field —
        every column is ``raw`` by definition."""
        return self.shards[index].get("codecs", {}).get(column, "raw")

    def shard_stored_nbytes(self, index: int, column: str) -> int:
        """Bytes of one column file as stored on disk (encoded size)."""
        nb = self.shards[index].get("nbytes", {}).get(column)
        if nb is not None:
            return int(nb)
        return int(self.shards[index]["rows"]) * self.columns[column].row_nbytes

    def stored_nbytes(self, columns: Optional[Iterable[str]] = None) -> int:
        """Total on-disk bytes of the store's column files (manifest
        arithmetic, no IO) — the number compression shrinks."""
        names = tuple(columns if columns is not None else self.columns)
        return sum(self.shard_stored_nbytes(i, n)
                   for i in range(self.n_shards) for n in names)

    def _check_stored_size(self, index: int, column: str) -> str:
        path = self._shard_path(index, column)
        want = self.shard_stored_nbytes(index, column)
        got = os.path.getsize(path)
        if got != want:
            raise ShardCorruptionError(
                f"{path} is {got} bytes, manifest implies {want} stored "
                f"({self.shard_rows(index)} rows, "
                f"codec={self.shard_codec(index, column)}) — truncated or "
                "mismatched shard file")
        return path

    def open_shard(self, index: int,
                   columns: Optional[Iterable[str]] = None
                   ) -> Dict[str, np.ndarray]:
        """Open one shard: dict of read-only column arrays. ``raw`` columns
        are zero-copy ``np.memmap``; compressed columns are decoded into
        RAM (any decode failure raises :class:`ShardCorruptionError` — a
        corrupt stream that happens to keep its stored size still fails
        closed)."""
        rows = self.shard_rows(index)
        out = {}
        for name in (columns if columns is not None else self.columns):
            spec = self.columns[name]
            codec = self.shard_codec(index, name)
            path = self._check_stored_size(index, name)
            if codec == "raw":
                out[name] = np.memmap(path, dtype=np.dtype(spec.dtype),
                                      mode="r", shape=(rows,) + spec.shape)
                continue
            with open(path, "rb") as f:
                data = f.read()
            try:
                arr = _codecs.decode(codec, data, np.dtype(spec.dtype),
                                     (rows,) + spec.shape)
            except ValueError as e:
                raise ShardCorruptionError(
                    f"{path}: {codec} decode failed ({e}) — corrupt or "
                    "mismatched shard file") from e
            arr.flags.writeable = False  # match the memmap's read-only view
            out[name] = arr
        return out

    def verify(self, index: Optional[int] = None,
               columns: Optional[Iterable[str]] = None) -> None:
        """Check crc32 of every column file (or one shard's, or a subset of
        columns) over the *stored* bytes — no decode needed, so a corrupt
        compressed stream is caught before any decoder sees it. Raises
        :class:`ShardCorruptionError` on drift."""
        indices = range(self.n_shards) if index is None else [index]
        for i in indices:
            names = tuple(columns if columns is not None else self.columns)
            for name in names:
                path = self._check_stored_size(i, name)
                with open(path, "rb") as f:
                    got = _crc32_bytes(f.read())
                want = self.shards[i]["checksums"][name]
                if got != want:
                    raise ShardCorruptionError(
                        f"checksum mismatch in {path}: "
                        f"manifest={want} file={got}")

    def read_all(self, columns: Optional[Iterable[str]] = None
                 ) -> Dict[str, np.ndarray]:
        """Materialize the whole store in RAM (tests / small stores only)."""
        names = tuple(columns if columns is not None else self.columns)
        parts = {k: [] for k in names}
        for i in range(self.n_shards):
            shard = self.open_shard(i, columns=names)
            for k in names:
                parts[k].append(np.asarray(shard[k]))
        return {k: np.concatenate(v, axis=0) for k, v in parts.items()}


def write_session_store(data: Mapping[str, np.ndarray], directory: str,
                        shard_rows: int = 1_000_000,
                        metadata: Optional[Mapping] = None,
                        codec: str = "raw") -> SessionStore:
    """One-shot convenience: write an in-memory session dict as a store.

    Defaults to ``codec="raw"`` — every column file is the array's bytes
    (v1-identical, memmap reads); pass ``codec="auto"`` for per-column
    compression."""
    with SessionStoreWriter(directory, shard_rows=shard_rows,
                            metadata=metadata, codec=codec) as w:
        w.append(data)
    return SessionStore(directory)


def split_sizes(n: int, splits: Mapping[str, float]) -> List[int]:
    """Rows of an ``n``-row chunk routed to each split, in ``splits`` order:
    ``round(n * fraction)`` for all but the last split, which takes the
    exact remainder. Shared by the single-process and parallel ingest paths
    so their routing arithmetic can never drift."""
    names = list(splits)
    sizes = [int(round(n * splits[k])) for k in names[:-1]]
    sizes.append(n - sum(sizes))
    if min(sizes) < 0:
        raise ValueError(f"split fractions {dict(splits)} overflow a "
                         f"chunk of {n} rows")
    return sizes


def split_permutation(seed: int, chunk_index: int, n: int) -> np.ndarray:
    """The deterministic permutation routing chunk ``chunk_index``'s rows
    into splits (domain-separated from the chunk-synthesis streams)."""
    return np.random.default_rng((seed, 7, chunk_index)).permutation(n)


def ingest_synthetic(cfg, directory: str, chunk_sessions: int = 100_000,
                     shard_rows: int = 1_000_000,
                     splits: Optional[Mapping[str, float]] = None,
                     codec: str = "auto",
                     extra_metadata: Optional[Mapping] = None,
                     ) -> Dict[str, SessionStore]:
    """Stream a synthetic log into session store(s) with bounded memory.

    ``splits`` (e.g. ``{"train": .8, "val": .1, "test": .1}``) routes each
    chunk's rows into per-split writers under ``directory/<split>`` using a
    deterministic per-chunk permutation (last split takes the exact
    remainder), so arbitrarily large logs are split without ever being
    held. With ``splits=None`` the whole log lands in one store at
    ``directory``. Peak memory is O(chunk_sessions + shard_rows) rows,
    independent of ``cfg.n_sessions``.

    ``codec="auto"`` (default) picks a per-column codec per shard; pass
    ``"raw"`` for v1-byte-compatible stores. This single-process path is the
    reference implementation: :func:`repro.data.ingest.ingest_synthetic`
    fans the same chunk stream across worker processes and is pinned
    byte-identical to it.
    """
    from repro.data.synthetic import iter_click_log_chunks

    meta = {"synthetic_config": dataclasses.asdict(cfg),
            "chunk_sessions": int(chunk_sessions),
            "store_codec": codec}
    meta.update(extra_metadata or {})
    if splits is None:
        writers = {"": SessionStoreWriter(directory, shard_rows=shard_rows,
                                          metadata=meta, codec=codec)}
    else:
        writers = {name: SessionStoreWriter(os.path.join(directory, name),
                                            shard_rows=shard_rows,
                                            metadata=dict(meta, split=name,
                                                          fraction=frac),
                                            codec=codec)
                   for name, frac in splits.items()}

    for c, chunk in enumerate(iter_click_log_chunks(cfg, chunk_sessions)):
        if splits is None:
            writers[""].append(chunk)
            continue
        n = chunk["clicks"].shape[0]
        perm = split_permutation(cfg.seed, c, n)
        sizes = split_sizes(n, splits)
        start = 0
        for name, size in zip(splits, sizes):
            idx = perm[start:start + size]
            start += size
            if size:
                writers[name].append({k: v[idx] for k, v in chunk.items()})

    # Validate every split BEFORE committing any manifest, so a bad split
    # spec can't leave a half-committed train/val/test tree behind.
    empty = [name for name, w in writers.items() if w._specs is None]
    if empty:
        raise ValueError(
            f"splits {empty} received zero rows — fractions too small for "
            f"chunk_sessions={chunk_sessions}; use larger chunks")
    out = {}
    for name, w in writers.items():
        w.close()
        out[name] = SessionStore(w.directory)
    return out
