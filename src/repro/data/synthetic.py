"""Synthetic click-log simulator.

Generates WSCD/Baidu-ULTR-shaped interaction logs by sampling clicks from a
*ground-truth* CLAX click model (PBM / DBN / UBM / mixture), preserving the
statistical regime of the real datasets: Zipf-long-tailed query frequencies,
position bias from a production-ranker ordering, multi-click sessions, and
optional query-document feature vectors correlated with true attractiveness.

Because clicks come from our own ``model.sample``, the simulator doubles as a
correctness oracle: training the matching model on its own samples must
recover the ground-truth parameters (tested in tests/test_recovery.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticConfig:
    n_sessions: int = 100_000
    n_queries: int = 1_000
    docs_per_query: int = 20
    positions: int = 10
    behavior: str = "pbm"  # pbm | dbn | ubm | cascade | mixture
    zipf_exponent: float = 1.1  # query frequency long tail
    attr_alpha: float = 1.0  # Beta prior on attractiveness
    attr_beta: float = 5.0   # mean CTR ~ alpha/(alpha+beta) ~ 1/6
    exam_decay: float = 0.85  # theta_k = decay^(k-1) position bias
    continuation: float = 0.9  # DBN lambda
    ranker_noise: float = 1.0  # Gumbel noise scale of the logging ranker
    n_features: int = 0  # if > 0, emit query_doc_features
    feature_noise: float = 0.3
    seed: int = 0

    @property
    def n_query_doc_pairs(self) -> int:
        return self.n_queries * self.docs_per_query


def _ground_truth(cfg: SyntheticConfig, rng: np.random.Generator):
    gamma = rng.beta(cfg.attr_alpha, cfg.attr_beta,
                     size=(cfg.n_queries, cfg.docs_per_query)).astype(np.float32)
    theta = cfg.exam_decay ** np.arange(cfg.positions, dtype=np.float32)
    sigma = rng.beta(cfg.attr_alpha, cfg.attr_beta,
                     size=(cfg.n_queries, cfg.docs_per_query)).astype(np.float32)
    return gamma, theta, sigma


def _sample_clicks(cfg: SyntheticConfig, behavior: str, gamma_s, theta, sigma_s,
                   rng: np.random.Generator):
    """Vectorized numpy click sampling for (S, K) attractiveness arrays."""
    S, K = gamma_s.shape
    attracted = rng.random((S, K)) < gamma_s
    if behavior == "pbm":
        examined = rng.random((S, K)) < theta[None, :]
        return (attracted & examined).astype(np.float32)
    if behavior == "cascade":
        clicks = np.zeros((S, K), np.float32)
        browsing = np.ones(S, bool)
        for k in range(K):
            click = browsing & attracted[:, k]
            clicks[:, k] = click
            browsing = browsing & ~click
        return clicks
    if behavior == "dbn":
        satisfied_draw = rng.random((S, K)) < sigma_s
        cont_draw = rng.random((S, K)) < cfg.continuation
        clicks = np.zeros((S, K), np.float32)
        examining = np.ones(S, bool)
        for k in range(K):
            click = examining & attracted[:, k]
            clicks[:, k] = click
            satisfied = click & satisfied_draw[:, k]
            examining = examining & ~satisfied & cont_draw[:, k]
        return clicks
    if behavior == "ubm":
        # theta_{k,k'} = base_k * recency boost for clicks close to k
        clicks = np.zeros((S, K), np.float32)
        last = np.zeros(S, np.int64)  # 0 = no click yet, else 1-based rank
        for k in range(K):
            dist = np.where(last == 0, k + 1, k + 1 - last)
            th = theta[k] * (0.95 ** (dist - 1))
            examined = rng.random(S) < th
            click = examined & attracted[:, k]
            clicks[:, k] = click
            last = np.where(click, k + 1, last)
        return clicks
    raise ValueError(f"unknown behavior {behavior!r}")


def _query_probs(cfg: SyntheticConfig) -> np.ndarray:
    # Zipf query sampling (bounded), long tail like WSCD.
    ranks = np.arange(1, cfg.n_queries + 1, dtype=np.float64)
    q_probs = ranks ** (-cfg.zipf_exponent)
    return q_probs / q_probs.sum()


def _generate_sessions(cfg: SyntheticConfig, n_sessions: int,
                       gamma: np.ndarray, theta: np.ndarray, sigma: np.ndarray,
                       q_probs: np.ndarray,
                       rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """Sample ``n_sessions`` sessions against fixed ground-truth parameters."""
    queries = rng.choice(cfg.n_queries, size=n_sessions, p=q_probs)

    # Logging ranker: order docs by noisy attractiveness (selection bias),
    # show top-K.
    S, K = n_sessions, cfg.positions
    noise = rng.gumbel(scale=cfg.ranker_noise,
                       size=(S, cfg.docs_per_query)).astype(np.float32)
    scores = np.log(np.maximum(gamma[queries], 1e-6)) + noise
    top_docs = np.argsort(-scores, axis=1)[:, :K].astype(np.int64)

    gamma_s = np.take_along_axis(gamma[queries], top_docs, axis=1)
    sigma_s = np.take_along_axis(sigma[queries], top_docs, axis=1)

    if cfg.behavior == "mixture":
        # Half the population browses PBM-style, half cascade-style.
        pick = rng.random(S) < 0.5
        clicks = np.where(
            pick[:, None],
            _sample_clicks(cfg, "pbm", gamma_s, theta, sigma_s, rng),
            _sample_clicks(cfg, "cascade", gamma_s, theta, sigma_s, rng))
    else:
        clicks = _sample_clicks(cfg, cfg.behavior, gamma_s, theta, sigma_s, rng)

    query_doc_ids = (queries[:, None] * cfg.docs_per_query + top_docs).astype(np.int64)
    data = {
        "positions": np.broadcast_to(np.arange(1, K + 1, dtype=np.int32),
                                     (S, K)).copy(),
        "query_doc_ids": query_doc_ids,
        "clicks": clicks.astype(np.float32),
        "mask": np.ones((S, K), bool),
        # ground truth for evaluation (NOT model inputs):
        "true_attractiveness": gamma_s,
        "true_satisfaction": sigma_s,
    }
    if cfg.n_features > 0:
        data["query_doc_features"] = make_features(
            gamma_s, cfg.n_features, cfg.feature_noise, rng)
    return data


def generate_click_log(cfg: SyntheticConfig) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(cfg.seed)
    gamma, theta, sigma = _ground_truth(cfg, rng)
    data = _generate_sessions(cfg, cfg.n_sessions, gamma, theta, sigma,
                              _query_probs(cfg), rng)
    meta = {
        "theta": theta,
        "gamma": gamma.reshape(-1),
        "sigma": sigma.reshape(-1),
        "n_query_doc_pairs": cfg.n_query_doc_pairs,
    }
    return data, meta


def chunk_sizes(cfg: SyntheticConfig, chunk_sessions: int):
    """Row count of every chunk ``iter_click_log_chunks`` would yield —
    pure arithmetic, no synthesis. The parallel ingest planner maps shard
    boundaries to chunk ranges with this."""
    if chunk_sessions < 1:
        raise ValueError(f"chunk_sessions must be >= 1, got {chunk_sessions}")
    return [min(chunk_sessions, cfg.n_sessions - lo)
            for lo in range(0, cfg.n_sessions, chunk_sessions)]


# Ground-truth tables are O(n_queries * docs_per_query) and identical for
# every chunk of a config; a parallel-ingest worker synthesizing many chunks
# of the same log must not re-draw them per chunk. Keyed by the config
# (hashable via its dataclass fields), one entry per process is plenty.
_GROUND_TRUTH_CACHE: Dict = {}


def synthesize_chunk(cfg: SyntheticConfig, chunk_index: int,
                     chunk_sessions: int) -> Dict[str, np.ndarray]:
    """Synthesize chunk ``chunk_index`` of the deterministic chunk stream —
    bit-identical to the ``chunk_index``-th yield of
    :func:`iter_click_log_chunks` for the same ``(cfg, chunk_sessions)``,
    but addressable at random: workers generate exactly the chunks whose
    rows land in their shard range and nothing else."""
    sizes = chunk_sizes(cfg, chunk_sessions)
    if not 0 <= chunk_index < len(sizes):
        raise IndexError(f"chunk {chunk_index} out of range: "
                         f"{len(sizes)} chunks of {chunk_sessions}")
    key = dataclasses.astuple(cfg)
    if _GROUND_TRUTH_CACHE.get("key") != key:
        gamma, theta, sigma = _ground_truth(cfg, np.random.default_rng(cfg.seed))
        _GROUND_TRUTH_CACHE.update(key=key, tables=(gamma, theta, sigma),
                                   q_probs=_query_probs(cfg))
    gamma, theta, sigma = _GROUND_TRUTH_CACHE["tables"]
    rng = np.random.default_rng((cfg.seed, chunk_index))
    return _generate_sessions(cfg, sizes[chunk_index], gamma, theta, sigma,
                              _GROUND_TRUTH_CACHE["q_probs"], rng)


def iter_click_log_chunks(cfg: SyntheticConfig, chunk_sessions: int):
    """Generator-mode synthesis: yield the log in bounded-memory chunks.

    Ground-truth parameters (attractiveness/satisfaction tables, position
    bias) are drawn once from ``cfg.seed`` — bit-identical to the tables
    behind :func:`generate_click_log` — and held while sessions stream out
    in chunks of ``chunk_sessions`` rows (last chunk partial). Each chunk
    uses an independent generator seeded ``(cfg.seed, chunk_index)``, so the
    stream is deterministic in ``(cfg, chunk_sessions)`` and chunks can in
    principle be produced in parallel. Peak memory is O(chunk_sessions)
    rows regardless of ``cfg.n_sessions``; feeding the chunks into a
    :class:`repro.data.store.SessionStoreWriter` synthesizes a 100M+ session
    log without ever materializing it.

    Note: the concatenated chunk stream is statistically identical to — but
    not a bit-exact replay of — the monolithic ``generate_click_log`` draw
    for the same seed (the session-level rng consumption order differs).
    """
    if chunk_sessions < 1:
        raise ValueError(f"chunk_sessions must be >= 1, got {chunk_sessions}")
    gamma, theta, sigma = _ground_truth(cfg, np.random.default_rng(cfg.seed))
    q_probs = _query_probs(cfg)
    emitted = 0
    chunk_index = 0
    while emitted < cfg.n_sessions:
        n = min(chunk_sessions, cfg.n_sessions - emitted)
        rng = np.random.default_rng((cfg.seed, chunk_index))
        yield _generate_sessions(cfg, n, gamma, theta, sigma, q_probs, rng)
        emitted += n
        chunk_index += 1


def make_features(gamma_s: np.ndarray, n_features: int, noise: float,
                  rng: np.random.Generator) -> np.ndarray:
    """Feature vectors carrying attractiveness signal + distractor dims."""
    S, K = gamma_s.shape
    logit = np.log(np.maximum(gamma_s, 1e-6)) - np.log(np.maximum(1 - gamma_s, 1e-6))
    feats = rng.normal(scale=1.0, size=(S, K, n_features)).astype(np.float32)
    # first few dims carry signal with varying SNR
    n_signal = max(n_features // 4, 1)
    for i in range(n_signal):
        feats[:, :, i] = logit * (1.0 / (i + 1)) + rng.normal(
            scale=noise, size=(S, K)).astype(np.float32)
    return feats
