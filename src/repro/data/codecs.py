"""Per-column storage codecs for the session store (format v2).

Click logs are dominated by columns that barely carry entropy: ``clicks``
and ``mask`` are almost entirely zeros/ones, ``positions`` is the same
``1..K`` row repeated for every session, and id columns are small integers
rattling around in int64 slots. Storing them raw wastes bytes *and* read
bandwidth — at billion-session scale the store's byte volume is the data
plane's binding constraint. This module gives every column file an explicit
codec:

=========  =============================================================
``raw``    the v1 format: the array's contiguous bytes, ``np.memmap``-able
           (zero-copy reads; the only codec v1 stores know)
``bitpack``  1 bit per element via ``np.packbits`` — exact for any column
           whose values are all 0 or 1 (bool masks, float 0.0/1.0 click
           indicators): 8x for bool, 32x for float32
``zlib``   DEFLATE over the raw bytes (zstd-style byte-stream compression
           with a stdlib-only dependency) — wins on repetitive or
           small-integer columns, skipped when it doesn't pay
=========  =============================================================

Codec choice is **deterministic in the column bytes alone**
(:func:`encode_auto`): bitpack if every value is 0/1, else zlib if it
shrinks the column below :data:`ZLIB_ACCEPT` of raw, else raw. Two writers
handed the same shard rows therefore emit byte-identical column files —
the property the parallel-ingest byte-identity pin rests on.

Checksums and truncation checks operate on the *stored* (encoded) bytes,
so the store's fail-closed corruption paths (crc32 verify, quarantine)
work unchanged on compressed columns; :func:`decode` additionally wraps
any decoder error in ``ValueError`` so a corrupt stream that defeats a
size check still fails closed instead of returning garbage-shaped data.
"""
from __future__ import annotations

import zlib
from typing import Tuple

import numpy as np

CODECS = ("raw", "bitpack", "zlib")
#: DEFLATE level used at write time (decode is level-independent). Level 1
#: keeps ingest compute-light; the columns zlib wins on (constant or
#: small-integer patterns) compress nearly as well as at level 9.
ZLIB_LEVEL = 1
#: zlib is only chosen when it shrinks a column below this fraction of raw
#: — a marginal win is not worth losing the zero-copy memmap read path.
ZLIB_ACCEPT = 0.9


def raw_bytes(arr: np.ndarray) -> bytes:
    return np.ascontiguousarray(arr).tobytes()


def is_binary(arr: np.ndarray) -> bool:
    """True when every element is exactly 0 or 1 (any dtype), i.e. the
    column round-trips exactly through 1-bit packing."""
    if arr.dtype == np.bool_:
        return True
    if arr.dtype.kind not in "iuf":
        return False
    return bool(((arr == 0) | (arr == 1)).all())


def encode(codec: str, arr: np.ndarray) -> bytes:
    """Encode one column of one shard into its stored byte stream."""
    if codec == "raw":
        return raw_bytes(arr)
    if codec == "bitpack":
        if not is_binary(arr):
            raise ValueError(
                "bitpack requires every value to be 0 or 1 — refusing a "
                "lossy encode (use codec='auto' to pick per shard)")
        flat = np.ascontiguousarray(arr).reshape(-1)
        return np.packbits(flat != 0).tobytes()
    if codec == "zlib":
        return zlib.compress(raw_bytes(arr), ZLIB_LEVEL)
    raise ValueError(f"unknown codec {codec!r}; known: {CODECS}")


def decode(codec: str, data: bytes, dtype, shape: Tuple[int, ...]
           ) -> np.ndarray:
    """Decode a stored byte stream back into the column array.

    Any decoder failure (corrupt DEFLATE stream, wrong element count) is
    raised as ``ValueError`` so callers can map it onto
    ``ShardCorruptionError`` uniformly.
    """
    dtype = np.dtype(dtype)
    n = int(np.prod(shape, dtype=np.int64))
    if codec == "raw":
        arr = np.frombuffer(data, dtype=dtype)
        if arr.size != n:
            raise ValueError(f"raw column holds {arr.size} elements, "
                             f"expected {n}")
        return arr.reshape(shape)
    if codec == "bitpack":
        want_bytes = (n + 7) // 8
        if len(data) != want_bytes:
            raise ValueError(f"bitpack column is {len(data)} bytes, "
                             f"expected {want_bytes} for {n} elements")
        bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8), count=n)
        return bits.astype(dtype).reshape(shape)
    if codec == "zlib":
        try:
            raw = zlib.decompress(data)
        except zlib.error as e:
            raise ValueError(f"zlib stream corrupt: {e}") from e
        arr = np.frombuffer(raw, dtype=dtype)
        if arr.size != n:
            raise ValueError(f"zlib column decodes to {arr.size} elements, "
                             f"expected {n}")
        return arr.reshape(shape)
    raise ValueError(f"unknown codec {codec!r}; known: {CODECS}")


def encode_auto(arr: np.ndarray) -> Tuple[str, bytes]:
    """Pick the best codec for this shard's column and encode in one pass.

    Deterministic in the column values: bitpack when exact, else zlib when
    it clears :data:`ZLIB_ACCEPT`, else raw. Returns ``(codec, stored)``
    so the trial compression is never repeated.
    """
    if is_binary(arr):
        return "bitpack", encode("bitpack", arr)
    raw = raw_bytes(arr)
    z = zlib.compress(raw, ZLIB_LEVEL)
    if len(z) <= ZLIB_ACCEPT * len(raw):
        return "zlib", z
    return "raw", raw
