"""Deterministic, checkpointable, shard-aware batch loader.

Designed for the fault-tolerance story: loader state (epoch, step, shuffle
seed) is a tiny pytree saved with every checkpoint, so a preempted run resumes
mid-epoch bit-exactly. For multi-host setups, ``host_id``/``host_count`` carve
disjoint session shards per host (each host loads only its slice, the standard
data-parallel input pipeline at pod scale).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

MODEL_KEYS = ("positions", "query_doc_ids", "clicks", "mask",
              "query_doc_features", "bias_features")


def split_sessions(data: Dict[str, np.ndarray], fractions=(0.8, 0.1, 0.1),
                   seed: int = 0):
    """Shuffle-split a session dict into train/val/test dicts.

    The last split takes the exact remainder (independent per-fraction
    rounding could overlap splits or silently drop tail sessions); the
    splits always partition the input.
    """
    n = data["positions"].shape[0]
    order = np.random.default_rng(seed).permutation(n)
    sizes = [int(round(n * frac)) for frac in fractions[:-1]]
    sizes.append(n - sum(sizes))
    if sizes[-1] < 0:
        raise ValueError(f"fractions {fractions} overflow {n} sessions")
    assert sum(sizes) == n, (sizes, n)
    out = []
    start = 0
    for size in sizes:
        idx = order[start:start + size]
        out.append({k: v[idx] for k, v in data.items()})
        start += size
    return tuple(out)


@dataclasses.dataclass
class LoaderState:
    epoch: int = 0
    step: int = 0  # batch index within the epoch

    def to_dict(self):
        return {"epoch": self.epoch, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(epoch=int(d["epoch"]), step=int(d["step"]))


class ClickLogLoader:
    def __init__(self, data: Dict[str, np.ndarray], batch_size: int,
                 shuffle: bool = True, seed: int = 0, drop_last: bool = True,
                 host_id: int = 0, host_count: int = 1,
                 include_keys: Optional[Tuple[str, ...]] = None):
        keys = include_keys or tuple(k for k in data if k in MODEL_KEYS)
        self.data = {k: data[k] for k in keys}
        n = next(iter(self.data.values())).shape[0]
        # host shard: contiguous slice per host
        per_host = n // host_count
        lo, hi = host_id * per_host, (host_id + 1) * per_host
        self.data = {k: v[lo:hi] for k, v in self.data.items()}
        self.n = per_host
        self.batch_size = batch_size
        self.shuffle = shuffle
        self.seed = seed
        self.drop_last = drop_last
        self.state = LoaderState()

    @property
    def batches_per_epoch(self) -> int:
        if self.drop_last:
            return self.n // self.batch_size
        return -(-self.n // self.batch_size)

    def _epoch_order(self, epoch: int) -> np.ndarray:
        if not self.shuffle:
            return np.arange(self.n)
        return np.random.default_rng((self.seed, epoch)).permutation(self.n)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        """Resumes from self.state; advances it as batches are consumed."""
        while True:
            order = self._epoch_order(self.state.epoch)
            nb = self.batches_per_epoch
            while self.state.step < nb:
                i = self.state.step
                idx = order[i * self.batch_size:(i + 1) * self.batch_size]
                self.state.step += 1
                yield {k: v[idx] for k, v in self.data.items()}
            self.state = LoaderState(epoch=self.state.epoch + 1, step=0)
            return  # one epoch per __iter__ call

    def epochs(self, n_epochs: int):
        start = self.state.epoch
        while self.state.epoch < start + n_epochs:
            yield from iter(self)

    # -- checkpointing -----------------------------------------------------------
    def state_dict(self):
        return self.state.to_dict()

    def load_state_dict(self, d):
        self.state = LoaderState.from_dict(d)


class DevicePrefetcher:
    """Double-buffered device-put prefetch over one loader epoch.

    Keeps ``size`` batches resident on device so the host->device copy of
    batch i+1 (and the host-side slicing behind it) overlaps the
    asynchronously dispatched step on batch i — the train loop never blocks
    on input, and the per-batch ``jnp.asarray`` re-wrap disappears.

    With ``overlap=True`` (default) the whole host side — pulling loader
    batches (which drains the streaming loader's read-ahead queue, i.e.
    shard decompress + window assembly), chunk stacking, and
    ``jax.device_put`` — runs in a dedicated staging thread feeding a
    bounded queue of device-resident items. The consumer then only pops
    finished device buffers: the H2D copy of chunk k+1 genuinely overlaps
    the dispatched scan over chunk k instead of running between dispatches.
    Item order, payloads, and the recorded resume states are identical to
    ``overlap=False`` (single producer, FIFO queue) — bit-exact mid-epoch
    checkpoint/resume is preserved, and a staging-thread exception (e.g.
    ``ShardCorruptionError`` from a fail-closed reader) re-raises on the
    consumer with its original traceback. Abandoning the iterator mid-epoch
    closes the staging thread, which in turn closes the loader's epoch
    generator *from the thread that was consuming it*.

    Iterating yields ``(device_batch, loader_state)`` pairs. ``loader_state``
    is the loader's resume point recorded *when that batch was produced*;
    mid-epoch checkpoints must save it (not ``loader.state_dict()``, which has
    run up to ``size`` batches ahead) to stay bit-exact across preemption.

    **Chunk mode** (``chunk_batches=N``): stacks N consecutive host batches
    into one ``(N, B, ...)`` array per key before the single ``device_put``,
    and yields ``(chunk, loader_state, n)`` triples instead of pairs, where
    ``loader_state`` is the resume point of the chunk's *last* batch (the
    correct cursor after all ``n`` contained steps ran) and ``n <= N`` (the
    epoch tail may form a partial chunk). A batch whose shapes differ from
    the chunk being accumulated (e.g. the final ``drop_last=False`` partial
    batch) flushes the current chunk and starts its own, so every yielded
    chunk is rectangular. This feeds the scan-jitted
    :class:`repro.train.engine.TrainEngine` one dispatch per N steps.

    ``device`` may be a ``jax.sharding.Sharding`` (e.g. a NamedSharding
    splitting the batch axis over a data-parallel mesh) — ``device_put``
    then places each (stacked) batch directly into its sharded layout — or
    a callable ``batch -> device/sharding`` for per-batch placement (e.g.
    shard divisible batches, replicate the ``drop_last=False`` tail).
    """

    def __init__(self, loader, size: int = 2, device=None,
                 chunk_batches: Optional[int] = None, overlap: bool = True):
        if size < 1:
            raise ValueError(f"prefetch size must be >= 1, got {size}")
        if chunk_batches is not None and chunk_batches < 1:
            raise ValueError(
                f"chunk_batches must be >= 1, got {chunk_batches}")
        if chunk_batches is not None and callable(device):
            # A batch-shaped callable would see the stacked (N, B, ...)
            # chunk and shard the scanned axis; chunks take one fixed
            # sharding (e.g. TrainEngine.batch_sharding()).
            raise ValueError(
                "callable device is not supported with chunk_batches — "
                "pass a fixed sharding shaped for the stacked chunk")
        self.loader = loader
        self.size = size
        self.device = device
        self.chunk_batches = chunk_batches
        self.overlap = overlap

    def _put(self, batch):
        import jax

        device = self.device(batch) if callable(self.device) else self.device
        return {k: jax.device_put(v, device) for k, v in batch.items()}

    # -- host-side item stream (shared by both execution modes) ----------------
    def _items(self):
        """Generator of finished queue items: loader pull + (chunk stack) +
        ``device_put`` + resume-state capture. Everything host-side lives
        here, so whichever thread iterates it does all the staging work.
        The loader's epoch iterator is created on first next() — in overlap
        mode that is the staging thread, which therefore also owns closing
        it (a generator must be closed from the thread executing it)."""
        it = iter(self.loader)
        get_state = getattr(self.loader, "state_dict", lambda: None)
        if self.chunk_batches is None:
            for batch in it:
                yield (self._put(batch), get_state())
            return
        pushback = []  # one-batch lookahead for the shape-change flush
        while True:
            batches, state, sig = [], None, None
            while len(batches) < self.chunk_batches:
                if pushback:
                    item = pushback.pop()
                else:
                    try:
                        item = (next(it), get_state())
                    except StopIteration:
                        break
                batch, s = item
                bsig = {k: (v.shape, v.dtype) for k, v in batch.items()}
                if sig is not None and bsig != sig:
                    pushback.append(item)
                    break
                sig = bsig
                batches.append(batch)
                state = s
            if not batches:
                return
            chunk = {k: np.stack([b[k] for b in batches])
                     for k in batches[0]}
            yield (self._put(chunk), state, len(batches))

    # -- execution modes -------------------------------------------------------
    def _pump(self, items):
        """Inline mode: prime ``size`` items, then refill one ahead of each
        yield, all on the consumer thread (``overlap=False``)."""
        queue = collections.deque()
        try:
            for item in items:
                queue.append(item)
                if len(queue) >= self.size:
                    break
            while queue:
                nxt = next(items, None)
                if nxt is not None:  # refill before handing back to compute
                    queue.append(nxt)
                yield queue.popleft()
        finally:
            items.close()

    def _staged(self, items):
        """Overlap mode: run the item stream in a staging thread feeding a
        bounded queue; the consumer only pops device-resident items."""
        import queue as queue_mod

        q: queue_mod.Queue = queue_mod.Queue(maxsize=self.size)
        stop = threading.Event()
        done = object()
        fail = []  # [exception] — surfaced on the consumer

        def send(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue_mod.Full:
                    continue
            return False

        def run():
            try:
                for item in items:
                    if not send(item):
                        return
                send(done)
            except BaseException as e:
                fail.append(e)
                send(done)
            finally:
                # consumed here => closed here; for a streaming loader this
                # unwinds its epoch generator's finally (read-ahead shutdown)
                items.close()

        thread = threading.Thread(target=run, daemon=True,
                                  name="device-prefetch")
        thread.start()
        try:
            while True:
                try:
                    item = q.get(timeout=0.2)
                except queue_mod.Empty:
                    if not thread.is_alive() and q.empty() and not fail:
                        return  # crashed harder than except: nothing to raise
                    continue
                if item is done:
                    if fail:
                        raise fail[0]  # original traceback intact
                    return
                yield item
        finally:
            stop.set()
            thread.join(timeout=10.0)

    def __iter__(self):
        if self.overlap:
            yield from self._staged(self._items())
        else:
            yield from self._pump(self._items())
