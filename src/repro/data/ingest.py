"""Parallel ingest: fan deterministic chunk synthesis + shard writing
across worker processes, byte-identical to the single-process path.

Single-process ingest tops out on chunk synthesis (numpy-bound), which
makes a billion-session store an hours-long serial job. The chunk stream,
however, is *randomly addressable*: chunk ``c`` is a pure function of
``(cfg, chunk_sessions, c)`` (:func:`repro.data.synthetic.synthesize_chunk`)
and the split routing of its rows is a pure function of ``(seed, c)``
(:func:`repro.data.store.split_permutation`). So the whole store layout —
which row of which chunk lands at which offset of which shard of which
split — is fixed by arithmetic before any data exists, and can be carved
into disjoint jobs:

1. **Plan** (pure arithmetic, no IO): per split, the row stream is
   ``sum(split_sizes(chunk))`` long and cuts into ``ceil(rows/shard_rows)``
   shards. Worker ``w`` of ``W`` owns the contiguous shard block
   ``[w*K//W, (w+1)*K//W)`` of every split — block boundaries sit on shard
   boundaries, so every worker-written shard is also a single-process shard.
2. **Workers** generate exactly the chunks overlapping their row ranges
   (each chunk once, routed to all of the worker's splits — the per-split
   ranges nearly coincide because split fractions are uniform across
   chunks), slice off the rows inside their range, and write their shard
   files with the same encoder as the serial writer
   (``store._write_shard_dir``), under the same atomic discipline: shard
   files first, manifest last.
3. **Merge** (single writer): the parent validates the returned shard
   groups — any overlap or gap is a hard error — and commits one manifest
   per split via the same atomic ``os.replace``. A crash anywhere before
   that leaves no manifest: not a store.

Because shard bytes are a deterministic function of the rows they hold and
the codec choice is deterministic in those rows, the parallel store is
**bit-identical** to ``store.ingest_synthetic``'s — shard files and
manifest alike (metadata records the actual ``ingest_workers``) — pinned
in tests/test_ingest.py.

Workers are ``spawn`` processes that import only the numpy side of
``repro.data`` (no jax), so they start in well under a second.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import multiprocessing
import os
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.data.store import (FORMAT_VERSION, MANIFEST_NAME, ColumnSpec,
                              SessionStore, WRITER_CODECS, _shard_dirname,
                              _take_rows, _write_shard_dir, split_permutation,
                              split_sizes)
from repro.data import store as _store
from repro.data.synthetic import chunk_sizes, synthesize_chunk


# -- planning (pure arithmetic, shared by parent and workers) ------------------

def _split_names(splits: Optional[Mapping[str, float]]) -> List[str]:
    return list(splits) if splits is not None else [""]


def _split_cum_rows(chunk_rows: Sequence[int],
                    splits: Optional[Mapping[str, float]]
                    ) -> Dict[str, np.ndarray]:
    """Per split: cumulative row offsets ``cum[c]`` = first stream row of
    chunk ``c``'s contribution (``cum[-1]`` = the split's total rows)."""
    names = _split_names(splits)
    per_chunk = {name: np.zeros(len(chunk_rows) + 1, np.int64)
                 for name in names}
    for c, n in enumerate(chunk_rows):
        sizes = [n] if splits is None else split_sizes(n, splits)
        for name, s in zip(names, sizes):
            per_chunk[name][c + 1] = s
    return {name: np.cumsum(arr) for name, arr in per_chunk.items()}


def _shard_block(total_rows: int, shard_rows: int, worker: int,
                 workers: int) -> tuple:
    """Shard-index block ``[s_lo, s_hi)`` and row range ``[r_lo, r_hi)``
    worker ``worker`` owns for a split of ``total_rows`` rows."""
    n_shards = -(-total_rows // shard_rows) if total_rows else 0
    s_lo = (worker * n_shards) // workers
    s_hi = ((worker + 1) * n_shards) // workers
    return s_lo, s_hi, s_lo * shard_rows, min(s_hi * shard_rows, total_rows)


# -- worker side ---------------------------------------------------------------

class _ShardSliceWriter:
    """Writes one worker's contiguous shard block of one split.

    Same buffering (``_take_rows``) and encoding (``_write_shard_dir``) as
    ``SessionStoreWriter``, but shard numbering starts at ``first_shard``
    and no manifest is written — the parent merges entries from all
    workers and commits it once.
    """

    def __init__(self, directory: str, first_shard: int, shard_rows: int,
                 codec: str, row_lo: int, row_hi: int, cum: np.ndarray):
        self.directory = directory
        self.first_shard = first_shard
        self.shard_rows = shard_rows
        self.codec = codec
        self.row_lo, self.row_hi = row_lo, row_hi
        self.cum = cum  # chunk -> stream-row offset of this split
        self.entries: List[Dict] = []
        self.columns: Optional[Dict[str, Dict]] = None
        self._parts: List[Dict[str, np.ndarray]] = []
        self._buffered = 0

    def feed_chunk(self, c: int, chunk: Mapping[str, np.ndarray],
                   idx: np.ndarray) -> None:
        """Route chunk ``c``'s rows for this split (``idx``, in stream
        order) — keeping only the slice inside this worker's row range."""
        lo = int(self.cum[c])
        a = max(self.row_lo - lo, 0)
        b = min(self.row_hi - lo, len(idx))
        if b <= a:
            return
        sel = idx[a:b]
        # sorted key order matches SessionStoreWriter._fix_schema, so the
        # merged manifest's per-entry dicts serialize identically
        part = {k: np.asarray(chunk[k])[sel] for k in sorted(chunk)}
        if self.columns is None:
            self.columns = {k: ColumnSpec.of(v).to_json()
                            for k, v in part.items()}
        self._parts.append(part)
        self._buffered += len(sel)
        while self._buffered >= self.shard_rows:
            self._flush(self.shard_rows)

    def _flush(self, rows: int) -> None:
        shard = _take_rows(self._parts, rows)
        self._buffered -= rows
        index = self.first_shard + len(self.entries)
        sdir = os.path.join(self.directory, _shard_dirname(index))
        self.entries.append(_write_shard_dir(sdir, _shard_dirname(index),
                                             shard, rows, self.codec))

    def finish(self) -> Dict:
        if self._buffered:
            # Only the worker owning the stream's tail can hold a partial
            # shard — everyone else's range ends on a shard boundary.
            assert self.row_hi == int(self.cum[-1]), \
                (self.row_lo, self.row_hi, self._buffered)
            self._flush(self._buffered)
        return {"shards": self.entries, "columns": self.columns}


def _run_worker(worker: int, workers: int, chunk_fn: Callable,
                chunk_rows: Sequence[int], directory: str, shard_rows: int,
                splits: Optional[Mapping[str, float]], codec: str,
                seed: int) -> Dict[str, Dict]:
    """One worker's job: rebuild the plan (pure arithmetic — cheaper than
    shipping it), synthesize exactly the chunks its row ranges touch, and
    write its shard blocks for every split. Returns per-split shard
    entries + column specs for the parent's merge."""
    names = _split_names(splits)
    cum = _split_cum_rows(chunk_rows, splits)
    writers: Dict[str, _ShardSliceWriter] = {}
    for name in names:
        total = int(cum[name][-1])
        s_lo, s_hi, r_lo, r_hi = _shard_block(total, shard_rows, worker,
                                              workers)
        if s_hi > s_lo:
            writers[name] = _ShardSliceWriter(
                os.path.join(directory, name) if splits is not None
                else directory,
                s_lo, shard_rows, codec, r_lo, r_hi, cum[name])
    if not writers:
        return {}
    c_min = min(int(np.searchsorted(w.cum, w.row_lo, side="right")) - 1
                for w in writers.values())
    c_max = max(int(np.searchsorted(w.cum, w.row_hi, side="left"))
                for w in writers.values())
    for c in range(c_min, c_max):
        chunk = chunk_fn(c)
        n = next(iter(chunk.values())).shape[0]
        if n != chunk_rows[c]:
            raise ValueError(f"chunk {c} yielded {n} rows, plan says "
                             f"{chunk_rows[c]} — chunk_fn must be "
                             "deterministic in the chunk index")
        if splits is None:
            routed = {"": np.arange(n)}
        else:
            perm = split_permutation(seed, c, n)
            routed, start = {}, 0
            for name, size in zip(names, split_sizes(n, splits)):
                routed[name] = perm[start:start + size]
                start += size
        for name, w in writers.items():
            w.feed_chunk(c, chunk, routed[name])
    return {name: w.finish() for name, w in writers.items()}


# -- merge (single writer) -----------------------------------------------------

def merge_shard_groups(groups: Sequence[Sequence[Dict]]) -> List[Dict]:
    """Validate + order worker shard groups into one shard table.

    Each group is one worker's shard-entry list. Any shard index written by
    two groups (overlap) or by none (gap) is a hard error — a merged
    manifest must describe exactly the shards a single-process writer
    would have produced, or the store is silently wrong.
    """
    by_index: Dict[int, Dict] = {}
    for group in groups:
        for e in group:
            i = int(e["name"].rsplit("_", 1)[1])
            if i in by_index:
                raise ValueError(
                    f"overlapping shard groups: shard {i} written by two "
                    "workers — refusing to commit a manifest over "
                    "ambiguous bytes")
            by_index[i] = e
    if not by_index:
        raise ValueError("no shards to merge")
    missing = sorted(set(range(max(by_index) + 1)) - set(by_index))
    if missing:
        raise ValueError(f"shard groups leave gaps: shards {missing} "
                         "missing — refusing to commit a partial store")
    return [by_index[i] for i in range(len(by_index))]


def _commit_manifest(directory: str, columns: Dict, shards: List[Dict],
                     shard_rows: int, metadata: Mapping) -> None:
    # field-for-field the dict SessionStoreWriter.close() builds, committed
    # with the same atomic rename
    manifest = {
        "format_version": FORMAT_VERSION,
        "columns": columns,
        "shards": shards,
        "rows": int(sum(s["rows"] for s in shards)),
        "shard_rows": int(shard_rows),
        "metadata": dict(metadata),
    }
    tmp = os.path.join(directory, MANIFEST_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(directory, MANIFEST_NAME))


# -- entrypoints ---------------------------------------------------------------

def ingest_chunks(chunk_fn: Callable[[int], Dict[str, np.ndarray]],
                  chunk_rows: Sequence[int], directory: str,
                  shard_rows: int = 1_000_000,
                  splits: Optional[Mapping[str, float]] = None,
                  codec: str = "auto", workers: int = 1, seed: int = 0,
                  metadata: Optional[Mapping] = None
                  ) -> Dict[str, SessionStore]:
    """Ingest any randomly-addressable chunk stream across ``workers``
    processes.

    ``chunk_fn(c)`` must return chunk ``c`` as a column dict of
    ``chunk_rows[c]`` rows, deterministically, and be picklable (a
    module-level function or ``functools.partial`` over one — workers are
    spawned). ``seed`` feeds the deterministic split-routing permutation;
    ``metadata`` lands in every split's manifest (plus ``split``/
    ``fraction`` keys). Returns the committed store(s), keyed by split
    name (``""`` when ``splits is None``).
    """
    if codec not in WRITER_CODECS:
        raise ValueError(f"codec must be one of {WRITER_CODECS}, "
                         f"got {codec!r}")
    if workers < 1:
        raise ValueError(f"workers must be >= 1, got {workers}")
    if not len(chunk_rows) or min(chunk_rows) < 1:
        raise ValueError("chunk_rows must be a non-empty sequence of "
                         "positive per-chunk row counts")
    chunk_rows = [int(n) for n in chunk_rows]
    names = _split_names(splits)
    cum = _split_cum_rows(chunk_rows, splits)
    empty = [name for name in names if int(cum[name][-1]) == 0]
    if empty:
        raise ValueError(f"splits {empty} receive zero rows — fractions too "
                         "small for these chunk sizes; use larger chunks")
    for name in names:
        os.makedirs(os.path.join(directory, name) if splits is not None
                    else directory, exist_ok=True)
        stale = os.path.join(directory, name if splits is not None else "",
                             MANIFEST_NAME)
        if os.path.exists(stale):  # same re-ingest discipline as the writer
            os.remove(stale)

    args = [(w, workers, chunk_fn, chunk_rows, directory, shard_rows,
             dict(splits) if splits is not None else None, codec, seed)
            for w in range(workers)]
    if workers == 1:
        results = [_run_worker(*args[0])]
    else:
        ctx = multiprocessing.get_context("spawn")
        with ctx.Pool(workers) as pool:
            results = pool.starmap(_run_worker, args)

    out = {}
    for name in names:
        sdir = os.path.join(directory, name) if splits is not None \
            else directory
        per_worker = [r[name]["shards"] for r in results if name in r]
        column_sets = [json.dumps(r[name]["columns"], sort_keys=True)
                       for r in results if name in r]
        if len(set(column_sets)) > 1:
            raise ValueError(f"workers disagree on the column schema of "
                             f"split {name!r}")
        shards = merge_shard_groups(per_worker)
        total = int(cum[name][-1])
        got = sum(s["rows"] for s in shards)
        if got != total:
            raise ValueError(f"merged shards of split {name!r} hold {got} "
                             f"rows, plan says {total}")
        columns = next(r[name]["columns"] for r in results if name in r)
        meta = dict(metadata or {})
        if splits is not None:
            meta.update(split=name, fraction=splits[name])
        _commit_manifest(sdir, columns, shards, shard_rows, meta)
        out[name] = SessionStore(sdir)
    return out


def ingest_synthetic(cfg, directory: str, chunk_sessions: int = 100_000,
                     shard_rows: int = 1_000_000,
                     splits: Optional[Mapping[str, float]] = None,
                     codec: str = "auto", workers: int = 1
                     ) -> Dict[str, SessionStore]:
    """:func:`repro.data.store.ingest_synthetic` with a ``workers`` knob.

    ``workers=1`` runs the serial reference implementation in-process;
    ``workers>1`` fans the same deterministic chunk stream over processes
    via :func:`ingest_chunks` — byte-identical output either way (pinned
    in tests/test_ingest.py). The manifest metadata records the codec and
    worker count actually used.
    """
    if workers == 1:
        return _store.ingest_synthetic(
            cfg, directory, chunk_sessions=chunk_sessions,
            shard_rows=shard_rows, splits=splits, codec=codec,
            extra_metadata={"ingest_workers": 1})
    meta = {"synthetic_config": dataclasses.asdict(cfg),
            "chunk_sessions": int(chunk_sessions),
            "store_codec": codec,
            "ingest_workers": int(workers)}
    return ingest_chunks(
        functools.partial(synthesize_chunk, cfg,
                          chunk_sessions=chunk_sessions),
        chunk_sizes(cfg, chunk_sessions), directory, shard_rows=shard_rows,
        splits=splits, codec=codec, workers=workers, seed=cfg.seed,
        metadata=meta)
