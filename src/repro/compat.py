"""Version-compat shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed ``check_rep`` to ``check_vma`` along the way. Model code targets the
modern spelling; this shim maps it onto whichever API the installed jax has.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.6: top-level export with check_vma
    from jax import shard_map as _shard_map
except ImportError:  # older jax: experimental module with check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

_ACCEPTS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map).parameters


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    if check_vma is not None:
        kwargs["check_vma" if _ACCEPTS_CHECK_VMA else "check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def make_auto_mesh(axis_shapes, axis_names):
    """jax.make_mesh with Auto axis types where supported, plain mesh before."""
    import jax

    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    except (AttributeError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)


def set_mesh(mesh):
    """Context manager installing ``mesh``: jax.set_mesh on new jax, the
    legacy ``with mesh:`` resource context before it existed."""
    import jax

    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh  # Mesh is itself a context manager on older jax
