"""Decoder-only LM family: dense (llama3/phi3) and MoE (granite/llama4)."""
from repro.models.lm.transformer import (
    LMConfig,
    init_params,
    param_specs,
    forward,
    lm_loss,
    make_train_step,
    make_prefill_step,
    make_decode_step,
    init_cache,
    cache_specs,
)

__all__ = [
    "LMConfig",
    "init_params",
    "param_specs",
    "forward",
    "lm_loss",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "init_cache",
    "cache_specs",
]
