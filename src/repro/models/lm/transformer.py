"""Decoder-only transformer family (llama3 / phi3 / granite-MoE / llama4).

Production posture:
  * **scan-over-layers** with stacked parameters — HLO size (and compile time)
    independent of depth; remat policy on the layer body.
  * **3D sharding**: TP over ``model`` (heads / ffn hidden / vocab / experts),
    FSDP over the data axes (``pod`` + ``data``) on the d_model dim of every
    weight, DP over (pod, data) for activations. GSPMD inserts the FSDP
    all-gathers; the MoE block does its gather explicitly inside shard_map.
  * **MoE**: expert-parallel over ``model`` with capacity-bounded top-k
    routing. Activations stay replicated across ``model`` (Megatron-style),
    each expert shard processes the tokens routed to its local experts and
    one psum merges expert outputs — the same collective a dense TP FFN
    needs, so EP costs no extra wire vs dense. ``capacity_factor >= n_experts``
    makes routing lossless (used by tests to compare against the dense oracle).
  * **memory-efficient attention**: q-block-chunked softmax(QK^T)V (lax.scan)
    so the (S, S) score matrix never materializes for long prefill; the
    Pallas flash kernel is the TPU fast path (kernels/flash_attention.py),
    this jnp chunked path is the portable/compile-analysis path.
  * **decode**: KV cache stacked over layers, batch-sharded over DP and
    seq-sharded over ``model`` (flash-decoding style partial-softmax psum is
    exercised in the perf pass).
  * **microbatching**: train_step accumulates grads over ``microbatches``
    splits in fp32, keeping the global-batch interface while bounding live
    activation memory.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro import optim as optim_lib


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: Optional[int] = None
    rope_theta: float = 500_000.0
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 1
    d_ff_moe: int = 0
    moe_layer_step: int = 1          # 1 = every layer MoE, 2 = alternate
    n_shared_experts: int = 0        # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    # numerics / memory
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    opt_dtype: Any = jnp.float32      # AdamW moments (bf16 for 400B-class)
    remat: bool = True
    attn_chunk: int = 1024            # q-block size for chunked attention
    scan_chunks: Optional[int] = None  # two-level remat scan factor (U1)
    grad_accum_dtype: Any = jnp.float32  # microbatch grad accumulator
    explicit_row_parallel: bool = False  # shard_map bf16 psum for wo/w_down
    flash_decode: bool = False        # shard_map partial-softmax decode attn
    decode_seq_axes: Tuple[str, ...] = ("model",)  # KV cache seq sharding
    microbatches: int = 1
    max_seq: int = 8192               # decode cache capacity

    def __post_init__(self):
        if self.head_dim is None:
            self.head_dim = self.d_model // self.n_heads
        if self.moe and self.d_ff_moe == 0:
            self.d_ff_moe = self.d_ff

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a TP-shardable multiple (Megatron-style). The
        logical vocab is unchanged; padded logit columns are masked to -inf
        in the loss and in decode outputs."""
        return -(-self.vocab // 16) * 16

    @property
    def n_units(self) -> int:
        return self.n_layers // self.moe_layer_step if self.moe else self.n_layers

    @property
    def layers_per_unit(self) -> int:
        return self.moe_layer_step if self.moe else 1

    def param_count(self) -> int:
        D, Dh = self.d_model, self.head_dim
        attn = D * self.n_heads * Dh * 2 + D * self.n_kv_heads * Dh * 2
        dense_ffn = 3 * D * self.d_ff
        total = 2 * self.vocab * D + self.n_layers * (attn + 2 * D) + D
        if not self.moe:
            return total + self.n_layers * dense_ffn
        n_moe = self.n_layers // self.moe_layer_step
        n_dense = self.n_layers - n_moe
        total += n_dense * dense_ffn
        total += n_moe * (self.n_experts * 3 * D * self.d_ff_moe + D * self.n_experts)
        total += n_moe * self.n_shared_experts * 3 * D * self.d_ff_moe
        return total

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        D = self.d_model
        n_moe = self.n_layers // self.moe_layer_step
        routed = self.n_experts * 3 * D * self.d_ff_moe
        active_routed = self.top_k * 3 * D * self.d_ff_moe
        return self.param_count() - n_moe * (routed - active_routed)


# ---------------------------------------------------------------------------
# Parameter init + sharding specs
# ---------------------------------------------------------------------------

def _dense_layer_shapes(cfg: LMConfig) -> Dict[str, tuple]:
    D, Dh = cfg.d_model, cfg.head_dim
    return {
        "ln1": (D,), "ln2": (D,),
        "wq": (D, cfg.n_heads * Dh), "wk": (D, cfg.n_kv_heads * Dh),
        "wv": (D, cfg.n_kv_heads * Dh), "wo": (cfg.n_heads * Dh, D),
        "w_gate": (D, cfg.d_ff), "w_up": (D, cfg.d_ff), "w_down": (cfg.d_ff, D),
    }


def _moe_layer_shapes(cfg: LMConfig) -> Dict[str, tuple]:
    D, Dh, E, F = cfg.d_model, cfg.head_dim, cfg.n_experts, cfg.d_ff_moe
    shapes = {
        "ln1": (D,), "ln2": (D,),
        "wq": (D, cfg.n_heads * Dh), "wk": (D, cfg.n_kv_heads * Dh),
        "wv": (D, cfg.n_kv_heads * Dh), "wo": (cfg.n_heads * Dh, D),
        "router": (D, E),
        "we_gate": (E, D, F), "we_up": (E, D, F), "we_down": (E, F, D),
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * F
        shapes.update({"ws_gate": (D, Fs), "ws_up": (D, Fs), "ws_down": (Fs, D)})
    return shapes


def _stack_shapes(cfg: LMConfig) -> Dict[str, Dict[str, tuple]]:
    out = {}
    if cfg.moe:
        out["moe"] = _moe_layer_shapes(cfg)
        if cfg.moe_layer_step == 2:
            out["dense"] = _dense_layer_shapes(cfg)
    else:
        out["dense"] = _dense_layer_shapes(cfg)
    return out


def init_params(cfg: LMConfig, rng: jax.Array):
    """Real initialization (smoke tests); the dry-run uses eval_shape."""
    U = cfg.n_units
    keys = iter(jax.random.split(rng, 64))

    def init_one(shape, scale=None):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = scale if scale is not None else (1.0 / max(fan_in, 1)) ** 0.5
        return (jax.random.normal(next(keys), shape) * scale).astype(cfg.param_dtype)

    params = {
        "embed": init_one((cfg.padded_vocab, cfg.d_model), scale=0.02),
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "lm_head": init_one((cfg.d_model, cfg.padded_vocab)),
    }
    for stack, shapes in _stack_shapes(cfg).items():
        params[stack] = {}
        for name, shape in shapes.items():
            full = (U,) + shape
            if name.startswith("ln"):
                params[stack][name] = jnp.ones(full, cfg.param_dtype)
            else:
                params[stack][name] = init_one(full)
    return params


def param_specs(cfg: LMConfig, mesh) -> Any:
    """FSDP over data axes on d_model dims + TP over 'model'.

    Any axis assignment whose mesh size does not divide the dimension is
    dropped to replication (e.g. granite's vocab 49155 is not 16-divisible,
    so its embed/lm_head stay vocab-replicated).
    """
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = "model"

    def axes_size(entry) -> int:
        if entry is None:
            return 1
        names = entry if isinstance(entry, tuple) else (entry,)
        out = 1
        for n in names:
            out *= mesh.shape[n]
        return out

    def guard(spec: P, shape: tuple) -> P:
        entries = []
        for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
            entries.append(entry if dim % axes_size(entry) == 0 else None)
        return P(*entries)

    def spec_for(name: str, shape: tuple) -> P:
        if name.startswith("ln"):
            return P(*([None] * len(shape)))
        table = {
            "wq": P(None, fsdp, tp), "wk": P(None, fsdp, tp),
            "wv": P(None, fsdp, tp), "wo": P(None, tp, fsdp),
            "w_gate": P(None, fsdp, tp), "w_up": P(None, fsdp, tp),
            "w_down": P(None, tp, fsdp),
            "ws_gate": P(None, fsdp, tp), "ws_up": P(None, fsdp, tp),
            "ws_down": P(None, tp, fsdp),
            "router": P(None, None, None),
            # experts: EP over model, FSDP on d_model dim
            "we_gate": P(None, tp, fsdp, None), "we_up": P(None, tp, fsdp, None),
            "we_down": P(None, tp, None, fsdp),
        }
        return guard(table[name], shape)

    D, V = cfg.d_model, cfg.padded_vocab
    specs = {
        "embed": guard(P(tp, fsdp), (V, D)),
        "ln_f": P(None),
        "lm_head": guard(P(fsdp, tp), (D, V)),
    }
    for stack, shapes in _stack_shapes(cfg).items():
        U = cfg.n_units
        specs[stack] = {name: spec_for(name, (U,) + shape)
                        for name, shape in shapes.items()}
    return specs


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def _wsc(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that no-ops outside a mesh context."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
    except AttributeError:  # jax < 0.5: context mesh lives in thread_resources
        from jax.interpreters import pxla

        if pxla.thread_resources.env.physical_mesh.empty:
            return x
    return jax.lax.with_sharding_constraint(x, spec)


def _flash_decode_attention(cfg: LMConfig, mesh, dp_axes, seq_axes,
                            q, k_cache, v_cache, new_k, new_v, cache_index):
    """Flash-decoding over a seq-sharded KV cache (beyond-paper serving opt).

    Each seq shard computes a PARTIAL softmax over its local KV block
    (running max m, exp-sum l, weighted value o) and a 3-tensor psum
    combines them — wire is O(B * H * Dh) per layer instead of the
    multi-GB all-gathers XLA-auto emits for softmax over a sharded axis.
    The new token's KV is scattered into whichever shard owns position
    ``cache_index`` inside the same region.

    q: (B, 1, Hq, Dh); caches: (B, S, Hkv, Dh) sharded on S over seq_axes.
    Returns (attn_out (B, 1, Hq, Dh), new_k_cache, new_v_cache).
    """
    group = cfg.n_heads // cfg.n_kv_heads
    n_seq_shards = 1
    for a in seq_axes:
        n_seq_shards *= mesh.shape[a]

    def body(q, k_loc, v_loc, new_k, new_v, index):
        B, S_loc, Hkv, Dh = k_loc.shape
        shard = jnp.zeros((), jnp.int32)
        for a in seq_axes:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        offset = shard * S_loc
        # scatter the new token's kv into the owning shard
        local_pos = jnp.clip(index - offset, 0, S_loc - 1)
        owns = (index >= offset) & (index < offset + S_loc)
        k_upd = jax.lax.dynamic_update_slice(
            k_loc, new_k.astype(k_loc.dtype), (0, local_pos, 0, 0))
        v_upd = jax.lax.dynamic_update_slice(
            v_loc, new_v.astype(v_loc.dtype), (0, local_pos, 0, 0))
        k_loc = jnp.where(owns, k_upd, k_loc)
        v_loc = jnp.where(owns, v_upd, v_loc)
        # local partial attention
        kq = jnp.repeat(k_loc, group, axis=2) if group > 1 else k_loc
        vq = jnp.repeat(v_loc, group, axis=2) if group > 1 else v_loc
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                       kq.astype(jnp.float32)) * (Dh ** -0.5)
        valid = (offset + jnp.arange(S_loc)) <= index
        s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
        m_loc = jnp.max(s, axis=-1)                       # (B, H, 1)
        # all-masked shards contribute zero weight
        m_safe = jnp.where(jnp.isfinite(m_loc), m_loc, 0.0)
        p = jnp.exp(jnp.where(jnp.isfinite(s), s - m_safe[..., None], -jnp.inf))
        l_loc = jnp.sum(p, axis=-1)                       # (B, H, 1)
        o_loc = jnp.einsum("bhqk,bkhd->bhqd", p, vq.astype(jnp.float32))
        m_g = jax.lax.pmax(m_safe, seq_axes)
        scale = jnp.where(l_loc > 0, jnp.exp(m_safe - m_g), 0.0)
        l_g = jax.lax.psum(l_loc * scale, seq_axes)
        o_g = jax.lax.psum(o_loc * scale[..., None], seq_axes)
        out = (o_g / jnp.maximum(l_g[..., None], 1e-30)).astype(cfg.dtype)
        return out.transpose(0, 2, 1, 3), k_loc, v_loc    # (B,1,H,Dh)

    dp = _dp(dp_axes)
    cache_spec = P(dp, seq_axes, None, None)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, None, None), cache_spec, cache_spec,
                  P(dp, None, None, None), P(dp, None, None, None), P()),
        out_specs=(P(dp, None, None, None), cache_spec, cache_spec),
        check_vma=False,
    )(q, k_cache, v_cache, new_k, new_v, cache_index)
    return out


def _dp(dp_axes: tuple):
    """PartitionSpec entry for the batch dim ('' tuple -> replicated)."""
    return dp_axes if dp_axes else None


def _rmsnorm(x: jax.Array, scale: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (S,) or (B, S)."""
    Dh = x.shape[-1]
    half = Dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (S, half)
        angles = angles[None, :, None, :]
    else:
        angles = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
        angles = angles[:, :, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


def _chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool, chunk: int, kv_offset: int = 0) -> jax.Array:
    """softmax(QK^T)V without materializing (Sq, Skv) — scan over q blocks.

    q, k, v: (B, H, S, Dh) with MATCHING head counts (GQA KV are repeated to
    Hq by the caller *after* the TP sharding constraint, so the repeat stays
    shard-local — reshaping a head-sharded tensor into (Hkv, group) instead
    triggers a GSPMD full-rematerialization with wrong numerics on CPU).
    kv_offset: absolute position of q[0] minus kv[0] (decode alignment).
    """
    B, Hq, Sq, Dh = q.shape
    Skv = k.shape[2]
    scale = Dh ** -0.5
    chunk = min(chunk, Sq)
    pad = (-Sq) % chunk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else q
    nq = (Sq + pad) // chunk
    qp = qp.reshape(B, Hq, nq, chunk, Dh)

    k_pos = jnp.arange(Skv)

    def block(carry, inputs):
        qi, q_blk = inputs  # q_blk: (B, H, chunk, Dh)
        s = jnp.einsum("bhqd,bhkd->bhqk", q_blk.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        if causal:
            q_pos = qi * chunk + jnp.arange(chunk) + kv_offset
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask[None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
        return carry, o.astype(q.dtype)

    _, out = jax.lax.scan(block, None,
                          (jnp.arange(nq), jnp.moveaxis(qp, 2, 0)))
    out = jnp.moveaxis(out, 0, 2).reshape(B, Hq, Sq + pad, Dh)
    return out[:, :, :Sq]


def _attention_block(cfg: LMConfig, lp: Dict[str, jax.Array], h: jax.Array,
                     positions: jax.Array, dp_axes: tuple,
                     cache: Optional[Dict] = None,
                     cache_index: Optional[jax.Array] = None,
                     mesh=None):
    """Self-attention sublayer. Returns (out, new_cache_entry)."""
    B, S, D = h.shape
    Dh = cfg.head_dim
    x = _rmsnorm(h, lp["ln1"])
    q = (x @ lp["wq"].astype(cfg.dtype)).reshape(B, S, cfg.n_heads, Dh)
    k = (x @ lp["wk"].astype(cfg.dtype)).reshape(B, S, cfg.n_kv_heads, Dh)
    v = (x @ lp["wv"].astype(cfg.dtype)).reshape(B, S, cfg.n_kv_heads, Dh)
    # NOTE: no explicit head-dim constraint on q/k — the TP ('model') head
    # sharding propagates naturally from wq/wk's output dim, and forcing it
    # with with_sharding_constraint miscompiles under CPU GSPMD (verified by
    # bisect: constrained head-sharded q + repeated kv give wrong numerics;
    # tests/test_archs.py::test_moe_shard_map_matches_dense_oracle guards it).
    k = _wsc(k, P(_dp(dp_axes), None, None, None))
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)
    group = cfg.n_heads // cfg.n_kv_heads

    def rep(x, constrain=True):
        """Broadcast KV heads to Hq. In the prefill/train path the result is
        constrained to the q sharding so each TP shard materializes only its
        local repeated heads; in decode the cache stays seq-sharded and the
        repeat must follow it (constrain=False) — flash-decoding style."""
        if group == 1:
            return x
        del constrain  # sharding left to propagation (see note above)
        return jnp.repeat(x, group, axis=2)

    if cache is None:
        out = _chunked_attention(q.transpose(0, 2, 1, 3),
                                 rep(k).transpose(0, 2, 1, 3),
                                 rep(v).transpose(0, 2, 1, 3), causal=True,
                                 chunk=cfg.attn_chunk)
        new_entry = {"k": k, "v": v}  # (B, S, Hkv, Dh) — prefill cache entry
    elif cfg.flash_decode and mesh is not None:
        seq_axes = tuple(cfg.decode_seq_axes)
        attn, k_new, v_new = _flash_decode_attention(
            cfg, mesh, dp_axes, seq_axes, q, cache["k"], cache["v"],
            k, v, cache_index)
        out = attn.transpose(0, 2, 1, 3)   # (B, H, 1, Dh)
        new_entry = {"k": k_new, "v": v_new}
    else:
        # decode: append S (=1) new kv at cache_index, attend over prefix
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        S_max = k_cache.shape[1]
        qt = q.transpose(0, 2, 1, 3)                       # (B, H, 1, Dh)
        kt = rep(k_cache, constrain=False).transpose(0, 2, 1, 3)  # (B, H, S, Dh)
        vt = rep(v_cache, constrain=False).transpose(0, 2, 1, 3)
        s = jnp.einsum("bhqd,bhkd->bhqk", qt.astype(jnp.float32),
                       kt.astype(jnp.float32)) * (Dh ** -0.5)
        valid = jnp.arange(S_max)[None, :] <= (cache_index + jnp.arange(S)[:, None])
        s = jnp.where(valid[None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", p, vt.astype(jnp.float32))
        out = out.astype(cfg.dtype)
        new_entry = {"k": k_cache, "v": v_cache}

    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * Dh)
    if cfg.explicit_row_parallel and mesh is not None and cache is None:
        attn_out = _row_parallel_matmul(cfg, mesh, dp_axes, out, lp["wo"])
    else:
        attn_out = out @ lp["wo"].astype(cfg.dtype)
        attn_out = _wsc(attn_out, P(_dp(dp_axes), None, None))
    return h + attn_out, new_entry


def _row_parallel_matmul(cfg: LMConfig, mesh, dp_axes, x, w):
    """Megatron row-parallel matmul as an explicit shard_map:
    x (B,S,K) sharded on K over 'model'; w (K,D) sharded (model, fsdp).
    The partial products psum over 'model' IN BF16 — GSPMD's auto placement
    reduces the pre-downcast f32 dot output (2x wire; on CPU backends the
    f32 convert is unavoidable in auto mode). The fsdp weight shard is
    all-gathered in bf16 inside the region for the same reason."""
    fsdp = dp_axes

    def body(x_l, w_l):
        if fsdp:
            w_l = jax.lax.all_gather(w_l, fsdp, axis=1, tiled=True)
        y = (x_l.astype(cfg.dtype) @ w_l.astype(cfg.dtype)).astype(cfg.dtype)
        return jax.lax.psum(y, "model")

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(_dp(dp_axes), None, "model"), P("model", _dp(fsdp))),
        out_specs=P(_dp(dp_axes), None, None), check_vma=False)(x, w)


def _dense_ffn(cfg: LMConfig, lp, h, dp_axes, mesh=None):
    x = _rmsnorm(h, lp["ln2"])
    gate = jax.nn.silu(x @ lp["w_gate"].astype(cfg.dtype))
    up = x @ lp["w_up"].astype(cfg.dtype)
    hidden = _wsc(gate * up, P(_dp(dp_axes), None, "model"))
    if cfg.explicit_row_parallel and mesh is not None:
        return h + _row_parallel_matmul(cfg, mesh, dp_axes, hidden,
                                        lp["w_down"])
    return h + hidden @ lp["w_down"].astype(cfg.dtype)


# ---------------------------------------------------------------------------
# MoE block (shard_map expert parallelism over 'model')
# ---------------------------------------------------------------------------

def _moe_ffn(cfg: LMConfig, lp, h, mesh, dp_axes):
    B, S, D = h.shape
    if mesh is None:
        return _moe_ffn_dense(cfg, lp, h)
    fsdp = dp_axes  # FSDP axes for the stored expert weights

    def body(x, router_w, wg, wu, wd):
        # x: (B_loc, S, D) local tokens (replicated across 'model')
        # wg/wu: (E_loc, D/fsdp, F); wd: (E_loc, F, D/fsdp)
        if fsdp:
            wg = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp, axis=2, tiled=True)
        Bl, Sl, Dl = x.shape
        T = Bl * Sl
        E_loc = wg.shape[0]
        E = cfg.n_experts
        m = jax.lax.axis_index("model")
        xt = x.reshape(T, Dl)
        logits = (xt.astype(jnp.float32) @ router_w.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)            # (T, E)
        top_p, top_i = jax.lax.top_k(probs, cfg.top_k)     # (T, k)
        top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
        capacity = max(int(T * cfg.top_k / E * cfg.capacity_factor), 1)
        capacity = min(capacity, T)

        out = jnp.zeros((T, Dl), jnp.float32)
        for e in range(E_loc):
            eid = m * E_loc + e
            gate = jnp.sum(jnp.where(top_i == eid, top_p, 0.0), axis=-1)  # (T,)
            sel_gate, sel_idx = jax.lax.top_k(gate, capacity)
            xe = jnp.take(xt, sel_idx, axis=0).astype(cfg.dtype)  # (C, D)
            hid = jax.nn.silu(xe @ wg[e].astype(cfg.dtype)) * (xe @ wu[e].astype(cfg.dtype))
            ye = (hid @ wd[e].astype(cfg.dtype)).astype(jnp.float32)
            out = out.at[sel_idx].add(ye * sel_gate[:, None])
        out = jax.lax.psum(out, "model")
        return out.reshape(Bl, Sl, Dl).astype(cfg.dtype)

    moe = shard_map(
        body, mesh=mesh,
        in_specs=(P(_dp(dp_axes), None, None), P(None, None),
                  P("model", _dp(fsdp), None), P("model", _dp(fsdp), None),
                  P("model", None, _dp(fsdp))),
        out_specs=P(_dp(dp_axes), None, None),
        check_vma=False,
    )
    x = _rmsnorm(h, lp["ln2"])
    y = moe(x, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"])
    if cfg.n_shared_experts:
        gate = jax.nn.silu(x @ lp["ws_gate"].astype(cfg.dtype))
        up = x @ lp["ws_up"].astype(cfg.dtype)
        y = y + (gate * up) @ lp["ws_down"].astype(cfg.dtype)
    return h + y


def _moe_ffn_dense(cfg: LMConfig, lp, h):
    """Exact (lossless) MoE oracle: every expert over every token, one-hot
    combined. Used on single-device smoke tests and as the routing oracle
    for the shard_map path (with capacity_factor >= n_experts they agree)."""
    B, S, D = h.shape
    x = _rmsnorm(h, lp["ln2"])
    xt = x.reshape(B * S, D)
    logits = xt.astype(jnp.float32) @ lp["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    combine = jnp.zeros_like(probs)
    for k in range(cfg.top_k):
        combine = combine + jax.nn.one_hot(top_i[:, k], cfg.n_experts) * top_p[:, k:k+1]
    y = jnp.zeros((B * S, D), jnp.float32)
    for e in range(cfg.n_experts):
        hid = jax.nn.silu(xt @ lp["we_gate"][e].astype(cfg.dtype)) * (
            xt @ lp["we_up"][e].astype(cfg.dtype))
        ye = (hid @ lp["we_down"][e].astype(cfg.dtype)).astype(jnp.float32)
        y = y + ye * combine[:, e:e+1]
    y = y.reshape(B, S, D).astype(cfg.dtype)
    if cfg.n_shared_experts:
        gate = jax.nn.silu(x @ lp["ws_gate"].astype(cfg.dtype))
        up = x @ lp["ws_up"].astype(cfg.dtype)
        y = y + (gate * up) @ lp["ws_down"].astype(cfg.dtype)
    return h + y


# ---------------------------------------------------------------------------
# Forward / loss / train step
# ---------------------------------------------------------------------------

def _unit_body(cfg: LMConfig, mesh, dp_axes, h, positions, unit_params,
               collect_kv: bool = False):
    """One scan unit: (step-1) dense layers then the MoE/dense layer."""
    entries = []
    if cfg.moe and cfg.moe_layer_step == 2:
        h, e = _attention_block(cfg, unit_params["dense"], h, positions,
                                dp_axes, mesh=mesh)
        entries.append(e)
        h = _dense_ffn(cfg, unit_params["dense"], h, dp_axes, mesh=mesh)
        h, e = _attention_block(cfg, unit_params["moe"], h, positions,
                                dp_axes, mesh=mesh)
        entries.append(e)
        h = _moe_ffn(cfg, unit_params["moe"], h, mesh, dp_axes)
    elif cfg.moe:
        h, e = _attention_block(cfg, unit_params["moe"], h, positions,
                                dp_axes, mesh=mesh)
        entries.append(e)
        h = _moe_ffn(cfg, unit_params["moe"], h, mesh, dp_axes)
    else:
        h, e = _attention_block(cfg, unit_params["dense"], h, positions,
                                dp_axes, mesh=mesh)
        entries.append(e)
        h = _dense_ffn(cfg, unit_params["dense"], h, dp_axes, mesh=mesh)
    if not collect_kv:
        return h
    unit_cache = {"k": jnp.stack([e["k"] for e in entries]),
                  "v": jnp.stack([e["v"] for e in entries])}
    return h, unit_cache


def forward(cfg: LMConfig, params, tokens: jax.Array, mesh=None) -> jax.Array:
    """tokens (B, S) -> logits (B, S, V)."""
    dp_axes = _dp_axes(mesh)
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    h = _wsc(h, P(_dp(dp_axes), None, None))
    positions = jnp.arange(S)

    stacks = {k: v for k, v in params.items()
              if k in ("dense", "moe")}

    def body(h, unit_params):
        h = _unit_body(cfg, mesh, dp_axes, h, positions, unit_params)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.scan_chunks and cfg.scan_chunks > 1 and cfg.n_units % cfg.scan_chunks == 0:
        # two-level (sqrt) remat scan: backward keeps U1 outer + U2 inner
        # carries live instead of all n_units — the 126-layer memory fix.
        u1 = cfg.scan_chunks
        u2 = cfg.n_units // u1
        stacks2 = jax.tree_util.tree_map(
            lambda x: x.reshape((u1, u2) + x.shape[1:]), stacks)

        def outer(h, chunk_params):
            h, _ = jax.lax.scan(body, h, chunk_params)
            return h, None

        if cfg.remat:
            outer = jax.checkpoint(
                outer, policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = jax.lax.scan(outer, h, stacks2)
    else:
        h, _ = jax.lax.scan(body, h, stacks)
    h = _rmsnorm(h, params["ln_f"])
    logits = h @ params["lm_head"].astype(cfg.dtype)
    logits = _wsc(logits, P(_dp(dp_axes), None, "model"))
    return logits


def _mask_padded_vocab(cfg: LMConfig, logits: jax.Array) -> jax.Array:
    if cfg.padded_vocab == cfg.vocab:
        return logits
    valid = jnp.arange(cfg.padded_vocab) < cfg.vocab
    return jnp.where(valid, logits, -jnp.inf)


def lm_loss(cfg: LMConfig, params, batch, mesh=None) -> jax.Array:
    logits = forward(cfg, params, batch["tokens"], mesh)
    logits = _mask_padded_vocab(cfg, logits.astype(jnp.float32))
    targets = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(targets, 0)[..., None],
                               axis=-1)[..., 0]
    nll = logz - gold
    mask = (targets >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_train_step(cfg: LMConfig, optimizer=None, mesh=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, loss).

    Accumulates over cfg.microbatches in fp32 (sequential lax.scan), so the
    global-batch interface holds while live activations stay bounded.
    """
    optimizer = optimizer or optim_lib.adamw(3e-4)

    def train_step(params, opt_state, batch):
        M = cfg.microbatches

        if M == 1:
            loss, grads = jax.value_and_grad(
                lambda p: lm_loss(cfg, p, batch, mesh))(params)
        else:
            def split(x):
                return x.reshape(M, x.shape[0] // M, *x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mb):
                loss_acc, g_acc = carry
                loss, g = jax.value_and_grad(
                    lambda p: lm_loss(cfg, p, mb, mesh))(params)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: (a.astype(jnp.float32)
                                  + b.astype(jnp.float32)
                                  ).astype(cfg.grad_accum_dtype), g_acc, g)
                return (loss_acc + loss, g_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, cfg.grad_accum_dtype), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (0.0, zeros), micro)
            loss = loss / M
            grads = jax.tree_util.tree_map(lambda g: g / M, grads)

        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = optim_lib.apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_seq: Optional[int] = None,
               dtype=None):
    S = max_seq or cfg.max_seq
    dtype = dtype or cfg.dtype
    U, n_sub = cfg.n_units, cfg.layers_per_unit
    shape = (U, n_sub, batch, S, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs(cfg: LMConfig, mesh, *, shard_seq: bool = True) -> Dict[str, P]:
    """Cache (U, sub, B, S, Hkv, Dh): batch over DP, seq over model."""
    dp_axes = _dp_axes(mesh)
    if shard_seq:
        spec = P(None, None, dp_axes, "model", None, None)
    else:
        spec = P(None, None, dp_axes, None, None, None)
    return {"k": spec, "v": spec}


def make_prefill_step(cfg: LMConfig, mesh=None, dp_axes=None):
    """prefill(params, tokens (B, S)) -> (last-token logits (B,1,V), cache).

    Runs the full layer stack once over the prompt, emitting the KV cache
    (stacked (U, sub, B, S, Hkv, Dh)) and only the final-position logits —
    the (B, S, V) logits tensor never materializes.
    """
    dp = _dp_axes(mesh) if dp_axes is None else dp_axes

    def prefill(params, tokens):
        B, S = tokens.shape
        h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        h = _wsc(h, P(_dp(dp), None, None))
        positions = jnp.arange(S)
        stacks = {k: v for k, v in params.items() if k in ("dense", "moe")}

        def body(h, unit_params):
            return _unit_body(cfg, mesh, dp, h, positions, unit_params,
                              collect_kv=True)

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        h, cache = jax.lax.scan(body, h, stacks)
        h_last = _rmsnorm(h[:, -1:], params["ln_f"])
        logits = h_last @ params["lm_head"].astype(cfg.dtype)
        return _mask_padded_vocab(cfg, logits), cache

    return prefill


def make_decode_step(cfg: LMConfig, mesh=None, dp_axes=None):
    """decode_step(params, cache, tokens (B,1), index) -> (logits, cache)."""
    dp_axes = _dp_axes(mesh) if dp_axes is None else dp_axes

    def decode_step(params, cache, tokens, index):
        B = tokens.shape[0]
        h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        positions = jnp.full((B, 1), index, jnp.int32)

        stacks = {k: v for k, v in params.items() if k in ("dense", "moe")}

        def body(h, xs):
            unit_params, unit_cache = xs
            new_entries = []
            for sub in range(cfg.layers_per_unit):
                kind = ("dense" if (cfg.moe and cfg.moe_layer_step == 2
                                    and sub == 0) else
                        ("moe" if cfg.moe else "dense"))
                lp = unit_params[kind]
                entry = {"k": unit_cache["k"][sub], "v": unit_cache["v"][sub]}
                h, new_entry = _attention_block(
                    cfg, lp, h, positions, dp_axes,
                    cache=entry,
                    cache_index=index, mesh=mesh)
                if kind == "moe":
                    h = _moe_ffn(cfg, lp, h, mesh, dp_axes)
                else:
                    h = _dense_ffn(cfg, lp, h, dp_axes)
                new_entries.append(new_entry)
            new_unit_cache = {
                "k": jnp.stack([e["k"] for e in new_entries]),
                "v": jnp.stack([e["v"] for e in new_entries]),
            }
            return h, new_unit_cache

        h, new_cache = jax.lax.scan(body, h, (stacks, cache))
        h = _rmsnorm(h, params["ln_f"])
        logits = h @ params["lm_head"].astype(cfg.dtype)
        return _mask_padded_vocab(cfg, logits), new_cache

    return decode_step


def _dp_axes(mesh) -> tuple:
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
