"""Assigned architectures: LM transformers, GraphSAGE, recsys models."""
