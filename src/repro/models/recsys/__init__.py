from repro.models.recsys.embedding import TableConfig, init_table, table_lookup, table_spec
from repro.models.recsys.deepfm import DeepFMConfig, DeepFM
from repro.models.recsys.autoint import AutoIntConfig, AutoInt
from repro.models.recsys.bst import BSTConfig, BST
from repro.models.recsys.mind import MINDConfig, MIND

__all__ = [
    "TableConfig", "init_table", "table_lookup", "table_spec",
    "DeepFMConfig", "DeepFM",
    "AutoIntConfig", "AutoInt",
    "BSTConfig", "BST",
    "MINDConfig", "MIND",
]
