"""Behavior Sequence Transformer [Chen et al. 2019, arXiv:1905.06874]:
transformer block over the user's behavior sequence + target item, MLP head.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import optim as optim_lib
from repro.kernels import flash_attention
from repro.models.recsys.embedding import (TableConfig, bag_lookup,
                                           init_table, table_lookup,
                                           table_spec)
from repro.nn import MLP
from repro.stable import log_bce, log_sigmoid


@dataclasses.dataclass
class BSTConfig:
    name: str = "bst"
    embed_dim: int = 32
    seq_len: int = 20            # behavior history length (target appended)
    n_blocks: int = 1
    n_heads: int = 8
    d_ff: int = 128
    mlp: Sequence[int] = (1024, 512, 256)
    item_vocab: int = 20_000_000
    compression: str = "none"
    compression_ratio: float = 1.0
    dtype: Any = jnp.float32

    @property
    def table(self) -> TableConfig:
        return TableConfig(self.item_vocab, self.embed_dim, self.compression,
                           self.compression_ratio)

    @property
    def total_len(self) -> int:
        return self.seq_len + 1


class BST:
    def __init__(self, cfg: BSTConfig):
        self.cfg = cfg
        self.mlp = MLP(cfg.total_len * cfg.embed_dim, list(cfg.mlp), 1,
                       activation="relu")

    def init(self, rng):
        cfg = self.cfg
        keys = jax.random.split(rng, 3 + 6 * cfg.n_blocks)
        D = cfg.embed_dim
        std = (1.0 / D) ** 0.5
        params = {
            "embedding": init_table(cfg.table, keys[0]),
            "pos_embed": (jax.random.normal(keys[1], (cfg.total_len, D)) * 0.02),
            "mlp": self.mlp.init(keys[2]),
        }
        for b in range(cfg.n_blocks):
            k = keys[3 + 6 * b: 9 + 6 * b]
            params[f"block_{b}"] = {
                "wq": jax.random.normal(k[0], (D, D)) * std,
                "wk": jax.random.normal(k[1], (D, D)) * std,
                "wv": jax.random.normal(k[2], (D, D)) * std,
                "wo": jax.random.normal(k[3], (D, D)) * std,
                "ff1": jax.random.normal(k[4], (D, cfg.d_ff)) * std,
                "ff2": jax.random.normal(k[5], (cfg.d_ff, D)) * (1.0 / cfg.d_ff) ** 0.5,
                "ln1": jnp.ones((D,), jnp.float32),
                "ln2": jnp.ones((D,), jnp.float32),
            }
        return params

    def param_specs(self, mesh):
        like = jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))
        specs = jax.tree_util.tree_map(lambda _: P(), like)
        specs["embedding"] = table_spec(self.cfg.table)
        return specs

    @staticmethod
    def _ln(x, scale):
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        return ((xf - mu) * jax.lax.rsqrt(var + 1e-6) * scale).astype(x.dtype)

    def encode(self, params, batch) -> jax.Array:
        """history_ids (B, L) + target_ids (B,) -> (B, total_len, D)."""
        cfg = self.cfg
        seq_ids = jnp.concatenate(
            [batch["history_ids"], batch["target_ids"][:, None]], axis=1)
        h = table_lookup(cfg.table, params["embedding"], seq_ids)
        h = h + params["pos_embed"][None]
        for b in range(cfg.n_blocks):
            bp = params[f"block_{b}"]
            x = self._ln(h, bp["ln1"])
            B, S, D = x.shape
            hd = D // cfg.n_heads
            q = (x @ bp["wq"]).reshape(B, S, cfg.n_heads, hd).transpose(0, 2, 1, 3)
            k = (x @ bp["wk"]).reshape(B, S, cfg.n_heads, hd).transpose(0, 2, 1, 3)
            v = (x @ bp["wv"]).reshape(B, S, cfg.n_heads, hd).transpose(0, 2, 1, 3)
            a = flash_attention(q, k, v, causal=False)
            a = a.transpose(0, 2, 1, 3).reshape(B, S, D)
            h = h + a @ bp["wo"]
            x = self._ln(h, bp["ln2"])
            h = h + jax.nn.relu(x @ bp["ff1"]) @ bp["ff2"]
        return h

    def forward(self, params, batch) -> jax.Array:
        h = self.encode(params, batch)
        flat = h.reshape(h.shape[0], -1)
        return self.mlp(params["mlp"], flat)[..., 0]

    def loss(self, params, batch) -> jax.Array:
        log_p = log_sigmoid(self.forward(params, batch))
        return jnp.mean(log_bce(log_p, batch["labels"]))

    def make_train_step(self, optimizer=None):
        optimizer = optimizer or optim_lib.adamw(1e-3)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(self.loss)(params, batch)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            return optim_lib.apply_updates(params, updates), opt_state, loss

        return step

    def serve(self, params, batch) -> jax.Array:
        return log_sigmoid(self.forward(params, batch))

    def retrieval_score(self, params, batch) -> jax.Array:
        """Two-tower factorization for candidate scoring: mean-pooled history
        encoding (computed once) dotted against 1M candidate item embeddings —
        a single batched matmul (the standard serving approximation for
        sequence rankers at retrieval stage)."""
        cfg = self.cfg
        # Mean-pool the history through the fused bag kernel; the (static)
        # positional mean separates out of the linear pooling.
        user_vec = (bag_lookup(cfg.table, params["embedding"],
                               batch["history_ids"], combiner="mean")
                    + jnp.mean(params["pos_embed"][:cfg.seq_len], axis=0))
        cand = table_lookup(cfg.table, params["embedding"],
                            batch["candidate_ids"])  # (C, D)
        return jnp.einsum("bd,cd->bc", user_vec, cand)
