"""MIND [Li et al. 2019, arXiv:1904.08030]: multi-interest extraction via
capsule dynamic (B2I) routing + label-aware attention."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import optim as optim_lib
from repro.models.recsys.embedding import TableConfig, init_table, table_lookup, table_spec
from repro.stable import log_bce, log_sigmoid


@dataclasses.dataclass
class MINDConfig:
    name: str = "mind"
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    history_len: int = 50
    label_aware_pow: float = 2.0
    item_vocab: int = 10_000_000
    compression: str = "none"
    compression_ratio: float = 1.0
    dtype: Any = jnp.float32

    @property
    def table(self) -> TableConfig:
        return TableConfig(self.item_vocab, self.embed_dim, self.compression,
                           self.compression_ratio)


def _squash(x, axis=-1):
    norm2 = jnp.sum(jnp.square(x), axis=axis, keepdims=True)
    scale = norm2 / (1.0 + norm2) / jnp.sqrt(norm2 + 1e-9)
    return scale * x


class MIND:
    def __init__(self, cfg: MINDConfig):
        self.cfg = cfg

    def init(self, rng):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(rng, 3)
        D = cfg.embed_dim
        return {
            "embedding": init_table(cfg.table, k1),
            "bilinear": jax.random.normal(k2, (D, D)) * (1.0 / D) ** 0.5,
            # fixed (non-trained in-paper) routing-logit init, kept learnable
            "routing_init": jax.random.normal(k3, (cfg.history_len,
                                                   cfg.n_interests)) * 0.02,
        }

    def param_specs(self, mesh):
        like = jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))
        specs = jax.tree_util.tree_map(lambda _: P(), like)
        specs["embedding"] = table_spec(self.cfg.table)
        return specs

    def interests(self, params, batch) -> jax.Array:
        """history_ids (B, L) [-1 = pad] -> interest capsules (B, K, D)."""
        cfg = self.cfg
        ids = batch["history_ids"]
        mask = (ids >= 0)
        e = table_lookup(cfg.table, params["embedding"], jnp.maximum(ids, 0))
        e = jnp.where(mask[..., None], e, 0.0)                    # (B, L, D)
        eh = e @ params["bilinear"]                                # (B, L, D)
        b = jnp.broadcast_to(params["routing_init"][None],
                             (ids.shape[0],) + params["routing_init"].shape)
        u = None
        for _ in range(cfg.capsule_iters):
            w = jax.nn.softmax(b, axis=-1)                         # (B, L, K)
            w = jnp.where(mask[..., None], w, 0.0)
            z = jnp.einsum("blk,bld->bkd", w, eh)
            u = _squash(z)                                         # (B, K, D)
            b = b + jnp.einsum("bkd,bld->blk", u, eh)
        return u

    def forward(self, params, batch) -> jax.Array:
        """Label-aware scoring of target_ids (B,) -> logit (B,)."""
        cfg = self.cfg
        u = self.interests(params, batch)                          # (B, K, D)
        t = table_lookup(cfg.table, params["embedding"], batch["target_ids"])
        scores = jnp.einsum("bkd,bd->bk", u, t)                    # (B, K)
        # label-aware attention: soft-select interests (pow sharpening)
        w = jax.nn.softmax(cfg.label_aware_pow * scores, axis=-1)
        return jnp.sum(w * scores, axis=-1)

    def loss(self, params, batch) -> jax.Array:
        log_p = log_sigmoid(self.forward(params, batch))
        return jnp.mean(log_bce(log_p, batch["labels"]))

    def make_train_step(self, optimizer=None):
        optimizer = optimizer or optim_lib.adamw(1e-3)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(self.loss)(params, batch)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            return optim_lib.apply_updates(params, updates), opt_state, loss

        return step

    def serve(self, params, batch) -> jax.Array:
        return log_sigmoid(self.forward(params, batch))

    def retrieval_score(self, params, batch) -> jax.Array:
        """True multi-interest retrieval: max over interests of the dot with
        every candidate — one (B,K,D)x(C,D) matmul + max, batched."""
        u = self.interests(params, batch)                          # (B, K, D)
        cand = table_lookup(self.cfg.table, params["embedding"],
                            batch["candidate_ids"])                # (C, D)
        scores = jnp.einsum("bkd,cd->bkc", u, cand)
        return jnp.max(scores, axis=1)                             # (B, C)
