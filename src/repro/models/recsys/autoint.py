"""AutoInt [Song et al. 2018, arXiv:1810.11921]: self-attention feature
interaction over field embeddings, with residual projections."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import optim as optim_lib
from repro.kernels import flash_attention
from repro.models.recsys.embedding import TableConfig, init_table, table_lookup, table_spec
from repro.stable import log_bce, log_sigmoid


@dataclasses.dataclass
class AutoIntConfig:
    name: str = "autoint"
    n_sparse: int = 39
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    table_rows: int = 80_000_000
    compression: str = "none"
    compression_ratio: float = 1.0
    dtype: Any = jnp.float32

    @property
    def table(self) -> TableConfig:
        return TableConfig(self.table_rows, self.embed_dim, self.compression,
                           self.compression_ratio)


class AutoInt:
    def __init__(self, cfg: AutoIntConfig):
        self.cfg = cfg

    def _layer_dims(self):
        dims = [self.cfg.embed_dim] + [self.cfg.d_attn] * self.cfg.n_attn_layers
        return dims

    def init(self, rng):
        cfg = self.cfg
        dims = self._layer_dims()
        keys = jax.random.split(rng, 4 * cfg.n_attn_layers + 2)
        params = {"embedding": init_table(cfg.table, keys[0])}
        for l in range(cfg.n_attn_layers):
            d_in, d_out = dims[l], dims[l + 1]
            std = (1.0 / d_in) ** 0.5
            params[f"attn_{l}"] = {
                "wq": (jax.random.normal(keys[4 * l + 1], (d_in, d_out)) * std),
                "wk": (jax.random.normal(keys[4 * l + 2], (d_in, d_out)) * std),
                "wv": (jax.random.normal(keys[4 * l + 3], (d_in, d_out)) * std),
                "w_res": (jax.random.normal(keys[4 * l + 4], (d_in, d_out)) * std),
            }
        params["head"] = {
            "w": (jax.random.normal(keys[-1], (cfg.n_sparse * dims[-1], 1))
                  * (1.0 / (cfg.n_sparse * dims[-1])) ** 0.5),
            "b": jnp.zeros((1,), jnp.float32),
        }
        return params

    def param_specs(self, mesh):
        like = jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))
        specs = jax.tree_util.tree_map(lambda _: P(), like)
        specs["embedding"] = table_spec(self.cfg.table)
        return specs

    def forward(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        h = table_lookup(cfg.table, params["embedding"], batch["field_ids"])
        for l in range(cfg.n_attn_layers):
            lp = params[f"attn_{l}"]
            B, F, _ = h.shape
            q = (h @ lp["wq"]).reshape(B, F, cfg.n_heads, -1).transpose(0, 2, 1, 3)
            k = (h @ lp["wk"]).reshape(B, F, cfg.n_heads, -1).transpose(0, 2, 1, 3)
            v = (h @ lp["wv"]).reshape(B, F, cfg.n_heads, -1).transpose(0, 2, 1, 3)
            attn = flash_attention(q, k, v, causal=False)
            attn = attn.transpose(0, 2, 1, 3).reshape(B, F, -1)
            h = jax.nn.relu(attn + h @ lp["w_res"])
        flat = h.reshape(h.shape[0], -1)
        return (flat @ params["head"]["w"])[..., 0] + params["head"]["b"][0]

    def loss(self, params, batch) -> jax.Array:
        log_p = log_sigmoid(self.forward(params, batch))
        return jnp.mean(log_bce(log_p, batch["labels"]))

    def make_train_step(self, optimizer=None):
        optimizer = optimizer or optim_lib.adamw(1e-3)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(self.loss)(params, batch)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            return optim_lib.apply_updates(params, updates), opt_state, loss

        return step

    def serve(self, params, batch) -> jax.Array:
        return log_sigmoid(self.forward(params, batch))

    def retrieval_score(self, params, batch) -> jax.Array:
        return self.forward(params, batch)
