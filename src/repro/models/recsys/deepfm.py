"""DeepFM [Guo et al. 2017, arXiv:1703.04247]: FM + deep tower, shared embeds.

logit = w0 + sum_f w[ids_f] + FM2(V[ids]) + MLP(flatten(V[ids]))
Loss: stable log-space BCE (repro.stable — the paper's §5 layer).
The FM second-order term is the fm_interaction Pallas kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import optim as optim_lib
from repro.kernels import fm_interaction
from repro.models.recsys.embedding import (TableConfig, bag_lookup,
                                           init_table, table_lookup,
                                           table_spec)
from repro.nn import MLP
from repro.stable import log_bce, log_sigmoid


@dataclasses.dataclass
class DeepFMConfig:
    name: str = "deepfm"
    n_sparse: int = 39
    embed_dim: int = 10
    mlp: Sequence[int] = (400, 400, 400)
    table_rows: int = 80_000_000
    compression: str = "none"
    compression_ratio: float = 1.0
    dtype: Any = jnp.float32

    @property
    def table(self) -> TableConfig:
        return TableConfig(self.table_rows, self.embed_dim, self.compression,
                           self.compression_ratio)

    @property
    def first_order_table(self) -> TableConfig:
        return TableConfig(self.table_rows, 1, self.compression,
                           self.compression_ratio)


class DeepFM:
    def __init__(self, cfg: DeepFMConfig):
        self.cfg = cfg
        self.mlp = MLP(cfg.n_sparse * cfg.embed_dim, list(cfg.mlp), 1,
                       activation="relu")

    def init(self, rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {
            "embedding": init_table(self.cfg.table, k1),
            "first_order": init_table(self.cfg.first_order_table, k2),
            "mlp": self.mlp.init(k3),
            "bias": jnp.zeros((), jnp.float32),
        }

    def param_specs(self, mesh):
        return {
            "embedding": table_spec(self.cfg.table),
            "first_order": table_spec(self.cfg.first_order_table),
            "mlp": jax.tree_util.tree_map(lambda _: P(),
                                          self.mlp.init(jax.random.PRNGKey(0))),
            "bias": P(),
        }

    def forward(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        """batch["field_ids"]: (B, n_sparse) global ids -> logits (B,)."""
        ids = batch["field_ids"]
        v = table_lookup(self.cfg.table, params["embedding"], ids)  # (B, F, D)
        # First-order term as one fused bag reduction over the (N, 1) table:
        # sum_f w[ids_f] without a (B, F, 1) gather intermediate.
        first = bag_lookup(self.cfg.first_order_table,
                           params["first_order"], ids)[..., 0]      # (B,)
        fm = fm_interaction(v)                                      # (B,)
        flat = v.reshape(v.shape[0], -1)
        deep = self.mlp(params["mlp"], flat)[..., 0]                # (B,)
        return params["bias"] + first + fm + deep

    def loss(self, params, batch) -> jax.Array:
        log_p = log_sigmoid(self.forward(params, batch))
        return jnp.mean(log_bce(log_p, batch["labels"]))

    def make_train_step(self, optimizer=None):
        optimizer = optimizer or optim_lib.adamw(1e-3)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(self.loss)(params, batch)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            return optim_lib.apply_updates(params, updates), opt_state, loss

        return step

    def serve(self, params, batch) -> jax.Array:
        """Click log-probabilities for a request batch."""
        return log_sigmoid(self.forward(params, batch))

    def retrieval_score(self, params, batch) -> jax.Array:
        """Full batched forward over the candidate-expanded field matrix
        (1M candidate rows in one XLA program — batched, never a host loop)."""
        return self.forward(params, batch)
