"""Shared huge-table embedding substrate for the recsys archs.

This is the paper's §4.2 scale machinery applied outside click models: one
unified table (fields reach it via offsets), optional hashing-trick or
quotient-remainder compression, row-sharding over the ``model`` mesh axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.parameterization import SHARD_MULTIPLE, _round_up, hash_ids


@dataclasses.dataclass
class TableConfig:
    rows: int
    dim: int
    compression: str = "none"           # none | hash | qr
    compression_ratio: float = 1.0
    param_dtype: Any = jnp.float32

    @property
    def stored_rows(self) -> int:
        if self.compression == "hash":
            return _round_up(
                max(int(self.rows / max(self.compression_ratio, 1.0)), 2))
        return self.rows

    @property
    def qr_rem_rows(self) -> int:
        return _round_up(
            max(int(self.rows / max(self.compression_ratio, 1.0) / 2), 2))

    @property
    def qr_quot_rows(self) -> int:
        return _round_up(int(-(-self.rows // self.qr_rem_rows)))


def init_table(cfg: TableConfig, rng: jax.Array, stddev: float = 0.02) -> Dict:
    if cfg.compression == "qr":
        k1, k2 = jax.random.split(rng)
        return {
            "quotient": (jax.random.normal(k1, (cfg.qr_quot_rows, cfg.dim))
                         * stddev).astype(cfg.param_dtype),
            "remainder": (jax.random.normal(k2, (cfg.qr_rem_rows, cfg.dim))
                          * stddev).astype(cfg.param_dtype),
        }
    return {"table": (jax.random.normal(rng, (cfg.stored_rows, cfg.dim))
                      * stddev).astype(cfg.param_dtype)}


def table_lookup(cfg: TableConfig, params: Dict, ids: jax.Array) -> jax.Array:
    """ids (...,) -> embeddings (..., dim)."""
    if cfg.compression == "hash":
        return jnp.take(params["table"], hash_ids(ids, cfg.stored_rows), axis=0)
    if cfg.compression == "qr":
        q = jnp.take(params["quotient"],
                     (ids // cfg.qr_rem_rows) % cfg.qr_quot_rows, axis=0)
        r = jnp.take(params["remainder"], ids % cfg.qr_rem_rows, axis=0)
        return q * r
    return jnp.take(params["table"], jnp.clip(ids, 0, cfg.stored_rows - 1), axis=0)


def bag_lookup(cfg: TableConfig, params: Dict, ids: jax.Array,
               weights: jax.Array = None, combiner: str = "sum") -> jax.Array:
    """Fused bag reduction: out[b] = reduce_l w[b,l] * table[ids[b,l]].

    Routes through the embedding_bag kernel (gather + weighted reduce in one
    pass, ids < 0 = padding, impl via the dispatch registry). QR-compressed
    tables have no materialized row table to gather from, so they fall back
    to lookup + reduce.
    """
    from repro.kernels import embedding_bag

    if cfg.compression == "qr":
        rows = table_lookup(cfg, params, jnp.maximum(ids, 0))
        w = jnp.ones(ids.shape, jnp.float32) if weights is None else weights
        w = jnp.where(ids >= 0, w, 0.0).astype(jnp.float32)
        if combiner == "mean":
            count = jnp.sum((ids >= 0).astype(jnp.float32), axis=1,
                            keepdims=True)
            w = w / jnp.maximum(count, 1.0)
        return jnp.einsum("bld,bl->bd", rows.astype(jnp.float32), w)
    if cfg.compression == "hash":
        ids = jnp.where(ids >= 0, hash_ids(ids, cfg.stored_rows), -1)
    else:
        ids = jnp.where(ids >= 0, jnp.clip(ids, 0, cfg.stored_rows - 1), -1)
    return embedding_bag(params["table"], ids, weights, combiner=combiner)


def table_spec(cfg: TableConfig) -> Dict:
    """Row-sharded over 'model' (both QR components too)."""
    if cfg.compression == "qr":
        return {"quotient": P("model", None), "remainder": P("model", None)}
    return {"table": P("model", None)}
