from repro.models.gnn.graphsage import (
    SAGEConfig,
    init_params,
    param_specs,
    full_graph_forward,
    sampled_forward,
    node_classification_loss,
    make_full_graph_train_step,
    make_sampled_train_step,
)
from repro.models.gnn.sampler import NeighborSampler, random_graph

__all__ = [
    "SAGEConfig",
    "init_params",
    "param_specs",
    "full_graph_forward",
    "sampled_forward",
    "node_classification_loss",
    "make_full_graph_train_step",
    "make_sampled_train_step",
    "NeighborSampler",
    "random_graph",
]
