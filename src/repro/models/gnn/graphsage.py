"""GraphSAGE [Hamilton et al. 2017, arXiv:1706.02216], mean aggregator.

Two execution regimes (kernel_taxonomy §GNN: SpMM / gather-scatter):

* **full-graph**: message passing over the raw edge list via
  ``jax.ops.segment_sum`` (src->dst scatter). Distribution: edges sharded
  over every mesh axis, node states replicated per device; each shard
  aggregates its edge slice locally and one psum merges partial node sums —
  collective bytes = n_nodes * d * 4 per layer, independent of edge count.

* **sampled minibatch**: fixed-fanout neighbor tensors from the host-side
  :class:`~repro.models.gnn.sampler.NeighborSampler`. The per-hop
  mean-aggregation is exactly the embedding_bag kernel regime
  (gather + segment-mean with static bag size), so the TPU path reuses
  kernels/embedding_bag.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro import optim as optim_lib


@dataclasses.dataclass
class SAGEConfig:
    name: str = "graphsage"
    n_layers: int = 2
    d_in: int = 602
    d_hidden: int = 128
    n_classes: int = 41
    sample_sizes: Sequence[int] = (25, 10)
    dtype: Any = jnp.float32
    # beyond-paper: edges pre-partitioned by dst range -> each shard owns a
    # disjoint node block; aggregation needs NO reduction (output is node-
    # sharded) and only one all-gather of h per layer (half an all-reduce's
    # wire). Input contract: edge i lives on the shard owning dst[i].
    partitioned_edges: bool = False


def init_params(cfg: SAGEConfig, rng: jax.Array):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(rng, 2 * cfg.n_layers)
    params = {}
    for l in range(cfg.n_layers):
        fan_in = dims[l]
        std = (1.0 / fan_in) ** 0.5
        params[f"layer_{l}"] = {
            "w_self": (jax.random.normal(keys[2 * l], (dims[l], dims[l + 1]))
                       * std).astype(cfg.dtype),
            "w_neigh": (jax.random.normal(keys[2 * l + 1], (dims[l], dims[l + 1]))
                        * std).astype(cfg.dtype),
            "bias": jnp.zeros((dims[l + 1],), cfg.dtype),
        }
    return params


def param_specs(cfg: SAGEConfig, mesh):
    """Weights are tiny -> replicated; graph tensors shard over all axes."""
    return jax.tree_util.tree_map(lambda _: P(), init_shapes(cfg))


def init_shapes(cfg: SAGEConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    return {f"layer_{l}": {"w_self": jnp.zeros((dims[l], dims[l + 1])),
                           "w_neigh": jnp.zeros((dims[l], dims[l + 1])),
                           "bias": jnp.zeros((dims[l + 1],))}
            for l in range(cfg.n_layers)}


# ---------------------------------------------------------------------------
# Full-graph path
# ---------------------------------------------------------------------------

def _aggregate_dense(h, src, dst, n_nodes, degree_inv, edge_weight=None):
    msgs = jnp.take(h, src, axis=0)
    if edge_weight is not None:
        msgs = msgs * edge_weight[:, None]
    agg = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    return agg * degree_inv[:, None]


def _aggregate_sharded(mesh, h, src, dst, n_nodes, degree_inv,
                        edge_weight=None):
    """Edge-sharded mean aggregation: local segment_sum + psum over shards.

    Edges are padded to a multiple of the device count; padded entries carry
    edge_weight 0 so they contribute nothing."""
    axes = tuple(mesh.axis_names)

    def body(h_rep, src_loc, dst_loc, w_loc):
        msgs = jnp.take(h_rep, src_loc, axis=0) * w_loc[:, None]
        partial = jax.ops.segment_sum(msgs, dst_loc, num_segments=n_nodes)
        return jax.lax.psum(partial, axes)

    if edge_weight is None:
        edge_weight = jnp.ones(src.shape, h.dtype)
    agg = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P(axes), P(axes), P(axes)),
        out_specs=P(None, None), check_vma=False,
    )(h, src, dst, edge_weight)
    return agg * degree_inv[:, None]


def _aggregate_dst_partitioned(mesh, h, src, dst, n_nodes, degree_inv,
                               edge_weight=None):
    """Aggregation with dst-partitioned edges: shard i's edge slice only
    targets nodes [i*Nl, (i+1)*Nl), so the local segment_sum IS the final
    block — no psum. h arrives replicated (one all-gather per layer upstream,
    i.e. half the wire of the replicated+psum scheme)."""
    axes = tuple(mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    n_local = n_nodes // n_shards

    def body(h_rep, src_loc, dst_loc, w_loc, deg_loc):
        shard = jnp.zeros((), jnp.int32)
        for a in axes:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        msgs = jnp.take(h_rep, src_loc, axis=0) * w_loc[:, None]
        local = jax.ops.segment_sum(msgs, dst_loc - shard * n_local,
                                    num_segments=n_local)
        return local * deg_loc[:, None]

    if edge_weight is None:
        edge_weight = jnp.ones(src.shape, h.dtype)
    agg = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P(axes), P(axes), P(axes), P(axes)),
        out_specs=P(axes, None), check_vma=False,
    )(h, src, dst, edge_weight, degree_inv)
    return agg  # node-sharded (gathered lazily by the next matmul)


def full_graph_forward(cfg: SAGEConfig, params, graph: Dict[str, jax.Array],
                       mesh=None) -> jax.Array:
    """graph: features (N, F), src (E,), dst (E,), degree_inv (N,)."""
    h = graph["features"].astype(cfg.dtype)
    n_nodes = h.shape[0]
    for l in range(cfg.n_layers):
        lp = params[f"layer_{l}"]
        ew = graph.get("edge_weight")
        if mesh is None:
            neigh = _aggregate_dense(h, graph["src"], graph["dst"], n_nodes,
                                     graph["degree_inv"], ew)
        elif cfg.partitioned_edges:
            neigh = _aggregate_dst_partitioned(mesh, h, graph["src"],
                                               graph["dst"], n_nodes,
                                               graph["degree_inv"], ew)
        else:
            neigh = _aggregate_sharded(mesh, h, graph["src"], graph["dst"],
                                       n_nodes, graph["degree_inv"], ew)
        h = (h @ lp["w_self"].astype(cfg.dtype)
             + neigh @ lp["w_neigh"].astype(cfg.dtype) + lp["bias"])
        if l < cfg.n_layers - 1:
            h = jax.nn.relu(h)
    return h  # (N, n_classes)


# ---------------------------------------------------------------------------
# Sampled-minibatch path (fixed fanout)
# ---------------------------------------------------------------------------

def sampled_forward(cfg: SAGEConfig, params, batch: Dict[str, jax.Array],
                    use_kernel_bag: bool = False) -> jax.Array:
    """batch: feats_hop_0 (B, F), feats_hop_1 (B, f1, F),
    feats_hop_2 (B, f1, f2, F), ... (n_layers hops; -1-padded neighbors have
    zero features and a validity mask per hop).

    2-layer SAGE: aggregate hop2 -> hop1, then hop1 -> hop0.
    """
    hops = [batch[f"feats_hop_{i}"].astype(cfg.dtype)
            for i in range(cfg.n_layers + 1)]
    masks = [batch.get(f"mask_hop_{i}") for i in range(cfg.n_layers + 1)]

    def mean_agg(x, mask):
        # x: (..., fanout, F) -> (..., F) masked mean over the fanout dim
        if mask is None:
            return jnp.mean(x, axis=-2)
        m = mask.astype(x.dtype)[..., None]
        return jnp.sum(x * m, axis=-2) / jnp.maximum(
            jnp.sum(m, axis=-2), 1.0)

    # Iteratively collapse the deepest hop.
    for l in range(cfg.n_layers):
        lp = params[f"layer_{l}"]
        new_hops = []
        for depth in range(len(hops) - 1):
            self_h = hops[depth]
            neigh_h = mean_agg(hops[depth + 1], masks[depth + 1])
            h = (self_h @ lp["w_self"].astype(cfg.dtype)
                 + neigh_h @ lp["w_neigh"].astype(cfg.dtype) + lp["bias"])
            if l < cfg.n_layers - 1:
                h = jax.nn.relu(h)
            new_hops.append(h)
        hops = new_hops
        masks = masks[:len(hops)]
    return hops[0]  # (B, n_classes)


# ---------------------------------------------------------------------------
# Loss / train steps
# ---------------------------------------------------------------------------

def node_classification_loss(logits, labels, mask=None):
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = labels >= 0
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def make_full_graph_train_step(cfg: SAGEConfig, optimizer=None, mesh=None):
    optimizer = optimizer or optim_lib.adam(1e-2)

    def step(params, opt_state, graph):
        def loss_fn(p):
            logits = full_graph_forward(cfg, p, graph, mesh)
            return node_classification_loss(logits, graph["labels"],
                                            graph.get("label_mask"))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return optim_lib.apply_updates(params, updates), opt_state, loss

    return step


def make_sampled_train_step(cfg: SAGEConfig, optimizer=None):
    optimizer = optimizer or optim_lib.adam(1e-2)

    def step(params, opt_state, batch):
        def loss_fn(p):
            logits = sampled_forward(cfg, p, batch)
            return node_classification_loss(logits, batch["labels"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return optim_lib.apply_updates(params, updates), opt_state, loss

    return step
