"""Host-side uniform neighbor sampler (GraphSAGE minibatch training).

Builds a CSR adjacency once, then samples fixed-fanout neighbor tensors per
minibatch (with replacement when degree < fanout, matching the original
GraphSAGE implementation). Produces the ``feats_hop_*`` tensors consumed by
``sampled_forward`` — static shapes, so one jit compilation serves every
batch.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


def random_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                 seed: int = 0) -> Dict[str, np.ndarray]:
    """Power-law-ish random graph for tests/benchmarks."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-flavored degree skew
    weights = rng.pareto(1.5, n_nodes) + 1.0
    weights /= weights.sum()
    src = rng.choice(n_nodes, n_edges, p=weights)
    dst = rng.integers(0, n_nodes, n_edges)
    features = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    deg = np.bincount(dst, minlength=n_nodes).astype(np.float32)
    return {
        "src": src.astype(np.int32), "dst": dst.astype(np.int32),
        "features": features, "labels": labels,
        "degree_inv": (1.0 / np.maximum(deg, 1.0)).astype(np.float32),
    }


class NeighborSampler:
    def __init__(self, src: np.ndarray, dst: np.ndarray, n_nodes: int,
                 seed: int = 0):
        # CSR over incoming edges: for node v, neighbors = sources of v's edges
        order = np.argsort(dst, kind="stable")
        self.nbr = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        self.offsets = np.concatenate([[0], np.cumsum(counts)])
        self.n_nodes = n_nodes
        self.rng = np.random.default_rng(seed)

    def sample_hop(self, nodes: np.ndarray, fanout: int) -> np.ndarray:
        """nodes (...,) -> neighbors (..., fanout); isolated nodes self-loop."""
        flat = nodes.reshape(-1)
        lo = self.offsets[flat]
        deg = self.offsets[flat + 1] - lo
        # uniform with replacement
        draw = self.rng.integers(0, 1 << 31, size=(flat.size, fanout))
        idx = lo[:, None] + draw % np.maximum(deg, 1)[:, None]
        out = self.nbr[idx]
        out = np.where(deg[:, None] > 0, out, flat[:, None])  # self-loop fallback
        return out.reshape(*nodes.shape, fanout).astype(np.int32)

    def sample_batch(self, nodes: np.ndarray, fanouts: Sequence[int],
                     features: np.ndarray, labels: np.ndarray
                     ) -> Dict[str, np.ndarray]:
        """Returns feats_hop_0..L (+ labels) for ``sampled_forward``."""
        hops = [nodes]
        for f in fanouts:
            hops.append(self.sample_hop(hops[-1], f))
        batch = {f"feats_hop_{i}": features[h] for i, h in enumerate(hops)}
        batch["labels"] = labels[nodes].astype(np.int32)
        return batch
