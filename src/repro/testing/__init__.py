"""Test-support machinery: deterministic chaos/fault injection for the data
plane, the train step, and the process itself, plus the differential kernel
conformance harness (repro.testing.conformance)."""
from repro.testing.conformance import (KERNEL_SPECS, SPECS_BY_NAME,
                                       KernelSpec, check_extreme, check_grads,
                                       check_value, run_conformance)
from repro.testing.faults import (POISON_MODES, FlakyShardReads, KillSwitch,
                                  NonFiniteBatchInjector, PoisonTrace,
                                  ServeFault, ServeKillSwitch, SlowModel,
                                  corrupt_shard_file, poison_request,
                                  truncate_tail)

__all__ = [
    "corrupt_shard_file",
    "truncate_tail",
    "NonFiniteBatchInjector",
    "FlakyShardReads",
    "KillSwitch",
    "ServeFault",
    "SlowModel",
    "ServeKillSwitch",
    "poison_request",
    "PoisonTrace",
    "POISON_MODES",
    "KernelSpec",
    "KERNEL_SPECS",
    "SPECS_BY_NAME",
    "check_value",
    "check_grads",
    "check_extreme",
    "run_conformance",
]
