"""Chaos-testing utilities: deterministic fault injection for the data
plane, the train step, and the process itself."""
from repro.testing.faults import (FlakyShardReads, KillSwitch,
                                  NonFiniteBatchInjector, corrupt_shard_file,
                                  truncate_tail)

__all__ = [
    "corrupt_shard_file",
    "truncate_tail",
    "NonFiniteBatchInjector",
    "FlakyShardReads",
    "KillSwitch",
]
