"""Test-support machinery: deterministic chaos/fault injection for the data
plane, the train step, and the process itself, plus the differential kernel
conformance harness (repro.testing.conformance)."""
from repro.testing.conformance import (KERNEL_SPECS, SPECS_BY_NAME,
                                       KernelSpec, check_extreme, check_grads,
                                       check_value, run_conformance)
from repro.testing.faults import (FlakyShardReads, KillSwitch,
                                  NonFiniteBatchInjector, corrupt_shard_file,
                                  truncate_tail)

__all__ = [
    "corrupt_shard_file",
    "truncate_tail",
    "NonFiniteBatchInjector",
    "FlakyShardReads",
    "KillSwitch",
    "KernelSpec",
    "KERNEL_SPECS",
    "SPECS_BY_NAME",
    "check_value",
    "check_grads",
    "check_extreme",
    "run_conformance",
]
